//! Domain-shift demo — the paper's Figure 2 story in one run.
//!
//! Offline Wanda calibrated on the *wrong* domain pays a perplexity tax on
//! every prompt; μ-MoE recalibrates per prompt and never pays it.
//!
//!     make artifacts && cargo run --release --example domain_shift

use mumoe::benchlib::{fmt_f, Table};
use mumoe::data::corpus::Corpus;
use mumoe::data::{domain_label, DOMAINS};
use mumoe::eval::harness::EvalStack;
use std::path::Path;

fn main() -> Result<(), mumoe::util::error::Error> {
    let dir = Path::new("artifacts");
    let model = "mu-opt-micro";
    let rho = 0.5;
    let stack = EvalStack::open(dir, model)?;
    let seq = stack.cfg.max_seq_len;

    println!("model={model} rho={rho}: offline Wanda per calibration domain vs mu-MoE\n");

    // test windows per domain
    let tests: Vec<(&str, Vec<_>)> = DOMAINS
        .iter()
        .map(|d| {
            let c = Corpus::load(&dir.join("data"), d, "test").expect("corpus");
            (*d, c.eval_windows(seq, 8))
        })
        .collect();

    let mut headers = vec!["method \\ test domain"];
    headers.extend(DOMAINS.iter().map(|d| domain_label(d)));
    let mut table = Table::new("perplexity under domain shift (rho=0.5)", &headers);

    // offline Wanda calibrated on each domain in turn
    for calib_domain in DOMAINS {
        let cw = Corpus::load(&dir.join("data"), calib_domain, "train")?
            .eval_windows(seq, 8);
        let stats = stack.calibrate(&cw)?;
        let v = stack.variant_wanda(&stats, rho)?;
        let mut cells = vec![format!("Wanda calib={}", domain_label(calib_domain))];
        for (_, windows) in &tests {
            cells.push(fmt_f(stack.perplexity(&v, windows, None)?.value()));
        }
        table.row(cells);
    }

    // μ-MoE: no calibration input at all
    let mut cells = vec!["mu-MoE (no calib)".to_string()];
    for (_, windows) in &tests {
        cells.push(fmt_f(stack.perplexity(&stack.ckpt, windows, Some(rho))?.value()));
    }
    table.row(cells);
    table.print();

    println!(
        "\nreading: each Wanda row is best on its own calibration domain \
         (the matched diagonal) and worse off-diagonal; mu-MoE adapts to \
         every prompt without any offline calibration."
    );
    Ok(())
}
