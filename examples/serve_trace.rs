//! E2E serving validation (DESIGN.md §7): start the full coordinator
//! (router → sparsity-aware dynamic batcher → serve loop on the engine
//! the config selects), replay a Poisson trace of mixed-domain,
//! mixed-sparsity prompts in real time, and report throughput, latency
//! percentiles and batch occupancy.
//!
//!     make artifacts && cargo run --release --example serve_trace
//!
//! The default `host` engine needs no `pjrt` feature (only the data
//! corpora under artifacts/data); set MUMOE_SERVE_ENGINE=pjrt on a
//! `--features pjrt` build to drive the artifact sessions instead. The
//! numbers printed here are the repo's serving headline and are recorded
//! in EXPERIMENTS.md.

use mumoe::config::{EngineKind, ServeConfig};
use mumoe::coordinator::server::replay_trace;

fn main() -> Result<(), mumoe::util::error::Error> {
    let model =
        std::env::var("MUMOE_SERVE_MODEL").unwrap_or_else(|_| "mu-opt-micro".into());
    let engine = match std::env::var("MUMOE_SERVE_ENGINE") {
        Ok(s) => EngineKind::parse(&s)?,
        Err(_) => EngineKind::Host,
    };
    let n: usize = std::env::var("MUMOE_SERVE_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(48);
    let rate: f64 = std::env::var("MUMOE_SERVE_RATE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20.0);

    let cfg = ServeConfig {
        model,
        engine,
        rho_levels: vec![0.4, 0.6, 1.0],
        batch_window_us: 4_000,
        ..Default::default()
    };
    println!(
        "serving {} on the {} engine — replaying {n} requests @ {rate}/s \
         over rho levels {:?}",
        cfg.model,
        cfg.engine.label(),
        cfg.rho_levels
    );
    let report = replay_trace(cfg, n, rate)?;
    println!("{report}");
    Ok(())
}
