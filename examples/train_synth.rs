//! Rust-driven training: drive the AOT `train_step` artifact (full Adam
//! update lowered from JAX, including backward) for a few hundred steps on
//! synthetic corpus windows and log the loss curve — proving the L3↔L2↔L1
//! train path composes without python at runtime.
//!
//!     make artifacts && cargo run --release --example train_synth
//!
//! The step count is deliberately small (single-core sandbox); the loss
//! log is recorded in EXPERIMENTS.md §E2E.

use mumoe::data::corpus::Corpus;
use mumoe::model::checkpoint::Checkpoint;
use mumoe::runtime::registry::Registry;
use mumoe::runtime::session::literal_f32;
use mumoe::runtime::Client;
use mumoe::util::rng::Pcg32;
use std::path::Path;

fn main() -> Result<(), mumoe::util::error::Error> {
    let dir = Path::new("artifacts");
    let steps: usize = std::env::var("MUMOE_TRAIN_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60);

    let client = Client::cpu()?;
    let registry = Registry::open(dir, client.clone())?;
    let meta = registry.meta_for("train_step", "mu-opt-micro")?;
    let (name, order, batch, seq) =
        (meta.name.clone(), meta.params.clone(), meta.batch, meta.seq_len);
    let exe = registry.executable(&name)?;

    // fresh random init via the checkpoint shapes (continue-training works
    // too — swap in the trained checkpoint)
    let ckpt = Checkpoint::load(&registry.ckpt_path("mu-opt-micro"))?;
    let mut rng = Pcg32::new(1234, 0);
    let mut params: Vec<(Vec<usize>, Vec<f32>)> = order
        .iter()
        .map(|n| {
            let t = ckpt.get(n).expect("tensor");
            let data = if n.ends_with(".g") {
                vec![1.0; t.numel()]
            } else if n.ends_with(".b") && t.dims.len() == 1 {
                vec![0.0; t.numel()]
            } else {
                rng.normal_vec(t.numel()).iter().map(|x| x * 0.02).collect()
            };
            (t.dims.clone(), data)
        })
        .collect();
    let mut m: Vec<Vec<f32>> = params.iter().map(|(_, d)| vec![0.0; d.len()]).collect();
    let mut v: Vec<Vec<f32>> = params.iter().map(|(_, d)| vec![0.0; d.len()]).collect();

    let corpus = Corpus::load(&dir.join("data"), "synth_wiki", "train")?;
    println!("training mu-opt-micro from scratch for {steps} steps (batch {batch});");
    println!("step\tloss\tsec/step");

    let np = order.len();
    for step in 0..steps {
        // sample a fresh batch of windows
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut lengths = Vec::with_capacity(batch);
        for _ in 0..batch {
            let w = corpus.sample_window(&mut rng, seq);
            tokens.extend_from_slice(&w.tokens);
            lengths.push(w.valid_len as i32);
        }
        let lr = 3e-3_f32 * (1.0 - step as f32 / steps as f32).max(0.2);

        // build the input literal list: params, m, v, step, tokens, lengths, lr
        let mut bufs = Vec::with_capacity(3 * np + 4);
        for (dims, data) in &params {
            bufs.push(client.upload_f32(data, dims)?);
        }
        for (i, mm) in m.iter().enumerate() {
            bufs.push(client.upload_f32(mm, &params[i].0)?);
        }
        for (i, vv) in v.iter().enumerate() {
            bufs.push(client.upload_f32(vv, &params[i].0)?);
        }
        bufs.push(client.upload_f32(&[step as f32], &[])?);
        bufs.push(client.upload_i32(&tokens, &[batch, seq])?);
        bufs.push(client.upload_i32(&lengths, &[batch])?);
        bufs.push(client.upload_f32(&[lr], &[])?);

        let t0 = std::time::Instant::now();
        let refs: Vec<&xla::PjRtBuffer> = bufs.iter().collect();
        let outs = exe.execute_b(&refs).map_err(mumoe::util::error::Error::from)?;
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(mumoe::util::error::Error::from)?;
        let parts = lit.to_tuple().map_err(mumoe::util::error::Error::from)?;
        let loss = literal_f32(&parts[0])?[0];

        // unpack new params/m/v
        for i in 0..np {
            params[i].1 = literal_f32(&parts[1 + i])?;
            m[i] = literal_f32(&parts[1 + np + i])?;
            v[i] = literal_f32(&parts[1 + 2 * np + i])?;
        }
        if step % 10 == 0 || step == steps - 1 {
            println!("{step}\t{loss:.4}\t{:.2}", t0.elapsed().as_secs_f64());
        }
    }
    println!("loss curve should fall from ~5.6 (uniform) toward < 3 within {steps} steps");
    Ok(())
}
