//! μ-MoE analysis: how micro-grained is the mixture really?
//!
//! Treats every weight as a single-parameter expert, extracts the active
//! sets different prompts induce (host-side reference model), and reports
//! per-layer overlap + utilization statistics — within-domain prompts
//! should overlap more than cross-domain ones, and utilization should show
//! a hot core plus a prompt-dependent tail.
//!
//!     make artifacts && cargo run --release --example expert_overlap

use mumoe::data::corpus::Corpus;
use mumoe::data::DOMAINS;
use mumoe::model::checkpoint::Checkpoint;
use mumoe::model::config_by_name;
use mumoe::moe::{overlap, select_experts, utilization};
use mumoe::nn::Model;
use mumoe::util::rng::Pcg32;
use std::path::Path;

fn main() -> Result<(), mumoe::util::error::Error> {
    let dir = Path::new("artifacts");
    let model_name = "mu-opt-micro";
    let rho = 0.5;
    let cfg = config_by_name(model_name).unwrap();
    let ckpt = Checkpoint::load(&dir.join("ckpt").join(format!("{model_name}.ckpt")))?;
    let model = Model::from_checkpoint(&cfg, &ckpt)?;
    let mut rng = Pcg32::new(7, 0);

    println!("micro-expert analysis, {model_name} at rho={rho}\n");

    let mut all = Vec::new();
    for domain in DOMAINS {
        let corpus = Corpus::load(&dir.join("data"), domain, "test")?;
        let sels: Vec<_> = (0..4)
            .map(|_| {
                let w = corpus.sample_window(&mut rng, 64);
                select_experts(&model, &w.tokens, w.valid_len, rho)
            })
            .collect();
        let st = overlap(&sels);
        println!("within {domain:11}: mean active-set overlap {:.4}", st.overall);
        all.extend(sels);
    }
    let cross = overlap(&all);
    println!("across all domains : mean active-set overlap {:.4}\n", cross.overall);

    // utilization histogram for one attention projection and one FFN layer
    for lin in ["layers.0.q.w", "layers.2.fc1.w"] {
        let u = utilization(&all, lin)?;
        let always = u.iter().filter(|&&x| x == 1.0).count();
        let never = u.iter().filter(|&&x| x == 0.0).count();
        let sometimes = u.len() - always - never;
        println!(
            "{lin}: {} experts | always-on {:.1}% | prompt-dependent {:.1}% | never {:.1}%",
            u.len(),
            100.0 * always as f64 / u.len() as f64,
            100.0 * sometimes as f64 / u.len() as f64,
            100.0 * never as f64 / u.len() as f64,
        );
    }
    println!(
        "\nthe prompt-dependent slice is what offline pruning freezes wrongly \
         and mu-MoE re-selects per prompt (paper Figure 2)."
    );
    Ok(())
}
