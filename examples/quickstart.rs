//! Quickstart: load μ-OPT-micro, run one prompt through the μ-MoE serving
//! head at several sparsity levels, and print greedy continuations.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Demonstrates the whole stack in ~40 lines of user code: PJRT client +
//! artifact registry + resident weights + the ρ-as-runtime-input design
//! (one executable serves every sparsity level).

use mumoe::model::tokenizer::ByteTokenizer;
use mumoe::runtime::registry::Registry;
use mumoe::runtime::session::{literal_f32, Input, Session};
use mumoe::runtime::weights::DeviceWeights;
use mumoe::runtime::Client;
use std::path::Path;
use std::sync::Arc;

fn main() -> Result<(), mumoe::util::error::Error> {
    let dir = Path::new("artifacts");
    let model = "mu-opt-micro";
    let prompt = "The archive of northern tyrolia is a ";

    // 1. runtime up: client, manifest, checkpoint, weights on device
    let client = Client::cpu()?;
    let registry = Registry::open(dir, client.clone())?;
    let ckpt = mumoe::model::checkpoint::Checkpoint::load(&registry.ckpt_path(model))?;
    let meta = registry.meta_for("mumoe_logits", model)?;
    let (name, order, batch, seq) =
        (meta.name.clone(), meta.params.clone(), meta.batch, meta.seq_len);
    let weights = Arc::new(DeviceWeights::upload(&client, &ckpt, &order)?);
    let session = Session::bind(&registry, &name, weights)?;
    println!("loaded {model}: {} parameters on device", session.weights().total_params);

    // 2. tokenize + pad to the artifact's static shape
    let tok = ByteTokenizer;
    let ids = tok.encode(prompt, true);
    let (ids, valid) = tok.pad_to(ids, seq);

    // 3. one execute per sparsity level — same executable, ρ is an input
    for rho in [1.0f32, 0.8, 0.6, 0.4, 0.2] {
        let mut tokens = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            tokens.extend_from_slice(&ids);
        }
        let lengths = vec![valid as i32; batch];
        let t0 = std::time::Instant::now();
        let outs = session.run(&[
            Input::I32(tokens, vec![batch, seq]),
            Input::I32(lengths, vec![batch]),
            Input::ScalarF32(rho),
        ])?;
        let dt = t0.elapsed();
        let logits = literal_f32(&outs[0])?;
        let vocab = logits.len() / batch;

        // greedy top-3 next tokens for slot 0
        let row = &logits[..vocab];
        let mut idx: Vec<usize> = (0..vocab).collect();
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        let top: Vec<String> = idx[..3]
            .iter()
            .map(|&i| format!("{:?}", tok.decode(&[i as i32])))
            .collect();
        println!(
            "rho={rho:.1}  ({:5.1}% micro-experts active)  next-token top3: {}  [{:.0}ms/batch]",
            rho * 100.0,
            top.join(" "),
            dt.as_millis()
        );
    }
    println!("\nprompt: {prompt:?}");
    Ok(())
}
