"""L2 correctness: mu-OPT model variants, shapes and cross-variant
equivalences that the AOT artifacts rely on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model
from compile.pruning import online_wanda_mask

CFG = configs.ModelConfig("test-tiny", n_layers=2, n_heads=2, d_model=32)


@pytest.fixture(scope="module")
def setup():
    params = model.init_params(CFG, jax.random.PRNGKey(3))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 255, size=(2, 24)), jnp.int32)
    lens = jnp.asarray([24, 15], jnp.int32)
    return params, toks, lens


def test_param_order_matches_shapes(setup):
    order = model.param_order(CFG)
    shapes = model.param_shapes(CFG)
    assert sorted(order) == sorted(shapes)
    params, *_ = setup
    for n in order:
        assert params[n].shape == shapes[n], n


def test_n_params_formula():
    shapes = model.param_shapes(CFG)
    total = sum(int(np.prod(s)) for s in shapes.values())
    assert total == CFG.n_params()


def test_dense_forward_shapes(setup):
    params, toks, lens = setup
    hidden, logits = model.forward(CFG, params, toks, lens)
    assert hidden.shape == (2, 24, 32)
    assert logits.shape == (2, 24, configs.VOCAB_SIZE)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_mumoe_rho1_equals_dense(setup):
    """rho=1.0 activates every micro-expert: identical to dense."""
    params, toks, lens = setup
    _, dense = model.forward(CFG, params, toks, lens)
    _, moe = model.forward(CFG, params, toks, lens, rho=jnp.float32(1.0))
    np.testing.assert_allclose(moe, dense, rtol=1e-4, atol=1e-4)


def test_mumoe_rho_monotone_divergence(setup):
    """Lower rho prunes more -> output drifts further from dense."""
    params, toks, lens = setup
    _, dense = model.forward(CFG, params, toks, lens)
    diffs = []
    for rho in (0.9, 0.5, 0.2):
        _, out = model.forward(CFG, params, toks, lens, rho=jnp.float32(rho))
        diffs.append(float(jnp.mean(jnp.abs(out - dense))))
    assert diffs[0] < diffs[1] < diffs[2]


def test_masked_weights_equal_online_mask_single_linear(setup):
    """Zeroing weights on the host with the oracle's online mask must equal
    the in-graph mu-MoE result for a single linear layer."""
    params, toks, lens = setup
    rng = np.random.default_rng(5)
    x = rng.normal(size=(40, 32)).astype(np.float32)
    w = np.asarray(params["layers.0.q.w"])
    b = np.asarray(params["layers.0.q.b"])
    rho = 0.5
    mask = online_wanda_mask(w, x, rho)
    host = x @ (w * mask).T + b

    from compile.kernels import ref, wanda

    norms = ref.col_l2_norms(jnp.asarray(x))
    s = ref.wanda_score(jnp.asarray(w), norms)
    kc = jnp.int32(int((1 - rho) * 32))
    thr = ref.row_kth_threshold(s, kc)
    ingraph = wanda.prune_matmul(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), norms, thr
    )
    np.testing.assert_allclose(ingraph, host, rtol=1e-4, atol=1e-4)


def test_nll_ignores_padding(setup):
    """NLL sums must not change when padding content changes."""
    params, toks, lens = setup
    s1, c1 = model.nll_sums(CFG, params, toks, lens)
    toks2 = np.asarray(toks).copy()
    toks2[1, 20:] = 99  # beyond lens[1]=15 (+1 for shift)
    s2, c2 = model.nll_sums(CFG, params, jnp.asarray(toks2), lens)
    np.testing.assert_allclose(s1[1], s2[1], rtol=1e-5)
    assert int(c1[1]) == 14  # length-1 predicted tokens


def test_last_logits_picks_last_valid_position(setup):
    params, toks, lens = setup
    _, logits = model.forward(CFG, params, toks, lens)
    out = model.last_logits(CFG, params, toks, lens)
    np.testing.assert_allclose(out[0], logits[0, 23], rtol=1e-5)
    np.testing.assert_allclose(out[1], logits[1, 14], rtol=1e-5)


def test_calib_stats_match_manual(setup):
    """Wanda sq-sums from calib_stats must equal a manual hook on the dense
    forward for the first linear (ln1 output of layer 0)."""
    params, toks, lens = setup
    stats = model.calib_stats(CFG, params, toks, lens, with_hessian=True)
    names = CFG.linear_names()
    assert len(stats) == 2 * len(names)

    from compile.kernels import ref

    b_, t_ = toks.shape
    h = params["tok_emb"][toks] + params["pos_emb"][None, :t_, :]
    x2d = h.reshape(b_ * t_, CFG.d_model)
    y = ref.layernorm(x2d, params["layers.0.ln1.g"], params["layers.0.ln1.b"])
    pos = jnp.arange(t_)
    vmask = (pos[None, :] < lens[:, None]).astype(jnp.float32).reshape(-1, 1)
    y = y * vmask
    np.testing.assert_allclose(stats[0], jnp.sum(y * y, axis=0), rtol=1e-3)
    # Hessian block for the same linear
    hidx = len(names)
    np.testing.assert_allclose(stats[hidx], y.T @ y, rtol=1e-3, atol=1e-3)


def test_train_step_reduces_loss(setup):
    params, toks, lens = setup
    m, v = model.adam_init(params)
    l0, params, m, v = model.train_step(CFG, params, m, v, 0.0, toks, lens, 1e-3)
    l_prev = float(l0)
    for s in range(1, 6):
        l, params, m, v = model.train_step(
            CFG, params, m, v, float(s), toks, lens, 1e-3
        )
    assert float(l) < l_prev


def test_pad_batch():
    toks, lens = model.pad_batch([[1, 2, 3], [4]], 6)
    assert toks.shape == (2, 6)
    assert list(np.asarray(lens)) == [3, 1]
    assert int(toks[0, 3]) == configs.PAD_ID
