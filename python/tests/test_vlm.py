"""mu-VLM: shapes, rho=1 equivalence, patchify correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, vlm

CFG = configs.VlmConfig(
    name="test-vlm",
    image_size=8,
    patch_size=4,
    vision_layers=1,
    vision_heads=2,
    vision_d=16,
    text=configs.ModelConfig("test-vlm-text", n_layers=1, n_heads=2, d_model=16),
)


@pytest.fixture(scope="module")
def setup():
    params = vlm.init_params(CFG, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    images = jnp.asarray(rng.random((2, 8, 8)).astype(np.float32))
    toks = jnp.asarray(rng.integers(0, 255, size=(2, 12)), jnp.int32)
    lens = jnp.asarray([12, 7], jnp.int32)
    return params, images, toks, lens


def test_param_order_matches_shapes():
    order = vlm.param_order(CFG)
    shapes = vlm.param_shapes(CFG)
    assert sorted(order) == sorted(shapes)
    assert len(order) == len(set(order))


def test_patchify_layout():
    img = jnp.arange(64, dtype=jnp.float32).reshape(1, 8, 8)
    p = vlm.patchify(CFG, img)
    assert p.shape == (1, 4, 16)
    # first patch = top-left 4x4 block, row-major
    np.testing.assert_array_equal(
        np.asarray(p[0, 0]).reshape(4, 4), np.asarray(img[0, :4, :4])
    )


def test_forward_shapes(setup):
    params, images, toks, lens = setup
    logits = vlm.forward(CFG, params, images, toks, lens)
    assert logits.shape == (2, CFG.n_patches + 12, configs.VOCAB_SIZE)


def test_answer_logits_position(setup):
    params, images, toks, lens = setup
    logits = vlm.forward(CFG, params, images, toks, lens)
    ans = vlm.answer_logits(CFG, params, images, toks, lens)
    np.testing.assert_allclose(ans[1], logits[1, CFG.n_patches + 6], rtol=1e-5)


def test_mumoe_rho1_equals_dense(setup):
    params, images, toks, lens = setup
    dense = vlm.answer_logits(CFG, params, images, toks, lens)
    moe = vlm.answer_logits(CFG, params, images, toks, lens, rho=jnp.float32(1.0))
    np.testing.assert_allclose(moe, dense, rtol=1e-4, atol=1e-4)


def test_mumoe_low_rho_changes_output(setup):
    params, images, toks, lens = setup
    dense = vlm.answer_logits(CFG, params, images, toks, lens)
    moe = vlm.answer_logits(CFG, params, images, toks, lens, rho=jnp.float32(0.3))
    assert float(jnp.max(jnp.abs(moe - dense))) > 1e-3


def test_calib_stats_order(setup):
    params, images, toks, lens = setup
    stats = vlm.calib_stats(CFG, params, images, toks, lens)
    names = CFG.linear_names()
    assert len(stats) == 2 * len(names)
    for i, n in enumerate(names):
        d_in = vlm.param_shapes(CFG)[n][1]
        assert stats[i].shape == (d_in,), n
        assert stats[len(names) + i].shape == (d_in, d_in), n


def test_choice_nll_scores_continuation_only(setup):
    """Changing tokens before ans_start must not change the NLL sum... it
    does change it (context!), but changing tokens *after* `lengths` must
    not, and the count of scored positions is lengths - ans_start."""
    params, images, toks, lens = setup
    starts = jnp.asarray([8, 4], jnp.int32)
    base = vlm.choice_nll(CFG, params, images, toks, lens, starts)
    assert base.shape == (2,)
    assert bool(jnp.all(base > 0))
    # mutate padding beyond lengths: no effect
    toks2 = np.asarray(toks).copy()
    toks2[1, int(lens[1]):] = 77
    after = vlm.choice_nll(CFG, params, images, jnp.asarray(toks2), lens, starts)
    np.testing.assert_allclose(base, after, rtol=1e-5)


def test_choice_nll_mumoe_rho1_matches_dense(setup):
    params, images, toks, lens = setup
    starts = jnp.asarray([8, 4], jnp.int32)
    dense = vlm.choice_nll(CFG, params, images, toks, lens, starts)
    moe = vlm.choice_nll(
        CFG, params, images, toks, lens, starts, rho=jnp.float32(1.0)
    )
    np.testing.assert_allclose(dense, moe, rtol=1e-3, atol=1e-3)


def test_train_step_runs(setup):
    params, images, toks, lens = setup
    m = {k: jnp.zeros_like(x) for k, x in params.items()}
    v = {k: jnp.zeros_like(x) for k, x in params.items()}
    starts = jnp.asarray([8, 4], jnp.int32)
    loss, p2, *_ = vlm.train_step(
        CFG, params, m, v, 0.0, images, toks, lens, starts, 1e-3
    )
    assert np.isfinite(float(loss))
    assert any(
        not np.allclose(p2[k], params[k]) for k in params
    )
