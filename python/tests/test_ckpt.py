"""MUCK checkpoint format round-trip (python side; rust reads the same)."""

import numpy as np

from compile import ckpt


def test_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    tensors = {
        "a.w": rng.normal(size=(3, 5)).astype(np.float32),
        "b": rng.normal(size=(7,)).astype(np.float32),
        "scalar": np.float32(3.5),
        "deep.nested.name.t": rng.normal(size=(2, 3, 4)).astype(np.float32),
    }
    p = str(tmp_path / "m.ckpt")
    ckpt.save(p, tensors)
    back = ckpt.load(p)
    assert sorted(back) == sorted(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], np.asarray(tensors[k], np.float32))


def test_deterministic_bytes(tmp_path):
    t = {"x": np.ones((4, 4), np.float32)}
    p1, p2 = str(tmp_path / "1.ckpt"), str(tmp_path / "2.ckpt")
    ckpt.save(p1, t)
    ckpt.save(p2, t)
    assert open(p1, "rb").read() == open(p2, "rb").read()
