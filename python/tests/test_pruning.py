"""Offline pruner reference implementations: invariants + known-answer
properties that the rust engines (rust/src/pruning) mirror."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import pruning


def test_kc_for_bounds():
    assert pruning.kc_for(10, 1.0) == 0
    assert pruning.kc_for(10, 0.0) == 9  # always keep >= 1 per row
    assert pruning.kc_for(100, 0.6) == 40


@settings(max_examples=20, deadline=None)
@given(
    d_out=st.integers(1, 40),
    d_in=st.integers(2, 60),
    rho=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_magnitude_mask_row_counts(d_out, d_in, rho, seed):
    """Exactly d_in - kc survivors per row (semi-structured sparsity)."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    mask = pruning.magnitude_mask(w, rho)
    kc = pruning.kc_for(d_in, rho)
    np.testing.assert_array_equal(mask.sum(axis=1), np.full(d_out, d_in - kc))


def test_magnitude_mask_keeps_largest():
    w = np.array([[1.0, -5.0, 0.1, 3.0]], np.float32)
    mask = pruning.magnitude_mask(w, 0.5)
    np.testing.assert_array_equal(mask, [[0, 1, 0, 1]])


def test_wanda_mask_weights_by_activation():
    """A small weight on a hot feature must beat a big weight on a cold one
    (the whole point of activation-aware scoring)."""
    w = np.array([[0.5, 1.0]], np.float32)
    sq = np.array([100.0, 0.01], np.float32)  # feature 0 is hot
    mask = pruning.wanda_mask(w, sq, 0.5)
    np.testing.assert_array_equal(mask, [[1, 0]])


@settings(max_examples=15, deadline=None)
@given(
    d_out=st.integers(1, 24),
    d_in=st.integers(2, 48),
    rho=st.floats(0.1, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
def test_wanda_equals_magnitude_under_uniform_activations(d_out, d_in, rho, seed):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    sq = np.ones(d_in, np.float32)
    np.testing.assert_array_equal(
        pruning.wanda_mask(w, sq, rho), pruning.magnitude_mask(w, rho)
    )


def _rand_hessian(rng, d, t=256):
    x = rng.normal(size=(d, t)).astype(np.float64)
    x *= rng.uniform(0.2, 3.0, size=(d, 1))  # per-feature scale diversity
    return (x @ x.T).astype(np.float32), x.astype(np.float32)


@pytest.mark.parametrize("rho", [0.4, 0.6])
def test_sparsegpt_beats_wanda_mask_on_loss(rho):
    """SparseGPT's OBS update should achieve lower ||(W - What) X||^2 than
    mask-only Wanda at the same sparsity (it compensates survivors)."""
    rng = np.random.default_rng(7)
    d_out, d_in = 24, 48
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    hess, x = _rand_hessian(rng, d_in)

    w_gpt = pruning.sparsegpt_prune(w, hess, rho, blocksize=16)
    sq = np.sum(x.astype(np.float64) ** 2, axis=1)
    w_wanda = w * pruning.wanda_mask(w, sq, rho)

    loss_gpt = np.linalg.norm((w - w_gpt) @ x) ** 2
    loss_wanda = np.linalg.norm((w - w_wanda) @ x) ** 2
    assert loss_gpt < loss_wanda


@pytest.mark.parametrize("rho", [0.3, 0.5, 0.8])
def test_sparsegpt_sparsity_close_to_target(rho):
    rng = np.random.default_rng(11)
    d_out, d_in = 16, 64
    w = rng.normal(size=(d_out, d_in)).astype(np.float32)
    hess, _ = _rand_hessian(rng, d_in)
    w_gpt = pruning.sparsegpt_prune(w, hess, rho, blocksize=16)
    active = np.mean(np.abs(w_gpt) > 0)
    # per-block rounding makes this approximate
    assert abs(active - rho) < 0.12


def test_sparsegpt_rho1_keeps_weights():
    rng = np.random.default_rng(13)
    w = rng.normal(size=(8, 32)).astype(np.float32)
    hess, _ = _rand_hessian(rng, 32)
    w_gpt = pruning.sparsegpt_prune(w, hess, 1.0)
    np.testing.assert_allclose(w_gpt, w, rtol=1e-4, atol=1e-5)


def test_online_wanda_mask_is_prompt_dependent():
    """mu-MoE's premise: different prompts activate different micro-experts."""
    rng = np.random.default_rng(17)
    w = rng.normal(size=(16, 32)).astype(np.float32)
    x1 = rng.normal(size=(40, 32)).astype(np.float32)
    x2 = rng.normal(size=(40, 32)).astype(np.float32)
    x2[:, :16] *= 10.0  # shift the activation distribution
    m1 = pruning.online_wanda_mask(w, x1, 0.5)
    m2 = pruning.online_wanda_mask(w, x2, 0.5)
    assert np.any(m1 != m2)
