"""Synthetic data substrates: determinism, domain separation, formats."""

import numpy as np
import pytest

from compile import data


def test_corpora_deterministic():
    for gen in data.CORPUS_GENERATORS.values():
        a = gen(np.random.default_rng(42), 5000)
        b = gen(np.random.default_rng(42), 5000)
        assert a == b


def test_corpora_are_ascii():
    for gen in data.CORPUS_GENERATORS.values():
        text = gen(np.random.default_rng(1), 3000)
        assert all(ord(c) < 128 for c in text)


def test_corpora_domains_differ():
    """The three grammars must have measurably different byte statistics —
    this is what makes Table 1's calibration mismatch meaningful."""
    def hist(text):
        h = np.zeros(128)
        for c in text.encode():
            h[c] += 1
        return h / h.sum()

    texts = {
        n: g(np.random.default_rng(3), 20000)
        for n, g in data.CORPUS_GENERATORS.items()
    }
    hs = {n: hist(t) for n, t in texts.items()}
    names = sorted(hs)
    for i, a in enumerate(names):
        for b in names[i + 1 :]:
            tv = 0.5 * np.abs(hs[a] - hs[b]).sum()  # total variation
            assert tv > 0.05, f"{a} vs {b} too similar ({tv:.3f})"


def test_synthqa_strata_coverage():
    rng = np.random.default_rng(5)
    recs = [data.make_synthqa_record(rng) for _ in range(300)]
    subjects = {r[3] for r in recs}
    modalities = {r[4] for r in recs}
    grades = {r[5] for r in recs}
    assert subjects == {0, 1, 2}
    assert modalities == {0, 1, 2}
    assert grades == {0, 1}


def test_synthqa_answers_valid():
    rng = np.random.default_rng(6)
    for _ in range(100):
        img, q, a, *_ = data.make_synthqa_record(rng)
        n_choices = q.count(") ")
        assert 0 <= a < n_choices
        assert img.shape == (data.IMG, data.IMG)
        assert img.dtype == np.float32
        assert q.endswith("Answer:")


def test_synthvqa_glyphs_rendered():
    rng = np.random.default_rng(7)
    img, q, a, *_ = data.make_synthvqa_record(rng)
    assert img.max() == 1.0  # glyph pixels at full intensity
    assert "number" in q


def test_qa_bin_roundtrip(tmp_path):
    rng = np.random.default_rng(8)
    recs = [data.make_synthqa_record(rng) for _ in range(10)]
    p = str(tmp_path / "t.bin")
    data.write_qa_bin(p, recs)
    back = data.read_qa_bin(p)
    assert len(back) == 10
    for (i1, q1, a1, s1, m1, g1), (i2, q2, a2, s2, m2, g2) in zip(recs, back):
        np.testing.assert_array_equal(i1, i2)
        assert (q1, a1, s1, m1, g1) == (q2, a2, s2, m2, g2)


def test_font_glyphs_distinct():
    digits = list(data._FONT)
    for i, a in enumerate(digits):
        for b in digits[i + 1 :]:
            assert data._FONT[a] != data._FONT[b], (a, b)
