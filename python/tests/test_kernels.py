"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (and the sparsity scalar) so odd/non-power-of-two
dims exercise the block-divisibility logic in kernels/wanda.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, ref, wanda

RTOL, ATOL = 1e-4, 1e-4


def _arr(rng, *shape):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


dims = st.integers(min_value=2, max_value=96)
toks = st.integers(min_value=1, max_value=80)


@settings(max_examples=12, deadline=None)
@given(d_out=dims, d_in=dims, seed=st.integers(0, 2**31 - 1))
def test_wanda_score_matches_ref(d_out, d_in, seed):
    rng = np.random.default_rng(seed)
    w = _arr(rng, d_out, d_in)
    norms = jnp.abs(_arr(rng, d_in)) + 0.01
    np.testing.assert_allclose(
        wanda.wanda_score(w, norms), ref.wanda_score(w, norms), rtol=1e-6
    )


@settings(max_examples=12, deadline=None)
@given(t=toks, d=dims, seed=st.integers(0, 2**31 - 1))
def test_col_sq_sums_matches_ref(t, d, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, t, d)
    np.testing.assert_allclose(
        wanda.col_sq_sums(x), jnp.sum(x * x, axis=0), rtol=1e-4, atol=1e-5
    )
    np.testing.assert_allclose(
        wanda.col_l2_norms(x), ref.col_l2_norms(x), rtol=1e-4, atol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(
    m=toks,
    d_out=dims,
    d_in=dims,
    rho=st.floats(min_value=0.05, max_value=1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_prune_matmul_matches_masked_ref(m, d_out, d_in, rho, seed):
    """The fused kernel must equal score->threshold->mask->matmul by ref."""
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, d_in), _arr(rng, d_out, d_in)
    b = _arr(rng, d_out)
    norms = ref.col_l2_norms(x)
    s = ref.wanda_score(w, norms)
    kc = jnp.int32(int(np.clip(int((1 - rho) * d_in), 0, d_in - 1)))
    thr = ref.row_kth_threshold(s, kc)
    got = wanda.prune_matmul(x, w, b, norms, thr)
    want = ref.masked_linear(x, w, b, ref.prune_mask(s, thr))
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@settings(max_examples=10, deadline=None)
@given(m=toks, d_out=dims, d_in=dims, seed=st.integers(0, 2**31 - 1))
def test_masked_matmul_matches_ref(m, d_out, d_in, seed):
    rng = np.random.default_rng(seed)
    x, w = _arr(rng, m, d_in), _arr(rng, d_out, d_in)
    b = _arr(rng, d_out)
    mask = jnp.asarray((rng.random((d_out, d_in)) > 0.5).astype(np.float32))
    np.testing.assert_allclose(
        wanda.masked_matmul(x, w, b, mask),
        ref.masked_linear(x, w, b, mask),
        rtol=RTOL,
        atol=ATOL,
    )


@settings(max_examples=10, deadline=None)
@given(m=toks, d=dims, seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(m, d, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, m, d)
    g, b = _arr(rng, d), _arr(rng, d)
    np.testing.assert_allclose(
        layernorm.layernorm(x, g, b), ref.layernorm(x, g, b), rtol=RTOL, atol=ATOL
    )


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(2, 40),
    hd=st.sampled_from([8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(b, h, t, hd, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (_arr(rng, b, h, t, hd) for _ in range(3))
    lens = jnp.asarray(rng.integers(1, t + 1, size=b), jnp.int32)
    got = attention.causal_attention(q, k, v, lens)
    want = ref.causal_attention(q, k, v, lens)
    # only positions < length are meaningful downstream
    for i in range(b):
        li = int(lens[i])
        np.testing.assert_allclose(
            got[i, :, :li], want[i, :, :li], rtol=RTOL, atol=ATOL
        )


def test_row_kth_threshold_edges():
    """kc=0 keeps everything; kc=d-1 keeps exactly one weight per row."""
    rng = np.random.default_rng(0)
    s = jnp.asarray(np.abs(rng.normal(size=(5, 9))).astype(np.float32))
    thr0 = ref.row_kth_threshold(s, jnp.int32(0))
    assert float(jnp.min(ref.prune_mask(s, thr0))) == 1.0
    thr_max = ref.row_kth_threshold(s, jnp.int32(8))
    mask = ref.prune_mask(s, thr_max)
    np.testing.assert_array_equal(np.asarray(jnp.sum(mask, axis=1)), np.ones(5))


@pytest.mark.parametrize("rho", [0.25, 0.5, 0.75])
def test_prune_mask_active_fraction(rho):
    """With continuous scores, exactly d - kc weights survive per row."""
    rng = np.random.default_rng(1)
    d = 64
    s = jnp.asarray(np.abs(rng.normal(size=(16, d))).astype(np.float32))
    kc = int((1 - rho) * d)
    thr = ref.row_kth_threshold(s, jnp.int32(kc))
    mask = ref.prune_mask(s, thr)
    np.testing.assert_array_equal(
        np.asarray(jnp.sum(mask, axis=1)), np.full(16, d - kc)
    )
