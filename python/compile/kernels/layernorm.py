"""L1 Pallas layernorm kernel (row-tiled)."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_ROW = 128

_INTERPRET = True


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * g_ref[...][None, :] + b_ref[...][
        None, :
    ]


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    """x: (M, d); g,b: (d,). The feature dimension stays whole in VMEM (the
    reduction is over it); rows are tiled."""
    import functools

    m_, d_ = x.shape
    bm = min(BLK_ROW, m_)
    while m_ % bm:  # interpret-mode pallas needs evenly tiling blocks
        bm -= 1
    grid = (-(-m_ // bm),)
    kern = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d_), lambda i: (i, 0)),
            pl.BlockSpec((d_,), lambda i: (0,)),
            pl.BlockSpec((d_,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d_), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_, d_), x.dtype),
        interpret=_INTERPRET,
    )(x, g, b)
