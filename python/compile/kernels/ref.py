"""Pure-jnp reference oracle for every Pallas kernel (L1 correctness signal).

Each function here is the mathematical definition the corresponding Pallas
kernel must match to within float tolerance; pytest sweeps shapes/dtypes via
hypothesis and asserts allclose (python/tests/test_kernels.py).
"""

import jax.numpy as jnp
import jax


def wanda_score(w: jnp.ndarray, col_norms: jnp.ndarray) -> jnp.ndarray:
    """Wanda importance score S'_{i,j} = |W_{i,j}| * ||X_{j,:}||_2 (paper eq. 3).

    w: (d_out, d_in) weight matrix; col_norms: (d_in,) activation l2 norms.
    """
    return jnp.abs(w) * col_norms[None, :]


def row_kth_threshold(scores: jnp.ndarray, k_inactive: jnp.ndarray) -> jnp.ndarray:
    """Per-row threshold = k_inactive-th smallest score (paper App. B,
    torch.kthvalue formulation), with k_inactive a *dynamic* scalar so a
    single AOT artifact serves every sparsity level.

    Returns (d_out,) thresholds; rows keep weights with score > threshold.
    k_inactive == 0 (rho = 1.0) keeps everything: threshold is -1 (scores
    are non-negative).
    """
    srt = jnp.sort(scores, axis=-1)  # ascending, static shape
    d_in = scores.shape[-1]
    idx = jnp.clip(k_inactive - 1, 0, d_in - 1).astype(jnp.int32)
    thr = jax.lax.dynamic_index_in_dim(srt, idx, axis=-1, keepdims=False)
    return jnp.where(k_inactive <= 0, -1.0, thr)


def prune_mask(scores: jnp.ndarray, thresholds: jnp.ndarray) -> jnp.ndarray:
    """Binary micro-expert activation mask: 1 where score > row threshold."""
    return (scores > thresholds[:, None]).astype(scores.dtype)


def masked_linear(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """y = x @ (W * mask)^T + b — the micro-expert mixture: each surviving
    weight is a single-parameter expert, gated by `mask`.

    x: (..., d_in), w: (d_out, d_in), mask: (d_out, d_in), b: (d_out,).
    """
    return x @ (w * mask).T + b


def wanda_prune_linear(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, k_inactive: jnp.ndarray
) -> jnp.ndarray:
    """Full online (test-time) Wanda pruning of one linear: score from the
    *current* activations x, threshold per row, mask, apply. This is the
    mu-MoE hot path (paper S2, 'Instant Wanda Pruning as mu-MoE')."""
    flat = x.reshape(-1, x.shape[-1])
    col_norms = jnp.sqrt(jnp.sum(flat * flat, axis=0))
    s = wanda_score(w, col_norms)
    thr = row_kth_threshold(s, k_inactive)
    mask = prune_mask(s, thr)
    return masked_linear(x, w, b, mask)


def layernorm(x: jnp.ndarray, g: jnp.ndarray, b: jnp.ndarray, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def causal_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lengths: jnp.ndarray
) -> jnp.ndarray:
    """Multi-head causal attention with right-padding masked out.

    q,k,v: (B, H, T, hd); lengths: (B,) valid-token counts.
    """
    b_, h_, t_, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    pos = jnp.arange(t_)
    causal = pos[None, :] <= pos[:, None]  # (Tq, Tk)
    valid = pos[None, :] < lengths[:, None]  # (B, Tk)
    m = causal[None, None, :, :] & valid[:, None, None, :]
    logits = jnp.where(m, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def col_l2_norms(x: jnp.ndarray) -> jnp.ndarray:
    """Per-feature l2 norm over all tokens: ||X_{j,:}||_2 with X (d, T) in
    paper notation; here x is (T, d) so we reduce over axis 0."""
    return jnp.sqrt(jnp.sum(x * x, axis=0))
