"""L1 Pallas causal attention kernel.

One grid step per (batch, head): at mu-OPT scale (T<=128, head_dim<=64) a
full (T, hd) Q/K/V panel fits comfortably in VMEM (3*128*64*4B = 96KiB),
so the kernel computes the whole attention matrix for its (b, h) program
rather than streaming K/V flash-style; the flash decomposition only pays
once T*hd exceeds VMEM. Padding and causality are masked in-kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_INTERPRET = True


def _attn_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, *, scale):
    q = q_ref[0, 0]  # (T, hd)
    k = k_ref[0, 0]
    v = v_ref[0, 0]
    t = q.shape[0]
    logits = (q @ k.T) * scale
    pos = jax.lax.iota(jnp.int32, t)
    causal = pos[None, :] <= pos[:, None]
    valid = pos[None, :] < len_ref[0]
    logits = jnp.where(causal & valid, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - mx)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o_ref[0, 0] = p @ v


def causal_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lengths: jnp.ndarray
) -> jnp.ndarray:
    """q,k,v: (B, H, T, hd); lengths: (B,) int32 -> (B, H, T, hd)."""
    b_, h_, t_, hd = q.shape
    scale = 1.0 / (hd**0.5)
    kern = functools.partial(_attn_kernel, scale=scale)
    spec = pl.BlockSpec((1, 1, t_, hd), lambda b, h: (b, h, 0, 0))
    return pl.pallas_call(
        kern,
        grid=(b_, h_),
        in_specs=[
            spec,
            spec,
            spec,
            pl.BlockSpec((1,), lambda b, h: (b,)),
        ],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b_, h_, t_, hd), q.dtype),
        interpret=_INTERPRET,
    )(q, k, v, lengths)
