"""Pallas L1 kernels (interpret=True) + pure-jnp reference oracle."""

from . import attention, layernorm, ref, wanda  # noqa: F401
