"""L1 Pallas kernels for the mu-MoE hot spot: Wanda scoring, micro-expert
masking, and the fused prune+matmul that the L2 model calls.

All kernels run under ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode lowers them to plain HLO that both the
python tests and the rust runtime can run. Block shapes are chosen for a
TPU-shaped memory hierarchy (DESIGN.md S3): weight tiles of (BLK_OUT, BLK_IN)
live in VMEM, the per-column norm vector stays resident, and the mask is
applied to the tile right before the MXU dot so the systolic array always
sees a dense tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: multiples of the (8, 128) f32 TPU tile; d_model in the mu-OPT
# family is 128..256 so a single block often covers the full dimension.
BLK_OUT = 128
BLK_IN = 128
BLK_TOK = 128

_INTERPRET = True  # CPU sandbox; see module docstring.


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pick_block(dim: int, pref: int) -> int:
    """Largest block size <= pref that divides dim exactly. Interpret-mode
    pallas pads out-of-bounds tiles with undefined values, so blocks must
    tile the array evenly; model dims are powers-of-two multiples so this
    almost always returns pref."""
    b = min(pref, dim)
    while dim % b:
        b -= 1
    return b


# ---------------------------------------------------------------------------
# Wanda scoring: S = |W| * col_norms  (paper eq. 3)
# ---------------------------------------------------------------------------


def _score_kernel(w_ref, n_ref, o_ref):
    o_ref[...] = jnp.abs(w_ref[...]) * n_ref[...][None, :]


def wanda_score(w: jnp.ndarray, col_norms: jnp.ndarray) -> jnp.ndarray:
    """Tiled Wanda score. w: (d_out, d_in), col_norms: (d_in,)."""
    d_out, d_in = w.shape
    bo, bi = _pick_block(d_out, BLK_OUT), _pick_block(d_in, BLK_IN)
    grid = (_ceil_div(d_out, bo), _ceil_div(d_in, bi))
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bo, bi), lambda i, j: (i, j)),
            pl.BlockSpec((bi,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bo, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d_out, d_in), w.dtype),
        interpret=_INTERPRET,
    )(w, col_norms)


# ---------------------------------------------------------------------------
# Column l2 norms over tokens: ||X_{j,:}||_2 (the activation statistic)
# ---------------------------------------------------------------------------


def _colnorm_kernel(x_ref, o_ref, *, n_tok_blocks):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    o_ref[...] += jnp.sum(x * x, axis=0)


def col_sq_sums(x: jnp.ndarray) -> jnp.ndarray:
    """Per-feature sum of squares over tokens; sqrt gives the Wanda norm.

    x: (T, d). Returned un-rooted so offline calibration can accumulate
    across batches before the sqrt (matches rust/src/pruning/wanda.rs).
    """
    t_, d_ = x.shape
    bt, bd = _pick_block(t_, BLK_TOK), _pick_block(d_, BLK_IN)
    grid = (_ceil_div(d_, bd), _ceil_div(t_, bt))
    kern = functools.partial(_colnorm_kernel, n_tok_blocks=grid[1])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((bt, bd), lambda j, t: (t, j))],
        out_specs=pl.BlockSpec((bd,), lambda j, t: (j,)),
        out_shape=jax.ShapeDtypeStruct((d_,), x.dtype),
        interpret=_INTERPRET,
    )(x)


def col_l2_norms(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sqrt(col_sq_sums(x))


# ---------------------------------------------------------------------------
# Fused micro-expert gate + matmul:
#   y = x @ (W * [S > thr_row])^T + b
# The mask never materializes in HBM: each (BLK_OUT, BLK_IN) weight tile is
# scored, gated and fed to the dot in VMEM. This is the kernel that makes
# "instant Wanda pruning" nearly free (paper S2 complexity argument).
# ---------------------------------------------------------------------------


def _prune_matmul_kernel(x_ref, w_ref, n_ref, thr_ref, b_ref, o_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    w = w_ref[...]
    s = jnp.abs(w) * n_ref[...][None, :]
    gated = jnp.where(s > thr_ref[...][:, None], w, 0.0)
    o_ref[...] += x_ref[...] @ gated.T

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...][None, :]


def prune_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    col_norms: jnp.ndarray,
    thresholds: jnp.ndarray,
) -> jnp.ndarray:
    """x: (M, d_in), w: (d_out, d_in), b/thresholds: (d_out,), col_norms:
    (d_in,) -> (M, d_out)."""
    m_, d_in = x.shape
    d_out = w.shape[0]
    bm = _pick_block(m_, BLK_TOK)
    bn = _pick_block(d_out, BLK_OUT)
    bk = _pick_block(d_in, BLK_IN)
    grid = (_ceil_div(m_, bm), _ceil_div(d_out, bn), _ceil_div(d_in, bk))
    kern = functools.partial(_prune_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bk,), lambda i, j, k: (k,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_, d_out), x.dtype),
        interpret=_INTERPRET,
    )(x, w, col_norms, thresholds, b)


# ---------------------------------------------------------------------------
# Plain masked matmul (offline pruning path / oracle for fused kernel)
# ---------------------------------------------------------------------------


def _masked_matmul_kernel(x_ref, w_ref, m_ref, b_ref, o_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ (w_ref[...] * m_ref[...]).T

    @pl.when(k == nk - 1)
    def _bias():
        o_ref[...] += b_ref[...][None, :]


def masked_matmul(
    x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """y = x @ (W*mask)^T + b with mask applied tile-wise in VMEM."""
    m_, d_in = x.shape
    d_out = w.shape[0]
    bm = _pick_block(m_, BLK_TOK)
    bn = _pick_block(d_out, BLK_OUT)
    bk = _pick_block(d_in, BLK_IN)
    grid = (_ceil_div(m_, bm), _ceil_div(d_out, bn), _ceil_div(d_in, bk))
    kern = functools.partial(_masked_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
            pl.BlockSpec((bn,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m_, d_out), x.dtype),
        interpret=_INTERPRET,
    )(x, w, mask, b)
