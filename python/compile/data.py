"""Synthetic data substrates (DESIGN.md S2 substitutions).

The sandbox has no dataset hub, so we build generators whose *statistical
structure* reproduces what each paper experiment needs:

- Three text corpora with deliberately different domain statistics
  (synth-wiki / synth-news / synth-web standing in for WT2 / PTB / C4).
  Table 1's experiment is about calibration/test domain shift — the three
  grammars use different topic vocabularies, sentence shapes and markup
  noise, so cross-domain calibration mismatch is real and controllable.

- SynthQA (stands in for ScienceQA): multimodal multiple-choice questions
  stratified by subject {NAT, SOC, LAN}, context modality {TXT, IMG, NO}
  and grade {G1-6, G7-12}, over 24x24 synthetic images.

- SynthVQA (stands in for TextVQA): the answer must be *read from pixels*
  (a glyph rendered into the image), exercising the text-in-image skill.

Everything is seeded and versioned; rust reads the corpora as plain text
and the QA sets through the SQAB binary format (rust/src/data/qa.rs).
"""

import struct

import numpy as np

IMG = 24  # image side (matches configs.VlmConfig.image_size)

# ---------------------------------------------------------------------------
# Corpora
# ---------------------------------------------------------------------------

_WIKI_ENTITIES = (
    "aldebaran basilica cathedral dynasty empire fjord glacier harbor "
    "islet junction kingdom lagoon monastery nebula obelisk plateau "
    "quarry reef summit temple uplands valley watermill zeppelin "
    "archive ballad chronicle dialect epic folklore gazette hymn"
).split()
_WIKI_CLASSES = (
    "settlement river mountain crater basin province comet mineral "
    "species manuscript fortress observatory aqueduct cloister"
).split()
_WIKI_PLACES = [
    "northern tyrolia", "the veldt coast", "lower saxonia",
    "the amber isles", "upper carinthia", "the basalt steppe",
    "west lusatia", "the coral strait",
]
_WIKI_VERBS = "founded charted surveyed excavated restored annexed".split()

_NEWS_COMPANIES = [
    "acme corp", "nordbank", "helix industries", "veritas group",
    "zenith holdings", "crestline partners", "omega mills", "atlas freight",
]
_NEWS_VERBS = (
    "reported posted projected announced disclosed forecast revised".split()
)
_NEWS_ITEMS = [
    "quarterly earnings", "net income", "operating revenue",
    "share dividends", "bond yields", "futures contracts",
]

_WEB_TOPICS = (
    "recipe garden review tutorial coupon forum blog travel gadget diet"
).split()
_WEB_FILLER = (
    "click here for more best top free easy quick ultimate guide tips "
    "tricks how to near me online cheap deal"
).split()


def _sentence(rng, words, zipf_a=1.3):
    """Zipf-weighted word draw — natural-language-like rank frequencies."""
    n = len(words)
    ranks = rng.zipf(zipf_a, size=64) - 1
    ranks = ranks[ranks < n]
    return [words[r] for r in ranks]


def gen_synth_wiki(rng: np.random.Generator, n_chars: int) -> str:
    """Encyclopedia-style: headings, entity-is-a-class sentences, years."""
    out = []
    size = 0
    while size < n_chars:
        ent = rng.choice(_WIKI_ENTITIES)
        out.append(f"\n== {ent.capitalize()} ==\n")
        for _ in range(rng.integers(2, 6)):
            e = rng.choice(_WIKI_ENTITIES)
            c = rng.choice(_WIKI_CLASSES)
            p = rng.choice(_WIKI_PLACES)
            v = rng.choice(_WIKI_VERBS)
            y = rng.integers(1100, 1990)
            s = f"The {e} of {p} is a {c} {v} in {y}. "
            extra = " ".join(_sentence(rng, _WIKI_ENTITIES + _WIKI_CLASSES)[:6])
            if extra:
                s += f"It is related to the {extra}. "
            out.append(s)
            size += len(s)
    return "".join(out)


def gen_synth_news(rng: np.random.Generator, n_chars: int) -> str:
    """PTB/WSJ-style: short finance sentences, numerals, fixed idioms."""
    out = []
    size = 0
    while size < n_chars:
        co = rng.choice(_NEWS_COMPANIES)
        v = rng.choice(_NEWS_VERBS)
        item = rng.choice(_NEWS_ITEMS)
        pct = rng.integers(1, 40)
        mm = rng.integers(2, 980)
        s = f"{co} {v} {item} of $ {mm} million , {'up' if rng.random() < 0.5 else 'down'} {pct} % from a year earlier . "
        if rng.random() < 0.3:
            s += f"analysts said the {rng.choice(_NEWS_ITEMS)} outlook remains {'strong' if rng.random() < 0.5 else 'weak'} . "
        out.append(s)
        size += len(s)
        if rng.random() < 0.12:
            out.append("\n")
    return "".join(out)


def gen_synth_web(rng: np.random.Generator, n_chars: int) -> str:
    """C4-style: noisy web text — boilerplate, urls, lists, mixed casing."""
    out = []
    size = 0
    while size < n_chars:
        t = rng.choice(_WEB_TOPICS)
        f1 = " ".join(_sentence(rng, _WEB_FILLER)[:5])
        mode = rng.integers(0, 4)
        if mode == 0:
            s = f"{f1} {t} 2023 | www.{t}{rng.integers(1, 99)}.example.com\n"
        elif mode == 1:
            s = f"- {t}: {f1} ({rng.integers(1, 500)} reviews)\n"
        elif mode == 2:
            s = f"THE BEST {t.upper()} {f1}!!! "
        else:
            s = f"posted by user{rng.integers(1, 400)}: my {t} {f1}. "
        out.append(s)
        size += len(s)
    return "".join(out)


CORPUS_GENERATORS = {
    "synth_wiki": gen_synth_wiki,
    "synth_news": gen_synth_news,
    "synth_web": gen_synth_web,
}


def write_corpora(out_dir, train_chars=1_500_000, test_chars=96_000, seed=2026):
    """Write {domain}.{train,test}.txt; train/test use disjoint seeds."""
    import os

    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    for i, (name, gen) in enumerate(sorted(CORPUS_GENERATORS.items())):
        for split, n, salt in (("train", train_chars, 0), ("test", test_chars, 7)):
            rng = np.random.default_rng(seed + 100 * i + salt)
            text = gen(rng, n)
            p = f"{out_dir}/{name}.{split}.txt"
            with open(p, "w") as f:
                f.write(text)
            paths[f"{name}.{split}"] = p
    return paths


# ---------------------------------------------------------------------------
# Glyph font for SynthVQA (digits rendered into pixels, 3x5 bitmaps)
# ---------------------------------------------------------------------------

_FONT = {
    "0": "111101101101111",
    "1": "010110010010111",
    "2": "111001111100111",
    "3": "111001111001111",
    "4": "101101111001001",
    "5": "111100111001111",
    "6": "111100111101111",
    "7": "111001010010010",
    "8": "111101111101111",
    "9": "111101111001111",
}


def _draw_glyph(img: np.ndarray, ch: str, r: int, c: int, scale: int = 3):
    bits = _FONT[ch]
    for i in range(5):
        for j in range(3):
            if bits[i * 3 + j] == "1":
                img[
                    r + i * scale : r + (i + 1) * scale,
                    c + j * scale : c + (j + 1) * scale,
                ] = 1.0


def _draw_blob(img, rng, quadrant=None, intensity=None):
    """Fill a 6x6 blob at a random spot (optionally inside a quadrant)."""
    half = IMG // 2
    if quadrant is None:
        r0, c0 = rng.integers(0, IMG - 6), rng.integers(0, IMG - 6)
    else:
        qr, qc = divmod(quadrant, 2)
        r0 = qr * half + rng.integers(0, half - 6)
        c0 = qc * half + rng.integers(0, half - 6)
    val = intensity if intensity is not None else float(rng.uniform(0.4, 1.0))
    img[r0 : r0 + 6, c0 : c0 + 6] = np.maximum(img[r0 : r0 + 6, c0 : c0 + 6], val)
    return val


# ---------------------------------------------------------------------------
# SynthQA: ScienceQA-like strata (subject x modality x grade)
# ---------------------------------------------------------------------------

SUBJECT_NAT, SUBJECT_SOC, SUBJECT_LAN = 0, 1, 2
MOD_TXT, MOD_IMG, MOD_NO = 0, 1, 2
GRADE_LO, GRADE_HI = 0, 1  # G1-6 / G7-12

_NAT_FACTS = [
    ("iron", "metal"), ("quartz", "mineral"), ("oak", "tree"),
    ("fern", "plant"), ("granite", "rock"), ("helium", "gas"),
    ("salmon", "fish"), ("falcon", "bird"), ("amber", "resin"),
    ("basalt", "rock"),
]
_SOC_FACTS = [
    ("mayor", "city"), ("judge", "court"), ("farmer", "field"),
    ("sailor", "ship"), ("teacher", "school"), ("miner", "mine"),
    ("baker", "bakery"), ("guard", "gate"),
]
_LAN_WORDS = "cat dog sun map pen cup log fox hat jar kit lamp".split()

_LETTERS = "ABCD"


def _mc_question(rng, stem, correct, pool, n_choices=4):
    """Build a multiple-choice record: distractors drawn from pool.

    The question text lists the choices and ends with "Answer:"; grading
    appends each choice text and compares continuation NLL (both the rust
    harness and parse_choices() below rely on this exact format).
    """
    distract = [p for p in pool if p != correct]
    rng.shuffle(distract)
    choices = distract[: n_choices - 1] + [correct]
    rng.shuffle(choices)
    ans = choices.index(correct)
    body = " ".join(
        f"{_LETTERS[i]}) {c}" for i, c in enumerate(choices)
    )
    text = f"Q: {stem}\n{body}\nAnswer:"
    return text, ans


def parse_choices(question: str):
    """Recover choice texts from the canonical question format."""
    body = question.split("\n")[1]
    parts = []
    for i, letter in enumerate(_LETTERS):
        tag = f"{letter}) "
        start = body.find(tag)
        if start < 0:
            break
        start += len(tag)
        nxt = len(body)
        for l2 in _LETTERS[i + 1 :]:
            j = body.find(f" {l2}) ", start)
            if j >= 0:
                nxt = j
                break
        parts.append(body[start:nxt])
    return parts


def make_synthqa_record(rng: np.random.Generator):
    """One SynthQA sample: (image f32[24,24], question str, answer idx,
    subject, modality, grade)."""
    subject = int(rng.integers(0, 3))
    grade = int(rng.integers(0, 2))
    img = np.zeros((IMG, IMG), np.float32)

    if subject == SUBJECT_NAT:
        modality = int(rng.integers(0, 3))
        if modality == MOD_IMG:
            lo, hi = (1, 5) if grade == GRADE_LO else (4, 8)
            n = int(rng.integers(lo, hi))
            for _ in range(n):
                _draw_blob(img, rng)
            q, a = _mc_question(
                rng, "how many mineral samples are shown?", str(n),
                [str(x) for x in range(0, 10)],
            )
        elif modality == MOD_TXT:
            thing, cls = _NAT_FACTS[rng.integers(0, len(_NAT_FACTS))]
            q, a = _mc_question(
                rng,
                f"the {thing} sample was collected. what kind of matter is {thing}?",
                cls, sorted({c for _, c in _NAT_FACTS}),
            )
        else:
            thing, cls = _NAT_FACTS[rng.integers(0, len(_NAT_FACTS))]
            q, a = _mc_question(
                rng, f"what is {thing}?", cls, sorted({c for _, c in _NAT_FACTS})
            )
    elif subject == SUBJECT_SOC:
        modality = int(rng.integers(0, 3))
        if modality == MOD_IMG:
            quad = int(rng.integers(0, 4))
            _draw_blob(img, rng, quadrant=quad, intensity=1.0)
            for oq in range(4):
                if oq != quad:
                    _draw_blob(img, rng, quadrant=oq, intensity=0.3)
            names = ["north-west", "north-east", "south-west", "south-east"]
            q, a = _mc_question(
                rng, "which district on the map is most populated?",
                names[quad], names,
            )
        elif modality == MOD_TXT:
            who, where = _SOC_FACTS[rng.integers(0, len(_SOC_FACTS))]
            q, a = _mc_question(
                rng,
                f"the {who} went to work this morning. where does the {who} work?",
                where, sorted({w for _, w in _SOC_FACTS}),
            )
        else:
            who, where = _SOC_FACTS[rng.integers(0, len(_SOC_FACTS))]
            q, a = _mc_question(
                rng, f"where does a {who} work?", where,
                sorted({w for _, w in _SOC_FACTS}),
            )
    else:  # SUBJECT_LAN
        modality = MOD_TXT if rng.random() < 0.5 else MOD_NO
        w = _LAN_WORDS[rng.integers(0, len(_LAN_WORDS))]
        if grade == GRADE_LO:
            q, a = _mc_question(
                rng, f"which letter does the word '{w}' start with?",
                w[0], sorted({x[0] for x in _LAN_WORDS}),
            )
        else:
            q, a = _mc_question(
                rng, f"which letter does the word '{w}' end with?",
                w[-1], sorted({x[-1] for x in _LAN_WORDS}),
            )

    return img, q, a, subject, modality, grade


def make_synthvqa_record(rng: np.random.Generator):
    """One SynthVQA sample: a 2-digit number rendered into the image; the
    question asks to read it (answer among 4 numeric choices)."""
    img = np.zeros((IMG, IMG), np.float32)
    # light clutter so reading is non-trivial
    for _ in range(int(rng.integers(0, 3))):
        _draw_blob(img, rng, intensity=0.25)
    n = int(rng.integers(10, 100))
    s = str(n)
    _draw_glyph(img, s[0], 4, 2)
    _draw_glyph(img, s[1], 4, 13)
    pool = {str(int(rng.integers(10, 100))) for _ in range(12)} | {str(n)}
    q, a = _mc_question(
        rng, "what number is written in the picture?", str(n), sorted(pool)
    )
    return img, q, a, 0, MOD_IMG, 0


# ---------------------------------------------------------------------------
# SQAB binary format (shared with rust/src/data/qa.rs — keep in sync)
# ---------------------------------------------------------------------------

SQAB_MAGIC = b"SQAB0001"


def write_qa_bin(path, records, max_qlen=120):
    """records: iterable of (img, qtext, answer, subject, modality, grade)."""
    recs = list(records)
    with open(path, "wb") as f:
        f.write(SQAB_MAGIC)
        f.write(struct.pack("<IIII", len(recs), IMG, IMG, max_qlen))
        for img, q, a, subj, mod, grade in recs:
            qb = q.encode("utf-8")
            assert len(qb) <= max_qlen, f"question too long ({len(qb)}): {q!r}"
            f.write(struct.pack("<BBBBI", subj, mod, grade, a, len(qb)))
            f.write(qb.ljust(max_qlen, b"\x00"))
            f.write(img.astype("<f4").tobytes())


def read_qa_bin(path):
    with open(path, "rb") as f:
        assert f.read(8) == SQAB_MAGIC
        n, h, w, max_qlen = struct.unpack("<IIII", f.read(16))
        out = []
        for _ in range(n):
            subj, mod, grade, a, qlen = struct.unpack("<BBBBI", f.read(8))
            q = f.read(max_qlen)[:qlen].decode("utf-8")
            img = np.frombuffer(f.read(h * w * 4), dtype="<f4").reshape(h, w)
            out.append((img, q, a, subj, mod, grade))
        return out


def write_qa_sets(out_dir, n_train=4000, n_test=600, seed=2027):
    import os

    os.makedirs(out_dir, exist_ok=True)
    for name, maker, salt in (
        ("synthqa", make_synthqa_record, 0),
        ("synthvqa", make_synthvqa_record, 31),
    ):
        for split, n, salt2 in (("train", n_train, 0), ("test", n_test, 13)):
            rng = np.random.default_rng(seed + salt + salt2)
            recs = [maker(rng) for _ in range(n)]
            write_qa_bin(f"{out_dir}/{name}.{split}.bin", recs)
