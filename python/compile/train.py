"""Build-time trainer for the mu-OPT family and mu-VLM.

Runs ONCE under `make artifacts` (python never appears on the request path).
Trains each model on a mixed-domain stream of the three synthetic corpora
(generalist pretraining, like OPT's corpus mix), and mu-VLM on SynthQA +
SynthVQA jointly. Writes MUCK checkpoints plus a loss-curve log per model.

Usage: python -m compile.train --out ../artifacts [--steps-scale 1.0]
"""

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import ckpt, data, model, vlm
from .configs import MODEL_FAMILY, MU_VLM, MAX_SEQ_LEN, BOS_ID

# Single-core sandbox: step counts sized to finish `make artifacts` in
# ~30 min total; the synthetic grammars converge fast at byte level.
TRAIN_STEPS = {"mu-opt-micro": 1000, "mu-opt-mini": 500, "mu-opt-small": 300}
VLM_STEPS = 1600
BATCH = 16
LR_PEAK = 3e-3


def _lr(step, total, peak=LR_PEAK, warmup=100):
    """Linear warmup + cosine decay to 10% of peak."""
    w = np.minimum(step / warmup, 1.0)
    t = np.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    return float(peak * w * (0.55 + 0.45 * np.cos(np.pi * t)))


def _sample_windows(rng, corpus_bytes, b, t):
    """(B, T) int32 windows + lengths; BOS-prefixed byte tokens."""
    toks = np.empty((b, t), np.int32)
    n = len(corpus_bytes)
    for i in range(b):
        off = int(rng.integers(0, n - t))
        toks[i, 0] = BOS_ID
        toks[i, 1:] = np.frombuffer(corpus_bytes[off : off + t - 1], np.uint8)
    lens = np.full((b,), t, np.int32)
    return jnp.asarray(toks), jnp.asarray(lens)


def train_lm(cfg, corpora, out_dir, steps, seed=7, log_every=50):
    """Train one mu-OPT model on the mixed corpus; returns final loss."""
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    params = model.init_params(cfg, key)
    m, v = model.adam_init(params)
    blobs = [c.encode("utf-8") for c in corpora]

    log_path = f"{out_dir}/ckpt/{cfg.name}.train.log"
    losses = []
    t0 = time.time()
    with open(log_path, "w") as log:
        log.write("step\tloss\tlr\telapsed_s\n")
        for step in range(steps):
            blob = blobs[int(rng.integers(0, len(blobs)))]
            toks, lens = _sample_windows(rng, blob, BATCH, MAX_SEQ_LEN)
            lr = _lr(step, steps)
            loss, params, m, v = model.train_step(
                cfg, params, m, v, float(step), toks, lens, lr
            )
            losses.append(float(loss))
            if step % log_every == 0 or step == steps - 1:
                log.write(
                    f"{step}\t{float(loss):.4f}\t{lr:.2e}\t{time.time()-t0:.1f}\n"
                )
                log.flush()
                print(
                    f"[{cfg.name}] step {step}/{steps} loss={float(loss):.4f}",
                    flush=True,
                )
    ckpt.save(f"{out_dir}/ckpt/{cfg.name}.ckpt", params)
    return losses[-1]


def _qa_batch(rng, records, b, max_qlen):
    """Training batch: question + " " + correct-choice text appended; the
    loss covers only the appended continuation (LM-style MC scoring)."""
    idx = rng.integers(0, len(records), size=b)
    imgs = np.stack([records[i][0] for i in idx]).astype(np.float32)
    toks = np.zeros((b, max_qlen), np.int32)
    lens = np.zeros((b,), np.int32)
    starts = np.zeros((b,), np.int32)
    for j, i in enumerate(idx):
        q, ans_idx = records[i][1], records[i][2]
        choice = data.parse_choices(q)[ans_idx]
        full = (q + " " + choice).encode("utf-8")[:max_qlen]
        qlen = min(len(q.encode("utf-8")), max_qlen)
        toks[j, : len(full)] = np.frombuffer(full, np.uint8)
        lens[j] = len(full)
        starts[j] = qlen  # first appended token (the space)
    return (
        jnp.asarray(imgs),
        jnp.asarray(toks),
        jnp.asarray(lens),
        jnp.asarray(starts),
    )


def train_vlm(cfg, qa_train, vqa_train, out_dir, steps, seed=11, log_every=50):
    rng = np.random.default_rng(seed)
    params = vlm.init_params(cfg, jax.random.PRNGKey(seed))
    m, v = {k: jnp.zeros_like(x) for k, x in params.items()}, {
        k: jnp.zeros_like(x) for k, x in params.items()
    }
    step_fn = jax.jit(functools.partial(vlm.train_step, cfg))
    max_qlen = cfg.text.max_seq_len - 1

    log_path = f"{out_dir}/ckpt/{cfg.name}.train.log"
    t0 = time.time()
    loss = jnp.float32(0)
    with open(log_path, "w") as log:
        log.write("step\tloss\tlr\telapsed_s\n")
        for step in range(steps):
            # 70/30 mix of the two tasks (LLaVA trains on mixed instructions)
            recs = qa_train if rng.random() < 0.7 else vqa_train
            imgs, toks, lens, starts = _qa_batch(rng, recs, BATCH, max_qlen)
            lr = _lr(step, steps, peak=1.5e-3)
            loss, params, m, v = step_fn(
                params, m, v, float(step), imgs, toks, lens, starts, lr
            )
            if step % log_every == 0 or step == steps - 1:
                log.write(
                    f"{step}\t{float(loss):.4f}\t{lr:.2e}\t{time.time()-t0:.1f}\n"
                )
                log.flush()
                print(
                    f"[{cfg.name}] step {step}/{steps} loss={float(loss):.4f}",
                    flush=True,
                )
    ckpt.save(f"{out_dir}/ckpt/{cfg.name}.ckpt", params)
    return float(loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps-scale", type=float, default=1.0)
    ap.add_argument("--only", default=None, help="train a single model by name")
    args = ap.parse_args()
    out = args.out
    os.makedirs(f"{out}/ckpt", exist_ok=True)
    os.makedirs(f"{out}/data", exist_ok=True)

    print("generating corpora...", flush=True)
    data.write_corpora(f"{out}/data")
    print("generating QA sets...", flush=True)
    data.write_qa_sets(f"{out}/data")

    corpora = []
    for name in sorted(data.CORPUS_GENERATORS):
        with open(f"{out}/data/{name}.train.txt") as f:
            corpora.append(f.read())

    for cfg_name, cfg in MODEL_FAMILY.items():
        if args.only and args.only != cfg_name:
            continue
        steps = max(int(TRAIN_STEPS[cfg_name] * args.steps_scale), 10)
        print(f"training {cfg_name} ({cfg.n_params():,} params, {steps} steps)")
        train_lm(cfg, corpora, out, steps)

    if args.only in (None, MU_VLM.name):
        qa = data.read_qa_bin(f"{out}/data/synthqa.train.bin")
        vqa = data.read_qa_bin(f"{out}/data/synthvqa.train.bin")
        steps = max(int(VLM_STEPS * args.steps_scale), 10)
        print(f"training {MU_VLM.name} ({steps} steps)")
        train_vlm(MU_VLM, qa, vqa, out, steps)


if __name__ == "__main__":
    main()
