"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT."""
