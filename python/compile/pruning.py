"""Offline (calibration-based) pruning baselines, build/test-path Python.

These are the reference implementations the rust engines
(rust/src/pruning/*.rs) are tested against; the runtime uses the rust ones.

- magnitude:  S = |W|                       (Han et al., 2015)
- wanda:      S = |W| * ||X_j||_2           (Sun et al., 2023; paper eq. 3)
- sparsegpt:  OBS with damped Hessian,      (Frantar & Alistarh, 2023;
              Cholesky, column elimination   paper eq. 2)

All produce per-row semi-structured sparsity: exactly k_c zeros per output
row at active ratio rho (k_c = floor((1-rho) d_in)), matching the paper's
"constant number of active weights per row".
"""

import numpy as np


def kc_for(d_in: int, rho: float) -> int:
    return int(np.clip(int(np.floor((1.0 - rho) * d_in)), 0, d_in - 1))


def _mask_from_scores(scores: np.ndarray, rho: float) -> np.ndarray:
    """Keep the top rho fraction per row (kthvalue formulation: zero the
    k_c smallest-scored weights per row, ties broken by index order)."""
    d_out, d_in = scores.shape
    kc = kc_for(d_in, rho)
    if kc == 0:
        return np.ones_like(scores, dtype=np.float32)
    # argpartition = quickselect; matches rust selection::kthvalue semantics
    idx = np.argpartition(scores, kc - 1, axis=-1)[:, :kc]
    mask = np.ones_like(scores, dtype=np.float32)
    np.put_along_axis(mask, idx, 0.0, axis=-1)
    return mask


def magnitude_mask(w: np.ndarray, rho: float) -> np.ndarray:
    return _mask_from_scores(np.abs(w), rho)


def wanda_mask(w: np.ndarray, col_sq_sums: np.ndarray, rho: float) -> np.ndarray:
    """col_sq_sums: per-input-feature sum of squares accumulated over the
    calibration activations (sqrt gives ||X_j||_2)."""
    scores = np.abs(w) * np.sqrt(col_sq_sums)[None, :]
    return _mask_from_scores(scores, rho)


def sparsegpt_prune(
    w: np.ndarray,
    hessian: np.ndarray,
    rho: float,
    damp_ratio: float = 0.01,
    blocksize: int = 128,
) -> np.ndarray:
    """SparseGPT one-shot pruning with weight update.

    w: (d_out, d_in); hessian: (d_in, d_in) = X X^T accumulated over
    calibration tokens. Returns the *updated* pruned weight matrix (unlike
    the mask-only methods, OBS compensates surviving weights).

    Follows the reference algorithm: damp H, invert via Cholesky, take
    Hinv's Cholesky factor (upper), then column-wise: score with eq. 2,
    prune to per-row k_c within each block, propagate the error with
    Gaussian elimination.
    """
    d_out, d_in = w.shape
    kc = kc_for(d_in, rho)
    w = w.astype(np.float64).copy()
    h = hessian.astype(np.float64).copy()

    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    w[:, dead] = 0.0

    damp = damp_ratio * np.mean(np.diag(h))
    h[np.arange(d_in), np.arange(d_in)] += damp

    hinv = np.linalg.inv(h)
    # Upper Cholesky factor U of H^-1 with Hinv = U^T U (the paper's
    # Chol[(XX^T + lam I)^-1]; torch.linalg.cholesky(..., upper=True))
    u = np.linalg.cholesky(hinv).T

    losses = np.zeros_like(w)
    target_zeros_per_row = kc

    for i1 in range(0, d_in, blocksize):
        i2 = min(i1 + blocksize, d_in)
        count = i2 - i1
        w_blk = w[:, i1:i2].copy()
        q_blk = np.zeros_like(w_blk)
        err_blk = np.zeros_like(w_blk)
        u_blk = u[i1:i2, i1:i2]

        # per-block score and mask: keep the proportional share of zeros
        scores = (w_blk**2) / (np.diag(u_blk)[None, :] ** 2)
        n_zero = int(round(target_zeros_per_row * count / d_in))
        mask = np.ones_like(w_blk)
        if n_zero > 0:
            idx = np.argpartition(scores, n_zero - 1, axis=-1)[:, :n_zero]
            np.put_along_axis(mask, idx, 0.0, axis=-1)

        for j in range(count):
            col = w_blk[:, j]
            dj = u_blk[j, j]
            q = col * mask[:, j]
            q_blk[:, j] = q
            losses[:, i1 + j] = (col - q) ** 2 / dj**2
            e = (col - q) / dj
            w_blk[:, j:] -= np.outer(e, u_blk[j, j:])
            err_blk[:, j] = e
        w[:, i1:i2] = q_blk
        w[:, i2:] -= err_blk @ u[i1:i2, i2:]

    return w.astype(np.float32)


def online_wanda_mask(
    w: np.ndarray, x: np.ndarray, rho: float
) -> np.ndarray:
    """mu-MoE: Wanda mask from the *test-time* activations x (T, d_in).
    This is the numpy oracle for the in-graph (L1/L2) online pruning."""
    sq = np.sum(x.astype(np.float64) ** 2, axis=0)
    return wanda_mask(w, sq, rho)
