"""L2: mu-VLM — a patch-embed vision tower feeding a mu-OPT text decoder.

Stands in for LLaVA-7B (vision transformer tower + Vicuna LM) in the paper's
Tables 2-3 multimodal experiments (DESIGN.md S2). Image patches are embedded,
encoded by a small bidirectional transformer, projected into the text
embedding space, and prepended as prefix tokens to the question; the answer
is read from the logits at the last question position.

The mu-MoE / dense / masked variant selection mirrors model.py: rho=None is
the dense (or host-side offline-pruned) path, rho=scalar runs online Wanda
through the L1 Pallas kernels on *every* linear in both towers.
"""

import jax
import jax.numpy as jnp

from .configs import VlmConfig
from .kernels import layernorm as kln
from .kernels import ref as kref
from .kernels import wanda as kwanda
from .model import _kc_for, _mumoe_linear


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_order(cfg: VlmConfig) -> list:
    names = ["patch_emb.w", "patch_emb.b", "vis_pos_emb"]
    for i in range(cfg.vision_layers):
        p = f"vision.{i}"
        names += [f"{p}.ln1.g", f"{p}.ln1.b"]
        for lin in ("q", "k", "v", "o"):
            names += [f"{p}.{lin}.w", f"{p}.{lin}.b"]
        names += [f"{p}.ln2.g", f"{p}.ln2.b"]
        names += [f"{p}.fc1.w", f"{p}.fc1.b", f"{p}.fc2.w", f"{p}.fc2.b"]
    names += ["vis_ln.g", "vis_ln.b", "proj.w", "proj.b"]

    t = cfg.text
    names += ["tok_emb", "pos_emb"]
    for i in range(t.n_layers):
        p = f"layers.{i}"
        names += [f"{p}.ln1.g", f"{p}.ln1.b"]
        for lin in ("q", "k", "v", "o"):
            names += [f"{p}.{lin}.w", f"{p}.{lin}.b"]
        names += [f"{p}.ln2.g", f"{p}.ln2.b"]
        names += [f"{p}.fc1.w", f"{p}.fc1.b", f"{p}.fc2.w", f"{p}.fc2.b"]
    names += ["ln_f.g", "ln_f.b"]
    return names


def param_shapes(cfg: VlmConfig) -> dict:
    dv, di_v = cfg.vision_d, 4 * cfg.vision_d
    t = cfg.text
    d, di, v = t.d_model, t.d_inner, t.vocab_size
    shapes = {
        "patch_emb.w": (dv, cfg.patch_dim),
        "patch_emb.b": (dv,),
        "vis_pos_emb": (cfg.n_patches, dv),
    }
    for i in range(cfg.vision_layers):
        p = f"vision.{i}"
        shapes[f"{p}.ln1.g"] = (dv,)
        shapes[f"{p}.ln1.b"] = (dv,)
        for lin in ("q", "k", "v", "o"):
            shapes[f"{p}.{lin}.w"] = (dv, dv)
            shapes[f"{p}.{lin}.b"] = (dv,)
        shapes[f"{p}.ln2.g"] = (dv,)
        shapes[f"{p}.ln2.b"] = (dv,)
        shapes[f"{p}.fc1.w"] = (di_v, dv)
        shapes[f"{p}.fc1.b"] = (di_v,)
        shapes[f"{p}.fc2.w"] = (dv, di_v)
        shapes[f"{p}.fc2.b"] = (dv,)
    shapes["vis_ln.g"] = (dv,)
    shapes["vis_ln.b"] = (dv,)
    shapes["proj.w"] = (d, dv)
    shapes["proj.b"] = (d,)

    shapes["tok_emb"] = (v, d)
    # text positions: prefix patches + question tokens
    shapes["pos_emb"] = (cfg.n_patches + t.max_seq_len, d)
    for i in range(t.n_layers):
        p = f"layers.{i}"
        shapes[f"{p}.ln1.g"] = (d,)
        shapes[f"{p}.ln1.b"] = (d,)
        for lin in ("q", "k", "v", "o"):
            shapes[f"{p}.{lin}.w"] = (d, d)
            shapes[f"{p}.{lin}.b"] = (d,)
        shapes[f"{p}.ln2.g"] = (d,)
        shapes[f"{p}.ln2.b"] = (d,)
        shapes[f"{p}.fc1.w"] = (di, d)
        shapes[f"{p}.fc1.b"] = (di,)
        shapes[f"{p}.fc2.w"] = (d, di)
        shapes[f"{p}.fc2.b"] = (d,)
    shapes["ln_f.g"] = (d,)
    shapes["ln_f.b"] = (d,)
    return shapes


def init_params(cfg: VlmConfig, key) -> dict:
    shapes = param_shapes(cfg)
    params = {}
    keys = jax.random.split(key, len(shapes))
    for k, (name, shape) in zip(keys, sorted(shapes.items())):
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b") and len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
    return params


def params_to_list(cfg, params):
    return [params[n] for n in param_order(cfg)]


def params_from_list(cfg, flat):
    return dict(zip(param_order(cfg), flat))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _ln(x2d, g, b, use_kernels):
    return kln.layernorm(x2d, g, b) if use_kernels else kref.layernorm(x2d, g, b)


def _linear(params, name, x2d, mumoe, norms, kc):
    w, b = params[f"{name}.w"], params[f"{name}.b"]
    if mumoe:
        return _mumoe_linear(x2d, w, b, norms, kc)
    return x2d @ w.T + b


def _block(params, prefix, h, heads, lengths, rho, causal, record=None):
    """One pre-LN transformer block shared by both towers.

    record(name, x2d): optional calibration-stat hook (see calib_stats).
    """
    b_, t_, d = h.shape
    mumoe = rho is not None
    hd = d // heads

    x2d = h.reshape(b_ * t_, d)
    y = _ln(x2d, params[f"{prefix}.ln1.g"], params[f"{prefix}.ln1.b"], mumoe)
    norms = kc = None
    if record is not None:
        for lin in ("q", "k", "v"):
            record(f"{prefix}.{lin}.w", y)
    if mumoe:
        norms = jnp.sqrt(kwanda.col_sq_sums(y))
        kc = _kc_for(d, rho)
    q = _linear(params, f"{prefix}.q", y, mumoe, norms, kc)
    k = _linear(params, f"{prefix}.k", y, mumoe, norms, kc)
    v = _linear(params, f"{prefix}.v", y, mumoe, norms, kc)
    q = q.reshape(b_, t_, heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b_, t_, heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b_, t_, heads, hd).transpose(0, 2, 1, 3)
    if causal:
        attn = kref.causal_attention(q, k, v, lengths)
    else:
        # bidirectional (vision tower): all positions valid
        scale = 1.0 / jnp.sqrt(jnp.asarray(hd, q.dtype))
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    attn = attn.transpose(0, 2, 1, 3).reshape(b_ * t_, d)
    if record is not None:
        record(f"{prefix}.o.w", attn)
    norms_o = jnp.sqrt(kwanda.col_sq_sums(attn)) if mumoe else None
    h = h + _linear(params, f"{prefix}.o", attn, mumoe, norms_o, kc).reshape(
        b_, t_, d
    )

    x2d = h.reshape(b_ * t_, d)
    y = _ln(x2d, params[f"{prefix}.ln2.g"], params[f"{prefix}.ln2.b"], mumoe)
    if record is not None:
        record(f"{prefix}.fc1.w", y)
    norms1 = jnp.sqrt(kwanda.col_sq_sums(y)) if mumoe else None
    z = jax.nn.relu(_linear(params, f"{prefix}.fc1", y, mumoe, norms1, kc))
    if record is not None:
        record(f"{prefix}.fc2.w", z)
    norms2 = jnp.sqrt(kwanda.col_sq_sums(z)) if mumoe else None
    kc2 = _kc_for(4 * d, rho) if mumoe else None
    h = h + _linear(params, f"{prefix}.fc2", z, mumoe, norms2, kc2).reshape(
        b_, t_, d
    )
    return h


def patchify(cfg: VlmConfig, images: jnp.ndarray) -> jnp.ndarray:
    """(B, H, W) grayscale -> (B, n_patches, patch_dim)."""
    b_ = images.shape[0]
    p = cfg.patch_size
    g = cfg.image_size // p
    x = images.reshape(b_, g, p, g, p)
    x = x.transpose(0, 1, 3, 2, 4)
    return x.reshape(b_, g * g, p * p)


def forward(cfg: VlmConfig, params, images, tokens, lengths, rho=None, record=None):
    """images: (B, H, W) f32; tokens: (B, Tq) i32; lengths: (B,) i32.

    Returns logits (B, n_patches + Tq, V). Answer logits live at position
    n_patches + length - 1.
    """
    b_, t_q = tokens.shape
    mumoe = rho is not None
    t_text = cfg.text

    # Vision tower
    patches = patchify(cfg, images)  # (B, P, pd)
    x2d = patches.reshape(b_ * cfg.n_patches, cfg.patch_dim)
    if record is not None:
        pass  # patch_emb is not in linear_names(); not pruned
    h = (x2d @ params["patch_emb.w"].T + params["patch_emb.b"]).reshape(
        b_, cfg.n_patches, cfg.vision_d
    )
    h = h + params["vis_pos_emb"][None]
    vlen = jnp.full((b_,), cfg.n_patches, jnp.int32)
    for i in range(cfg.vision_layers):
        h = _block(
            params, f"vision.{i}", h, cfg.vision_heads, vlen, rho, False, record
        )
    x2d = h.reshape(b_ * cfg.n_patches, cfg.vision_d)
    x2d = _ln(x2d, params["vis_ln.g"], params["vis_ln.b"], mumoe)
    if record is not None:
        record("proj.w", x2d)
    norms_p = jnp.sqrt(kwanda.col_sq_sums(x2d)) if mumoe else None
    kc_p = _kc_for(cfg.vision_d, rho) if mumoe else None
    prefix = _linear(params, "proj", x2d, mumoe, norms_p, kc_p).reshape(
        b_, cfg.n_patches, t_text.d_model
    )

    # Text decoder with image prefix
    tok = params["tok_emb"][tokens]
    h = jnp.concatenate([prefix, tok], axis=1)
    t_all = cfg.n_patches + t_q
    h = h + params["pos_emb"][None, :t_all, :]
    full_len = cfg.n_patches + lengths
    for i in range(t_text.n_layers):
        h = _block(
            params, f"layers.{i}", h, t_text.n_heads, full_len, rho, True, record
        )
    x2d = h.reshape(b_ * t_all, t_text.d_model)
    x2d = _ln(x2d, params["ln_f.g"], params["ln_f.b"], mumoe)
    hidden = x2d.reshape(b_, t_all, t_text.d_model)
    return hidden @ params["tok_emb"].T


def answer_logits(cfg: VlmConfig, params, images, tokens, lengths, rho=None):
    """Logits at the last question position: (B, V). The coordinator argmaxes
    these over the choice-letter tokens to grade multiple choice."""
    logits = forward(cfg, params, images, tokens, lengths, rho=rho)
    idx = cfg.n_patches + jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]


def calib_stats(cfg: VlmConfig, params, images, tokens, lengths, with_hessian=True):
    """Dense forward recording per-linear activation stats (cfg.linear_names()
    order): sum-of-squares per input feature, plus X^T X Hessians."""
    sq, hess = {}, {}

    def record(name, x2d):
        sq[name] = jnp.sum(x2d * x2d, axis=0)
        if with_hessian:
            hess[name] = x2d.T @ x2d

    forward(cfg, params, images, tokens, lengths, rho=None, record=record)
    names = cfg.linear_names()
    out = [sq[n] for n in names]
    if with_hessian:
        out += [hess[n] for n in names]
    return tuple(out)


def choice_nll(cfg: VlmConfig, params, images, tokens, lengths, ans_start, rho=None):
    """Sum NLL of the answer-continuation tokens: positions ans_start <= t <
    length of `tokens`, where the question ends with "Answer:" and the
    candidate choice text is appended after it.

    This is the standard LM multiple-choice scoring rule: grade each
    choice by the likelihood of its continuation and pick the argmin
    (rust/src/eval/vlm_harness.rs mirrors this). Returns (B,) sums.
    """
    logits = forward(cfg, params, images, tokens, lengths, rho=rho)
    b_, t_q = tokens.shape
    # position n_patches + t - 1 predicts text token t (t >= 1)
    pred = logits[:, cfg.n_patches : cfg.n_patches + t_q - 1, :]
    logp = jax.nn.log_softmax(pred, axis=-1)
    targets = tokens[:, 1:]
    tgt_lp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    t_idx = jnp.arange(1, t_q)
    sel = (t_idx[None, :] >= ans_start[:, None]) & (
        t_idx[None, :] < lengths[:, None]
    )
    return -jnp.sum(jnp.where(sel, tgt_lp, 0.0), axis=-1)


def answer_loss(cfg: VlmConfig, params, images, tokens, lengths, ans_start):
    """Mean per-token NLL of the correct answer continuation (training
    objective — teaches the model to produce the right choice text)."""
    sums = choice_nll(cfg, params, images, tokens, lengths, ans_start)
    counts = jnp.maximum(lengths - ans_start, 1)
    return jnp.mean(sums / counts)


def train_step(cfg: VlmConfig, params, m, v, step, images, tokens, lengths, ans_start, lr):
    loss, grads = jax.value_and_grad(
        lambda p: answer_loss(cfg, p, images, tokens, lengths, ans_start)
    )(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1.0
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = b1 * m[k] + (1 - b1) * g
        vk = b2 * v[k] + (1 - b2) * g * g
        new_p[k] = params[k] - lr * (mk / (1 - b1**t)) / (
            jnp.sqrt(vk / (1 - b2**t)) + eps
        )
        new_m[k], new_v[k] = mk, vk
    return loss, new_p, new_m, new_v
