"""MUCK checkpoint format — shared with rust/src/model/checkpoint.rs.

Layout (little-endian):
  magic   8 bytes  b"MUCKPT01"
  n       u32      tensor count
  per tensor:
    name_len u32, name utf-8 bytes
    ndim     u32, dims u64 * ndim
    data     f32 * prod(dims)
"""

import struct

import numpy as np

MAGIC = b"MUCKPT01"


def save(path: str, tensors: dict):
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name in sorted(tensors):
            arr = np.asarray(tensors[name], dtype=np.float32)
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<Q", d))
            f.write(arr.astype("<f4").tobytes())


def load(path: str) -> dict:
    out = {}
    with open(path, "rb") as f:
        assert f.read(8) == MAGIC, f"bad checkpoint magic in {path}"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (nl,) = struct.unpack("<I", f.read(4))
            name = f.read(nl).decode("utf-8")
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}Q", f.read(8 * ndim)) if ndim else ()
            count = 1
            for d in dims:
                count *= d
            data = np.frombuffer(f.read(4 * count), dtype="<f4").reshape(dims)
            out[name] = data.copy()
    return out
