"""AOT export: lower every runtime computation to HLO *text* + manifest.

This is the L2->L3 bridge. Each artifact is a jitted jax function lowered to
stablehlo and converted to an XlaComputation HLO text dump, which the rust
runtime parses with `HloModuleProto::from_text_file` and compiles on the
PJRT CPU client. Text (not `.serialize()`) is mandatory: jax >= 0.5 emits
protos with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

The manifest (artifacts/manifest.json) tells rust, per artifact: the HLO
file, the model, the static batch/seq shapes, the ordered parameter-tensor
names (fed as leading inputs from the MUCK checkpoint), the extra runtime
inputs, and the output arity. rust/src/runtime/registry.rs is the consumer —
keep formats in sync.

Usage: python -m compile.aot --out ../artifacts [--models micro,mini,...]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model, vlm
from .configs import (
    EVAL_BATCH,
    MAX_SEQ_LEN,
    MODEL_FAMILY,
    MU_VLM,
    SERVE_BATCH,
    VLM_BATCH,
    OPT_PAPER_TABLE,
)

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _param_specs(shapes: dict, order: list):
    return [_spec(shapes[n]) for n in order]


class Exporter:
    def __init__(self, out_dir):
        self.out = out_dir
        self.entries = []
        os.makedirs(f"{out_dir}/hlo", exist_ok=True)

    def export(self, name, fn, specs, meta):
        """Lower fn(*specs) to HLO text at hlo/{name}.hlo.txt."""
        path = f"hlo/{name}.hlo.txt"
        full = f"{self.out}/{path}"
        print(f"  lowering {name} ...", flush=True)
        # keep_unused=True: the artifact signature must match the manifest's
        # full parameter list even when a computation (e.g. calib_stats)
        # does not touch every tensor — rust feeds them positionally.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        with open(full, "w") as f:
            f.write(text)
        entry = dict(meta)
        entry.update(
            name=name,
            path=path,
            inputs=[
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
        )
        self.entries.append(entry)
        print(f"    -> {len(text)} chars", flush=True)

    def write_manifest(self, extra):
        manifest = dict(extra)
        manifest["artifacts"] = self.entries
        with open(f"{self.out}/manifest.json", "w") as f:
            json.dump(manifest, f, indent=1)


def export_lm(ex: Exporter, cfg, kinds):
    order = model.param_order(cfg)
    shapes = model.param_shapes(cfg)
    psl = _param_specs(shapes, order)
    np_ = len(order)
    t = MAX_SEQ_LEN

    def unpack(args, n_extra):
        params = model.params_from_list(cfg, list(args[:np_]))
        return params, args[np_:]

    base_meta = dict(
        model=cfg.name,
        params=order,
        seq_len=t,
    )

    if "dense_nll" in kinds:
        def dense_nll(*args):
            params, (toks, lens) = unpack(args, 2)
            return model.nll_sums(cfg, params, toks, lens)

        ex.export(
            f"dense_nll_{cfg.name}",
            dense_nll,
            psl + [_spec((EVAL_BATCH, t), I32), _spec((EVAL_BATCH,), I32)],
            dict(base_meta, kind="dense_nll", batch=EVAL_BATCH, outputs=2,
                 extra_inputs=["tokens", "lengths"]),
        )

    if "mumoe_nll" in kinds:
        def mumoe_nll(*args):
            params, (toks, lens, rho) = unpack(args, 3)
            return model.nll_sums(cfg, params, toks, lens, rho=rho)

        ex.export(
            f"mumoe_nll_{cfg.name}",
            mumoe_nll,
            psl
            + [
                _spec((EVAL_BATCH, t), I32),
                _spec((EVAL_BATCH,), I32),
                _spec((), F32),
            ],
            dict(base_meta, kind="mumoe_nll", batch=EVAL_BATCH, outputs=2,
                 extra_inputs=["tokens", "lengths", "rho"]),
        )

    if "dense_logits" in kinds:
        def dense_logits(*args):
            params, (toks, lens) = unpack(args, 2)
            return (model.last_logits(cfg, params, toks, lens),)

        ex.export(
            f"dense_logits_{cfg.name}",
            dense_logits,
            psl + [_spec((SERVE_BATCH, t), I32), _spec((SERVE_BATCH,), I32)],
            dict(base_meta, kind="dense_logits", batch=SERVE_BATCH, outputs=1,
                 extra_inputs=["tokens", "lengths"]),
        )

    if "mumoe_logits" in kinds:
        def mumoe_logits(*args):
            params, (toks, lens, rho) = unpack(args, 3)
            return (model.last_logits(cfg, params, toks, lens, rho=rho),)

        ex.export(
            f"mumoe_logits_{cfg.name}",
            mumoe_logits,
            psl
            + [
                _spec((SERVE_BATCH, t), I32),
                _spec((SERVE_BATCH,), I32),
                _spec((), F32),
            ],
            dict(base_meta, kind="mumoe_logits", batch=SERVE_BATCH, outputs=1,
                 extra_inputs=["tokens", "lengths", "rho"]),
        )

    if "calib_stats" in kinds:
        lin = cfg.linear_names()

        def calib(*args):
            params, (toks, lens) = unpack(args, 2)
            return model.calib_stats(cfg, params, toks, lens, with_hessian=True)

        ex.export(
            f"calib_stats_{cfg.name}",
            calib,
            psl + [_spec((EVAL_BATCH, t), I32), _spec((EVAL_BATCH,), I32)],
            dict(base_meta, kind="calib_stats", batch=EVAL_BATCH,
                 outputs=2 * len(lin), linears=lin,
                 extra_inputs=["tokens", "lengths"]),
        )

    if "train_step" in kinds:
        def tstep(*args):
            params = model.params_from_list(cfg, list(args[:np_]))
            m = model.params_from_list(cfg, list(args[np_ : 2 * np_]))
            v = model.params_from_list(cfg, list(args[2 * np_ : 3 * np_]))
            step, toks, lens, lr = args[3 * np_ :]
            loss, p2, m2, v2 = model.train_step(
                cfg, params, m, v, step, toks, lens, lr
            )
            return tuple(
                [loss]
                + model.params_to_list(cfg, p2)
                + model.params_to_list(cfg, m2)
                + model.params_to_list(cfg, v2)
            )

        tb = 16
        ex.export(
            f"train_step_{cfg.name}",
            tstep,
            psl * 3
            + [
                _spec((), F32),
                _spec((tb, t), I32),
                _spec((tb,), I32),
                _spec((), F32),
            ],
            dict(base_meta, kind="train_step", batch=tb, outputs=1 + 3 * np_,
                 extra_inputs=["step", "tokens", "lengths", "lr"]),
        )


def export_vlm(ex: Exporter, kinds):
    cfg = MU_VLM
    order = vlm.param_order(cfg)
    shapes = vlm.param_shapes(cfg)
    psl = _param_specs(shapes, order)
    np_ = len(order)
    tq = cfg.text.max_seq_len - 1  # question token budget (prefix uses pos)
    img = cfg.image_size

    base_meta = dict(model=cfg.name, params=order, seq_len=tq, batch=VLM_BATCH)

    if "vlm_dense" in kinds:
        def dense(*args):
            params = vlm.params_from_list(cfg, list(args[:np_]))
            images, toks, lens, starts = args[np_:]
            return (
                vlm.choice_nll(cfg, params, images, toks, lens, starts),
            )

        ex.export(
            "vlm_dense_nll",
            dense,
            psl
            + [
                _spec((VLM_BATCH, img, img), F32),
                _spec((VLM_BATCH, tq), I32),
                _spec((VLM_BATCH,), I32),
                _spec((VLM_BATCH,), I32),
            ],
            dict(base_meta, kind="vlm_dense_nll", outputs=1,
                 extra_inputs=["images", "tokens", "lengths", "ans_start"]),
        )

    if "vlm_mumoe" in kinds:
        def mumoe(*args):
            params = vlm.params_from_list(cfg, list(args[:np_]))
            images, toks, lens, starts, rho = args[np_:]
            return (
                vlm.choice_nll(
                    cfg, params, images, toks, lens, starts, rho=rho
                ),
            )

        ex.export(
            "vlm_mumoe_nll",
            mumoe,
            psl
            + [
                _spec((VLM_BATCH, img, img), F32),
                _spec((VLM_BATCH, tq), I32),
                _spec((VLM_BATCH,), I32),
                _spec((VLM_BATCH,), I32),
                _spec((), F32),
            ],
            dict(base_meta, kind="vlm_mumoe_nll", outputs=1,
                 extra_inputs=["images", "tokens", "lengths", "ans_start", "rho"]),
        )

    if "vlm_calib" in kinds:
        lin = cfg.linear_names()

        def calib(*args):
            params = vlm.params_from_list(cfg, list(args[:np_]))
            images, toks, lens = args[np_:]
            return vlm.calib_stats(cfg, params, images, toks, lens)

        ex.export(
            "vlm_calib_stats",
            calib,
            psl
            + [
                _spec((VLM_BATCH, img, img), F32),
                _spec((VLM_BATCH, tq), I32),
                _spec((VLM_BATCH,), I32),
            ],
            dict(base_meta, kind="vlm_calib_stats", outputs=2 * len(lin),
                 linears=lin, extra_inputs=["images", "tokens", "lengths"]),
        )


DEFAULT_LM_KINDS = (
    "dense_nll",
    "mumoe_nll",
    "dense_logits",
    "mumoe_logits",
    "calib_stats",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=None, help="comma list of model names")
    ap.add_argument("--skip-vlm", action="store_true")
    ap.add_argument("--skip-train-step", action="store_true")
    args = ap.parse_args()

    ex = Exporter(args.out)
    wanted = args.models.split(",") if args.models else list(MODEL_FAMILY)

    for name in wanted:
        cfg = MODEL_FAMILY[name]
        kinds = list(DEFAULT_LM_KINDS)
        # train_step triples the parameter I/O; export for micro only
        if name == "mu-opt-micro" and not args.skip_train_step:
            kinds.append("train_step")
        print(f"exporting {name}: {kinds}", flush=True)
        export_lm(ex, cfg, kinds)

    if not args.skip_vlm:
        print("exporting mu-vlm", flush=True)
        export_vlm(ex, ("vlm_dense", "vlm_mumoe", "vlm_calib"))

    ex.write_manifest(
        {
            "version": 1,
            "models": {c.name: c.to_dict() for c in MODEL_FAMILY.values()},
            "vlm": MU_VLM.to_dict(),
            "opt_paper_table": {
                k: {"layers": v[0], "heads": v[1], "d_model": v[2]}
                for k, v in OPT_PAPER_TABLE.items()
            },
            "specials": {"pad": 256, "bos": 257, "eos": 258, "vocab": 259},
        }
    )
    print(f"wrote manifest with {len(ex.entries)} artifacts")


if __name__ == "__main__":
    main()
