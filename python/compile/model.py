"""L2: the mu-OPT decoder in JAX — dense, masked (offline pruning) and
mu-MoE (online test-time pruning) forward variants, plus loss/train-step.

The mu-MoE variant is the paper's contribution: every linear layer scores
its weights against the *current prompt's* activation norms (Wanda, eq. 3),
thresholds per output row at the k_c-th smallest score (App. B kthvalue
formulation) and multiplies through the resulting micro-expert gate. The
sparsity rho enters as a runtime scalar so a single AOT artifact serves all
sparsity levels (DESIGN.md S6).

Parameters travel as a flat {name: array} dict; `param_order(cfg)` fixes the
canonical ordering used for AOT artifact signatures and the rust checkpoint
loader (rust/src/model/checkpoint.rs) — keep the three in sync.
"""

import functools

import jax
import jax.numpy as jnp

from .configs import ModelConfig, PAD_ID
from .kernels import attention as kattn
from .kernels import layernorm as kln
from .kernels import ref as kref
from .kernels import wanda as kwanda

# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def param_order(cfg: ModelConfig) -> list:
    """Canonical parameter name order for artifacts and checkpoints."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        names += [f"{p}.ln1.g", f"{p}.ln1.b"]
        for lin in ("q", "k", "v", "o"):
            names += [f"{p}.{lin}.w", f"{p}.{lin}.b"]
        names += [f"{p}.ln2.g", f"{p}.ln2.b"]
        names += [f"{p}.fc1.w", f"{p}.fc1.b", f"{p}.fc2.w", f"{p}.fc2.b"]
    names += ["ln_f.g", "ln_f.b"]
    return names


def param_shapes(cfg: ModelConfig) -> dict:
    d, di, v, t = cfg.d_model, cfg.d_inner, cfg.vocab_size, cfg.max_seq_len
    shapes = {"tok_emb": (v, d), "pos_emb": (t, d)}
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        shapes[f"{p}.ln1.g"] = (d,)
        shapes[f"{p}.ln1.b"] = (d,)
        for lin in ("q", "k", "v", "o"):
            shapes[f"{p}.{lin}.w"] = (d, d)
            shapes[f"{p}.{lin}.b"] = (d,)
        shapes[f"{p}.ln2.g"] = (d,)
        shapes[f"{p}.ln2.b"] = (d,)
        shapes[f"{p}.fc1.w"] = (di, d)
        shapes[f"{p}.fc1.b"] = (di,)
        shapes[f"{p}.fc2.w"] = (d, di)
        shapes[f"{p}.fc2.b"] = (d,)
    shapes["ln_f.g"] = (d,)
    shapes["ln_f.b"] = (d,)
    return shapes


def init_params(cfg: ModelConfig, key) -> dict:
    """OPT-style init: N(0, 0.02) for weights, zeros for biases, ones for LN
    scales."""
    shapes = param_shapes(cfg)
    params = {}
    keys = jax.random.split(key, len(shapes))
    for k, (name, shape) in zip(keys, sorted(shapes.items())):
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b") and len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name == "pos_emb":
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
        else:
            params[name] = 0.02 * jax.random.normal(k, shape, jnp.float32)
    return params


def params_to_list(cfg: ModelConfig, params: dict) -> list:
    return [params[n] for n in param_order(cfg)]


def params_from_list(cfg: ModelConfig, flat: list) -> dict:
    return dict(zip(param_order(cfg), flat))


# ---------------------------------------------------------------------------
# Linear-layer strategies (the micro-expert gating point)
# ---------------------------------------------------------------------------


def _dense_linear(x2d, w, b, _norms, _kc):
    return x2d @ w.T + b


def _mumoe_linear(x2d, w, b, norms, k_inactive):
    """Online Wanda gate + fused masked matmul (L1 Pallas kernels).

    `norms` is the per-feature l2 norm of the *current* activations —
    computed once per distinct input (q/k/v share theirs) by the caller.
    """
    s = kwanda.wanda_score(w, norms)
    thr = kref.row_kth_threshold(s, k_inactive)
    return kwanda.prune_matmul(x2d, w, b, norms, thr)


def _kc_for(d_in: int, rho):
    """Number of *inactive* weights per row: k_c = floor((1-rho) d_in),
    clipped to [0, d_in-1] so rho=0 still keeps one weight per row."""
    kc = jnp.floor((1.0 - rho) * d_in).astype(jnp.int32)
    return jnp.clip(kc, 0, d_in - 1)


# ---------------------------------------------------------------------------
# Transformer forward
# ---------------------------------------------------------------------------


def _ln(x2d, g, b, use_kernels=False):
    """Pallas layernorm on the mu-MoE path; pure-jnp on the dense/training
    path (interpret-mode pallas_call has no autodiff rules, and the dense
    baseline should be exactly the plain-XLA reference)."""
    if use_kernels:
        return kln.layernorm(x2d, g, b)
    return kref.layernorm(x2d, g, b)


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    rho=None,
):
    """Returns final-LN hidden states (B, T, d) and logits (B, T, V).

    rho=None -> dense path (plain XLA matmuls; also the offline-pruned path,
    where the host has already zeroed weights). rho=scalar -> mu-MoE online
    pruning of every linear layer, through the L1 Pallas kernels.
    """
    b_, t_ = tokens.shape
    d = cfg.d_model
    mumoe = rho is not None
    attn_fn = kattn.causal_attention if mumoe else kref.causal_attention

    tok_emb = params["tok_emb"]
    h = tok_emb[tokens] + params["pos_emb"][None, :t_, :]

    def linear(x2d, name, norms, kc):
        w, bb = params[f"{name}.w"], params[f"{name}.b"]
        if mumoe:
            return _mumoe_linear(x2d, w, bb, norms, kc)
        return _dense_linear(x2d, w, bb, None, None)

    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        x2d = h.reshape(b_ * t_, d)
        y = _ln(x2d, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"], mumoe)

        norms = kc = None
        if mumoe:
            norms = jnp.sqrt(kwanda.col_sq_sums(y))
            kc = _kc_for(d, rho)
        q = linear(y, f"{p}.q", norms, kc)
        k = linear(y, f"{p}.k", norms, kc)
        v = linear(y, f"{p}.v", norms, kc)

        hd = cfg.head_dim
        q = q.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        attn = attn_fn(q, k, v, lengths)
        attn = attn.transpose(0, 2, 1, 3).reshape(b_ * t_, d)

        if mumoe:
            norms_o = jnp.sqrt(kwanda.col_sq_sums(attn))
        else:
            norms_o = None
        h = h + linear(attn, f"{p}.o", norms_o, kc).reshape(b_, t_, d)

        x2d = h.reshape(b_ * t_, d)
        y = _ln(x2d, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"], mumoe)
        if mumoe:
            norms1 = jnp.sqrt(kwanda.col_sq_sums(y))
        else:
            norms1 = None
        z = linear(y, f"{p}.fc1", norms1, kc)
        z = jax.nn.relu(z)
        if mumoe:
            norms2 = jnp.sqrt(kwanda.col_sq_sums(z))
            kc2 = _kc_for(cfg.d_inner, rho)
        else:
            norms2 = kc2 = None
        h = h + linear(z, f"{p}.fc2", norms2, kc2).reshape(b_, t_, d)

    x2d = h.reshape(b_ * t_, d)
    x2d = _ln(x2d, params["ln_f.g"], params["ln_f.b"], mumoe)
    hidden = x2d.reshape(b_, t_, d)
    logits = hidden @ tok_emb.T  # tied LM head (OPT ties embeddings)
    return hidden, logits


# ---------------------------------------------------------------------------
# Evaluation / serving heads
# ---------------------------------------------------------------------------


def nll_sums(cfg: ModelConfig, params, tokens, lengths, rho=None):
    """Per-sequence (sum of next-token NLL, predicted-token count).

    Position t predicts token t+1; only positions t+1 < length count.
    Returns (B,) f32 sums and (B,) i32 counts — the rust evaluator
    aggregates exp(sum/count) into perplexity without shipping logits.
    """
    _, logits = forward(cfg, params, tokens, lengths, rho=rho)
    b_, t_ = tokens.shape
    logp = jax.nn.log_softmax(logits[:, :-1, :], axis=-1)
    targets = tokens[:, 1:]
    tgt_lp = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    pos = jnp.arange(t_ - 1)
    valid = (pos[None, :] + 1) < lengths[:, None]
    nll = -jnp.where(valid, tgt_lp, 0.0)
    return jnp.sum(nll, axis=-1), jnp.sum(valid.astype(jnp.int32), axis=-1)


def last_logits(cfg: ModelConfig, params, tokens, lengths, rho=None):
    """Next-token logits at each sequence's last valid position: (B, V).
    This is the serving head used by the coordinator's generate path."""
    _, logits = forward(cfg, params, tokens, lengths, rho=rho)
    idx = jnp.clip(lengths - 1, 0, tokens.shape[1] - 1)
    return jnp.take_along_axis(
        logits, idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]


# ---------------------------------------------------------------------------
# Calibration statistics (offline pruning path)
# ---------------------------------------------------------------------------


def calib_stats(cfg: ModelConfig, params, tokens, lengths, with_hessian=True):
    """Dense forward that records, for every prunable linear, the activation
    statistics offline pruners need: per-feature sum of squares (Wanda) and,
    optionally, the full empirical Hessian X X^T (SparseGPT).

    Padding tokens are zero-weighted so they do not pollute the statistics.
    Outputs are ordered by cfg.linear_names().
    """
    b_, t_ = tokens.shape
    d = cfg.d_model
    pos = jnp.arange(t_)
    valid = (pos[None, :] < lengths[:, None]).astype(jnp.float32)
    vmask = valid.reshape(b_ * t_, 1)

    sq, hess = {}, {}

    def record(name, x2d):
        x = x2d * vmask
        sq[name] = jnp.sum(x * x, axis=0)
        if with_hessian:
            hess[name] = x.T @ x

    h = params["tok_emb"][tokens] + params["pos_emb"][None, :t_, :]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        x2d = h.reshape(b_ * t_, d)
        y = _ln(x2d, params[f"{p}.ln1.g"], params[f"{p}.ln1.b"])
        for lin in ("q", "k", "v"):
            record(f"{p}.{lin}.w", y)
        q = _dense_linear(y, params[f"{p}.q.w"], params[f"{p}.q.b"], None, None)
        k = _dense_linear(y, params[f"{p}.k.w"], params[f"{p}.k.b"], None, None)
        v = _dense_linear(y, params[f"{p}.v.w"], params[f"{p}.v.b"], None, None)
        hd = cfg.head_dim
        q = q.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        k = k.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        v = v.reshape(b_, t_, cfg.n_heads, hd).transpose(0, 2, 1, 3)
        attn = kref.causal_attention(q, k, v, lengths)
        attn = attn.transpose(0, 2, 1, 3).reshape(b_ * t_, d)
        record(f"{p}.o.w", attn)
        h = h + _dense_linear(
            attn, params[f"{p}.o.w"], params[f"{p}.o.b"], None, None
        ).reshape(b_, t_, d)

        x2d = h.reshape(b_ * t_, d)
        y = _ln(x2d, params[f"{p}.ln2.g"], params[f"{p}.ln2.b"])
        record(f"{p}.fc1.w", y)
        z = jax.nn.relu(
            _dense_linear(y, params[f"{p}.fc1.w"], params[f"{p}.fc1.b"], None, None)
        )
        record(f"{p}.fc2.w", z)
        h = h + _dense_linear(
            z, params[f"{p}.fc2.w"], params[f"{p}.fc2.b"], None, None
        ).reshape(b_, t_, d)

    names = cfg.linear_names()
    out = [sq[n] for n in names]
    if with_hessian:
        out += [hess[n] for n in names]
    return tuple(out)


# ---------------------------------------------------------------------------
# Training (build-time only; also AOT-exported for examples/train_synth.rs)
# ---------------------------------------------------------------------------


def loss_fn(cfg: ModelConfig, params, tokens, lengths):
    sums, counts = nll_sums(cfg, params, tokens, lengths, rho=None)
    return jnp.sum(sums) / jnp.maximum(jnp.sum(counts), 1)


def adam_init(params: dict):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return zeros, {k: jnp.zeros_like(v) for k, v in zeros.items()}


@functools.partial(jax.jit, static_argnums=0)
def train_step(cfg: ModelConfig, params, m, v, step, tokens, lengths, lr):
    """One Adam step; returns (loss, params', m', v'). b1=0.9 b2=0.999."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens, lengths))(
        params
    )
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1.0
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        g = grads[k]
        mk = b1 * m[k] + (1 - b1) * g
        vk = b2 * v[k] + (1 - b2) * g * g
        mhat = mk / (1 - b1**t)
        vhat = vk / (1 - b2**t)
        new_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_m[k], new_v[k] = mk, vk
    return loss, new_p, new_m, new_v


def pad_batch(seqs, max_len, pad_id=PAD_ID):
    """Right-pad a list of python int lists to (B, max_len) + lengths."""
    import numpy as np

    b = len(seqs)
    out = np.full((b, max_len), pad_id, dtype=np.int32)
    lens = np.zeros((b,), dtype=np.int32)
    for i, s in enumerate(seqs):
        s = s[:max_len]
        out[i, : len(s)] = s
        lens[i] = len(s)
    return jnp.asarray(out), jnp.asarray(lens)
