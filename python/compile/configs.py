"""Model family configuration for the mu-MoE reproduction.

The paper evaluates the OPT family (125M..13B, paper Table 5). The sandbox
has no model hub and no accelerator, so we train a scaled-down family with
the *same architecture* (decoder-only, pre-LN, learned positional embeddings,
ReLU FFN with d_i = 4d) from scratch on synthetic corpora. See DESIGN.md S2.
"""

from dataclasses import dataclass, field, asdict

# Byte-level vocabulary: 256 raw bytes + PAD/BOS/EOS specials.
PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259

MAX_SEQ_LEN = 128


@dataclass(frozen=True)
class ModelConfig:
    """mu-OPT model hyperparameters (mirrors paper Table 5 columns)."""

    name: str
    n_layers: int
    n_heads: int
    d_model: int
    max_seq_len: int = MAX_SEQ_LEN
    vocab_size: int = VOCAB_SIZE

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return 4 * self.d_model

    def n_params(self) -> int:
        """Total trainable parameter count (embeddings tied to LM head)."""
        d, di = self.d_model, self.d_inner
        per_layer = (
            4 * (d * d + d)  # q, k, v, o projections + biases
            + (di * d + di)  # fc1
            + (d * di + d)  # fc2
            + 4 * d  # ln1, ln2 scale+bias
        )
        emb = self.vocab_size * d + self.max_seq_len * d
        final_ln = 2 * d
        return self.n_layers * per_layer + emb + final_ln

    def linear_names(self) -> list:
        """Canonical order of prunable linear weights (all linears, as in
        the paper: 'we compress all linear layers in LLM transformers')."""
        names = []
        for i in range(self.n_layers):
            for lin in ("q", "k", "v", "o", "fc1", "fc2"):
                names.append(f"layers.{i}.{lin}.w")
        return names

    def to_dict(self) -> dict:
        d = asdict(self)
        d["head_dim"] = self.head_dim
        d["d_inner"] = self.d_inner
        d["n_params"] = self.n_params()
        return d


# The mu-OPT family. Scale ladder mirrors OPT's (each step ~2-4x params),
# shrunk to what a CPU sandbox can train in minutes.
MU_OPT_MICRO = ModelConfig("mu-opt-micro", n_layers=4, n_heads=4, d_model=128)
MU_OPT_MINI = ModelConfig("mu-opt-mini", n_layers=6, n_heads=6, d_model=192)
MU_OPT_SMALL = ModelConfig("mu-opt-small", n_layers=8, n_heads=8, d_model=256)

MODEL_FAMILY = {
    c.name: c for c in (MU_OPT_MICRO, MU_OPT_MINI, MU_OPT_SMALL)
}


@dataclass(frozen=True)
class VlmConfig:
    """mu-VLM: a patch-embed vision tower feeding a mu-OPT text decoder,
    standing in for LLaVA-7B (vision tower + Vicuna)."""

    name: str = "mu-vlm"
    image_size: int = 24
    patch_size: int = 4
    vision_layers: int = 2
    vision_heads: int = 4
    vision_d: int = 128
    text: ModelConfig = field(
        default_factory=lambda: ModelConfig(
            "mu-vlm-text", n_layers=4, n_heads=4, d_model=128
        )
    )

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size

    def linear_names(self) -> list:
        names = []
        for i in range(self.vision_layers):
            for lin in ("q", "k", "v", "o", "fc1", "fc2"):
                names.append(f"vision.{i}.{lin}.w")
        names.append("proj.w")
        names.extend(self.text.linear_names())
        return names

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "image_size": self.image_size,
            "patch_size": self.patch_size,
            "vision_layers": self.vision_layers,
            "vision_heads": self.vision_heads,
            "vision_d": self.vision_d,
            "n_patches": self.n_patches,
            "text": self.text.to_dict(),
        }


MU_VLM = VlmConfig()

# Static batch shapes baked into each artifact kind (PJRT programs have
# static shapes; the coordinator pads to these).
EVAL_BATCH = 8  # *_nll artifacts (perplexity evaluation)
SERVE_BATCH = 4  # *_logits artifacts (next-token serving)
VLM_BATCH = 8

# Paper Table 4 uses OPT-17B-like shapes analytically; we expose the OPT
# table so the rust flops counter can extrapolate to paper scale.
OPT_PAPER_TABLE = {
    # name: (layers, heads, d_model)
    "opt-125m": (12, 12, 768),
    "opt-350m": (24, 16, 1024),
    "opt-1.3b": (24, 32, 2048),
    "opt-2.7b": (32, 32, 2560),
    "opt-6.7b": (32, 32, 4096),
    "opt-13b": (40, 40, 5120),
    "opt-30b": (48, 56, 7168),
    "opt-66b": (64, 72, 9216),
    "opt-175b": (96, 96, 12288),
}
