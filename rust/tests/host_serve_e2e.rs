//! Integration: the full coordinator on the **host engine** — router →
//! rotating batcher → serve loop → `HostEngine` batched decode through
//! the router's shared layout cache. No artifacts, no `pjrt` feature:
//! the engine falls back to the deterministic random model, so every
//! response can be cross-checked token-for-token against a direct
//! `decode_greedy` on the same weights.

use mumoe::config::{EngineKind, ServeConfig};
use mumoe::coordinator::engine::HOST_FALLBACK_SEED;
use mumoe::coordinator::{Metrics, Router, Server};
use mumoe::decode::{decode_greedy, DecodeConfig};
use mumoe::model::config_by_name;
use mumoe::model::tokenizer::ByteTokenizer;
use mumoe::nn::{random_model, Model};
use mumoe::pruning::MaskPlan;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;

fn serve_cfg() -> ServeConfig {
    let mut cfg = ServeConfig {
        model: "mu-opt-micro".into(),
        // point at nothing so the engine deterministically falls back to
        // the random model regardless of whether artifacts were built
        artifacts_dir: "host-serve-e2e-no-artifacts".into(),
        engine: EngineKind::Host,
        rho_levels: vec![0.4, 0.6, 1.0],
        batch_window_us: 500,
        queue_cap: 64,
        ..Default::default()
    };
    cfg.decode.default_max_new = 2;
    cfg.decode.max_new_cap = 8;
    cfg.decode.batch_size = 4;
    // benches/tests compare against decode_greedy with a fixed step count
    cfg.decode.stop_at_eos = false;
    cfg
}

/// The exact model the engine's fallback path builds.
fn reference_model() -> Model {
    random_model(
        &config_by_name("mu-opt-micro").expect("known model"),
        HOST_FALLBACK_SEED,
    )
}

#[test]
fn batched_host_serving_matches_direct_decode() {
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone())
        .expect("router config");
    let handle = Server::start(&router).expect("host server");

    // mixed ρ, mixed max_new — all at configured levels so the reference
    // decode sees exactly the snapped ρ the engine executed (kept small:
    // every request pays real host forwards in a debug-profile test)
    let cases: Vec<(String, f64, usize)> = (0..6)
        .map(|i| {
            let rho = [0.4, 0.6, 1.0][i % 3];
            let max_new = 1 + (i % 3);
            (format!("tyrolia record {i} is "), rho, max_new)
        })
        .collect();

    let (tx, rx) = channel();
    let mut submitted = Vec::new();
    for (prompt, rho, max_new) in &cases {
        let req = router
            .admit_decode(prompt, *rho, "synth_wiki", *max_new, None, None, None, Some(tx.clone()))
            .expect("admit");
        submitted.push(req.id);
        handle.submit(req).expect("submit");
    }
    drop(tx);

    let model = reference_model();
    let tok = ByteTokenizer;
    let mut seen = 0usize;
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
        assert!(resp.is_ok(), "rejected: {:?}", resp.rejected);
        let idx = submitted
            .iter()
            .position(|&id| id == resp.id)
            .expect("known id");
        let (prompt, rho, max_new) = &cases[idx];
        let prompt_ids = tok.encode(prompt, true);
        // reference decodes without kv: the serve path's KV decode must
        // reproduce the plain full-window semantics token-for-token
        let reference = decode_greedy(
            &model,
            &prompt_ids,
            &DecodeConfig {
                rho: *rho,
                plan: MaskPlan::PruneOnce,
                max_new: *max_new,
                stop_at_eos: false,
                kv_cache: false,
            },
            None,
        );
        assert_eq!(
            resp.tokens,
            reference.new_tokens(),
            "request {idx} diverged from direct decode_greedy"
        );
        assert_eq!(resp.steps, *max_new);
        assert_eq!(resp.next_token, reference.new_tokens()[0]);
        assert_eq!(resp.logits, reference.steps.last().unwrap().logits);
        assert!((resp.rho_used - rho).abs() < 1e-9);
        assert!(resp.batch_size >= 1);
        seen += 1;
    }
    assert_eq!(seen, cases.len());
    handle.shutdown().expect("shutdown");

    assert_eq!(metrics.completed.load(Ordering::Relaxed), cases.len() as u64);
    let total_tokens: usize = cases.iter().map(|c| c.2).sum();
    let levels = metrics.level_stats();
    assert_eq!(levels.len(), 3, "all three ρ levels served");
    let level_tokens: u64 = levels.iter().map(|(_, st)| st.tokens).sum();
    assert_eq!(level_tokens, total_tokens as u64);
    assert!(metrics.decode_tokens_per_sec() > 0.0);
    // the prefill/step attribution flows engine → response → metrics;
    // every request pays at least a selection pass (mu-opt-micro at
    // these prompt lengths is far above timer resolution)
    let level_prefill: u64 = levels.iter().map(|(_, st)| st.prefill_us).sum();
    assert!(level_prefill > 0, "prefill time must be attributed per level");
    let (prefill_total, step_total) = metrics.decode_time_split_us();
    assert_eq!(prefill_total, level_prefill);
    let level_step: u64 = levels.iter().map(|(_, st)| st.step_us).sum();
    assert_eq!(step_total, level_step);
}

#[test]
fn warm_cache_hits_rise_across_repeated_requests() {
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router");
    let handle = Server::start(&router).expect("host server");
    let cache = router.layout_cache();

    let send_one = || {
        let (tx, rx) = channel();
        let req = router
            .admit_decode("a repeated prompt", 0.6, "synth_wiki", 2, None, None, None, Some(tx))
            .expect("admit");
        handle.submit(req).expect("submit");
        let resp = rx
            .recv_timeout(Duration::from_secs(60))
            .expect("response");
        assert!(resp.is_ok());
        resp
    };

    let first = send_one();
    let (hits_cold, misses_cold) = {
        let c = cache.lock().unwrap();
        (c.hits(), c.misses())
    };
    assert!(misses_cold > 0, "cold request must compress layouts");

    let second = send_one();
    let (hits_warm, misses_warm) = {
        let c = cache.lock().unwrap();
        (c.hits(), c.misses())
    };
    assert_eq!(first.tokens, second.tokens, "deterministic decode");
    assert!(
        hits_warm > hits_cold,
        "repeated request must hit the shared layout cache"
    );
    assert_eq!(
        misses_warm, misses_cold,
        "repeated request must not recompress anything"
    );
    handle.shutdown().expect("shutdown");
}

/// Decode a prompt directly on the reference model (the serve path must
/// reproduce this token-for-token whatever the scheduling did).
fn reference_decode(prompt: &str, rho: f64, max_new: usize) -> Vec<i32> {
    let ids = ByteTokenizer.encode(prompt, true);
    decode_greedy(
        &reference_model(),
        &ids,
        &DecodeConfig {
            rho,
            plan: MaskPlan::PruneOnce,
            max_new,
            stop_at_eos: false,
            kv_cache: false,
        },
        None,
    )
    .new_tokens()
    .to_vec()
}

#[test]
fn streamed_events_concatenate_to_response_tokens() {
    // both serve modes must deliver the same stream contract: one
    // StepEvent per generated token, dense indices, concatenating to
    // exactly the terminal Response::tokens
    for continuous in [true, false] {
        let mut cfg = serve_cfg();
        cfg.decode.continuous = continuous;
        let metrics = Arc::new(Metrics::new());
        let router =
            Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router config");
        let handle = Server::start(&router).expect("host server");

        let (tx, rx) = channel();
        let (stx, srx) = channel();
        let req = router
            .admit_decode("stream this back", 0.6, "synth_wiki", 4, None, None, Some(stx), Some(tx))
            .expect("admit");
        let id = req.id;
        handle.submit(req).expect("submit");

        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert!(resp.is_ok());
        assert_eq!(resp.steps, 4);
        // the serve loop drops the stream sender with the lane, so the
        // iterator terminates once every event is in
        let events: Vec<_> = srx.iter().collect();
        assert_eq!(events.len(), resp.tokens.len());
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.id, id, "continuous={continuous}");
            assert_eq!(ev.index, i, "continuous={continuous}: dense indices");
        }
        let streamed: Vec<i32> = events.iter().map(|e| e.token).collect();
        assert_eq!(
            streamed, resp.tokens,
            "continuous={continuous}: stream must concatenate to tokens"
        );
        assert_eq!(
            resp.tokens,
            reference_decode("stream this back", 0.6, 4),
            "continuous={continuous}: scheduling must not change tokens"
        );
        handle.shutdown().expect("shutdown");
    }
}

#[test]
fn cancellation_frees_lane_admits_queued_request_and_is_recorded() {
    // single-lane pool: the queued request can only run if cancelling the
    // in-flight one actually frees its lane mid-generation
    let mut cfg = serve_cfg();
    cfg.decode.batch_size = 1;
    cfg.decode.max_new_cap = 256;
    let metrics = Arc::new(Metrics::new());
    let router =
        Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone()).expect("router config");
    let handle = Server::start(&router).expect("host server");

    // A: long-running streaming request holding the only lane. 256 steps
    // of real host forwards take seconds; the test thread cancels within
    // microseconds of A's first token, so A finishing naturally before
    // the cancel is observed would need the thread descheduled for
    // essentially the whole generation — the cancel always lands
    // mid-flight in practice.
    let (atx, arx) = channel();
    let (astx, asrx) = channel();
    let a = router
        .admit_decode("the long one", 0.6, "synth_wiki", 256, None, None, Some(astx), Some(atx))
        .expect("admit A");
    let a_id = a.id;
    let a_cancel = a.cancel.clone();
    handle.submit(a).expect("submit A");

    // A's first streamed token proves its lane is running
    let first = asrx.recv_timeout(Duration::from_secs(60)).expect("A streams");
    assert_eq!(first.index, 0);

    // B queues behind A at the same ρ level, then A is cancelled
    let (btx, brx) = channel();
    let b = router
        .admit_decode("the queued one", 0.6, "synth_wiki", 2, None, None, None, Some(btx))
        .expect("admit B");
    handle.submit(b).expect("submit B");
    a_cancel.cancel();

    // A gets a terminal cancelled response carrying exactly what was
    // streamed before the cancel was observed
    let a_resp = arx.recv_timeout(Duration::from_secs(60)).expect("A terminal");
    assert!(a_resp.is_cancelled(), "rejected: {:?}", a_resp.rejected);
    assert!(!a_resp.is_ok());
    assert_eq!(a_resp.id, a_id);
    assert!(
        a_resp.steps < 256,
        "A must have been cut short, ran {} steps",
        a_resp.steps
    );
    let mut streamed = vec![first.token];
    streamed.extend(asrx.iter().map(|e| e.token));
    assert_eq!(streamed, a_resp.tokens, "stream must match the partial");

    // B rode the freed lane and decodes exactly like a direct call
    let b_resp = brx.recv_timeout(Duration::from_secs(60)).expect("B response");
    assert!(b_resp.is_ok(), "rejected: {:?}", b_resp.rejected);
    assert_eq!(b_resp.tokens, reference_decode("the queued one", 0.6, 2));
    handle.shutdown().expect("shutdown");

    // the cancellation and the admission-into-running-pool are observable
    assert_eq!(metrics.cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 1, "only B completed");
    let levels = metrics.level_stats();
    let (_, l06) = levels
        .iter()
        .find(|(r, _)| (r - 0.6).abs() < 1e-9)
        .expect("0.6 level served");
    assert!(
        l06.admitted_running >= 1,
        "B must have been admitted into the running pool"
    );
    assert!(metrics.lane_occupancy() > 0.0, "sweeps must be sampled");
}

#[test]
fn mixed_workload_fuses_shared_layouts_and_keeps_tokens_identical() {
    // Matrix-major sweeps: a continuous pool carrying two lanes with the
    // SAME prompt/plan (they share every compressed layout via the
    // router's cache, so their steps fuse into one batched matmul per
    // linear) plus two divergent lanes (different prompts; one on
    // Refresh(2), whose refresh steps keep splitting it out of any
    // group). Fusion must never change tokens, and the fused-width
    // metrics must prove it actually engaged (> 1 on the shared cells).
    let mut cfg = serve_cfg();
    cfg.decode.continuous = true;
    cfg.decode.batch_size = 4;
    // wide batching window so all four requests seed ONE pool run — the
    // batcher still fires early the moment the batch fills
    cfg.batch_window_us = 200_000;
    let metrics = Arc::new(Metrics::new());
    let router =
        Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone()).expect("router config");
    let handle = Server::start(&router).expect("host server");

    let cases: [(&str, MaskPlan, usize); 4] = [
        ("the fused twin prompt", MaskPlan::PruneOnce, 6),
        ("the fused twin prompt", MaskPlan::PruneOnce, 6),
        ("a diverging refresher", MaskPlan::Refresh(2), 6),
        ("a third odd one out", MaskPlan::PruneOnce, 3),
    ];
    let (tx, rx) = channel();
    let mut submitted = Vec::new();
    for (prompt, plan, max_new) in &cases {
        let req = router
            .admit_decode(
                prompt,
                0.6,
                "synth_wiki",
                *max_new,
                Some(*plan),
                None,
                None,
                Some(tx.clone()),
            )
            .expect("admit");
        submitted.push(req.id);
        handle.submit(req).expect("submit");
    }
    drop(tx);

    let model = reference_model();
    let tok = ByteTokenizer;
    let mut seen = 0usize;
    while let Ok(resp) = rx.recv_timeout(Duration::from_secs(60)) {
        assert!(resp.is_ok(), "rejected: {:?}", resp.rejected);
        let idx = submitted
            .iter()
            .position(|&id| id == resp.id)
            .expect("known id");
        let (prompt, plan, max_new) = cases[idx];
        let reference = decode_greedy(
            &model,
            &tok.encode(prompt, true),
            &DecodeConfig {
                rho: 0.6,
                plan,
                max_new,
                stop_at_eos: false,
                kv_cache: false,
            },
            None,
        );
        assert_eq!(
            resp.tokens,
            reference.new_tokens(),
            "request {idx}: fusion must not change tokens"
        );
        assert_eq!(resp.steps, max_new);
        seen += 1;
    }
    assert_eq!(seen, cases.len());
    handle.shutdown().expect("shutdown");

    let levels = metrics.level_stats();
    let (_, l06) = levels
        .iter()
        .find(|(r, _)| (r - 0.6).abs() < 1e-9)
        .expect("0.6 level served");
    assert!(l06.fused_groups > 0, "sweeps must report execution groups");
    assert!(
        l06.fused_width_hist[1..].iter().sum::<u64>() > 0,
        "the same-layout twins must have fused at width > 1: {:?}",
        l06.fused_width_hist
    );
    assert!(
        l06.fused_width_hist[0] > 0,
        "divergent lanes and refresh steps must stay singleton cells"
    );
    assert!(
        l06.mean_fused_width() > 1.0,
        "mean fused width must rise above lane-major's 1.0"
    );
    assert!(metrics.mean_fused_width() > 1.0);
}

#[test]
fn submit_after_shutdown_returns_error_not_panic() {
    // regression: submit used to panic via expect() once the sender was
    // taken — a network front-end races requests against shutdown
    // constantly, so the race must surface as a recoverable error
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router");
    let handle = Server::start(&router).expect("host server");
    handle.shutdown().expect("shutdown");

    let req = router
        .admit_decode("too late", 0.6, "synth_wiki", 1, None, None, None, None)
        .expect("admission is independent of the serve loop");
    let err = handle.submit(req).expect_err("submit after shutdown");
    assert!(
        err.to_string().contains("shut down"),
        "error should say the server is gone: {err}"
    );
    // shutdown is idempotent: a second call is an Ok no-op
    handle.shutdown().expect("second shutdown");
}

#[test]
fn dropped_stream_receiver_evicts_lane_and_records_cancel() {
    // single-lane pool: request B can only run if dropping A's StepEvent
    // receiver (the client hung up mid-stream) implicitly cancels A and
    // frees its lane — instead of decoding 256 tokens nobody will read
    let mut cfg = serve_cfg();
    cfg.decode.batch_size = 1;
    cfg.decode.max_new_cap = 256;
    let metrics = Arc::new(Metrics::new());
    let router =
        Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone()).expect("router config");
    let handle = Server::start(&router).expect("host server");

    let (atx, arx) = channel();
    let (astx, asrx) = channel();
    let a = router
        .admit_decode(
            "the abandoned one",
            0.6,
            "synth_wiki",
            256,
            None,
            None,
            Some(astx),
            Some(atx),
        )
        .expect("admit A");
    let a_id = a.id;
    handle.submit(a).expect("submit A");

    // wait for A's first token so the drop lands mid-generation, then
    // hang up: no explicit CancelToken, just a dead receiver
    let first = asrx.recv_timeout(Duration::from_secs(60)).expect("A streams");
    assert_eq!(first.index, 0);
    drop(asrx);

    // the serve loop notices the dead stream on its next send, cancels
    // the lane, and records a terminal cancelled response
    let a_resp = arx.recv_timeout(Duration::from_secs(60)).expect("A terminal");
    assert!(
        a_resp.is_cancelled(),
        "dead receiver must cancel, got {:?}",
        a_resp.rejected
    );
    assert_eq!(a_resp.id, a_id);
    assert!(
        a_resp.steps < 256,
        "A must have been cut short, ran {} steps",
        a_resp.steps
    );

    // the freed lane serves B normally
    let (btx, brx) = channel();
    let b = router
        .admit_decode("the next client", 0.6, "synth_wiki", 2, None, None, None, Some(btx))
        .expect("admit B");
    handle.submit(b).expect("submit B");
    let b_resp = brx.recv_timeout(Duration::from_secs(60)).expect("B response");
    assert!(b_resp.is_ok(), "rejected: {:?}", b_resp.rejected);
    assert_eq!(b_resp.tokens, reference_decode("the next client", 0.6, 2));
    handle.shutdown().expect("shutdown");

    assert_eq!(metrics.cancelled.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 1, "only B completed");
}

#[test]
fn host_server_rejects_unknown_model_at_startup() {
    let mut cfg = serve_cfg();
    cfg.model = "mu-opt-nonexistent".into();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router");
    assert!(
        Server::start(&router).is_err(),
        "startup must fail fast on unknown model"
    );
}

#[test]
fn pjrt_engine_selector_fails_cleanly_without_feature() {
    #[cfg(not(feature = "pjrt"))]
    {
        let mut cfg = serve_cfg();
        cfg.engine = EngineKind::Pjrt;
        let metrics = Arc::new(Metrics::new());
        let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router");
        let err = Server::start(&router).expect_err("must not start");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
