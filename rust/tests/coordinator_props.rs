//! Property tests over coordinator policy (mini-proptest; no XLA needed):
//! batching invariants, router snapping, and metrics consistency under
//! arbitrary request interleavings.

use mumoe::coordinator::batcher::{BatcherConfig, DynamicBatcher};
use mumoe::coordinator::request::Request;
use mumoe::moe::snap_rho;
use mumoe::proptest::{check, ensure, PropResult};
use std::time::{Duration, Instant};

const LEVELS: [f64; 3] = [0.4, 0.6, 1.0];

fn req(id: u64, rho: f64) -> Request {
    Request::new(id, vec![1, 2], 2, rho, "d", None)
}

/// Arbitrary interleavings of pushes never lose or duplicate requests,
/// batches never mix ρ, and never exceed the configured size.
#[test]
fn batcher_conserves_requests() {
    check(
        11,
        60,
        |rng| {
            let n = 1 + rng.gen_range_usize(40);
            (0..n)
                .map(|_| rng.gen_range_usize(LEVELS.len()))
                .collect::<Vec<usize>>()
        },
        |level_idxs: &Vec<usize>| -> PropResult {
            let mut b = DynamicBatcher::new(
                BatcherConfig {
                    batch_size: 4,
                    window: Duration::from_millis(5),
                },
                &LEVELS,
            );
            for (i, &li) in level_idxs.iter().enumerate() {
                b.push(req(i as u64, LEVELS[li]));
            }
            ensure(
                b.pending() == level_idxs.len(),
                format!("pending {} != {}", b.pending(), level_idxs.len()),
            )?;
            let later = Instant::now() + Duration::from_millis(50);
            let mut ids = Vec::new();
            while let Some(batch) = b.pop_ready(later) {
                ensure(batch.len() <= 4, "oversized batch")?;
                ensure(!batch.is_empty(), "empty batch")?;
                for r in &batch.requests {
                    ensure(
                        (r.rho - batch.rho).abs() < 1e-9,
                        "mixed-rho batch",
                    )?;
                    ids.push(r.id);
                }
            }
            ensure(b.pending() == 0, "requests left behind")?;
            ids.sort_unstable();
            let want: Vec<u64> = (0..level_idxs.len() as u64).collect();
            ensure(ids == want, "lost or duplicated request ids")
        },
    );
}

/// FIFO within a sparsity level, for any arrival pattern.
#[test]
fn batcher_fifo_within_level() {
    check(
        13,
        40,
        |rng| {
            let n = 1 + rng.gen_range_usize(30);
            (0..n)
                .map(|_| rng.gen_range_usize(LEVELS.len()))
                .collect::<Vec<usize>>()
        },
        |level_idxs: &Vec<usize>| -> PropResult {
            let mut b = DynamicBatcher::new(BatcherConfig::default(), &LEVELS);
            for (i, &li) in level_idxs.iter().enumerate() {
                b.push(req(i as u64, LEVELS[li]));
            }
            let later = Instant::now() + Duration::from_secs(1);
            let mut last_seen: std::collections::HashMap<u64, u64> = Default::default();
            while let Some(batch) = b.pop_ready(later) {
                let key = (batch.rho * 100.0) as u64;
                for r in &batch.requests {
                    if let Some(&prev) = last_seen.get(&key) {
                        ensure(r.id > prev, format!("FIFO violated at {}", r.id))?;
                    }
                    last_seen.insert(key, r.id);
                }
            }
            Ok(())
        },
    );
}

/// snap_rho always returns a configured level, and it's the closest one.
#[test]
fn snap_rho_is_nearest_level() {
    check(
        17,
        200,
        |rng| rng.next_f64(),
        |&rho: &f64| -> PropResult {
            let snapped = snap_rho(rho, &LEVELS);
            ensure(LEVELS.contains(&snapped), "snap left the level set")?;
            for &l in &LEVELS {
                ensure(
                    (rho - snapped).abs() <= (rho - l).abs() + 1e-12,
                    format!("{l} closer than {snapped} for {rho}"),
                )?;
            }
            Ok(())
        },
    );
}

/// Drain returns everything exactly once regardless of prior pops.
#[test]
fn drain_after_partial_pops_conserves() {
    check(
        19,
        40,
        |rng| {
            let n = 1 + rng.gen_range_usize(25);
            let pops = rng.gen_range_usize(4);
            (n, pops)
        },
        |&(n, pops): &(usize, usize)| -> PropResult {
            let mut b = DynamicBatcher::new(
                BatcherConfig {
                    batch_size: 3,
                    window: Duration::from_millis(0), // everything ready
                },
                &LEVELS,
            );
            for i in 0..n {
                b.push(req(i as u64, LEVELS[i % LEVELS.len()]));
            }
            let now = Instant::now() + Duration::from_millis(1);
            let mut got = 0usize;
            for _ in 0..pops {
                if let Some(batch) = b.pop_ready(now) {
                    got += batch.len();
                }
            }
            for batch in b.drain() {
                got += batch.len();
            }
            ensure(got == n, format!("{got} != {n}"))
        },
    );
}
