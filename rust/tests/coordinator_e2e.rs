//! Integration: the full coordinator (router → batcher → serve loop →
//! PJRT μ-MoE session) under concurrent client load, plus failure
//! injection at the admission layer. Needs the PJRT runtime, so it only
//! exists under `--features pjrt`.

#![cfg(feature = "pjrt")]

use mumoe::config::ServeConfig;
use mumoe::coordinator::{Metrics, Router, Server};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;

fn artifacts_available() -> bool {
    PathBuf::from("artifacts/manifest.json").exists()
}

fn serve_cfg() -> ServeConfig {
    ServeConfig {
        model: "mu-opt-micro".into(),
        engine: mumoe::config::EngineKind::Pjrt,
        rho_levels: vec![0.4, 1.0],
        batch_window_us: 1_000,
        queue_cap: 64,
        ..Default::default()
    }
}

#[test]
fn serves_concurrent_mixed_sparsity_requests() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone())
        .expect("router config");
    let handle = Server::start(&router).expect("server");

    let (tx, rx) = channel();
    let n = 12;
    for i in 0..n {
        let rho = if i % 2 == 0 { 0.4 } else { 1.0 };
        let prompt = format!("The archive of northern tyrolia number {i} is a ");
        let req = router
            .admit(&prompt, rho, "synth_wiki", Some(tx.clone()))
            .expect("admit");
        handle.submit(req).expect("submit");
    }
    drop(tx);

    let mut seen = 0;
    let mut rho_counts = (0, 0);
    while let Ok(resp) = rx.recv_timeout(std::time::Duration::from_secs(60)) {
        assert!(resp.is_ok(), "rejected: {:?}", resp.rejected);
        assert_eq!(resp.logits.len(), mumoe::model::VOCAB_SIZE);
        assert!(resp.next_token >= 0);
        assert!(resp.batch_size >= 1);
        if (resp.rho_used - 0.4).abs() < 1e-9 {
            rho_counts.0 += 1;
        } else {
            rho_counts.1 += 1;
        }
        seen += 1;
    }
    assert_eq!(seen, n);
    assert_eq!(rho_counts, (6, 6));
    handle.shutdown().expect("shutdown");

    assert_eq!(metrics.completed.load(Ordering::Relaxed), n as u64);
    assert!(metrics.batch_occupancy() > 0.0);
    assert!(metrics.latency_percentile_us(50.0) > 0);
}

#[test]
fn same_prompt_same_rho_is_deterministic() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router config");
    let handle = Server::start(&router).expect("server");

    let mut toks = Vec::new();
    for _ in 0..2 {
        let (tx, rx) = channel();
        let req = router
            .admit("veritas group reported net income of $", 0.4, "synth_news", Some(tx))
            .expect("admit");
        handle.submit(req).expect("submit");
        let resp = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .expect("response");
        assert!(resp.is_ok());
        toks.push(resp.next_token);
    }
    assert_eq!(toks[0], toks[1], "mu-MoE must be deterministic per prompt");
    handle.shutdown().expect("shutdown");
}

#[test]
fn dense_route_taken_for_rho_one() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    // rho=1.0 requests ride the dense artifact; verify they complete and
    // produce sane logits through that route
    let cfg = serve_cfg();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router config");
    let handle = Server::start(&router).expect("server");
    let (tx, rx) = channel();
    let req = router
        .admit("the quarterly earnings of", 1.0, "synth_news", Some(tx))
        .expect("admit");
    handle.submit(req).expect("submit");
    let resp = rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("response");
    assert!(resp.is_ok());
    assert_eq!(resp.rho_used, 1.0);
    assert!(resp.logits.iter().all(|x| x.is_finite()));
    handle.shutdown().expect("shutdown");
}

#[test]
fn admission_control_sheds_overload() {
    // no artifacts needed: router-only failure injection
    let mut cfg = serve_cfg();
    cfg.queue_cap = 4;
    let metrics = Arc::new(Metrics::new());
    let router =
        Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone()).expect("router config");
    // simulate a stuck server: depth never decremented
    router.depth_handle().store(4, Ordering::Relaxed);
    for _ in 0..5 {
        let r = router.admit("overload", 0.4, "d", None);
        assert!(r.is_err(), "must shed at cap");
    }
    assert_eq!(metrics.rejected.load(Ordering::Relaxed), 5);

    // recovery: queue drains, admission resumes
    router.depth_handle().store(0, Ordering::Relaxed);
    assert!(router.admit("ok now", 0.4, "d", None).is_ok());
}

#[test]
fn server_rejects_unknown_model_at_startup() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = serve_cfg();
    cfg.model = "mu-opt-nonexistent".into();
    let metrics = Arc::new(Metrics::new());
    let router = Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics).expect("router config");
    let r = Server::start(&router);
    assert!(r.is_err(), "startup must fail fast on unknown model");
}
