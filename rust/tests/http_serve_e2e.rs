//! Integration: the HTTP/SSE front-end over real loopback sockets — a
//! hand-rolled std `TcpStream` client POSTs `/generate` against
//! `HttpServer` and the assertions mirror `host_serve_e2e`: whatever the
//! transport and scheduling did, the streamed tokens must be
//! bit-identical to a direct `decode_greedy` on the same weights.

use mumoe::config::{EngineKind, ServeConfig};
use mumoe::coordinator::engine::HOST_FALLBACK_SEED;
use mumoe::coordinator::http::{HttpHandle, HttpServer};
use mumoe::coordinator::{Metrics, Router};
use mumoe::decode::{decode_greedy, DecodeConfig};
use mumoe::model::config_by_name;
use mumoe::model::tokenizer::ByteTokenizer;
use mumoe::pruning::MaskPlan;
use mumoe::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

fn serve_cfg() -> ServeConfig {
    let mut cfg = ServeConfig {
        model: "mu-opt-micro".into(),
        // point at nothing so the engine deterministically falls back to
        // the random model regardless of whether artifacts were built
        artifacts_dir: "http-serve-e2e-no-artifacts".into(),
        engine: EngineKind::Host,
        rho_levels: vec![0.4, 0.6, 1.0],
        batch_window_us: 500,
        queue_cap: 64,
        ..Default::default()
    };
    cfg.decode.default_max_new = 2;
    cfg.decode.max_new_cap = 8;
    cfg.decode.batch_size = 4;
    cfg.decode.stop_at_eos = false;
    cfg
}

fn start(cfg: ServeConfig) -> (Arc<Metrics>, HttpHandle) {
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(
        Router::new(cfg, mumoe::model::MAX_SEQ_LEN, metrics.clone()).expect("router config"),
    );
    let handle = HttpServer::start(router, "127.0.0.1:0").expect("http server");
    (metrics, handle)
}

/// The serve path must reproduce this token-for-token whatever the
/// transport and scheduling did (same invariant as `host_serve_e2e`).
fn reference_decode(prompt: &str, rho: f64, max_new: usize) -> Vec<i32> {
    let model = mumoe::nn::random_model(
        &config_by_name("mu-opt-micro").expect("known model"),
        HOST_FALLBACK_SEED,
    );
    let ids = ByteTokenizer.encode(prompt, true);
    decode_greedy(
        &model,
        &ids,
        &DecodeConfig {
            rho,
            plan: MaskPlan::PruneOnce,
            max_new,
            stop_at_eos: false,
            kv_cache: false,
        },
        None,
    )
    .new_tokens()
    .to_vec()
}

/// One exchange over a fresh connection (the server closes after each
/// response). Returns (status, head, de-chunked body).
fn http_request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> (u16, String, String) {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).expect("write request");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    let text = String::from_utf8(buf).expect("utf8 response");
    let head_end = text.find("\r\n\r\n").expect("response head");
    let head = text[..head_end].to_string();
    let raw_body = &text[head_end + 4..];
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    let body = if head.to_ascii_lowercase().contains("transfer-encoding: chunked") {
        dechunk(raw_body)
    } else {
        raw_body.to_string()
    };
    (status, head, body)
}

fn dechunk(mut rest: &str) -> String {
    let mut out = String::new();
    while let Some(nl) = rest.find("\r\n") {
        let size = usize::from_str_radix(rest[..nl].trim(), 16).expect("chunk size");
        if size == 0 {
            break;
        }
        let start = nl + 2;
        out.push_str(&rest[start..start + size]);
        rest = &rest[start + size + 2..];
    }
    out
}

/// Split an SSE body into its per-token `data:` payloads and the
/// terminal `event: done` payload.
fn parse_sse(body: &str) -> (Vec<Json>, Option<Json>) {
    let mut data = Vec::new();
    let mut done = None;
    for block in body.split("\n\n").filter(|b| !b.trim().is_empty()) {
        if let Some(rest) = block.strip_prefix("event: done\n") {
            let payload = rest.strip_prefix("data: ").expect("done payload");
            done = Some(Json::parse(payload).expect("done json"));
        } else if let Some(payload) = block.strip_prefix("data: ") {
            data.push(Json::parse(payload).expect("event json"));
        } else {
            panic!("unexpected SSE block: {block:?}");
        }
    }
    (data, done)
}

fn tokens_of(j: &Json) -> Vec<i32> {
    j.req("tokens")
        .expect("tokens field")
        .as_arr()
        .expect("tokens array")
        .iter()
        .map(|t| t.as_f64().expect("token number") as i32)
        .collect()
}

#[test]
fn streamed_sse_over_sockets_matches_direct_decode() {
    let (_, handle) = start(serve_cfg());
    let addr = handle.addr();

    // mixed ρ, mixed max_new, all at configured levels (kept small:
    // every request pays real host forwards in a debug-profile test)
    let cases: Vec<(String, f64, usize)> = (0..4)
        .map(|i| {
            let rho = [0.4, 0.6, 1.0][i % 3];
            let max_new = 1 + (i % 3);
            (format!("tyrolia record {i} is "), rho, max_new)
        })
        .collect();

    for (prompt, rho, max_new) in &cases {
        let body = format!(
            r#"{{"prompt": "{prompt}", "rho": {rho}, "max_new": {max_new}, "stream": true}}"#
        );
        let (status, head, sse) = http_request(addr, "POST", "/generate", Some(&body));
        assert_eq!(status, 200, "{head}\n{sse}");
        assert!(
            head.to_ascii_lowercase().contains("content-type: text/event-stream"),
            "{head}"
        );
        let (events, done) = parse_sse(&sse);
        let done = done.expect("terminal done event");

        // dense indices, streamed tokens == terminal tokens == reference
        for (i, ev) in events.iter().enumerate() {
            assert_eq!(ev.req("index").unwrap().as_f64(), Some(i as f64));
        }
        let streamed: Vec<i32> = events
            .iter()
            .map(|e| e.req("token").unwrap().as_f64().unwrap() as i32)
            .collect();
        let terminal = tokens_of(&done);
        assert_eq!(streamed, terminal, "stream must concatenate to tokens");
        assert_eq!(
            terminal,
            reference_decode(prompt, *rho, *max_new),
            "transport must not change tokens"
        );
        assert_eq!(done.req("cancelled").unwrap(), &Json::Bool(false));
        assert_eq!(done.req("steps").unwrap().as_usize(), Some(*max_new));
    }

    // the non-stream framing carries the same tokens as the SSE one
    let (prompt, rho, max_new) = &cases[1];
    let body =
        format!(r#"{{"prompt": "{prompt}", "rho": {rho}, "max_new": {max_new}}}"#);
    let (status, _, plain) = http_request(addr, "POST", "/generate", Some(&body));
    assert_eq!(status, 200, "{plain}");
    let resp = Json::parse(&plain).expect("response json");
    assert_eq!(tokens_of(&resp), reference_decode(prompt, *rho, *max_new));

    handle.shutdown().expect("shutdown");
}

#[test]
fn health_flips_ready_to_draining_and_sheds_new_generations() {
    let (_, handle) = start(serve_cfg());
    let addr = handle.addr();

    let (status, _, body) = http_request(addr, "GET", "/health", None);
    assert_eq!(status, 200);
    let health = Json::parse(&body).expect("health json");
    assert_eq!(health.req("status").unwrap().as_str(), Some("ready"));
    assert_eq!(health.req("model").unwrap().as_str(), Some("mu-opt-micro"));
    assert_eq!(
        health.req("version").unwrap().as_str(),
        Some(env!("CARGO_PKG_VERSION"))
    );
    assert!(health.req("uptime_seconds").unwrap().as_f64().unwrap() >= 0.0);
    assert_eq!(health.req("queue_depth").unwrap().as_f64(), Some(0.0));
    assert!(
        health.req("lane_occupancy").unwrap().as_f64().is_some(),
        "idle server still reports an occupancy gauge"
    );

    handle.begin_drain();
    let (status, _, body) = http_request(addr, "GET", "/health", None);
    assert_eq!(status, 200, "health keeps answering while draining");
    let health = Json::parse(&body).expect("health json");
    assert_eq!(health.req("status").unwrap().as_str(), Some("draining"));

    let (status, _, body) =
        http_request(addr, "POST", "/generate", Some(r#"{"prompt": "nope"}"#));
    assert_eq!(status, 503, "draining sheds new generations: {body}");

    handle.shutdown().expect("shutdown");
}

#[test]
fn malformed_and_overcap_requests_are_4xx_without_touching_the_engine() {
    let (metrics, handle) = start(serve_cfg());
    let addr = handle.addr();

    // malformed JSON: 400 before admission, nothing accepted
    let (status, _, body) =
        http_request(addr, "POST", "/generate", Some("{not json"));
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("JSON"), "{body}");

    // missing / mistyped fields: 400 naming the field
    let (status, _, body) =
        http_request(addr, "POST", "/generate", Some(r#"{"rho": 0.6}"#));
    assert_eq!(status, 400);
    assert!(body.contains("prompt"), "{body}");
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "p", "stream": "yes"}"#),
    );
    assert_eq!(status, 400);
    assert!(body.contains("stream"), "{body}");
    assert_eq!(metrics.accepted.load(Ordering::Relaxed), 0);

    // over-cap max_new: shed by admission control as a 400, engine idle
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "p", "max_new": 9999}"#),
    );
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("cap"), "{body}");
    assert_eq!(metrics.accepted.load(Ordering::Relaxed), 0);
    assert!(metrics.rejected.load(Ordering::Relaxed) >= 1);
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 0);

    // unknown route and wrong method
    let (status, _, _) = http_request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
    let (status, _, _) = http_request(addr, "GET", "/generate", None);
    assert_eq!(status, 405);

    handle.shutdown().expect("shutdown");
}

#[test]
fn metrics_endpoint_exposes_prometheus_families() {
    let (_, handle) = start(serve_cfg());
    let addr = handle.addr();

    // run one generation so the per-ρ families materialize
    let (status, _, _) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "count me", "rho": 0.6, "max_new": 2}"#),
    );
    assert_eq!(status, 200);

    let (status, head, text) = http_request(addr, "GET", "/metrics", None);
    assert_eq!(status, 200);
    assert!(head.contains("text/plain"), "{head}");
    for family in [
        "mumoe_requests_accepted_total 1",
        "mumoe_requests_completed_total 1",
        "mumoe_decode_tokens_total 2",
        "mumoe_level_tokens_total{rho=\"0.60\"} 2",
        "mumoe_fused_width_groups{rho=\"0.60\",width=\"1\"}",
        "mumoe_request_latency_us_bucket{le=\"+Inf\"} 1",
        "mumoe_queue_depth 0",
        // the prefill/seed split: "count me" is BOS + one token per byte,
        // all computed (nothing was in the store to seed from)
        "mumoe_level_prefilled_tokens_total{rho=\"0.60\"} 9",
        "mumoe_level_seeded_tokens_total{rho=\"0.60\"} 0",
        // occupancy gauges snapshotted by the serve loop
        "mumoe_layout_cache_entries",
        "mumoe_kvstore_entries",
        "mumoe_sessions_active",
    ] {
        assert!(text.contains(family), "missing {family:?} in:\n{text}");
    }

    handle.shutdown().expect("shutdown");
}

#[test]
fn multi_turn_session_seeds_parked_prefix_and_delete_resets_it() {
    let (_, handle) = start(serve_cfg());
    let addr = handle.addr();

    // turn 1 opens the session: nothing parked yet, so the whole BOS'd
    // prompt prefills, and the session id is echoed back terminally
    let p1 = "session turn one";
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(&format!(
            r#"{{"prompt": "{p1}", "rho": 0.6, "max_new": 3, "session": "chat-1"}}"#
        )),
    );
    assert_eq!(status, 200, "{body}");
    let turn1 = Json::parse(&body).expect("turn 1 json");
    assert_eq!(turn1.req("session").unwrap().as_str(), Some("chat-1"));
    assert_eq!(turn1.req("seeded").unwrap().as_usize(), Some(0));
    assert_eq!(
        turn1.req("prefilled").unwrap().as_usize(),
        Some(p1.len() + 1),
        "turn 1 prefills BOS + one token per byte"
    );

    // turn 2 continues it: the parked window (turn 1's BOS'd prompt plus
    // its 3 generated tokens, minus the never-forwarded last one) seeds
    // from the parked cache — zero full-prefix prefill — and only the
    // new turn (+ that last token) pays compute
    let p2 = " and turn two";
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(&format!(
            r#"{{"prompt": "{p2}", "rho": 0.6, "max_new": 2, "session": "chat-1"}}"#
        )),
    );
    assert_eq!(status, 200, "{body}");
    let turn2 = Json::parse(&body).expect("turn 2 json");
    assert_eq!(turn2.req("session").unwrap().as_str(), Some("chat-1"));
    assert_eq!(
        turn2.req("seeded").unwrap().as_usize(),
        Some(p1.len() + 1 + 3 - 1),
        "turn 2 must seed the whole parked window"
    );
    assert_eq!(
        turn2.req("prefilled").unwrap().as_usize(),
        Some(p2.len() + 2),
        "turn 2 prefills only its own turn plus the un-forwarded token"
    );
    assert_eq!(tokens_of(&turn2).len(), 2, "turn 2 generated its own tokens");

    // deleting the session works once, then reports not-found
    let (status, _, body) = http_request(addr, "DELETE", "/session/chat-1", None);
    assert_eq!(status, 200, "{body}");
    let del = Json::parse(&body).expect("delete json");
    assert_eq!(del.req("session").unwrap().as_str(), Some("chat-1"));
    assert_eq!(del.req("deleted").unwrap(), &Json::Bool(true));
    let (_, _, body) = http_request(addr, "DELETE", "/session/chat-1", None);
    let del = Json::parse(&body).expect("second delete json");
    assert_eq!(del.req("deleted").unwrap(), &Json::Bool(false));

    // a turn on the deleted id starts a fresh session: cold again
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "turn three", "rho": 0.6, "max_new": 2, "session": "chat-1"}"#),
    );
    assert_eq!(status, 200, "{body}");
    let turn3 = Json::parse(&body).expect("turn 3 json");
    assert_eq!(
        turn3.req("seeded").unwrap().as_usize(),
        Some(0),
        "a deleted session has nothing left to seed from"
    );

    // malformed ids are shed before admission, naming the field
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "p", "session": "bad/id"}"#),
    );
    assert_eq!(status, 400);
    assert!(body.contains("session"), "{body}");

    handle.shutdown().expect("shutdown");
}

#[test]
fn trace_endpoints_expose_timeline_and_chrome_json() {
    let (_, handle) = start(serve_cfg());
    let addr = handle.addr();

    let t0 = std::time::Instant::now();
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "trace me", "rho": 0.6, "max_new": 3}"#),
    );
    let client_us = t0.elapsed().as_micros() as u64;
    assert_eq!(status, 200, "{body}");
    let resp = Json::parse(&body).expect("response json");
    let id = resp.req("id").unwrap().as_f64().expect("request id") as u64;

    // the terminal response carries the server-side timing breakdown
    let timing = resp.req("timing").expect("timing object");
    let total_us = timing.req("total_us").unwrap().as_f64().unwrap() as u64;
    assert!(total_us > 0, "decode took measurable time");
    assert!(
        timing.req("ttft_us").unwrap().as_f64().unwrap() as u64 <= total_us,
        "the first token cannot land after the terminal response"
    );

    // GET /requests/:id — the single-request timeline
    let (status, _, body) = http_request(addr, "GET", &format!("/requests/{id}"), None);
    assert_eq!(status, 200, "{body}");
    let tl = Json::parse(&body).expect("timeline json");
    assert_eq!(tl.req("id").unwrap().as_f64(), Some(id as f64));
    assert_eq!(tl.req("outcome").unwrap().as_str(), Some("done"));
    let tl_total = tl.req("total_us").unwrap().as_f64().unwrap() as u64;
    let span_sum = tl.req("span_sum_us").unwrap().as_f64().unwrap() as u64;
    // span accounting must be consistent with the measured latency: the
    // timeline window fits inside the client-observed wall time, and
    // every span fits inside the timeline window
    assert!(
        tl_total <= client_us,
        "timeline {tl_total}us inside client-observed {client_us}us"
    );
    assert!(span_sum > 0, "phases were recorded with real durations");
    let begin = tl.req("begin_us").unwrap().as_f64().unwrap();
    let end = tl.req("end_us").unwrap().as_f64().unwrap();
    let spans = tl.req("spans").unwrap().as_arr().expect("spans array");
    assert!(!spans.is_empty());
    let mut phases = Vec::new();
    for s in spans {
        let s0 = s.req("start_us").unwrap().as_f64().unwrap();
        let s1 = s.req("end_us").unwrap().as_f64().unwrap();
        assert!(s0 >= begin && s1 <= end, "span inside the request window");
        phases.push(s.req("phase").unwrap().as_str().unwrap().to_string());
    }
    for expected in ["admit", "queue_wait", "prefill", "step"] {
        assert!(
            phases.iter().any(|p| p == expected),
            "missing phase {expected:?} in {phases:?}"
        );
    }

    // unknown ids and garbage queries answer 4xx, not 500
    let (status, _, _) = http_request(addr, "GET", "/requests/999999", None);
    assert_eq!(status, 404);
    let (status, _, _) = http_request(addr, "GET", "/trace?last=abc", None);
    assert_eq!(status, 400);

    // GET /trace — valid Chrome trace-event JSON, spans nested under the
    // per-request root event
    let (status, _, body) = http_request(addr, "GET", "/trace?last=8", None);
    assert_eq!(status, 200, "{body}");
    let trace = Json::parse(&body).expect("chrome trace json");
    let events = trace.req("traceEvents").unwrap().as_arr().expect("events");
    assert!(!events.is_empty());
    let root = events
        .iter()
        .find(|e| e.req("name").unwrap().as_str() == Some("request"))
        .expect("per-request root event");
    let root_ts = root.req("ts").unwrap().as_f64().unwrap();
    let root_end = root_ts + root.req("dur").unwrap().as_f64().unwrap();
    for e in events {
        assert_eq!(e.req("ph").unwrap().as_str(), Some("X"), "complete events");
        if e.req("pid").unwrap().as_f64() != Some(1.0) {
            continue; // kernel-sample track
        }
        let ts = e.req("ts").unwrap().as_f64().unwrap();
        let ev_end = ts + e.req("dur").unwrap().as_f64().unwrap();
        assert!(
            ts >= root_ts && ev_end <= root_end,
            "event nests within its request root"
        );
        assert_eq!(e.req("tid").unwrap().as_f64(), Some(id as f64));
    }

    handle.shutdown().expect("shutdown");
}

#[test]
fn trace_endpoints_are_404_when_tracing_is_disabled() {
    let mut cfg = serve_cfg();
    cfg.trace.enabled = false;
    let (_, handle) = start(cfg);
    let addr = handle.addr();

    let (status, _, body) = http_request(addr, "GET", "/trace", None);
    assert_eq!(status, 404, "{body}");
    assert!(body.contains("disabled"), "{body}");
    let (status, _, _) = http_request(addr, "GET", "/requests/1", None);
    assert_eq!(status, 404);

    handle.shutdown().expect("shutdown");
}

#[test]
fn server_ttft_is_bracketed_by_client_observed_ttft() {
    let (metrics, handle) = start(serve_cfg());
    let addr = handle.addr();

    // hand-rolled streaming exchange so the client can timestamp its own
    // first-token arrival
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let body = r#"{"prompt": "time to first token", "rho": 0.6, "max_new": 3, "stream": true}"#;
    let req = format!(
        "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    );
    let t0 = std::time::Instant::now();
    s.write_all(req.as_bytes()).expect("write request");
    let mut seen = Vec::new();
    let mut chunk = [0u8; 256];
    while !String::from_utf8_lossy(&seen).contains("data: ") {
        let n = s.read(&mut chunk).expect("read stream");
        assert!(n > 0, "server closed before the first token");
        seen.extend_from_slice(&chunk[..n]);
    }
    let client_ttft_us = t0.elapsed().as_micros() as u64;
    // drain to completion so the lane delivers cleanly before shutdown
    loop {
        match s.read(&mut chunk) {
            Ok(0) | Err(_) => break,
            Ok(n) => seen.extend_from_slice(&chunk[..n]),
        }
    }

    // server-side TTFT is measured from admission to the Token event, a
    // strict sub-interval of what the client observed around the wire
    let (count, sum_us) = metrics.ttft_stats();
    assert_eq!(count, 1, "one streamed request records one TTFT");
    assert!(sum_us > 0, "prefill plus the first step takes measurable time");
    assert!(
        sum_us <= client_ttft_us,
        "server TTFT {sum_us}us must not exceed client-observed {client_ttft_us}us"
    );

    // the same histogram family is scrapeable
    let (_, _, text) = http_request(addr, "GET", "/metrics", None);
    assert!(text.contains("mumoe_ttft_us_bucket{le=\"+Inf\"} 1"), "{text}");
    assert!(text.contains("mumoe_ttft_us_count 1"), "{text}");
    assert!(text.contains("mumoe_queue_wait_us_count 1"), "{text}");

    handle.shutdown().expect("shutdown");
}

#[test]
fn cancelled_session_turn_parks_partial_state_for_continuation() {
    // single-lane pool: hanging up on a streaming session turn must both
    // free the lane AND park the partial window under the session id, so
    // a retry on the same id continues instead of starting cold (the
    // regression behind the registry's generation guard)
    let mut cfg = serve_cfg();
    cfg.decode.batch_size = 1;
    cfg.decode.max_new_cap = 256;
    let (metrics, handle) = start(cfg);
    let addr = handle.addr();

    {
        let mut s = TcpStream::connect(addr).expect("connect A");
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let body = concat!(
            r#"{"prompt": "park me", "rho": 0.6, "max_new": 256, "#,
            r#""stream": true, "session": "live-1"}"#
        );
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).expect("write A");
        let mut seen = Vec::new();
        let mut chunk = [0u8; 256];
        while !String::from_utf8_lossy(&seen).contains("data: ") {
            let n = s.read(&mut chunk).expect("read A");
            assert!(n > 0, "server closed before first token");
            seen.extend_from_slice(&chunk[..n]);
        }
        // socket drops here: an implicit cancel mid-generation
    }

    // the continuation on the same id must find the parked partial
    // window: its prefix seeds instead of prefilling
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": " continue", "rho": 0.6, "max_new": 2, "session": "live-1"}"#),
    );
    assert_eq!(status, 200, "{body}");
    let resp = Json::parse(&body).expect("continuation json");
    assert_eq!(resp.req("session").unwrap().as_str(), Some("live-1"));
    assert_eq!(resp.req("cancelled").unwrap(), &Json::Bool(false));
    let seeded = resp.req("seeded").unwrap().as_usize().expect("seeded");
    assert!(seeded > 0, "the cancelled turn must park state to continue from");
    assert_eq!(tokens_of(&resp).len(), 2);

    handle.shutdown().expect("shutdown");
    assert!(
        metrics.cancelled.load(Ordering::Relaxed) >= 1,
        "the dropped stream must be recorded as a cancellation"
    );
}

#[test]
fn client_disconnect_mid_stream_frees_the_lane() {
    // single-lane pool: request B can only complete if hanging up on A's
    // SSE stream actually cancels A and frees the lane
    let mut cfg = serve_cfg();
    cfg.decode.batch_size = 1;
    cfg.decode.max_new_cap = 256;
    let (metrics, handle) = start(cfg);
    let addr = handle.addr();

    // A: long streaming generation; read until the first token event
    // proves the lane is running, then drop the socket mid-stream
    {
        let mut s = TcpStream::connect(addr).expect("connect A");
        s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let body = r#"{"prompt": "the abandoned one", "rho": 0.6, "max_new": 256, "stream": true}"#;
        let req = format!(
            "POST /generate HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\
             Connection: close\r\n\r\n{body}",
            body.len()
        );
        s.write_all(req.as_bytes()).expect("write A");
        let mut seen = Vec::new();
        let mut chunk = [0u8; 256];
        while !String::from_utf8_lossy(&seen).contains("data: ") {
            let n = s.read(&mut chunk).expect("read A");
            assert!(n > 0, "server closed before first token");
            seen.extend_from_slice(&chunk[..n]);
        }
        // socket drops here, mid-generation
    }

    // B completes on the freed lane and decodes exactly like a direct
    // call — if A's disconnect didn't cancel, the single lane would be
    // busy for 256 steps and this request would starve instead
    let (status, _, body) = http_request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "the next client", "rho": 0.6, "max_new": 2}"#),
    );
    assert_eq!(status, 200, "{body}");
    let resp = Json::parse(&body).expect("response json");
    assert_eq!(tokens_of(&resp), reference_decode("the next client", 0.6, 2));
    assert_eq!(resp.req("cancelled").unwrap(), &Json::Bool(false));

    handle.shutdown().expect("shutdown");
    assert!(
        metrics.cancelled.load(Ordering::Relaxed) >= 1,
        "A's disconnect must be recorded as a cancellation"
    );
    assert!(
        resp.req("steps").unwrap().as_usize() == Some(2),
        "B must have run its own 2 steps"
    );
}
