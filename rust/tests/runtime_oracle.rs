//! Integration: PJRT artifacts vs the pure-rust host reference model on
//! the *same trained checkpoint* — the strongest cross-layer correctness
//! signal in the repo (L1 Pallas kernels + L2 JAX graph + L3 runtime all
//! have to agree with an independent implementation).
//!
//! Requires `make artifacts`; tests no-op politely when absent so
//! `cargo test` works on a fresh clone. The whole suite needs the PJRT
//! runtime, so it only exists under `--features pjrt`.

#![cfg(feature = "pjrt")]

use mumoe::data::corpus::Corpus;
use mumoe::eval::harness::EvalStack;
use mumoe::model::checkpoint::Checkpoint;
use mumoe::model::config_by_name;
use mumoe::nn::{Model, PruneMode};
use std::path::{Path, PathBuf};

fn artifacts() -> Option<PathBuf> {
    let dir = PathBuf::from("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn eval_windows(dir: &Path, n: usize) -> Vec<mumoe::data::corpus::Window> {
    Corpus::load(&dir.join("data"), "synth_wiki", "test")
        .expect("corpus")
        .eval_windows(128, n)
}

/// Host model and dense artifact agree on per-window NLL.
#[test]
fn dense_artifact_matches_host_reference() {
    let Some(dir) = artifacts() else { return };
    let stack = EvalStack::open(&dir, "mu-opt-micro").expect("stack");
    let cfg = config_by_name("mu-opt-micro").unwrap();
    let ckpt = Checkpoint::load(&dir.join("ckpt/mu-opt-micro.ckpt")).expect("ckpt");
    let host = Model::from_checkpoint(&cfg, &ckpt).expect("host model");

    let windows = eval_windows(&dir, 4);
    let art = stack
        .perplexity(&stack.ckpt, &windows, None)
        .expect("artifact ppl");

    let mut host_nll = 0.0;
    let mut host_count = 0u64;
    for w in &windows {
        let (s, c) = host.nll_sum(&w.tokens, w.valid_len, PruneMode::Dense);
        host_nll += s;
        host_count += c as u64;
    }
    let host_ppl = (host_nll / host_count as f64).exp();
    assert_eq!(art.token_count, host_count);
    let rel = (art.value() - host_ppl).abs() / host_ppl;
    assert!(
        rel < 5e-3,
        "artifact ppl {} vs host {host_ppl} (rel {rel})",
        art.value()
    );
}

/// μ-MoE at ρ=1.0 equals the dense path through the real artifacts.
#[test]
fn mumoe_rho1_matches_dense_artifact() {
    let Some(dir) = artifacts() else { return };
    let stack = EvalStack::open(&dir, "mu-opt-micro").expect("stack");
    let windows = eval_windows(&dir, 4);
    let dense = stack.perplexity(&stack.ckpt, &windows, None).expect("ppl");
    let moe = stack
        .perplexity(&stack.ckpt, &windows, Some(1.0))
        .expect("ppl");
    let rel = (dense.value() - moe.value()).abs() / dense.value();
    assert!(rel < 1e-3, "dense {} vs mumoe@1.0 {}", dense.value(), moe.value());
}

/// μ-MoE artifact agrees with the host reference's online-Wanda mode.
/// Host prunes per single window; the artifact shares norms across the
/// batch — evaluate one window per batch for strict comparability.
#[test]
fn mumoe_artifact_matches_host_online_wanda() {
    let Some(dir) = artifacts() else { return };
    let stack = EvalStack::open(&dir, "mu-opt-micro").expect("stack");
    let cfg = config_by_name("mu-opt-micro").unwrap();
    let ckpt = Checkpoint::load(&dir.join("ckpt/mu-opt-micro.ckpt")).expect("ckpt");
    let host = Model::from_checkpoint(&cfg, &ckpt).expect("host");

    let rho = 0.5;
    // one real window replicated across the batch: batch-shared norms
    // equal per-window norms, so host and artifact see the same masks
    let w = &eval_windows(&dir, 1)[0];
    let windows: Vec<_> = (0..8).map(|_| w.clone()).collect();
    let art = stack
        .perplexity(&stack.ckpt, &windows, Some(rho))
        .expect("ppl");

    let (s, c) = host.nll_sum(&w.tokens, w.valid_len, PruneMode::OnlineWanda { rho });
    let host_ppl = (s / c as f64).exp();
    let rel = (art.value() - host_ppl).abs() / host_ppl;
    assert!(
        rel < 2e-2,
        "artifact mumoe ppl {} vs host online-wanda {host_ppl} (rel {rel})",
        art.value()
    );
}

/// Offline-pruned variants round-trip through the dense artifact: the
/// sparsity pattern of the uploaded weights is what the artifact computes
/// with (pruned weights -> higher ppl than dense, monotone in rho).
#[test]
fn pruned_variants_are_monotone_in_rho() {
    let Some(dir) = artifacts() else { return };
    let stack = EvalStack::open(&dir, "mu-opt-micro").expect("stack");
    let windows = eval_windows(&dir, 4);
    let dense = stack
        .perplexity(&stack.ckpt, &windows, None)
        .expect("ppl")
        .value();
    let mut last = dense;
    for rho in [0.8, 0.5, 0.3] {
        let v = stack.variant_magnitude(rho).expect("variant");
        let p = stack.perplexity(&v, &windows, None).expect("ppl").value();
        assert!(
            p >= last * 0.98,
            "magnitude ppl should not improve as rho falls: {p} vs {last} at rho={rho}"
        );
        last = p;
    }
    assert!(last > dense, "heavy pruning must cost perplexity");
}

/// calib_stats artifact output matches host-collected statistics.
#[test]
fn calib_stats_matches_host_collection() {
    let Some(dir) = artifacts() else { return };
    let stack = EvalStack::open(&dir, "mu-opt-micro").expect("stack");
    let cfg = config_by_name("mu-opt-micro").unwrap();
    let ckpt = Checkpoint::load(&dir.join("ckpt/mu-opt-micro.ckpt")).expect("ckpt");
    let host = Model::from_checkpoint(&cfg, &ckpt).expect("host");

    let windows = eval_windows(&dir, 2);
    let stats = stack.calibrate(&windows).expect("calibrate");

    // host-side statistics over the same windows
    let mut host_sq = std::collections::HashMap::new();
    for w in &windows {
        let acts = host.collect_activations(&w.tokens, w.valid_len);
        for (name, x) in acts {
            let sq = x.col_sq_sums();
            let e = host_sq
                .entry(name)
                .or_insert_with(|| vec![0.0f64; sq.len()]);
            for (a, b) in e.iter_mut().zip(sq) {
                *a += b as f64;
            }
        }
    }
    for name in cfg.linear_names() {
        let art = &stats.wanda[&name].sq_sums;
        let host_v = &host_sq[&name];
        for (i, (a, b)) in art.iter().zip(host_v).enumerate() {
            let denom = b.abs().max(1.0);
            assert!(
                (a - b).abs() / denom < 2e-2,
                "{name}[{i}]: artifact {a} vs host {b}"
            );
        }
    }
}
