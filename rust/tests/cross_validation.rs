//! Cross-implementation validation without XLA: the rust pruning engines
//! vs hand-computed fixtures and vs each other at scale, plus the Figure-3
//! selector equivalence on production-sized rows.

use mumoe::pruning::selection::{wanda_prune_with, Selector};
use mumoe::pruning::sparsegpt::{
    reconstruction_loss, sparsegpt_prune, HessianCalibrator, SparseGptConfig,
};
use mumoe::pruning::wanda::{online_wanda_mask, WandaCalibrator};
use mumoe::pruning::{kc_for, magnitude::magnitude_mask};
use mumoe::tensor::Mat;
use mumoe::util::rng::Pcg32;

/// Fixture mirrored in python/tests/test_pruning.py — the two language
/// implementations must agree on this exact case.
#[test]
fn wanda_fixture_matches_python() {
    // w = [[0.5, 1.0]]; feature 0 hot -> keep (0,0), drop (0,1)
    let w = Mat::from_vec(1, 2, vec![0.5, 1.0]);
    let mut calib = WandaCalibrator::new(2);
    calib.update_from_sq_sums(&[100.0, 0.01], 4);
    let mask = mumoe::pruning::wanda::wanda_mask(&w, &calib, 0.5);
    assert_eq!(mask.dense_bits(), vec![1, 0]);
}

#[test]
fn magnitude_fixture_matches_python() {
    let w = Mat::from_vec(1, 4, vec![1.0, -5.0, 0.1, 3.0]);
    let mask = magnitude_mask(&w, 0.5);
    assert_eq!(mask.dense_bits(), vec![0, 1, 0, 1]);
}

#[test]
fn kc_matches_python_kc_for() {
    for (d, rho, want) in [
        (10usize, 1.0, 0usize),
        (10, 0.0, 9),
        (100, 0.6, 40),
        (128, 0.5, 64),
        (48, 0.4, 28),
    ] {
        assert_eq!(kc_for(d, rho), want, "d={d} rho={rho}");
    }
}

/// All three selectors produce the *same pruning* on production-shaped
/// rows (d up to 4096), not just the toy sizes in unit tests.
#[test]
fn selectors_agree_at_scale() {
    let mut rng = Pcg32::new(31, 0);
    for d in [512usize, 1024, 4096] {
        let d_out = 8;
        let orig = rng.normal_vec(d_out * d);
        let norms: Vec<f32> = (0..d).map(|_| rng.next_f32() + 0.05).collect();
        let mut outs = Vec::new();
        for sel in Selector::ALL {
            let mut w = orig.clone();
            let mut scratch = Vec::new();
            wanda_prune_with(sel, &mut w, d_out, d, &norms, 0.5, &mut scratch);
            outs.push(w);
        }
        assert_eq!(outs[0], outs[1], "sort vs topk at d={d}");
        assert_eq!(outs[0], outs[2], "sort vs kthvalue at d={d}");
    }
}

/// SparseGPT's compensated loss beats mask-only Wanda across seeds
/// (statistical, not single-shot: 5 seeds, all must hold at blocksize =
/// d_in = canonical OBS).
#[test]
fn sparsegpt_dominates_wanda_across_seeds() {
    for seed in 0..5u64 {
        let mut rng = Pcg32::new(100 + seed, 0);
        let (d_out, d_in, t) = (16usize, 32usize, 256usize);
        let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
        let mut x = Mat::from_vec(t, d_in, rng.normal_vec(t * d_in));
        let scales: Vec<f32> = (0..d_in).map(|_| 0.2 + 2.8 * rng.next_f32()).collect();
        for tt in 0..t {
            for j in 0..d_in {
                *x.at_mut(tt, j) *= scales[j];
            }
        }
        let mut c = HessianCalibrator::new(d_in);
        c.update(&x);
        let cfg = SparseGptConfig {
            blocksize: d_in,
            ..Default::default()
        };
        let w_gpt = sparsegpt_prune(&w, &c, 0.5, cfg).expect("sparsegpt");
        let w_wanda = online_wanda_mask(&w, &x, 0.5).apply(&w);
        let lg = reconstruction_loss(&w, &w_gpt, &x);
        let lw = reconstruction_loss(&w, &w_wanda, &x);
        assert!(lg < lw, "seed {seed}: {lg} !< {lw}");
    }
}

/// The micro-expert premise at engine level: masks differ across shifted
/// activation distributions but row counts stay exact.
#[test]
fn online_masks_shift_with_distribution() {
    let mut rng = Pcg32::new(77, 0);
    let (d_out, d_in) = (32usize, 64usize);
    let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
    let base = Mat::from_vec(48, d_in, rng.normal_vec(48 * d_in));
    let mut shifted = Mat::from_vec(48, d_in, rng.normal_vec(48 * d_in));
    for t in 0..48 {
        for j in 0..d_in / 2 {
            *shifted.at_mut(t, j) *= 6.0;
        }
    }
    for rho in [0.25, 0.5, 0.75] {
        let m1 = online_wanda_mask(&w, &base, rho);
        let m2 = online_wanda_mask(&w, &shifted, rho);
        let keep = d_in - kc_for(d_in, rho);
        assert!(m1.row_active_counts().iter().all(|&c| c == keep));
        assert!(m2.row_active_counts().iter().all(|&c| c == keep));
        let j = m1.jaccard(&m2);
        assert!(j < 0.999, "rho={rho}: masks identical under shift");
        assert!(j > 0.05, "rho={rho}: masks unrealistically disjoint");
    }
}

/// The three executable forms of one Wanda selection agree: the in-place
/// dense prune (`wanda_prune_with`), the bitset mask applied to a dense
/// copy, and the compressed row-sparse layout expanded back to dense.
#[test]
fn mask_sparse_and_inplace_prune_agree() {
    let mut rng = Pcg32::new(55, 0);
    let (d_out, d_in) = (24usize, 100usize); // crosses a 64-bit word boundary
    let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
    let x = Mat::from_vec(32, d_in, rng.normal_vec(32 * d_in));
    let mut calib = WandaCalibrator::new(d_in);
    calib.update(&x);
    let norms = calib.col_norms();
    for rho in [0.3, 0.5, 0.7] {
        let mask = online_wanda_mask(&w, &x, rho);
        let masked = mask.apply(&w);
        let mut inplace = w.data.clone();
        let mut scratch = Vec::new();
        wanda_prune_with(
            Selector::KthValue,
            &mut inplace,
            d_out,
            d_in,
            &norms,
            rho,
            &mut scratch,
        );
        assert_eq!(masked.data, inplace, "rho={rho}: mask vs in-place prune");
        let dense_again = mask.compress(&w).to_dense();
        assert_eq!(masked.data, dense_again.data, "rho={rho}: mask vs sparse");
    }
}

/// The sparse kernel and the masked-dense matmul agree on
/// production-shaped linears, not just the toy sizes in unit tests.
#[test]
fn sparse_kernel_matches_masked_dense_at_scale() {
    let mut rng = Pcg32::new(56, 0);
    for (d_out, d_in, t) in [(256usize, 256usize, 64usize), (512, 128, 48)] {
        let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
        let x = Mat::from_vec(t, d_in, rng.normal_vec(t * d_in));
        let mask = online_wanda_mask(&w, &x, 0.5);
        let want = x.matmul_nt(&mask.apply(&w));
        let got = x.matmul_nt_sparse(&mask.compress(&w));
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "({d_out},{d_in},{t}): {a} vs {b}");
        }
    }
}

/// Host reference model: online-Wanda rho sweep degrades monotonically
/// on a random (untrained) model w.r.t. dense output distance.
#[test]
fn host_model_prune_distance_monotone() {
    use mumoe::model::ModelConfig;
    use mumoe::nn::{random_model, PruneMode};
    let m = random_model(&ModelConfig::new("t", 2, 2, 32), 5);
    let toks: Vec<i32> = (1..40).collect();
    let dense = m.forward(&toks, toks.len(), PruneMode::Dense);
    let mut last = 0.0;
    for rho in [0.9, 0.6, 0.3] {
        let out = m.forward(&toks, toks.len(), PruneMode::OnlineWanda { rho });
        let dist: f32 = dense
            .data
            .iter()
            .zip(&out.data)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(dist >= last * 0.9, "distance collapsed at rho={rho}");
        last = dist;
    }
}
