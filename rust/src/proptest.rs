//! Mini property-testing framework (proptest substitute).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` random inputs and,
//! on failure, greedily shrinks via the input's [`Shrink`] implementation
//! before panicking with the minimal counterexample. Coordinator invariants
//! (routing, batching, queue state) are tested with this.

use crate::util::rng::Pcg32;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve
        out.push(self[..self.len() / 2].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink each element (first few only, to bound work)
        for i in 0..self.len().min(4) {
            for cand in self[i].shrink() {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Convenience: turn a bool into a PropResult.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run a property over random inputs, shrinking failures.
///
/// Panics with the minimal counterexample found (bounded shrink passes).
pub fn check<T, G, P>(seed: u64, cases: usize, mut generate: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Pcg32::new(seed, 0xC0FFEE);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}, seed {seed}): {min_msg}\n\
                 minimal counterexample: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Debug, P: Fn(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // up to 200 successful shrink steps
    'outer: for _ in 0..200 {
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg)
}

/// Properties of the sparse execution engine: for arbitrary shapes,
/// active ratios and (deliberately tie-heavy) score matrices, the
/// compressed row-sparse kernel must be numerically indistinguishable
/// from the masked-dense reference, and the bitset mask bookkeeping must
/// be self-consistent. These are the contracts `nn::linear`'s OnlineWanda
/// path relies on.
#[cfg(test)]
mod sparse_props {
    use super::{check, ensure, PropResult};
    use crate::pruning::{kc_for, mask_from_scores, selection::Selector};
    use crate::tensor::Mat;
    use crate::util::rng::Pcg32;
    use crate::util::threadpool::ThreadPool;

    /// Derive a full test case from a (seed, rho) pair. Odd seeds build
    /// tie-heavy scores (values quantized to {0, 0.5, 1.0}) so threshold
    /// ties — the classic off-by-one breeding ground — are exercised hard.
    fn case(seed: u64, rho: f64) -> (Mat, Mat, Mat, f64) {
        let mut rng = Pcg32::new(seed, 17);
        let d_out = 1 + rng.gen_range_usize(24);
        let d_in = 1 + rng.gen_range_usize(80);
        let t = 1 + rng.gen_range_usize(12);
        let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
        let x = Mat::from_vec(t, d_in, rng.normal_vec(t * d_in));
        let scores = if seed % 2 == 0 {
            Mat::from_vec(d_out, d_in, w.data.iter().map(|v| v.abs()).collect())
        } else {
            Mat::from_fn(d_out, d_in, |_, _| {
                (rng.gen_range(3) as f32) * 0.5
            })
        };
        let rho = rho.clamp(0.0, 1.0);
        (w, x, scores, rho)
    }

    fn prop_sparse_equals_masked_dense(input: &(u64, f64)) -> PropResult {
        let (w, x, scores, rho) = case(input.0, input.1);
        let mask = mask_from_scores(&scores, rho, Selector::KthValue);
        let dense = x.matmul_nt(&mask.apply(&w));
        let sparse = x.matmul_nt_sparse(&mask.compress(&w));
        ensure(
            (dense.rows, dense.cols) == (sparse.rows, sparse.cols),
            "shape mismatch",
        )?;
        for (i, (a, b)) in sparse.data.iter().zip(&dense.data).enumerate() {
            ensure(
                (a - b).abs() < 1e-5,
                format!("elt {i}: sparse {a} vs dense {b} (rho={rho})"),
            )?;
        }
        Ok(())
    }

    fn prop_mask_bookkeeping(input: &(u64, f64)) -> PropResult {
        let (w, _x, scores, rho) = case(input.0, input.1);
        let mask = mask_from_scores(&scores, rho, Selector::KthValue);
        let rs = mask.compress(&w);
        let counts = mask.row_active_counts();
        ensure(
            counts.iter().sum::<usize>() == mask.active_count(),
            "row counts disagree with popcount",
        )?;
        ensure(
            rs.nnz() == mask.active_count(),
            format!("compress nnz {} != mask count {}", rs.nnz(), mask.active_count()),
        )?;
        ensure(
            rs.row_nnz_counts() == counts,
            "compress row counts disagree with mask",
        )?;
        // ties at the threshold can only make a row keep *fewer* weights
        // than the tie-free count d_in - kc, never more
        let keep_max = scores.cols - kc_for(scores.cols, rho);
        ensure(
            counts.iter().all(|&c| c <= keep_max),
            format!("a row keeps more than {keep_max} weights"),
        )?;
        // apply and apply_in_place agree exactly
        let a = mask.apply(&w);
        let mut b = w.clone();
        mask.apply_in_place(&mut b);
        ensure(a.data == b.data, "apply != apply_in_place")?;
        // and the sparse layout expands back to the masked weights
        ensure(rs.to_dense().data == a.data, "to_dense != apply")?;
        Ok(())
    }

    fn prop_parallel_matmul_bit_identical(input: &(u64, f64)) -> PropResult {
        let (w, x, _scores, _rho) = case(input.0, input.1);
        let pool = ThreadPool::new(3);
        let serial = x.matmul_nt(&w);
        let par = x.matmul_nt_par(&w, &pool);
        ensure(
            serial.data == par.data,
            "parallel matmul diverged from serial",
        )
    }

    /// The W-row-parallel sparse kernel (and its auto dispatch) must be
    /// bit-identical to the serial sparse kernel over arbitrary masked
    /// layouts — including tie-heavy masks with raggedly-sized rows.
    fn prop_parallel_sparse_bit_identical(input: &(u64, f64)) -> PropResult {
        let (w, x, scores, rho) = case(input.0, input.1);
        let mask = mask_from_scores(&scores, rho, Selector::KthValue);
        let rs = mask.compress(&w);
        let pool = ThreadPool::new(3);
        let serial = x.matmul_nt_sparse(&rs);
        let par = x.matmul_nt_sparse_par(&rs, &pool);
        ensure(
            serial.data == par.data,
            "parallel sparse kernel diverged from serial",
        )?;
        let auto = x.matmul_nt_sparse_auto(&rs);
        ensure(
            serial.data == auto.data,
            "auto sparse dispatch diverged from serial",
        )
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        // bias toward the boundary rhos where tie handling matters most
        let rho = match r.gen_range(5) {
            0 => 0.0,
            1 => 1.0,
            _ => r.next_f64(),
        };
        (r.next_u64(), rho)
    }

    #[test]
    fn sparse_kernel_equivalent_to_masked_dense() {
        check(101, 60, gen_seed_rho, prop_sparse_equals_masked_dense);
    }

    #[test]
    fn mask_bookkeeping_consistent() {
        check(102, 60, gen_seed_rho, prop_mask_bookkeeping);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        check(103, 25, gen_seed_rho, prop_parallel_matmul_bit_identical);
    }

    #[test]
    fn parallel_sparse_matmul_matches_serial() {
        check(104, 25, gen_seed_rho, prop_parallel_sparse_bit_identical);
    }
}

/// Properties of the decode engine (see `crate::decode`): the layout
/// cache must be *transparent* (decoding through it is bit-identical to
/// compressing directly, cold or warm), and the `Refresh(k)` plan must
/// degenerate to `EveryStep` at k=1 and to `PruneOnce` at k=∞ —
/// token-for-token and logit-for-logit. Checked over random model shapes,
/// prompts and active ratios.
#[cfg(test)]
mod decode_props {
    use super::{check, ensure, PropResult};
    use crate::decode::{decode_greedy, DecodeConfig, DecodeOutput};
    use crate::model::ModelConfig;
    use crate::nn::{random_model, Model};
    use crate::pruning::MaskPlan;
    use crate::tensor::LayoutCache;
    use crate::util::rng::Pcg32;

    /// Derive a random tiny model + prompt + ρ + generation length from a
    /// (seed, rho) pair. Shapes stay small so each case (several decodes,
    /// each a handful of forwards) is fast.
    fn case(seed: u64, rho: f64) -> (Model, Vec<i32>, f64, usize) {
        let mut rng = Pcg32::new(seed, 31);
        let n_layers = 1 + rng.gen_range_usize(2);
        let n_heads = 1 + rng.gen_range_usize(2);
        let head_dim = 4 + 4 * rng.gen_range_usize(2); // 4 or 8
        let cfg = ModelConfig::new("prop-tiny", n_layers, n_heads, n_heads * head_dim);
        let model = random_model(&cfg, seed ^ 0xABCD);
        let plen = 2 + rng.gen_range_usize(6);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
        // keep rho off the degenerate extremes but spanning wide
        let rho = 0.05 + 0.9 * rho.clamp(0.0, 1.0);
        let max_new = 3 + rng.gen_range_usize(3);
        (model, prompt, rho, max_new)
    }

    fn dcfg(rho: f64, plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho,
            plan,
            max_new,
            stop_at_eos: false,
            // these properties pin the full-window reference semantics;
            // kv_props proves the KV path equals them bit-for-bit
            kv_cache: false,
        }
    }

    fn bit_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) -> PropResult {
        ensure(a.tokens == b.tokens, format!("{label}: tokens diverged"))?;
        ensure(
            a.steps.len() == b.steps.len(),
            format!("{label}: step counts diverged"),
        )?;
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            ensure(
                sa.token == sb.token,
                format!("{label}: step {i} token {} vs {}", sa.token, sb.token),
            )?;
            ensure(
                sa.logits == sb.logits,
                format!("{label}: step {i} logits not bit-identical"),
            )?;
        }
        Ok(())
    }

    /// Satellite 1: cache transparency. A `PruneOnce` decode through a
    /// cold cache, through a warm cache (round-trip: the second decode
    /// reads back what the first inserted), and with no cache at all must
    /// be bit-identical — the cache may only skip recompression, never
    /// change what executes.
    fn prop_cache_round_trip_transparent(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let cfg = dcfg(rho, MaskPlan::PruneOnce, max_new);
        let direct = decode_greedy(&model, &prompt, &cfg, None);
        let mut cache = LayoutCache::new(64);
        let cold = decode_greedy(&model, &prompt, &cfg, Some(&mut cache));
        let warm = decode_greedy(&model, &prompt, &cfg, Some(&mut cache));
        bit_identical("cold cache vs direct", &cold, &direct)?;
        bit_identical("warm cache vs direct", &warm, &direct)?;
        ensure(
            warm.cache_misses == 0,
            format!("round-trip recompressed {} layouts", warm.cache_misses),
        )?;
        ensure(warm.cache_hits > 0, "warm decode never hit the cache")?;
        Ok(())
    }

    /// Satellite 2: plan degeneration. `Refresh(1)` ≡ `EveryStep` and
    /// `Refresh(∞)` ≡ `PruneOnce`, token-for-token on random prompts.
    fn prop_refresh_degenerates_to_endpoints(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let every = decode_greedy(&model, &prompt, &dcfg(rho, MaskPlan::EveryStep, max_new), None);
        let r1 = decode_greedy(&model, &prompt, &dcfg(rho, MaskPlan::Refresh(1), max_new), None);
        bit_identical("Refresh(1) vs EveryStep", &r1, &every)?;
        let once = decode_greedy(&model, &prompt, &dcfg(rho, MaskPlan::PruneOnce, max_new), None);
        let rinf = decode_greedy(
            &model,
            &prompt,
            &dcfg(rho, MaskPlan::Refresh(usize::MAX), max_new),
            None,
        );
        bit_identical("Refresh(MAX) vs PruneOnce", &rinf, &once)?;
        ensure(
            every.refresh_count == every.steps.len(),
            "EveryStep must refresh every step",
        )?;
        ensure(once.refresh_count == 1, "PruneOnce must refresh exactly once")?;
        Ok(())
    }

    /// Tentpole property: `decode_batch` over N requests at one snapped ρ
    /// — sharing one layout cache across batch-mates — is bit-identical,
    /// per request, to N independent `decode_greedy` calls. Batches
    /// deliberately include duplicated prompts (the coordinator's
    /// repeated-prefix case): for those the batch must also *reuse* the
    /// first lane's compressed layouts rather than recompress.
    fn prop_batch_matches_independent_greedy(input: &(u64, f64)) -> PropResult {
        use crate::decode::{decode_batch, BatchRequest};
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let mut rng = Pcg32::new(input.0 ^ 0x5EED, 13);
        let plans = [MaskPlan::EveryStep, MaskPlan::PruneOnce, MaskPlan::Refresh(2)];
        let plan = plans[rng.gen_range_usize(3)];
        // lanes: the base prompt, a variant, and an exact duplicate of the
        // base (cache-sharing case), with ragged max_new
        let variant: Vec<i32> = prompt.iter().map(|&t| (t + 7) % 256).collect();
        let lanes: [(&[i32], usize); 3] = [
            (&prompt, max_new),
            (&variant, 1 + max_new / 2),
            (&prompt, max_new),
        ];
        let items: Vec<BatchRequest> = lanes
            .iter()
            .map(|&(p, m)| BatchRequest {
                prompt: p,
                max_new: m,
                plan,
            })
            .collect();
        let mut cache = LayoutCache::new(256);
        // the batch runs the KV path (the serving default) while the
        // reference lanes run the full-window path: the comparison spans
        // both the batching and the caching dimension at once
        let batched = decode_batch(&model, &items, rho, false, true, Some(&mut cache));
        for (i, &(p, m)) in lanes.iter().enumerate() {
            let single = decode_greedy(&model, p, &dcfg(rho, plan, m), None);
            bit_identical(&format!("lane {i} vs independent greedy"), &batched[i], &single)?;
        }
        // duplicate-prompt lanes decode the same windows, so the third
        // lane must never compress a layout the first already built
        ensure(
            batched[2].cache_misses == 0,
            format!(
                "duplicate batch-mate recompressed {} layouts",
                batched[2].cache_misses
            ),
        )?;
        ensure(
            batched[2].cache_hits > 0,
            "duplicate batch-mate never hit the shared cache",
        )
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        (r.next_u64(), r.next_f64())
    }

    #[test]
    fn decode_cache_round_trip_is_transparent() {
        check(201, 10, gen_seed_rho, prop_cache_round_trip_transparent);
    }

    #[test]
    fn refresh_plan_degenerates_to_every_step_and_prune_once() {
        check(202, 10, gen_seed_rho, prop_refresh_degenerates_to_endpoints);
    }

    #[test]
    fn batched_decode_matches_independent_greedy() {
        check(203, 8, gen_seed_rho, prop_batch_matches_independent_greedy);
    }
}

/// Properties of the KV-cache incremental decode subsystem
/// (`nn::kv` + `Model::forward_step`): prefill-then-step must be
/// **bit-identical** to the full-window forward at every position, and
/// KV-cached decode must equal non-cached decode token-for-token and
/// logit-for-logit under every mask plan — including across the
/// sliding-window boundary, where the cache must rebuild (absolute
/// position embeddings shift with the window). Checked over random model
/// shapes, window lengths, prompts, plans and active ratios.
#[cfg(test)]
mod kv_props {
    use super::{check, ensure, PropResult};
    use crate::decode::{decode_greedy, DecodeConfig, DecodeOutput};
    use crate::model::ModelConfig;
    use crate::moe;
    use crate::nn::{random_model, KvCache, Model};
    use crate::pruning::MaskPlan;
    use crate::util::rng::Pcg32;

    /// Random tiny model with a deliberately *small* window so every
    /// generated case crosses the slide boundary, plus prompt/ρ/plan.
    fn case(seed: u64, rho: f64) -> (Model, Vec<i32>, f64, MaskPlan, usize) {
        let mut rng = Pcg32::new(seed, 47);
        let n_layers = 1 + rng.gen_range_usize(2);
        let n_heads = 1 + rng.gen_range_usize(2);
        let head_dim = 4 + 4 * rng.gen_range_usize(2); // 4 or 8
        let mut cfg = ModelConfig::new("kv-prop-tiny", n_layers, n_heads, n_heads * head_dim);
        cfg.max_seq_len = 5 + rng.gen_range_usize(5); // 5..=9
        let model = random_model(&cfg, seed ^ 0xBEEF);
        // prompt from 2 tokens up to a full window
        let plen = 2 + rng.gen_range_usize(cfg.max_seq_len - 1);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
        let rho = 0.05 + 0.9 * rho.clamp(0.0, 1.0);
        let plans = [
            MaskPlan::EveryStep,
            MaskPlan::PruneOnce,
            MaskPlan::Refresh(2),
            MaskPlan::Refresh(3),
        ];
        let plan = plans[rng.gen_range_usize(4)];
        // enough new tokens that the window always slides
        let max_new = cfg.max_seq_len + 2;
        (model, prompt, rho, plan, max_new)
    }

    fn bit_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) -> PropResult {
        ensure(a.tokens == b.tokens, format!("{label}: tokens diverged"))?;
        ensure(
            a.steps.len() == b.steps.len(),
            format!("{label}: step counts diverged"),
        )?;
        ensure(
            a.refresh_count == b.refresh_count,
            format!("{label}: refresh counts diverged"),
        )?;
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            ensure(
                sa.token == sb.token,
                format!("{label}: step {i} token {} vs {}", sa.token, sb.token),
            )?;
            ensure(
                sa.logits == sb.logits,
                format!("{label}: step {i} logits not bit-identical"),
            )?;
        }
        Ok(())
    }

    /// Tentpole property: KV-cached decode is bit-identical to the
    /// non-cached full-window decode under every plan, with every case
    /// generating past the slide boundary (rebuild-on-slide) and every
    /// `Refresh(k)` case exercising rebuild-on-refresh.
    fn prop_kv_decode_bit_identical(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, plan, max_new) = case(input.0, input.1);
        let base = DecodeConfig {
            rho,
            plan,
            max_new,
            stop_at_eos: false,
            kv_cache: false,
        };
        let without = decode_greedy(&model, &prompt, &base, None);
        let with_kv = decode_greedy(
            &model,
            &prompt,
            &DecodeConfig {
                kv_cache: true,
                ..base
            },
            None,
        );
        bit_identical(&format!("kv vs full ({})", plan.label()), &with_kv, &without)?;
        ensure(
            without.tokens.len() > model.cfg.max_seq_len,
            "case must cross the window-slide boundary",
        )
    }

    /// Satellite contract of the scratch struct: `forward_step_with` over
    /// ONE reused `StepScratch` must equal the allocating `forward_step`
    /// (a fresh scratch per call) logit-for-logit across consecutive
    /// steps, **and keep matching after a refresh rebuild** — new layouts
    /// plus a fresh prefill must not let any stale buffer content leak
    /// into later steps.
    fn prop_scratch_reuse_bit_identical(input: &(u64, f64)) -> PropResult {
        use crate::nn::StepScratch;
        let (model, prompt, rho, _plan, _max_new) = case(input.0, input.1);
        let seq = model.cfg.max_seq_len;
        let mut tokens = prompt;
        tokens.truncate(seq - 1);
        let sel = moe::select_experts(&model, &tokens, tokens.len(), rho);
        let layouts = moe::layouts_for(&model, &sel, None);

        let mut kv_fresh = KvCache::new(&model.cfg);
        let mut kv_reuse = KvCache::new(&model.cfg);
        model.forward_prefill_last(&tokens, tokens.len(), &layouts, &mut kv_fresh);
        model.forward_prefill_last(&tokens, tokens.len(), &layouts, &mut kv_reuse);
        let mut scratch = StepScratch::new(&model.cfg);
        let mut rng = Pcg32::new(input.0 ^ 0x7A7A, 9);
        while tokens.len() < seq {
            let next = rng.gen_range(256) as i32;
            tokens.push(next);
            let fresh = model.forward_step(next, &layouts, &mut kv_fresh);
            let reused = model.forward_step_with(next, &layouts, &mut kv_reuse, &mut scratch);
            ensure(
                fresh == reused,
                format!("scratch reuse diverged at window length {}", tokens.len()),
            )?;
        }
        // refresh rebuild: re-select on the grown window (different
        // layouts), prefill both caches again, keep stepping with the
        // SAME scratch — it must still match the allocating path
        let sel2 = moe::select_experts(&model, &tokens[1..], seq - 1, rho);
        let layouts2 = moe::layouts_for(&model, &sel2, None);
        model.forward_prefill_last(&tokens[1..], seq - 1, &layouts2, &mut kv_fresh);
        model.forward_prefill_last(&tokens[1..], seq - 1, &layouts2, &mut kv_reuse);
        let next = rng.gen_range(256) as i32;
        let fresh = model.forward_step(next, &layouts2, &mut kv_fresh);
        let reused = model.forward_step_with(next, &layouts2, &mut kv_reuse, &mut scratch);
        ensure(
            fresh == reused,
            "scratch reuse diverged after a refresh rebuild",
        )
    }

    /// Unit-level form of the same contract: `forward_step` equals
    /// `forward_fixed_last` at every position from one prefill up to a
    /// full window, and the forced rebuild after a slide repopulates the
    /// cache to the same logits the full forward produces.
    fn prop_forward_step_matches_fixed_last(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, _plan, _max_new) = case(input.0, input.1);
        let seq = model.cfg.max_seq_len;
        let mut tokens = prompt;
        tokens.truncate(seq - 1); // room to step at least once
        let sel = moe::select_experts(&model, &tokens, tokens.len(), rho);
        let layouts = moe::layouts_for(&model, &sel, None);

        let mut kv = KvCache::new(&model.cfg);
        let prefill = model.forward_prefill_last(&tokens, tokens.len(), &layouts, &mut kv);
        ensure(
            prefill == model.forward_fixed_last(&tokens, tokens.len(), &layouts),
            "prefill logits diverged from forward_fixed_last",
        )?;
        let mut rng = Pcg32::new(input.0 ^ 0x5A5A, 5);
        while tokens.len() < seq {
            let next = rng.gen_range(256) as i32;
            tokens.push(next);
            let stepped = model.forward_step(next, &layouts, &mut kv);
            let full = model.forward_fixed_last(&tokens, tokens.len(), &layouts);
            ensure(
                stepped == full,
                format!("forward_step diverged at window length {}", tokens.len()),
            )?;
            ensure(kv.len() == tokens.len(), "cache length out of sync")?;
        }
        // the window now slides: the step path is invalid (positions
        // shifted) and the engine rebuilds — the rebuilt prefill must
        // match the full forward on the slid window
        tokens.push(rng.gen_range(256) as i32);
        let window = &tokens[tokens.len() - seq..];
        let rebuilt = model.forward_prefill_last(window, seq, &layouts, &mut kv);
        ensure(
            rebuilt == model.forward_fixed_last(window, seq, &layouts),
            "slide rebuild diverged from the full forward",
        )?;
        ensure(kv.len() == seq, "rebuild must repopulate the full window")
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        (r.next_u64(), r.next_f64())
    }

    #[test]
    fn kv_decode_bit_identical_to_full_window_decode() {
        check(301, 10, gen_seed_rho, prop_kv_decode_bit_identical);
    }

    #[test]
    fn forward_step_equivalent_to_forward_fixed_last() {
        check(302, 10, gen_seed_rho, prop_forward_step_matches_fixed_last);
    }

    #[test]
    fn scratch_reuse_equivalent_to_allocating_step_path() {
        check(303, 10, gen_seed_rho, prop_scratch_reuse_bit_identical);
    }
}

/// Properties of continuous batching (`decode::LanePool` — what the
/// continuous serve loop drives): for ANY arrival schedule, lane count,
/// ρ, `MaskPlan` and `max_new` mix, admitting requests into freed lanes
/// of a running pool produces, per request, tokens and logits
/// bit-identical to N independent `decode_greedy` calls. Scheduling is a
/// throughput lever only — admission order and lane reuse can never leak
/// into decoded output; the shared layout cache may only gain hits.
#[cfg(test)]
mod continuous_props {
    use super::{check, ensure, PropResult};
    use crate::decode::{decode_greedy, DecodeConfig, DecodeOutput, LaneEvent, LanePool};
    use crate::model::ModelConfig;
    use crate::nn::{random_model, Model};
    use crate::pruning::MaskPlan;
    use crate::tensor::LayoutCache;
    use crate::util::rng::Pcg32;

    /// One scheduled request: when it arrives (in sweeps), its prompt and
    /// decode knobs.
    #[derive(Clone, Debug)]
    struct Arrival {
        at_sweep: usize,
        prompt: Vec<i32>,
        max_new: usize,
        plan: MaskPlan,
    }

    /// Random tiny model + lane count + ρ + arrival schedule.
    fn case(seed: u64, rho: f64) -> (Model, usize, f64, Vec<Arrival>) {
        let mut rng = Pcg32::new(seed, 53);
        let n_layers = 1 + rng.gen_range_usize(2);
        let n_heads = 1 + rng.gen_range_usize(2);
        let head_dim = 4 + 4 * rng.gen_range_usize(2);
        let cfg = ModelConfig::new("cont-prop-tiny", n_layers, n_heads, n_heads * head_dim);
        let model = random_model(&cfg, seed ^ 0xFACE);
        let lanes = 1 + rng.gen_range_usize(3); // 1..=3 lanes
        let rho = 0.05 + 0.9 * rho.clamp(0.0, 1.0);
        let plans = [MaskPlan::EveryStep, MaskPlan::PruneOnce, MaskPlan::Refresh(2)];
        let n_reqs = 2 + rng.gen_range_usize(4); // 2..=5 requests
        let mut base_prompt: Vec<i32> = (0..2 + rng.gen_range_usize(4))
            .map(|_| rng.gen_range(256) as i32)
            .collect();
        let arrivals = (0..n_reqs)
            .map(|i| {
                // half the prompts repeat (the cache-sharing case), half
                // mutate
                if i % 2 == 1 {
                    base_prompt = base_prompt.iter().map(|&t| (t + 3) % 256).collect();
                }
                Arrival {
                    at_sweep: rng.gen_range_usize(6),
                    prompt: base_prompt.clone(),
                    // 0..=4 (0 = degenerate); the first request always
                    // decodes so the schedule exercises at least one
                    // refresh (the warm-rerun assertions need one)
                    max_new: if i == 0 {
                        1 + rng.gen_range_usize(4)
                    } else {
                        rng.gen_range_usize(5)
                    },
                    plan: plans[rng.gen_range_usize(3)],
                }
            })
            .collect();
        (model, lanes, rho, arrivals)
    }

    /// Drive a pool over the schedule exactly like the continuous serve
    /// loop: before each sweep, admit every already-arrived request FIFO
    /// into free lanes; sweep; repeat until everything finished. Returns
    /// the outputs in request order plus each request's streamed tokens.
    fn run_schedule(
        model: &Model,
        lanes: usize,
        rho: f64,
        arrivals: &[Arrival],
        cache: &mut LayoutCache,
    ) -> (Vec<DecodeOutput>, Vec<Vec<i32>>) {
        run_schedule_fused(model, lanes, rho, arrivals, cache, true)
    }

    /// `run_schedule` with the pool's matrix-major fusion forced on or
    /// off — the lane-major (`fuse = false`) run is the per-lane
    /// reference the fused path must be bit-identical to.
    fn run_schedule_fused(
        model: &Model,
        lanes: usize,
        rho: f64,
        arrivals: &[Arrival],
        cache: &mut LayoutCache,
        fuse: bool,
    ) -> (Vec<DecodeOutput>, Vec<Vec<i32>>) {
        let mut pool = LanePool::new(lanes);
        pool.set_fuse(fuse);
        let mut outputs: Vec<Option<DecodeOutput>> = vec![None; arrivals.len()];
        let mut streamed: Vec<Vec<i32>> = vec![Vec::new(); arrivals.len()];
        // which request occupies each slot
        let mut owner: Vec<Option<usize>> = vec![None; lanes];
        let mut next_arrival = 0usize;
        let mut sweep_idx = 0usize;
        while outputs.iter().any(|o| o.is_none()) {
            while next_arrival < arrivals.len()
                && arrivals[next_arrival].at_sweep <= sweep_idx
                && pool.free_slot().is_some()
            {
                let a = &arrivals[next_arrival];
                let slot = pool.admit(model, &a.prompt, a.max_new, a.plan, true);
                owner[slot] = Some(next_arrival);
                next_arrival += 1;
            }
            let mut copt = Some(&mut *cache);
            for ev in pool.sweep(model, rho, false, &mut copt) {
                match ev {
                    LaneEvent::Token { slot, index, token } => {
                        let req = owner[slot].expect("token from an owned lane");
                        assert_eq!(streamed[req].len(), index, "dense stream indices");
                        streamed[req].push(token);
                    }
                    LaneEvent::Done { slot, output } => {
                        let req = owner[slot].take().expect("done lane owned");
                        outputs[req] = Some(output);
                    }
                }
            }
            sweep_idx += 1;
            assert!(sweep_idx < 200, "schedule failed to drain");
        }
        (
            outputs.into_iter().map(|o| o.expect("drained")).collect(),
            streamed,
        )
    }

    fn bit_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) -> PropResult {
        ensure(a.tokens == b.tokens, format!("{label}: tokens diverged"))?;
        ensure(
            a.steps.len() == b.steps.len(),
            format!("{label}: step counts diverged"),
        )?;
        ensure(
            a.refresh_count == b.refresh_count,
            format!("{label}: refresh counts diverged"),
        )?;
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            ensure(
                sa.logits == sb.logits,
                format!("{label}: step {i} logits not bit-identical"),
            )?;
        }
        Ok(())
    }

    /// THE arrival-schedule invariance property (the tentpole's
    /// correctness claim): every scheduled request decodes bit-identically
    /// to its own independent `decode_greedy` (full-window reference — so
    /// the claim spans lane reuse, KV caching and the shared layout cache
    /// at once), streamed tokens concatenate to exactly the output's
    /// `new_tokens()`, and re-running the same schedule against the warm
    /// cache changes nothing but hit counters (which may only rise).
    fn prop_schedule_invariant(input: &(u64, f64)) -> PropResult {
        let (model, lanes, rho, arrivals) = case(input.0, input.1);
        // big enough that no schedule can evict (eviction would make the
        // warm-rerun "no recompression" assertion flaky)
        let mut cache = LayoutCache::new(4096);
        let (outs, streamed) = run_schedule(&model, lanes, rho, &arrivals, &mut cache);
        let (hits_cold, misses_cold) = (cache.hits(), cache.misses());
        for (i, a) in arrivals.iter().enumerate() {
            let reference = decode_greedy(
                &model,
                &a.prompt,
                &DecodeConfig {
                    rho,
                    plan: a.plan,
                    max_new: a.max_new,
                    stop_at_eos: false,
                    kv_cache: false,
                },
                None,
            );
            bit_identical(
                &format!("request {i} (lanes={lanes}, plan={})", a.plan.label()),
                &outs[i],
                &reference,
            )?;
            ensure(
                streamed[i] == reference.new_tokens(),
                format!("request {i}: streamed tokens != decoded tokens"),
            )?;
        }
        // same schedule, warm cache: outputs identical, hit counters only
        // rise, nothing recompresses
        let (outs2, _) = run_schedule(&model, lanes, rho, &arrivals, &mut cache);
        for (i, (a, b)) in outs.iter().zip(&outs2).enumerate() {
            bit_identical(&format!("request {i} warm-cache rerun"), b, a)?;
        }
        ensure(
            cache.misses() == misses_cold,
            "warm schedule rerun recompressed a layout",
        )?;
        ensure(
            cache.hits() > hits_cold,
            "warm schedule rerun never hit the cache",
        )
    }

    /// Matrix-major fusion property (tentpole of the fused-sweep PR):
    /// over random group compositions — mixed plans, duplicate and
    /// divergent prompts, ragged `max_new`, staggered arrivals, refresh
    /// steps that split a group mid-flight, lanes at different window
    /// positions — a fused pool decodes bit-identically (tokens, logits,
    /// refresh counts, stream order) to the same schedule with fusion
    /// forced off. Prefill/refresh steps never fuse by construction, so
    /// every case also exercises the group-forming/splitting boundary.
    fn prop_fused_sweep_equals_lane_major(input: &(u64, f64)) -> PropResult {
        let (model, lanes, rho, arrivals) = case(input.0, input.1);
        let mut cache_fused = LayoutCache::new(4096);
        let (fused, fused_stream) =
            run_schedule_fused(&model, lanes, rho, &arrivals, &mut cache_fused, true);
        let mut cache_lane = LayoutCache::new(4096);
        let (lane_major, lane_stream) =
            run_schedule_fused(&model, lanes, rho, &arrivals, &mut cache_lane, false);
        for (i, a) in arrivals.iter().enumerate() {
            bit_identical(
                &format!(
                    "request {i} fused vs lane-major (lanes={lanes}, plan={})",
                    a.plan.label()
                ),
                &fused[i],
                &lane_major[i],
            )?;
            ensure(
                fused_stream[i] == lane_stream[i],
                format!("request {i}: fused stream != lane-major stream"),
            )?;
        }
        // fusion only changes how steps execute, never what compresses:
        // both runs must exercise the layout cache identically
        ensure(
            cache_fused.misses() == cache_lane.misses(),
            "fused run compressed a different number of layouts",
        )?;
        ensure(
            cache_fused.hits() == cache_lane.hits(),
            "fused run hit the cache a different number of times",
        )
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        (r.next_u64(), r.next_f64())
    }

    #[test]
    fn continuous_batching_token_identical_to_independent_greedy() {
        check(401, 8, gen_seed_rho, prop_schedule_invariant);
    }

    #[test]
    fn fused_sweeps_bit_identical_to_lane_major_sweeps() {
        check(402, 8, gen_seed_rho, prop_fused_sweep_equals_lane_major);
    }
}

/// Properties of cross-request KV reuse (`crate::kvstore` + the decode
/// lanes' `LaneSeed` path): the prefix store must be *transparent* —
/// decoding through it, cold or warm, is bit-identical to a storeless KV
/// decode, with seeding only re-labelling window work from `prefilled`
/// to `seeded` — its hit/miss/insertion counters must be exact, and a
/// session continuation must equal a hand-rolled decode of the
/// concatenated window under the parked (pinned) layouts. Checked over
/// random tiny models, prompts and active ratios.
#[cfg(test)]
mod kvstore_props {
    use super::{check, ensure, PropResult};
    use crate::decode::{
        decode_greedy, DecodeConfig, DecodeOutput, LaneEvent, LanePool, LaneSeed, SessionResume,
    };
    use crate::kvstore::KvStore;
    use crate::model::ModelConfig;
    use crate::nn::{random_model, Model};
    use crate::pruning::MaskPlan;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    /// Random tiny model + prompt + ρ + generation length. Lengths stay
    /// far below the default window (128), so no case ever slides —
    /// every prefill starts at absolute position 0, the store's domain.
    fn case(seed: u64, rho: f64) -> (Model, Vec<i32>, f64, usize) {
        let mut rng = Pcg32::new(seed, 61);
        let n_layers = 1 + rng.gen_range_usize(2);
        let n_heads = 1 + rng.gen_range_usize(2);
        let head_dim = 4 + 4 * rng.gen_range_usize(2); // 4 or 8
        let cfg = ModelConfig::new("kvstore-prop-tiny", n_layers, n_heads, n_heads * head_dim);
        let model = random_model(&cfg, seed ^ 0xD1CE);
        let plen = 2 + rng.gen_range_usize(6);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.gen_range(256) as i32).collect();
        let rho = 0.05 + 0.9 * rho.clamp(0.0, 1.0);
        let max_new = 2 + rng.gen_range_usize(4);
        (model, prompt, rho, max_new)
    }

    fn dcfg(rho: f64, plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho,
            plan,
            max_new,
            stop_at_eos: false,
            kv_cache: true,
        }
    }

    fn seed_with(store: &Arc<KvStore>) -> LaneSeed {
        LaneSeed {
            store: Some(store.clone()),
            resume: None,
            park: false,
        }
    }

    /// Drive one request through a fresh single-lane pool (the
    /// cross-request path only exists on pool admissions).
    fn run_pool(
        model: &Model,
        prompt: &[i32],
        rho: f64,
        plan: MaskPlan,
        max_new: usize,
        seed: LaneSeed,
    ) -> DecodeOutput {
        let mut pool = LanePool::new(1);
        pool.admit_with(model, prompt, max_new, plan, true, seed);
        let mut cache = None;
        let mut sweeps = 0;
        loop {
            for ev in pool.sweep(model, rho, false, &mut cache) {
                if let LaneEvent::Done { output, .. } = ev {
                    return output;
                }
            }
            sweeps += 1;
            assert!(sweeps < 200, "lane failed to drain");
        }
    }

    fn bit_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) -> PropResult {
        ensure(a.tokens == b.tokens, format!("{label}: tokens diverged"))?;
        ensure(
            a.steps.len() == b.steps.len(),
            format!("{label}: step counts diverged"),
        )?;
        ensure(
            a.refresh_count == b.refresh_count,
            format!("{label}: refresh counts diverged"),
        )?;
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            ensure(
                sa.token == sb.token,
                format!("{label}: step {i} token {} vs {}", sa.token, sb.token),
            )?;
            ensure(
                sa.logits == sb.logits,
                format!("{label}: step {i} logits not bit-identical"),
            )?;
        }
        Ok(())
    }

    /// Tentpole property (the warm-admission contract): re-admitting an
    /// identical prompt through a shared store is bit-identical to the
    /// cold run (itself bit-identical to a storeless decode), the warm
    /// run seeds all but one window token (`seeded = T − 1`,
    /// `prefilled = 1`), and the store's counters are exact throughout.
    fn prop_warm_rerun_bit_identical_counters_exact(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let plan = MaskPlan::PruneOnce;
        let reference = decode_greedy(&model, &prompt, &dcfg(rho, plan, max_new), None);
        let store = Arc::new(KvStore::new(4096));
        let cold = run_pool(&model, &prompt, rho, plan, max_new, seed_with(&store));
        bit_identical("cold through store vs storeless", &cold, &reference)?;
        ensure(cold.seeded_tokens == 0, "cold run seeded tokens")?;
        ensure(
            cold.prefilled_tokens == prompt.len(),
            format!("cold prefilled {} != {}", cold.prefilled_tokens, prompt.len()),
        )?;
        ensure(
            (store.hits(), store.misses(), store.insertions()) == (0, 1, 1),
            format!(
                "cold counters (h/m/i) = ({}, {}, {})",
                store.hits(),
                store.misses(),
                store.insertions()
            ),
        )?;
        let warm = run_pool(&model, &prompt, rho, plan, max_new, seed_with(&store));
        bit_identical("warm same-prefix rerun vs storeless", &warm, &reference)?;
        ensure(
            warm.seeded_tokens == prompt.len() - 1,
            format!("warm seeded {} != T-1 = {}", warm.seeded_tokens, prompt.len() - 1),
        )?;
        ensure(warm.prefilled_tokens == 1, "warm run must prefill exactly one token")?;
        ensure(
            (store.hits(), store.misses(), store.insertions()) == (1, 1, 1),
            format!(
                "warm counters (h/m/i) = ({}, {}, {})",
                store.hits(),
                store.misses(),
                store.insertions()
            ),
        )
    }

    /// Transparency over a mixed prompt family — the base prompt, an
    /// extension sharing its prefix, a mutation, and an exact repeat —
    /// decoded sequentially through ONE shared store: every output must
    /// equal its own storeless reference (hits may only relabel work,
    /// never change it), and the store must count exactly one lookup per
    /// stale position-0 prefill (one per refresh in these no-slide
    /// cases).
    fn prop_store_transparent_over_prompt_mix(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let mut rng = Pcg32::new(input.0 ^ 0x51DE, 23);
        let plans = [MaskPlan::PruneOnce, MaskPlan::Refresh(2)];
        let mut extended = prompt.clone();
        extended.extend((0..1 + rng.gen_range_usize(3)).map(|_| rng.gen_range(256) as i32));
        let mutated: Vec<i32> = prompt.iter().map(|&t| (t + 11) % 256).collect();
        let prompts = [prompt.clone(), extended, mutated, prompt];
        let store = Arc::new(KvStore::new(4096));
        let mut expected_lookups = 0u64;
        for (i, p) in prompts.iter().enumerate() {
            let plan = plans[rng.gen_range_usize(2)];
            let reference = decode_greedy(&model, p, &dcfg(rho, plan, max_new), None);
            let out = run_pool(&model, p, rho, plan, max_new, seed_with(&store));
            bit_identical(&format!("prompt {i} ({})", plan.label()), &out, &reference)?;
            ensure(
                out.seeded_tokens + out.prefilled_tokens >= p.len(),
                format!("prompt {i}: window work under-counted"),
            )?;
            expected_lookups += out.refresh_count as u64;
        }
        ensure(
            store.hits() + store.misses() == expected_lookups,
            format!(
                "{} hits + {} misses != {} stale prefills",
                store.hits(),
                store.misses(),
                expected_lookups
            ),
        )
    }

    /// A session continuation — parked window ++ new turn, layouts
    /// pinned, rows seeded — must skip every refresh, seed exactly the
    /// parked rows, prefill only the unseeded suffix, and produce step
    /// logits equal to the full-window fixed-layout forward over its own
    /// prefix: the hand-rolled reference in which nothing but the pinned
    /// layouts decides the outputs.
    fn prop_session_continuation_matches_pinned_reference(input: &(u64, f64)) -> PropResult {
        let (model, prompt, rho, max_new) = case(input.0, input.1);
        let mut rng = Pcg32::new(input.0 ^ 0xC0DE, 29);
        let store = Arc::new(KvStore::new(4096));
        let park = LaneSeed {
            store: Some(store.clone()),
            resume: None,
            park: true,
        };
        let turn1 = run_pool(&model, &prompt, rho, MaskPlan::PruneOnce, max_new, park);
        let parked = turn1.parked.as_deref().ok_or("turn 1 parked no state")?;
        ensure(
            parked.tokens == turn1.tokens,
            "parked window != final tokens (these cases never slide)",
        )?;
        ensure(
            parked.entry.len() == turn1.tokens.len() - 1,
            format!(
                "parked rows cover {} of {} tokens",
                parked.entry.len(),
                turn1.tokens.len()
            ),
        )?;
        let new_turn: Vec<i32> = (0..1 + rng.gen_range_usize(3))
            .map(|_| rng.gen_range(256) as i32)
            .collect();
        let mut concat = parked.tokens.clone();
        concat.extend_from_slice(&new_turn);
        let max_new2 = 2 + rng.gen_range_usize(3);
        let resume = LaneSeed {
            store: Some(store.clone()),
            resume: Some(SessionResume {
                layouts: parked.layouts.clone(),
                entry: Arc::new(parked.entry.clone()),
            }),
            park: true,
        };
        let cont = run_pool(&model, &concat, rho, MaskPlan::PruneOnce, max_new2, resume);
        ensure(cont.refresh_count == 0, "pinned continuation ran a refresh")?;
        ensure(
            cont.seeded_tokens == parked.entry.len(),
            format!(
                "continuation seeded {} != parked {}",
                cont.seeded_tokens,
                parked.entry.len()
            ),
        )?;
        ensure(
            cont.prefilled_tokens == concat.len() - parked.entry.len(),
            "continuation prefilled more than the unseeded suffix",
        )?;
        for (i, st) in cont.steps.iter().enumerate() {
            let valid = concat.len() + i;
            let want = model.forward_fixed_last(&cont.tokens[..valid], valid, &parked.layouts);
            ensure(
                st.logits == want,
                format!("continuation step {i} logits diverged from the pinned reference"),
            )?;
        }
        Ok(())
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        (r.next_u64(), r.next_f64())
    }

    #[test]
    fn warm_store_rerun_bit_identical_with_exact_counters() {
        check(501, 8, gen_seed_rho, prop_warm_rerun_bit_identical_counters_exact);
    }

    #[test]
    fn store_transparent_over_mixed_prompt_family() {
        check(502, 6, gen_seed_rho, prop_store_transparent_over_prompt_mix);
    }

    #[test]
    fn session_continuation_bit_exact_against_pinned_layout_reference() {
        check(503, 6, gen_seed_rho, prop_session_continuation_matches_pinned_reference);
    }
}

/// Properties of the SIMD dispatch layer (`tensor::simd`) and the int8
/// sidecar (`tensor::quant`): `Simd` mode must be bit-identical to
/// `Scalar` at all three kernel dispatch sites — over arbitrary shapes,
/// tie-heavy ragged masks and dirty reused buffers — and the quantizer's
/// round-trip error must stay within its per-row absmax bound. These are
/// the contracts that make the `[kernel] simd` knob (and the CI
/// `MUMOE_SIMD=off` leg) free to flip without changing a single token.
#[cfg(test)]
mod simd_props {
    use super::{check, ensure, PropResult};
    use crate::pruning::{mask_from_scores, selection::Selector, Mask};
    use crate::tensor::{
        matmul_tn_sparse_mode, matvec_nt_sparse_mode, quant_matmul_tn, quant_matvec_nt, Mat,
        QuantRowSparse, SimdMode,
    };
    use crate::util::rng::Pcg32;

    /// Random (w, x, mask) case. Odd seeds use tie-heavy quantized scores
    /// so threshold ties produce raggedly-sized sparse rows — the SIMD
    /// kernels' tail-handling breeding ground; the shape ranges straddle
    /// the 8-lane AVX2 width on both axes.
    fn case(seed: u64, rho: f64) -> (Mat, Mat, Mask) {
        let mut rng = Pcg32::new(seed, 43);
        let d_out = 1 + rng.gen_range_usize(24);
        let d_in = 1 + rng.gen_range_usize(80);
        let t = 1 + rng.gen_range_usize(12);
        let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
        let x = Mat::from_vec(t, d_in, rng.normal_vec(t * d_in));
        let scores = if seed % 2 == 0 {
            Mat::from_vec(d_out, d_in, w.data.iter().map(|v| v.abs()).collect())
        } else {
            Mat::from_fn(d_out, d_in, |_, _| (rng.gen_range(3) as f32) * 0.5)
        };
        let mask = mask_from_scores(&scores, rho.clamp(0.0, 1.0), Selector::KthValue);
        (w, x, mask)
    }

    /// Batch kernels: the sparse AXPY sweep and the dense row kernel at
    /// `Simd` must equal `Scalar` bit-for-bit, and the process-default
    /// entry points must agree with both (whatever mode the environment
    /// resolved — this is what keeps `MUMOE_SIMD` token-neutral).
    fn prop_batch_kernels_simd_bit_identical(input: &(u64, f64)) -> PropResult {
        let (w, x, mask) = case(input.0, input.1);
        let rs = mask.compress(&w);
        let xt = x.t();
        let scalar = matmul_tn_sparse_mode(&xt, &rs, SimdMode::Scalar);
        let simd = matmul_tn_sparse_mode(&xt, &rs, SimdMode::Simd);
        ensure(scalar.data == simd.data, "sparse simd diverged from scalar")?;
        ensure(
            scalar.data == x.matmul_nt_sparse(&rs).data,
            "sparse process-default diverged from scalar",
        )?;
        let d_scalar = x.matmul_nt_mode(&w, SimdMode::Scalar);
        let d_simd = x.matmul_nt_mode(&w, SimdMode::Simd);
        ensure(d_scalar.data == d_simd.data, "dense simd diverged from scalar")?;
        ensure(
            d_scalar.data == x.matmul_nt(&w).data,
            "dense process-default diverged from scalar",
        )
    }

    /// Decode kernel: the per-step sparse dot at `Simd` must equal
    /// `Scalar` bit-for-bit even when both write through the same dirty
    /// reused buffer, and must equal the T=1 batch kernel (the step ≡
    /// full-window contract the KV decode path rests on).
    fn prop_decode_matvec_simd_bit_identical(input: &(u64, f64)) -> PropResult {
        let (w, x, mask) = case(input.0, input.1);
        let rs = mask.compress(&w);
        let row = x.row(0);
        let mut rng = Pcg32::new(input.0 ^ 0x51D0, 5);
        // both buffers start with garbage of the wrong length
        let mut y_scalar = rng.normal_vec(1 + rng.gen_range_usize(40));
        let mut y_simd = rng.normal_vec(1 + rng.gen_range_usize(40));
        matvec_nt_sparse_mode(row, &rs, &mut y_scalar, SimdMode::Scalar);
        matvec_nt_sparse_mode(row, &rs, &mut y_simd, SimdMode::Simd);
        ensure(y_scalar == y_simd, "decode simd diverged from scalar")?;
        let x1 = Mat::from_vec(1, rs.cols, row.to_vec());
        let full = matmul_tn_sparse_mode(&x1.t(), &rs, SimdMode::Scalar);
        ensure(
            y_scalar == full.data,
            "decode step diverged from the T=1 batch kernel",
        )
    }

    /// Quantizer round-trip: every surviving weight must dequantize to
    /// within half a quantization step (`scale / 2`) of its f32 value,
    /// with structure (row_ptr/col_idx) preserved exactly — and the
    /// quantized decode matvec must equal the quantized T=1 matmul
    /// bit-for-bit (the same step ≡ full-window contract, within quant
    /// mode).
    fn prop_quant_round_trip_bounded(input: &(u64, f64)) -> PropResult {
        let (w, x, mask) = case(input.0, input.1);
        let rs = mask.compress(&w);
        let q = QuantRowSparse::from_sparse(&rs);
        let back = q.dequantize();
        ensure(back.row_ptr == rs.row_ptr, "quant changed row_ptr")?;
        ensure(back.col_idx == rs.col_idx, "quant changed col_idx")?;
        for i in 0..rs.rows {
            // scale/2 plus a whisker of fp slack from the two roundings
            let bound = q.scales[i] * 0.5001 + 1e-12;
            for p in rs.row_ptr[i]..rs.row_ptr[i + 1] {
                let err = (back.values[p] - rs.values[p]).abs();
                ensure(
                    err <= bound,
                    format!("row {i}: round-trip err {err} > bound {bound}"),
                )?;
            }
        }
        let row = x.row(0);
        let y = quant_matvec_nt(row, &q);
        let x1 = Mat::from_vec(1, rs.cols, row.to_vec());
        let full = quant_matmul_tn(&x1.t(), &q);
        ensure(
            y == full.data,
            "quant decode step diverged from the T=1 quant matmul",
        )
    }

    fn gen_seed_rho(r: &mut Pcg32) -> (u64, f64) {
        // bias toward the boundary rhos where ragged rows concentrate
        let rho = match r.gen_range(5) {
            0 => 0.0,
            1 => 1.0,
            _ => r.next_f64(),
        };
        (r.next_u64(), rho)
    }

    #[test]
    fn batch_kernels_simd_bit_identical_to_scalar() {
        check(601, 60, gen_seed_rho, prop_batch_kernels_simd_bit_identical);
    }

    #[test]
    fn decode_matvec_simd_bit_identical_over_dirty_buffers() {
        check(602, 60, gen_seed_rho, prop_decode_matvec_simd_bit_identical);
    }

    #[test]
    fn quant_round_trip_bounded_and_step_consistent() {
        check(603, 60, gen_seed_rho, prop_quant_round_trip_bounded);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, 50, |r| r.gen_range(100) as usize, |&x| {
            ensure(x < 100, "out of range")
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check(
            2,
            100,
            |r| r.gen_range(1000) as usize,
            |&x| ensure(x < 500, format!("x={x} too big")),
        );
    }

    #[test]
    fn shrink_reaches_small_values() {
        // failure iff x >= 500; the greedy shrinker steps down to exactly
        // 500 when the boundary is within its step budget
        let start = 650usize;
        let prop = |x: &usize| ensure(*x < 500, "big");
        let (min, _) = shrink_loop(start, "big".into(), &prop);
        assert_eq!(min, 500);
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5usize, 6, 7, 8];
        let cands = v.shrink();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }
}
