//! Mini property-testing framework (proptest substitute).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` random inputs and,
//! on failure, greedily shrinks via the input's [`Shrink`] implementation
//! before panicking with the minimal counterexample. Coordinator invariants
//! (routing, batching, queue state) are tested with this.

use crate::util::rng::Pcg32;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate strictly-smaller values, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for u64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // halve
        out.push(self[..self.len() / 2].to_vec());
        // drop one element
        if self.len() > 1 {
            let mut v = self.clone();
            v.pop();
            out.push(v);
        }
        // shrink each element (first few only, to bound work)
        for i in 0..self.len().min(4) {
            for cand in self[i].shrink() {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Outcome of a property: Ok or a failure message.
pub type PropResult = Result<(), String>;

/// Convenience: turn a bool into a PropResult.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Run a property over random inputs, shrinking failures.
///
/// Panics with the minimal counterexample found (bounded shrink passes).
pub fn check<T, G, P>(seed: u64, cases: usize, mut generate: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> PropResult,
{
    let mut rng = Pcg32::new(seed, 0xC0FFEE);
    for case in 0..cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg) = shrink_loop(input, msg, &prop);
            panic!(
                "property failed (case {case}, seed {seed}): {min_msg}\n\
                 minimal counterexample: {min_input:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Debug, P: Fn(&T) -> PropResult>(
    mut cur: T,
    mut msg: String,
    prop: &P,
) -> (T, String) {
    // up to 200 successful shrink steps
    'outer: for _ in 0..200 {
        for cand in cur.shrink() {
            if let Err(m) = prop(&cand) {
                cur = cand;
                msg = m;
                continue 'outer;
            }
        }
        break;
    }
    (cur, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check(1, 50, |r| r.gen_range(100) as usize, |&x| {
            ensure(x < 100, "out of range")
        });
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check(
            2,
            100,
            |r| r.gen_range(1000) as usize,
            |&x| ensure(x < 500, format!("x={x} too big")),
        );
    }

    #[test]
    fn shrink_reaches_small_values() {
        // failure iff x >= 500; the greedy shrinker steps down to exactly
        // 500 when the boundary is within its step budget
        let start = 650usize;
        let prop = |x: &usize| ensure(*x < 500, "big");
        let (min, _) = shrink_loop(start, "big".into(), &prop);
        assert_eq!(min, 500);
    }

    #[test]
    fn vec_shrink_reduces_len() {
        let v = vec![5usize, 6, 7, 8];
        let cands = v.shrink();
        assert!(cands.iter().any(|c| c.len() < v.len()));
    }
}
