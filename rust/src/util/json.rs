//! Minimal JSON parser (serde substitute) — reads `artifacts/manifest.json`
//! and emits metric dumps. Full RFC 8259 value grammar, no serialization of
//! exotic numbers; objects preserve no key order (we only look up).

use std::collections::HashMap;
use std::fmt::Write as _;

use super::error::Error;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(HashMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, Error> {
        let mut p = Parser {
            bytes: src.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::parse(format!(
                "trailing bytes at offset {}",
                p.pos
            )));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json, Error> {
        self.get(key)
            .ok_or_else(|| Error::parse(format!("missing key '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&HashMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_arr(&self) -> Option<Vec<String>> {
        self.as_arr().map(|v| {
            v.iter()
                .filter_map(|j| j.as_str().map(str::to_string))
                .collect()
        })
    }

    /// Serialize (stable output for tests: object keys sorted).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                let mut keys: Vec<_> = m.keys().collect();
                keys.sort();
                for (i, k) in keys.into_iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    m[k].write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), Error> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::parse(format!(
                "expected '{}' at offset {}",
                b as char, self.pos
            )))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, Error> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::parse(format!("bad literal at offset {}", self.pos)))
        }
    }

    fn value(&mut self) -> Result<Json, Error> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(Error::parse(format!(
                "unexpected byte at offset {}",
                self.pos
            ))),
        }
    }

    fn object(&mut self) -> Result<Json, Error> {
        self.eat(b'{')?;
        let mut m = HashMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(Error::parse("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, Error> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(Error::parse("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, Error> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::parse("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self
                        .peek()
                        .ok_or_else(|| Error::parse("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| Error::parse("short \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| Error::parse("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| Error::parse("bad \\u hex"))?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::parse("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.pos;
                    while let Some(&b) = self.bytes.get(self.pos) {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| Error::parse("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, Error> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| Error::parse(format!("bad number at offset {start}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\"").unwrap(),
            Json::Str("A".into())
        );
    }

    #[test]
    fn dump_roundtrip() {
        let src = r#"{"b":[1,2.5,"x"],"a":{"k":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn manifest_shape() {
        let src = r#"{"version":1,"artifacts":[{"name":"m","path":"hlo/m.txt",
                      "params":["a","b"],"batch":8}]}"#;
        let j = Json::parse(src).unwrap();
        let arts = j.req("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].req("batch").unwrap().as_usize(), Some(8));
        assert_eq!(
            arts[0].req("params").unwrap().str_arr().unwrap(),
            vec!["a", "b"]
        );
    }
}
