//! Crate error type: one enum, `From` conversions from everything the
//! stack touches (IO, XLA/PJRT, parsing), with context chaining.

use std::fmt;

/// Unified error for the mumoe crate.
#[derive(Debug)]
pub enum Error {
    /// Filesystem / IO failures (artifact files, checkpoints, corpora).
    Io(std::io::Error),
    /// Errors surfaced by the `xla` crate (PJRT compile/execute).
    Xla(String),
    /// Malformed input formats: manifest JSON, MUCK checkpoints, SQAB sets.
    Parse(String),
    /// Configuration errors (bad CLI flag, invalid config value).
    Config(String),
    /// Coordinator-level failures (queue closed, request rejected).
    Coordinator(String),
    /// Invariant violation — a bug, not an environment problem.
    Invariant(String),
    /// Context wrapper: what we were doing when the inner error happened.
    Context(String, Box<Error>),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Parse(m) => write!(f, "parse: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator: {m}"),
            Error::Invariant(m) => write!(f, "invariant violated: {m}"),
            Error::Context(ctx, inner) => write!(f, "{ctx}: {inner}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            Error::Context(_, inner) => Some(inner.as_ref()),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<anyhow::Error> for Error {
    fn from(e: anyhow::Error) -> Self {
        Error::Xla(format!("{e:#}"))
    }
}

impl Error {
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }
    pub fn invariant(msg: impl Into<String>) -> Self {
        Error::Invariant(msg.into())
    }
    pub fn coordinator(msg: impl Into<String>) -> Self {
        Error::Coordinator(msg.into())
    }
}

/// Context-chaining, mirroring `anyhow::Context`.
pub trait ResultExt<T> {
    fn context(self, ctx: impl Into<String>) -> Result<T, Error>;
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T, Error>;
}

impl<T, E: Into<Error>> ResultExt<T> for Result<T, E> {
    fn context(self, ctx: impl Into<String>) -> Result<T, Error> {
        self.map_err(|e| Error::Context(ctx.into(), Box::new(e.into())))
    }
    fn with_context(self, f: impl FnOnce() -> String) -> Result<T, Error> {
        self.map_err(|e| Error::Context(f(), Box::new(e.into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_chains_context() {
        let inner: Result<(), Error> =
            Err(Error::parse("bad magic")).context("loading ckpt");
        let msg = inner.unwrap_err().to_string();
        assert!(msg.contains("loading ckpt"));
        assert!(msg.contains("bad magic"));
    }

    #[test]
    fn io_conversion() {
        let e: Error =
            std::io::Error::new(std::io::ErrorKind::NotFound, "nope").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("nope"));
    }

    #[test]
    fn source_chain() {
        use std::error::Error as _;
        let e = Error::Context(
            "outer".into(),
            Box::new(Error::parse("inner")),
        );
        assert!(e.source().is_some());
    }
}
