//! Foundation substrates: error type, logging, RNG, threadpool, JSON.
//!
//! The sandbox carries no crates beyond `xla`/`anyhow`, so everything here
//! is built on std (DESIGN.md §2 substitution table).

pub mod error;
pub mod json;
pub mod log;
pub mod rng;
pub mod threadpool;

/// Monotonic wall-clock helper used across metrics and benches.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}
