//! Leveled stderr logger (std-only `log`-crate substitute).
//!
//! Level comes from `MUMOE_LOG` (error|warn|info|debug|trace) or
//! [`set_level`]; defaults to `info`. Output: `[12.345s INFO target] msg`.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset sentinel
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    let raw = LEVEL.load(Ordering::Relaxed);
    if raw == u8::MAX {
        let l = std::env::var("MUMOE_LOG")
            .ok()
            .and_then(|s| Level::parse(&s))
            .unwrap_or(Level::Info);
        LEVEL.store(l as u8, Ordering::Relaxed);
        return l;
    }
    // SAFETY-free decode: raw was stored from a Level
    match raw {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        let t = start().elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5} {target}] {msg}", l.as_str());
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error,
                               module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}
