//! Leveled stderr logger (std-only `log`-crate substitute).
//!
//! The filter comes from `MUMOE_LOG` (or [`set_level`], a global test
//! hook); the default level is `info`. `MUMOE_LOG` takes a default
//! level plus comma-separated per-target overrides:
//! `MUMOE_LOG=info,http=trace,server=debug`. A single-segment selector
//! matches any path segment of the logging module (`http` matches
//! `mumoe::coordinator::http`); selectors containing `::` match by
//! substring, and the longest matching selector wins.
//!
//! Output: `[12.345s INFO target] msg key=value ...` — the trailing
//! fields come from the macros' structured form,
//! `crate::info!("admitted"; id = id, slot = slot)`, and render lazily
//! (nothing formats unless the line is emitted).

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

/// A parsed `MUMOE_LOG` spec: a default level plus per-target overrides.
struct Filter {
    default: Level,
    targets: Vec<(String, Level)>,
}

impl Filter {
    /// Parse `info,http=trace`-style specs. Unknown levels and empty
    /// parts are ignored rather than fatal — a typo in an env var must
    /// never take the server down.
    fn parse(spec: &str) -> Filter {
        let mut default = Level::Info;
        let mut targets = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                Some((target, level)) => {
                    if let Some(level) = Level::parse(level.trim()) {
                        targets.push((target.trim().to_string(), level));
                    }
                }
                None => {
                    if let Some(level) = Level::parse(part) {
                        default = level;
                    }
                }
            }
        }
        Filter { default, targets }
    }

    /// Effective level for a module path; the longest matching selector
    /// wins, falling back to the default.
    fn level_for(&self, target: &str) -> Level {
        let mut best: Option<(usize, Level)> = None;
        for (sel, level) in &self.targets {
            let better = !best.is_some_and(|(len, _)| len >= sel.len());
            if selector_matches(target, sel) && better {
                best = Some((sel.len(), *level));
            }
        }
        best.map_or(self.default, |(_, l)| l)
    }
}

/// `http` (no `::`) matches any path segment; `coordinator::http`
/// matches as a substring of the module path.
fn selector_matches(target: &str, sel: &str) -> bool {
    if sel.contains("::") {
        target.contains(sel)
    } else {
        target.split("::").any(|seg| seg == sel)
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // unset sentinel
static FILTER: OnceLock<Filter> = OnceLock::new();
static START: OnceLock<Instant> = OnceLock::new();

fn start() -> Instant {
    *START.get_or_init(Instant::now)
}

fn filter() -> &'static Filter {
    FILTER.get_or_init(|| Filter::parse(&std::env::var("MUMOE_LOG").unwrap_or_default()))
}

/// Global override (test hook): trumps `MUMOE_LOG`, including its
/// per-target selectors.
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

fn override_level() -> Option<Level> {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Some(Level::Error),
        1 => Some(Level::Warn),
        2 => Some(Level::Info),
        3 => Some(Level::Debug),
        4 => Some(Level::Trace),
        _ => None,
    }
}

/// The effective default level (per-target overrides aside).
pub fn level() -> Level {
    override_level().unwrap_or_else(|| filter().default)
}

pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Would a record at `l` from module `target` be emitted?
pub fn enabled_for(l: Level, target: &str) -> bool {
    match override_level() {
        Some(max) => l <= max,
        None => l <= filter().level_for(target),
    }
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled_for(l, target) {
        let t = start().elapsed().as_secs_f64();
        eprintln!("[{t:9.3}s {:5} {target}] {msg}", l.as_str());
    }
}

/// Structured variant: appends ` key=value` pairs after the message.
/// Values only render when the line is actually emitted.
pub fn log_kv(
    l: Level,
    target: &str,
    msg: std::fmt::Arguments<'_>,
    kvs: &[(&str, &dyn std::fmt::Display)],
) {
    if enabled_for(l, target) {
        let t = start().elapsed().as_secs_f64();
        let mut line = format!("[{t:9.3}s {:5} {target}] {msg}", l.as_str());
        for (k, v) in kvs {
            let _ = write!(line, " {k}={v}");
        }
        eprintln!("{line}");
    }
}

#[macro_export]
macro_rules! info {
    ($fmt:literal $(, $arg:expr)* ; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::log::log_kv(
            $crate::util::log::Level::Info,
            module_path!(),
            format_args!($fmt $(, $arg)*),
            &[$((stringify!($k), &$v as &dyn ::std::fmt::Display)),+],
        )
    };
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($fmt:literal $(, $arg:expr)* ; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::log::log_kv(
            $crate::util::log::Level::Warn,
            module_path!(),
            format_args!($fmt $(, $arg)*),
            &[$((stringify!($k), &$v as &dyn ::std::fmt::Display)),+],
        )
    };
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($fmt:literal $(, $arg:expr)* ; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::log::log_kv(
            $crate::util::log::Level::Debug,
            module_path!(),
            format_args!($fmt $(, $arg)*),
            &[$((stringify!($k), &$v as &dyn ::std::fmt::Display)),+],
        )
    };
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug,
                               module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($fmt:literal $(, $arg:expr)* ; $($k:ident = $v:expr),+ $(,)?) => {
        $crate::util::log::log_kv(
            $crate::util::log::Level::Error,
            module_path!(),
            format_args!($fmt $(, $arg)*),
            &[$((stringify!($k), &$v as &dyn ::std::fmt::Display)),+],
        )
    };
    ($($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Error,
                               module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("warn"), Some(Level::Warn));
        assert_eq!(Level::parse("TRACE"), Some(Level::Trace));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn ordering() {
        assert!(Level::Error < Level::Trace);
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }

    #[test]
    fn filter_parses_default_and_targets() {
        let f = Filter::parse("info,http=trace,server=debug");
        assert_eq!(f.default, Level::Info);
        assert_eq!(f.level_for("mumoe::coordinator::http"), Level::Trace);
        assert_eq!(f.level_for("mumoe::coordinator::server"), Level::Debug);
        assert_eq!(f.level_for("mumoe::decode"), Level::Info);

        // bare level only
        let f = Filter::parse("warn");
        assert_eq!(f.default, Level::Warn);
        assert_eq!(f.level_for("anything"), Level::Warn);

        // junk is ignored, not fatal
        let f = Filter::parse("bogus,=,http=nope,,server=trace");
        assert_eq!(f.default, Level::Info);
        assert_eq!(f.level_for("mumoe::coordinator::http"), Level::Info);
        assert_eq!(f.level_for("mumoe::coordinator::server"), Level::Trace);
    }

    #[test]
    fn filter_longest_selector_wins() {
        let f = Filter::parse("warn,coordinator=info,coordinator::http=trace");
        assert_eq!(f.level_for("mumoe::coordinator::http"), Level::Trace);
        assert_eq!(f.level_for("mumoe::coordinator::server"), Level::Info);
        assert_eq!(f.level_for("mumoe::nn"), Level::Warn);
    }

    #[test]
    fn single_segment_selector_matches_whole_segments_only() {
        assert!(selector_matches("mumoe::coordinator::http", "http"));
        assert!(selector_matches("mumoe::coordinator::http", "coordinator"));
        assert!(!selector_matches("mumoe::coordinator::http", "htt"));
        assert!(selector_matches("mumoe::coordinator::http", "coordinator::http"));
        assert!(!selector_matches("mumoe::decode", "coordinator::http"));
    }
}
