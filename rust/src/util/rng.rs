//! Deterministic pseudo-random generators: SplitMix64 (seeding) and PCG32
//! (streams). Every stochastic component in the stack — workload traces,
//! synthetic tensors, property tests — draws from here so runs reproduce
//! bit-for-bit from a seed.

/// SplitMix64: used to expand a user seed into stream seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR 64/32): small, fast, statistically solid.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Seed a stream; distinct `stream` values give independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.wrapping_mul(0xDA94_2042_E4DD_58B5));
        let mut rng = Self {
            state: 0,
            inc: (sm.next_u64() << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn gen_range(&mut self, n: u32) -> u32 {
        assert!(n > 0, "gen_range(0)");
        let mut x = self.next_u32();
        let mut m = (x as u64) * (n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    pub fn gen_range_usize(&mut self, n: usize) -> usize {
        assert!(n > 0 && n <= u32::MAX as usize);
        self.gen_range(n as u32) as usize
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box-Muller.
    pub fn next_normal(&mut self) -> f32 {
        let u1 = (self.next_f64()).max(1e-12);
        let u2 = self.next_f64();
        ((-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()) as f32
    }

    /// Exponential with rate `lambda` (inter-arrival times in traces).
    pub fn next_exp(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals (synthetic weights/activations in tests).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 0);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = Pcg32::new(42, 0);
        let mut b = Pcg32::new(42, 1);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_bounds() {
        let mut r = Pcg32::new(7, 3);
        for _ in 0..10_000 {
            assert!(r.gen_range(17) < 17);
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Pcg32::new(1, 1);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(5, 5);
        let xs = r.normal_vec(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Pcg32::new(9, 0);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn exp_mean() {
        let mut r = Pcg32::new(11, 2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }
}
