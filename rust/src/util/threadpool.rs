//! Fixed-size threadpool (tokio/rayon substitute) built on std mpsc.
//!
//! The coordinator uses it for calibration jobs and corpus preprocessing;
//! the serve loop itself is a single event thread (the PJRT CPU client is
//! effectively serial on this box anyway). `scope_map` provides the one
//! parallel primitive the rest of the code wants: map a function over a
//! slice with worker threads and collect results in order.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads pulling jobs from a shared queue.
pub struct ThreadPool {
    tx: Sender<Msg>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("mumoe-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        Self { tx, workers, size }
    }

    /// Pool sized to the machine (at least 1).
    pub fn for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .send(Msg::Run(Box::new(job)))
            .expect("threadpool queue closed");
    }

    /// Run `f` over each item, returning results in input order. Panics in
    /// workers are converted to a panic here (fail loud, not silent loss).
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, ResultSlot<R>)>, Receiver<_>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let slot = match out {
                    Ok(v) => ResultSlot::Ok(v),
                    Err(_) => ResultSlot::Panicked,
                };
                let _ = rtx.send((i, slot));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, slot) = rrx.recv().expect("worker result channel closed");
            match slot {
                ResultSlot::Ok(v) => slots[i] = Some(v),
                ResultSlot::Panicked => panic!("threadpool job {i} panicked"),
            }
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

enum ResultSlot<R> {
    Ok(R),
    Panicked,
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    loop {
        let msg = {
            let guard = rx.lock().expect("poisoned queue lock");
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                // Swallow panics at the worker level; map() re-raises.
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..32 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn shutdown_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }
}
