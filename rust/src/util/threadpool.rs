//! Fixed-size threadpool (tokio/rayon substitute) built on std mpsc.
//!
//! The coordinator uses it for calibration jobs and corpus preprocessing;
//! the serve loop itself is a single event thread (the PJRT CPU client is
//! effectively serial on this box anyway). `scope_map` provides the one
//! parallel primitive the rest of the code wants: map a function over a
//! slice with worker threads and collect results in order.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

static GLOBAL: OnceLock<ThreadPool> = OnceLock::new();

/// Process-wide shared pool, sized to the machine on first use. The
/// parallel tensor kernels draw from this so callers don't thread a pool
/// handle through every matmul.
pub fn global() -> &'static ThreadPool {
    GLOBAL.get_or_init(ThreadPool::for_host)
}

thread_local! {
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// True on threads that are themselves pool workers. Blocking fan-out
/// from inside a worker can deadlock a saturated pool, so `scope_map`
/// degrades to inline execution there.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads pulling jobs from a shared queue.
///
/// The submit side is mutex-wrapped so the pool is `Sync` (shareable by
/// reference across threads and storable in the `global()` OnceLock) on
/// every supported toolchain — `mpsc::Sender` itself only became `Sync`
/// in Rust 1.72.
pub struct ThreadPool {
    tx: Mutex<Sender<Msg>>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = channel::<Msg>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("mumoe-worker-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawn worker")
            })
            .collect();
        Self {
            tx: Mutex::new(tx),
            workers,
            size,
        }
    }

    /// Pool sized to the machine (at least 1).
    pub fn for_host() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::new(n)
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Fire-and-forget.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .lock()
            .expect("poisoned submit lock")
            .send(Msg::Run(Box::new(job)))
            .expect("threadpool queue closed");
    }

    /// Run `f` over each item, returning results in input order. Panics in
    /// workers are converted to a panic here (fail loud, not silent loss).
    /// `'static` captures trivially satisfy [`ThreadPool::scope_map`]'s
    /// drain-before-return protocol, so this is just the owning special
    /// case of it.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        self.scope_map(items, f)
    }

    /// Like [`ThreadPool::map`], but the closure and items may borrow from
    /// the caller's stack (the primitive the parallel matmul kernels
    /// need: workers read the input matrices in place, no copies).
    ///
    /// Unlike `map`, a panicking job does not abort the collection early:
    /// every job is drained before the panic is re-raised, which is what
    /// makes lending stack references to the workers sound.
    pub fn scope_map<'env, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'env,
        R: Send + 'env,
        F: Fn(T) -> R + Sync + 'env,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        if in_pool_worker() {
            // A worker blocking on sub-jobs it queued behind itself can
            // deadlock a saturated pool — run nested fan-out inline.
            return items.into_iter().map(f).collect();
        }
        let f = &f;
        let (rtx, rrx) = channel::<(usize, ResultSlot<R>)>();
        for (i, item) in items.into_iter().enumerate() {
            let rtx = rtx.clone();
            self.submit_scoped(Box::new(move || {
                let out = catch_unwind(AssertUnwindSafe(|| f(item)));
                let slot = match out {
                    Ok(v) => ResultSlot::Ok(v),
                    Err(_) => ResultSlot::Panicked,
                };
                let _ = rtx.send((i, slot));
            }));
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panicked = false;
        for _ in 0..n {
            // Block until *every* job has reported; each job sends exactly
            // one slot (after `f` returned or unwound), so no borrow handed
            // to a worker can outlive this call.
            let (i, slot) = rrx.recv().expect("worker result channel closed");
            match slot {
                ResultSlot::Ok(v) => slots[i] = Some(v),
                ResultSlot::Panicked => panicked = true,
            }
        }
        if panicked {
            panic!("threadpool scope_map job panicked");
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }

    /// Enqueue a job that may borrow non-`'static` data.
    ///
    /// SAFETY: the lifetime is erased here and re-established by the
    /// caller's protocol: `scope_map` does not return (normally or by
    /// unwinding) until every submitted job has sent its result slot, and
    /// a job sends only after its closure has finished running. Workers
    /// never drop the queue receiver while the pool is alive, and the pool
    /// cannot be dropped while `&self` is borrowed, so a queued job is
    /// always executed (never silently discarded with live borrows).
    fn submit_scoped<'env>(&self, job: Box<dyn FnOnce() + Send + 'env>) {
        let job: Job = unsafe {
            std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(job)
        };
        self.tx
            .lock()
            .expect("poisoned submit lock")
            .send(Msg::Run(job))
            .expect("threadpool queue closed");
    }
}

enum ResultSlot<R> {
    Ok(R),
    Panicked,
}

fn worker_loop(rx: Arc<Mutex<Receiver<Msg>>>) {
    IN_POOL_WORKER.with(|c| c.set(true));
    loop {
        let msg = {
            let guard = rx.lock().expect("poisoned queue lock");
            guard.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                // Swallow panics at the worker level; map() re-raises.
                let _ = catch_unwind(AssertUnwindSafe(job));
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            for _ in &self.workers {
                let _ = tx.send(Msg::Shutdown);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..32 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..32 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..100).collect(), |x: i32| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn map_propagates_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.map(vec![1, 2, 3], |x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn scope_map_borrows_stack_data() {
        let pool = ThreadPool::new(3);
        let data: Vec<i64> = (0..64).collect();
        let out = pool.scope_map((0..64usize).collect(), |i| data[i] * 2);
        assert_eq!(out, (0..64).map(|x| x * 2).collect::<Vec<i64>>());
    }

    #[test]
    #[should_panic(expected = "scope_map job panicked")]
    fn scope_map_drains_then_panics() {
        let pool = ThreadPool::new(2);
        let _ = pool.scope_map(vec![1, 2, 3], |x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn global_pool_is_shared() {
        assert!(std::ptr::eq(global(), global()));
        assert!(global().size() >= 1);
    }

    #[test]
    fn nested_scope_map_runs_inline_without_deadlock() {
        // every worker fans out again on the same pool; the nested calls
        // must degrade to inline execution instead of deadlocking
        let pool = ThreadPool::new(2);
        let outer = pool.scope_map((0..8i64).collect(), |x| {
            let inner = pool.scope_map((0..4i64).collect(), |y| y + 1);
            x + inner.iter().sum::<i64>()
        });
        assert_eq!(outer, (0..8).map(|x| x + 10).collect::<Vec<i64>>());
    }

    #[test]
    fn shutdown_joins() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang
    }
}
