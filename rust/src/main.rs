//! mumoe — CLI launcher for the μ-MoE serving stack.
//!
//! Subcommands:
//!   serve       replay a synthetic request trace through the coordinator;
//!               `--engine host` (default, no pjrt needed) runs batched
//!               multi-token decode through the shared layout cache,
//!               `--engine pjrt` drives the AOT artifact sessions
//!   generate    autoregressive greedy decode through the same HostEngine
//!               the server uses, with a mask plan (every-step |
//!               prune-once | refresh:<k>) and a compressed-layout cache —
//!               no artifacts or `pjrt` needed; `--device` decodes through
//!               the PJRT artifact instead
//!   eval        perplexity of one (model, method, ρ, dataset) cell
//!   vlm-eval    strata accuracy of μ-VLM under one method/ρ
//!   flops       Table-4 style FLOPs/MACs analysis
//!   selection   Figure-3 style selection-algorithm timing
//!   overlap     μ-MoE micro-expert overlap analysis across domains
//!   inspect     print manifest / checkpoint summaries

use mumoe::cli::{flag, opt, usage, Args, OptSpec};
use mumoe::util::error::Error;

/// Subcommands that execute PJRT artifacts are only available when the
/// crate is built with `--features pjrt`; without it they fail with a
/// pointer instead of being silently absent.
#[cfg(not(feature = "pjrt"))]
fn pjrt_unavailable(cmd: &str) -> Result<(), Error> {
    Err(Error::config(format!(
        "'{cmd}' needs the PJRT runtime; rebuild with `--features pjrt` \
         (requires the xla toolchain — see rust/Cargo.toml)"
    )))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<(), Error> {
    let Some(cmd) = argv.first() else {
        print_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "serve" => cmd_serve(rest),
        "generate" => cmd_generate(rest),
        "eval" => cmd_eval(rest),
        "vlm-eval" => cmd_vlm_eval(rest),
        "flops" => cmd_flops(rest),
        "selection" => cmd_selection(rest),
        "overlap" => cmd_overlap(rest),
        "inspect" => cmd_inspect(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(Error::config(format!("unknown subcommand '{other}'"))),
    }
}

fn print_help() {
    println!(
        "mumoe — test-time pruning as micro-grained mixture-of-experts\n\n\
         subcommands:\n\
         \x20 serve      replay a request trace (host engine by default;\n\
         \x20            --engine pjrt needs --features pjrt)\n\
         \x20 generate   host greedy decode with mask-plan reuse (no pjrt)\n\
         \x20 eval       perplexity of one (model, method, rho, dataset) cell\n\
         \x20 vlm-eval   mu-VLM strata accuracy under one method/rho\n\
         \x20 flops      Table-4 FLOPs/MACs analysis\n\
         \x20 selection  Figure-3 selection-algorithm timing\n\
         \x20 overlap    micro-expert overlap across domains\n\
         \x20 inspect    print manifest / checkpoint summaries\n\n\
         run `mumoe <cmd> --help` for options"
    );
}

fn wants_help(rest: &[String]) -> bool {
    rest.iter().any(|a| a == "--help" || a == "-h")
}

// ---------------------------------------------------------------------------
// serve
// ---------------------------------------------------------------------------

const SERVE_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory", "artifacts"),
    opt("model", "model to serve", "mu-opt-micro"),
    opt("engine", "execution backend: host | pjrt", "host"),
    opt("requests", "trace length", "64"),
    opt("rate", "mean arrival rate (req/s)", "50"),
    opt("rhos", "sparsity levels clients request", "0.4,0.6,1.0"),
    opt("window-us", "batch window (microseconds)", "2000"),
    opt("max-new", "new tokens per request (host engine)", "1"),
    flag("kv", "force the per-lane KV decode cache on (host engine)"),
    flag("no-kv", "full-window decode every step (A/B baseline)"),
    flag("continuous", "force continuous batching on (host engine default)"),
    flag(
        "drain",
        "drain each batch to completion before admitting the next \
         (the pre-continuous A/B baseline)",
    ),
    flag("stream", "force per-token response streaming on (default)"),
    flag("no-stream", "ignore per-request stream channels"),
    flag("kvstore", "force the cross-request prefix KV store on (default)"),
    flag("no-kvstore", "disable prefix reuse and session continuation"),
    opt("kv-budget", "prefix KV store capacity (cached tokens)", "4096"),
    opt("session-ttl", "idle session lifetime (seconds)", "600"),
    opt(
        "max-sessions",
        "session registry capacity (idle sessions LRU-evict at the bound; \
         all-in-flight sheds with 429)",
        "1024",
    ),
    opt(
        "simd",
        "kernel dispatch: scalar | simd | fma (MUMOE_SIMD env overrides)",
        "",
    ),
    flag("quant", "force int8-quantized sparse decode layouts on"),
    flag("no-quant", "force f32 sparse layouts (default)"),
    opt(
        "http",
        "serve HTTP/SSE on this address (e.g. 127.0.0.1:8080) instead of \
         replaying a trace",
        "",
    ),
    flag("trace", "force the request flight recorder on (default)"),
    flag("no-trace", "disable request tracing (allocation-free hot path)"),
    opt("trace-capacity", "flight-recorder ring size (completed requests)", "64"),
    opt(
        "trace-kernel-every",
        "sample kernel attribution every Nth sweep (0 = never)",
        "0",
    ),
    opt("config", "optional mumoe.toml to load first", ""),
];

/// Resolve an on/off flag pair against a config default. Typing both is
/// contradictory and rejected rather than silently picked.
fn flag_pair(a: &Args, on: &str, off: &str, default: bool) -> Result<bool, Error> {
    match (a.flag(on), a.flag(off)) {
        (true, true) => Err(Error::config(format!(
            "--{on} and --{off} are mutually exclusive"
        ))),
        (true, false) => Ok(true),
        (false, true) => Ok(false),
        (false, false) => Ok(default),
    }
}

/// Replay a synthetic trace through the full coordinator, or — with
/// `--http <addr>` (or `coordinator.http_addr` in the TOML) — serve real
/// clients over HTTP/SSE until killed. The default `host` engine runs
/// batched multi-token decode through the router's shared layout cache
/// and needs no `pjrt` feature (a missing checkpoint falls back to a
/// deterministic random model); `--engine pjrt` drives the AOT artifact
/// sessions instead.
fn cmd_serve(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("serve", "replay a trace", SERVE_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, SERVE_SPEC)?;
    let mut cfg = if a.get("config").map(|s| !s.is_empty()).unwrap_or(false) {
        let t = mumoe::config::Toml::load(std::path::Path::new(a.req("config")?))?;
        mumoe::config::ServeConfig::from_toml(&t)?
    } else {
        mumoe::config::ServeConfig::default()
    };
    // Args pre-fills every option with its spec default, so a blanket
    // overwrite would silently undo whatever the TOML just loaded; only
    // options the user actually typed (either spelling) override it.
    if a.given("artifacts") || !a.given("config") {
        cfg.artifacts_dir = a.req("artifacts")?.to_string();
    }
    if a.given("model") || !a.given("config") {
        cfg.model = a.req("model")?.to_string();
    }
    if a.given("engine") {
        cfg.engine = mumoe::config::EngineKind::parse(a.req("engine")?)?;
    }
    if a.given("window-us") {
        cfg.batch_window_us = a.get_u64("window-us")?;
    }
    if a.given("rhos") || !a.given("config") {
        cfg.rho_levels = a.get_f64_list("rhos")?;
    }
    if a.given("max-new") {
        cfg.decode.default_max_new = a.get_usize("max-new")?;
        cfg.decode.max_new_cap = cfg.decode.max_new_cap.max(cfg.decode.default_max_new);
    }
    cfg.decode.kv_cache = flag_pair(&a, "kv", "no-kv", cfg.decode.kv_cache)?;
    cfg.decode.continuous = flag_pair(&a, "continuous", "drain", cfg.decode.continuous)?;
    cfg.decode.stream = flag_pair(&a, "stream", "no-stream", cfg.decode.stream)?;
    cfg.kvstore.enabled = flag_pair(&a, "kvstore", "no-kvstore", cfg.kvstore.enabled)?;
    if a.given("kv-budget") {
        cfg.kvstore.token_budget = a.get_usize("kv-budget")?;
    }
    if a.given("session-ttl") {
        cfg.kvstore.session_ttl_secs = a.get_u64("session-ttl")?;
    }
    if a.given("max-sessions") {
        cfg.kvstore.max_sessions = a.get_usize("max-sessions")?;
    }
    if a.given("simd") {
        let s = a.req("simd")?;
        cfg.kernel.simd = mumoe::tensor::SimdMode::parse(s).ok_or_else(|| {
            Error::config(format!("unknown --simd '{s}' (expected scalar | simd | fma)"))
        })?;
    }
    cfg.kernel.quant = flag_pair(&a, "quant", "no-quant", cfg.kernel.quant)?;
    if a.given("http") {
        cfg.http_addr = a.req("http")?.to_string();
    }
    cfg.trace.enabled = flag_pair(&a, "trace", "no-trace", cfg.trace.enabled)?;
    if a.given("trace-capacity") {
        cfg.trace.capacity = a.get_usize("trace-capacity")?;
    }
    if a.given("trace-kernel-every") {
        cfg.trace.kernel_sample_every = a.get_u64("trace-kernel-every")?;
    }
    cfg.validate()?;

    if !cfg.http_addr.is_empty() {
        let addr = cfg.http_addr.clone();
        return mumoe::coordinator::http::serve_http(cfg, &addr);
    }
    let report = mumoe::coordinator::server::replay_trace(
        cfg,
        a.get_usize("requests")?,
        a.get_f64("rate")?,
    )?;
    println!("{report}");
    Ok(())
}

// ---------------------------------------------------------------------------
// generate
// ---------------------------------------------------------------------------

const GEN_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory (checkpoint source)", "artifacts"),
    opt("model", "model name", "mu-opt-micro"),
    opt("prompt", "prompt text", "The archive of northern tyrolia is a "),
    opt("rho", "active-weight ratio", "0.6"),
    opt("tokens", "tokens to generate", "48"),
    opt("plan", "mask plan: every-step | prune-once | refresh:<k> (host engine)", "prune-once"),
    opt("cache-cap", "layout cache capacity (entries, host engine)", "512"),
    flag("kv", "force the per-lane KV decode cache on (default)"),
    flag("no-kv", "full-window decode every step (A/B baseline)"),
    flag(
        "stream",
        "print tokens as they decode (drives the continuous lane pool \
         directly; token-identical to the batch path)",
    ),
    flag(
        "device",
        "decode through the PJRT artifact session instead of the host \
         engine (needs --features pjrt; re-prunes every step in-graph)",
    ),
    opt(
        "simd",
        "kernel dispatch: scalar | simd | fma (MUMOE_SIMD env overrides)",
        "",
    ),
    flag("quant", "decode through int8-quantized sparse layouts"),
    opt(
        "trace-out",
        "write a Chrome trace-event JSON (Perfetto-loadable) of the \
         decode to this file (host engine; drives the lane-pool path)",
        "",
    ),
];

/// Greedy autoregressive decoding through the serving engine path: the
/// same `HostEngine` the server loop drives, fed one single-request
/// `DecodeBatch` (so `generate` and `serve` cannot drift apart). The mask
/// plan decides when micro-expert selection is refreshed against the
/// growing context, and the layout cache skips recompression when the
/// selection repeats. Runs without artifacts or the `pjrt` feature — a
/// missing checkpoint falls back to a deterministic random model so the
/// pipeline stays demonstrable anywhere.
fn cmd_generate(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("generate", "mu-MoE greedy decode (host engine)", GEN_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, GEN_SPEC)?;
    if a.flag("device") {
        return cmd_generate_device(&a);
    }
    let model_name = a.req("model")?;
    let rho = a.get_f64("rho")?;
    let n_new = a.get_usize("tokens")?;
    let plan = mumoe::pruning::MaskPlan::parse(a.req("plan")?)?;
    let cache_cap = a.get_usize("cache-cap")?;
    if cache_cap == 0 {
        return Err(Error::config("--cache-cap must be > 0"));
    }
    let kv = flag_pair(&a, "kv", "no-kv", mumoe::config::DecodeKnobs::default().kv_cache)?;
    let quant = a.flag("quant");
    // resolve the process-wide SIMD mode up front, like serve's prepare()
    let simd = match a.get("simd").filter(|s| !s.is_empty()) {
        Some(s) => mumoe::tensor::SimdMode::parse(s).ok_or_else(|| {
            Error::config(format!("unknown --simd '{s}' (expected scalar | simd | fma)"))
        })?,
        None => mumoe::config::KernelKnobs::default().simd,
    };
    mumoe::tensor::simd::set_mode(simd);

    use mumoe::coordinator::engine::{host_model, Engine, HostEngine};
    use mumoe::coordinator::request::Request;
    use mumoe::coordinator::DecodeBatch;
    use mumoe::model::tokenizer::ByteTokenizer;
    use mumoe::tensor::LayoutCache;
    use std::sync::{Arc, Mutex};

    let serve_cfg = mumoe::config::ServeConfig {
        artifacts_dir: a.req("artifacts")?.to_string(),
        model: model_name.to_string(),
        ..Default::default()
    };
    let model = host_model(&serve_cfg)?;
    let cache = Arc::new(Mutex::new(LayoutCache::new(cache_cap)));

    let tok = ByteTokenizer;
    let prompt_ids = tok.encode(a.req("prompt")?, true);
    let prompt_len = prompt_ids.len();
    let t0 = std::time::Instant::now();

    let trace_out = a
        .get("trace-out")
        .filter(|s| !s.is_empty())
        .map(str::to_string);
    let (tokens, steps, prefill_us, step_us) = if a.flag("stream") || trace_out.is_some() {
        // stream mode: drive the continuous lane pool directly and print
        // each token as its decode step finishes (token-identical to the
        // batch path below — both run the same Lane::step). --trace-out
        // rides this path too, because the pool is what exposes the
        // per-sweep lane steps the flight recorder turns into spans.
        use mumoe::decode::{LaneEvent, LanePool};
        use mumoe::trace::{chrome_trace, FlightRecorder};
        use std::io::Write;

        let streaming = a.flag("stream");
        // single-request CLI decode: one trace timeline, id 1, with
        // kernel attribution sampled on every sweep
        let recorder = trace_out.as_ref().map(|_| FlightRecorder::new(true, 8, 1));
        if streaming {
            print!("{}", tok.decode(&prompt_ids));
            std::io::stdout().flush().ok();
        }
        let mut pool = LanePool::new(1);
        pool.set_quant(quant);
        if let Some(rec) = &recorder {
            pool.set_kernel_sampling(rec.kernel_sample_every());
            rec.begin(1);
        }
        let t_admit = recorder.as_ref().map(|r| r.now_us());
        pool.admit(&model, &prompt_ids, n_new, plan, kv);
        if let (Some(rec), Some(t0)) = (&recorder, t_admit) {
            rec.span(1, "admit", None, t0, rec.now_us(), &[]);
        }
        let mut done = None;
        while done.is_none() {
            let mut guard = cache.lock().expect("cache lock");
            let mut copt = Some(&mut *guard);
            for ev in pool.sweep(&model, rho, true, &mut copt) {
                match ev {
                    LaneEvent::Token { token, .. } => {
                        if streaming {
                            print!("{}", tok.decode(&[token]));
                            std::io::stdout().flush().ok();
                        }
                    }
                    LaneEvent::Done { output, .. } => done = Some(output),
                }
            }
            if let Some(rec) = &recorder {
                let sample = pool.take_kernel_sample();
                rec.record_sweep(|_| Some(1), pool.last_sweep_lane_steps(), sample);
            }
        }
        if streaming {
            println!();
        }
        let out = done.expect("lane finished");
        if !streaming {
            let mut text_ids = prompt_ids.clone();
            text_ids.extend_from_slice(out.new_tokens());
            println!("{}", tok.decode(&text_ids));
        }
        if let (Some(rec), Some(path)) = (&recorder, &trace_out) {
            rec.finish(1, "done");
            let json = chrome_trace(&rec.last(1), &rec.kernel_samples());
            std::fs::write(path, json.dump())
                .map_err(|e| Error::config(format!("write {path}: {e}")))?;
            eprintln!("[trace written to {path}]");
        }
        (
            out.new_tokens().to_vec(),
            out.steps.len(),
            out.prefill_us,
            out.step_us,
        )
    } else {
        let mut engine = HostEngine::with_model_quant(model, cache.clone(), true, kv, quant);
        let request = Request::new(1, prompt_ids.clone(), prompt_len, rho, "cli", None)
            .with_decode(n_new, plan);
        let responses = engine.execute(DecodeBatch {
            rho,
            requests: vec![request],
        })?;
        let resp = &responses[0];
        let mut text_ids = prompt_ids.clone();
        text_ids.extend_from_slice(&resp.tokens);
        println!("{}", tok.decode(&text_ids));
        (resp.tokens.clone(), resp.steps, resp.prefill_us, resp.step_us)
    };
    let dt = t0.elapsed().as_secs_f64();

    let (hits, misses) = {
        let c = cache.lock().expect("cache lock");
        (c.hits(), c.misses())
    };
    // tokens, not steps: an EOS-terminated generation runs one more step
    // than it emits tokens, and the count must match the printed text
    let generated = tokens.len();
    println!(
        "\n[host engine: model={model_name} plan={} rho={rho} kv={} kernels={}{}: \
         {generated} new tokens in {dt:.2}s = {:.2} tok/s ({steps} decode steps, \
         prefill {prefill_us}us + steps {step_us}us); layout cache {hits} hits / \
         {misses} misses]",
        plan.label(),
        if kv { "on" } else { "off" },
        mumoe::tensor::simd::mode().label(),
        if quant { "+int8" } else { "" },
        generated as f64 / dt.max(1e-9),
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_generate_device(_a: &Args) -> Result<(), Error> {
    Err(Error::config(
        "--device needs the PJRT runtime; rebuild with `--features pjrt` \
         (requires the xla toolchain — see rust/Cargo.toml), or drop \
         --device to use the host engine",
    ))
}

/// Device-executed decode through the mu-MoE serving artifact: each step
/// re-runs online pruning *inside* the AOT graph against the growing
/// context (the in-graph analogue of the host engine's `every-step` plan).
#[cfg(feature = "pjrt")]
fn cmd_generate_device(a: &Args) -> Result<(), Error> {
    let dir = std::path::PathBuf::from(a.req("artifacts")?);
    let model = a.req("model")?;
    let rho = a.get_f64("rho")? as f32;
    let n_new = a.get_usize("tokens")?;

    use mumoe::model::tokenizer::ByteTokenizer;
    use mumoe::runtime::registry::Registry;
    use mumoe::runtime::session::{literal_f32, Input, Session};
    use mumoe::runtime::weights::DeviceWeights;
    use mumoe::runtime::Client;
    use std::sync::Arc;

    let client = Client::cpu()?;
    let registry = Registry::open(&dir, client.clone())?;
    let ckpt = mumoe::model::checkpoint::Checkpoint::load(&registry.ckpt_path(model))?;
    let meta = registry.meta_for("mumoe_logits", model)?;
    let (name, order, batch, seq) =
        (meta.name.clone(), meta.params.clone(), meta.batch, meta.seq_len);
    let weights = Arc::new(DeviceWeights::upload(&client, &ckpt, &order)?);
    let session = Session::bind(&registry, &name, weights)?;

    let tok = ByteTokenizer;
    // EOS from the model config (mirrors the host engine; checkpoints
    // with another vocabulary stop at their own id, not the constant)
    let eos = mumoe::model::config_by_name(model)
        .map(|c| c.eos_id)
        .unwrap_or(mumoe::model::EOS_ID);
    let mut ids = tok.encode(a.req("prompt")?, true);
    let t0 = std::time::Instant::now();
    for _ in 0..n_new {
        let start = ids.len().saturating_sub(seq); // sliding context window
        let window = ids[start..].to_vec();
        let (padded, valid) = tok.pad_to(window, seq);
        let mut tokens = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            tokens.extend_from_slice(&padded);
        }
        let outs = session.run(&[
            Input::I32(tokens, vec![batch, seq]),
            Input::I32(vec![valid as i32; batch], vec![batch]),
            Input::ScalarF32(rho),
        ])?;
        let logits = literal_f32(&outs[0])?;
        let vocab = logits.len() / batch;
        let next = mumoe::coordinator::request::argmax(&logits[..vocab]);
        if next == eos {
            break;
        }
        ids.push(next);
    }
    let dt = t0.elapsed().as_secs_f64();
    println!("{}", tok.decode(&ids));
    println!(
        "\n[device decode: rho={rho}, {n_new} new tokens in {dt:.1}s = {:.2} tok/s]",
        n_new as f64 / dt.max(1e-9)
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// eval
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
fn cmd_eval(_rest: &[String]) -> Result<(), Error> {
    pjrt_unavailable("eval")
}

#[cfg(feature = "pjrt")]
const EVAL_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory", "artifacts"),
    opt("model", "model name", "mu-opt-micro"),
    opt("method", "dense|magnitude|wanda|sparsegpt|mumoe", "mumoe"),
    opt("rho", "active-weight ratio", "0.5"),
    opt("dataset", "test corpus", "synth_wiki"),
    opt("calib", "calibration corpus (wanda/sparsegpt)", "synth_web"),
    opt("windows", "max eval windows", "16"),
    opt("calib-windows", "calibration windows", "8"),
];

#[cfg(feature = "pjrt")]
fn cmd_eval(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("eval", "one perplexity cell", EVAL_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, EVAL_SPEC)?;
    let dir = std::path::PathBuf::from(a.req("artifacts")?);
    let model = a.req("model")?;
    let method = a.req("method")?;
    let rho = a.get_f64("rho")?;

    use mumoe::data::corpus::Corpus;
    use mumoe::eval::harness::EvalStack;

    let stack = EvalStack::open(&dir, model)?;
    let test = Corpus::load(&dir.join("data"), a.req("dataset")?, "test")?;
    let windows = test.eval_windows(stack.cfg.max_seq_len, a.get_usize("windows")?);

    let ppl = match method {
        "dense" => stack.perplexity(&stack.ckpt.clone(), &windows, None)?,
        "mumoe" => stack.perplexity(&stack.ckpt.clone(), &windows, Some(rho))?,
        "magnitude" => {
            let v = stack.variant_magnitude(rho)?;
            stack.perplexity(&v, &windows, None)?
        }
        "wanda" | "sparsegpt" => {
            let calib_corpus =
                Corpus::load(&dir.join("data"), a.req("calib")?, "train")?;
            let cwin = calib_corpus
                .eval_windows(stack.cfg.max_seq_len, a.get_usize("calib-windows")?);
            let stats = stack.calibrate(&cwin)?;
            let v = if method == "wanda" {
                stack.variant_wanda(&stats, rho)?
            } else {
                stack.variant_sparsegpt(&stats, rho)?
            };
            stack.perplexity(&v, &windows, None)?
        }
        other => return Err(Error::config(format!("unknown method '{other}'"))),
    };
    println!(
        "model={model} method={method} rho={rho} dataset={} ppl={:.2} \
         (over {} tokens)",
        a.req("dataset")?,
        ppl.value(),
        ppl.token_count
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// vlm-eval
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
fn cmd_vlm_eval(_rest: &[String]) -> Result<(), Error> {
    pjrt_unavailable("vlm-eval")
}

#[cfg(feature = "pjrt")]
const VLM_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory", "artifacts"),
    opt("method", "dense|magnitude|wanda|sparsegpt|mumoe", "mumoe"),
    opt("rho", "active-weight ratio", "0.6"),
    opt("dataset", "synthqa|synthvqa", "synthqa"),
    opt("limit", "max eval records", "64"),
    opt("calib-samples", "cross-task calibration samples", "32"),
];

#[cfg(feature = "pjrt")]
fn cmd_vlm_eval(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("vlm-eval", "mu-VLM accuracy cell", VLM_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, VLM_SPEC)?;
    let dir = std::path::PathBuf::from(a.req("artifacts")?);
    let method = a.req("method")?;
    let rho = a.get_f64("rho")?;
    let dataset = a.req("dataset")?;

    use mumoe::data::qa::QaSet;
    use mumoe::eval::vlm_harness::VlmStack;

    let stack = VlmStack::open(&dir)?;
    let test = QaSet::load(&dir.join("data").join(format!("{dataset}.test.bin")))?;
    let limit = a.get_usize("limit")?;

    let acc = match method {
        "dense" => stack.accuracy(&stack.ckpt.clone(), &test, None, limit)?,
        "mumoe" => stack.accuracy(&stack.ckpt.clone(), &test, Some(rho), limit)?,
        "magnitude" => {
            let v = stack.variant_magnitude(rho)?;
            stack.accuracy(&v, &test, None, limit)?
        }
        "wanda" | "sparsegpt" => {
            // cross-task calibration, as in the paper
            let other = if dataset == "synthqa" { "synthvqa" } else { "synthqa" };
            let calib_set =
                QaSet::load(&dir.join("data").join(format!("{other}.train.bin")))?;
            let calib = stack.calibrate(&calib_set, a.get_usize("calib-samples")?)?;
            let v = if method == "wanda" {
                stack.variant_wanda(&calib, rho)?
            } else {
                stack.variant_sparsegpt(&calib, rho)?
            };
            stack.accuracy(&v, &test, None, limit)?
        }
        other => return Err(Error::config(format!("unknown method '{other}'"))),
    };
    print!("method={method} rho={rho} dataset={dataset}:");
    for (name, pct) in acc.row() {
        print!(" {name}={pct:.2}");
    }
    println!();
    Ok(())
}

// ---------------------------------------------------------------------------
// flops
// ---------------------------------------------------------------------------

const FLOPS_SPEC: &[OptSpec] = &[
    opt("arch", "mu-opt-* name or opt:<layers>:<dmodel>", "opt:40:5120"),
    opt("tokens", "sequence length", "128"),
    opt("rhos", "active ratios", "1.0,0.8,0.6,0.4,0.2"),
];

fn cmd_flops(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("flops", "Table-4 analysis", FLOPS_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, FLOPS_SPEC)?;
    let arch = parse_arch(a.req("arch")?)?;
    let t = a.get_usize("tokens")?;
    let mut table = mumoe::benchlib::Table::new(
        format!("FLOPs/MACs at T={t} ({})", a.req("arch")?),
        &["Active Weights", "FLOPs", "MACs"],
    );
    for rho in a.get_f64_list("rhos")? {
        let c = mumoe::flops::count_forward(arch, t, rho, true);
        table.row(vec![
            format!("{:.0}%", rho * 100.0),
            format!("{:.2}T", c.tflops()),
            format!("{:.0}G", c.gmacs()),
        ]);
    }
    table.print();
    Ok(())
}

fn parse_arch(s: &str) -> Result<mumoe::flops::ArchShape, Error> {
    if let Some(cfg) = mumoe::model::config_by_name(s) {
        return Ok(mumoe::flops::ArchShape::of(&cfg));
    }
    let parts: Vec<&str> = s.split(':').collect();
    if parts.len() == 3 && parts[0] == "opt" {
        let layers = parts[1]
            .parse()
            .map_err(|_| Error::config("bad layer count"))?;
        let d = parts[2]
            .parse()
            .map_err(|_| Error::config("bad d_model"))?;
        return Ok(mumoe::flops::ArchShape::opt(layers, d));
    }
    Err(Error::config(format!("unknown arch '{s}'")))
}

// ---------------------------------------------------------------------------
// selection
// ---------------------------------------------------------------------------

const SEL_SPEC: &[OptSpec] = &[
    opt("dims", "embedding sizes", "512,1024,2048,4096"),
    opt("rhos", "active ratios", "0.25,0.5,0.75"),
];

fn cmd_selection(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("selection", "Figure-3 timing", SEL_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, SEL_SPEC)?;
    use mumoe::benchlib::{Bencher, Table};
    use mumoe::pruning::selection::{wanda_prune_with, Selector};
    use mumoe::util::rng::Pcg32;

    let bencher = Bencher::default();
    let mut table = Table::new(
        "Wanda selection runtime (ms, per (d x d) linear)",
        &["d", "rho", "sort", "topk", "kthvalue"],
    );
    for d in a.get_str_list("dims")? {
        let d: usize = d.parse().map_err(|_| Error::config("bad dim"))?;
        let mut rng = Pcg32::new(7, d as u64);
        let w = rng.normal_vec(d * d);
        let norms: Vec<f32> = (0..d).map(|_| rng.next_f32() + 0.1).collect();
        for rho in a.get_f64_list("rhos")? {
            let mut cells = vec![format!("{d}"), format!("{rho}")];
            for sel in Selector::ALL {
                let stats = bencher.run(|| {
                    let mut wc = w.clone();
                    let mut scratch = Vec::new();
                    wanda_prune_with(sel, &mut wc, d, d, &norms, rho, &mut scratch);
                    wc
                });
                cells.push(format!("{:.3}", stats.mean_ms()));
            }
            table.row(cells);
        }
    }
    table.print();
    Ok(())
}

// ---------------------------------------------------------------------------
// overlap
// ---------------------------------------------------------------------------

const OVERLAP_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory", "artifacts"),
    opt("model", "model name", "mu-opt-micro"),
    opt("rho", "active ratio for the probe", "0.5"),
    opt("prompts", "prompts per domain", "3"),
];

fn cmd_overlap(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("overlap", "expert overlap", OVERLAP_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, OVERLAP_SPEC)?;
    let dir = std::path::PathBuf::from(a.req("artifacts")?);
    let model_name = a.req("model")?;
    let rho = a.get_f64("rho")?;
    let n = a.get_usize("prompts")?;

    use mumoe::data::corpus::Corpus;
    use mumoe::model::checkpoint::Checkpoint;
    use mumoe::model::config_by_name;
    use mumoe::nn::Model;
    use mumoe::util::rng::Pcg32;

    let cfg = config_by_name(model_name)
        .ok_or_else(|| Error::config(format!("unknown model '{model_name}'")))?;
    let ckpt =
        Checkpoint::load(&dir.join("ckpt").join(format!("{model_name}.ckpt")))?;
    let model = Model::from_checkpoint(&cfg, &ckpt)?;
    let mut rng = Pcg32::new(99, 0);

    let mut within = Vec::new();
    let mut all = Vec::new();
    for domain in mumoe::data::DOMAINS {
        let corpus = Corpus::load(&dir.join("data"), domain, "test")?;
        let sels: Vec<_> = (0..n)
            .map(|_| {
                let w = corpus.sample_window(&mut rng, 64);
                mumoe::moe::select_experts(&model, &w.tokens, w.valid_len, rho)
            })
            .collect();
        let st = mumoe::moe::overlap(&sels);
        println!(
            "domain {domain}: mean within-domain expert overlap {:.4}",
            st.overall
        );
        within.push(st.overall);
        all.extend(sels);
    }
    let cross = mumoe::moe::overlap(&all);
    println!(
        "cross-domain overlap {:.4} (within-domain mean {:.4}) — lower cross \
         overlap = prompt-dependent micro-expert selection",
        cross.overall,
        within.iter().sum::<f64>() / within.len() as f64
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// inspect
// ---------------------------------------------------------------------------

#[cfg(not(feature = "pjrt"))]
fn cmd_inspect(_rest: &[String]) -> Result<(), Error> {
    pjrt_unavailable("inspect")
}

#[cfg(feature = "pjrt")]
const INSPECT_SPEC: &[OptSpec] = &[
    opt("artifacts", "artifact directory", "artifacts"),
    flag("ckpts", "also summarize checkpoints"),
];

#[cfg(feature = "pjrt")]
fn cmd_inspect(rest: &[String]) -> Result<(), Error> {
    if wants_help(rest) {
        println!("{}", usage("inspect", "artifact summary", INSPECT_SPEC));
        return Ok(());
    }
    let a = Args::parse(rest, INSPECT_SPEC)?;
    let dir = std::path::PathBuf::from(a.req("artifacts")?);
    let client = mumoe::runtime::Client::cpu()?;
    let reg = mumoe::runtime::registry::Registry::open(&dir, client)?;
    let mut names = reg.names();
    names.sort();
    println!("{} artifacts:", names.len());
    for n in names {
        let m = reg.meta(n)?;
        println!(
            "  {:32} kind={:16} model={:12} batch={} seq={} outputs={}",
            m.name, m.kind, m.model, m.batch, m.seq_len, m.outputs
        );
    }
    if a.flag("ckpts") {
        for model in ["mu-opt-micro", "mu-opt-mini", "mu-opt-small", "mu-vlm"] {
            let p = reg.ckpt_path(model);
            match mumoe::model::checkpoint::Checkpoint::load(&p) {
                Ok(c) => println!(
                    "  ckpt {model}: {} tensors, {} params",
                    c.tensors.len(),
                    c.total_params()
                ),
                Err(e) => println!("  ckpt {model}: unavailable ({e})"),
            }
        }
    }
    Ok(())
}
