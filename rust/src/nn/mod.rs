//! Host-side reference μ-OPT forward pass (pure rust, no XLA).
//!
//! Three jobs:
//! 1. **Oracle** — integration tests cross-check the PJRT artifacts against
//!    this implementation on the same checkpoint (tests/runtime_oracle.rs).
//! 2. **CPU baseline** — the Figure 4 / Table 1 benches can run host-side
//!    when artifacts are absent, and `moe` uses it to extract per-layer
//!    activations for micro-expert analysis.
//! 3. **Offline-pruning substrate** — pruned-weight variants are plain
//!    weight edits before calling [`Model::forward`].
//!
//! Numerics mirror python/compile/model.py exactly: pre-LN blocks, causal
//! attention with right-padding masked, ReLU FFN, tied LM head.
//!
//! There is exactly one traversal, [`Model::forward_with`]; plain forwards
//! and activation collection are thin wrappers over it, so the two can
//! never drift apart. Parameter names are resolved once at construction
//! ([`LayerNames`]) — the hot loop allocates no format strings. The
//! `OnlineWanda` mode routes through the row-sparse kernels: score → mask
//! → [`crate::pruning::Mask::compress`] → `matmul_nt_sparse`, with no
//! dense zeroed weight copy anywhere.

use crate::model::checkpoint::Checkpoint;
use crate::model::{ModelConfig, PAD_ID};
use crate::pruning::wanda;
use crate::tensor::{layernorm_rows, log_softmax, relu, Mat};
use crate::util::error::Error;
use std::collections::HashMap;

/// Pruning mode for a host-side forward.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PruneMode {
    /// Full weights.
    Dense,
    /// μ-MoE: online Wanda per linear at the given active ratio, executed
    /// on the compressed row-sparse layout.
    OnlineWanda { rho: f64 },
}

/// Pre-resolved parameter names of one linear (`{prefix}.w` / `{prefix}.b`).
#[derive(Clone, Debug)]
pub struct LinearNames {
    pub w: String,
    pub b: String,
}

impl LinearNames {
    fn new(prefix: &str, lin: &str) -> LinearNames {
        LinearNames {
            w: format!("{prefix}.{lin}.w"),
            b: format!("{prefix}.{lin}.b"),
        }
    }
}

/// All parameter names of one transformer block, built once per model so
/// the forward loop never formats strings.
#[derive(Clone, Debug)]
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    ln2_g: String,
    ln2_b: String,
    q: LinearNames,
    k: LinearNames,
    v: LinearNames,
    o: LinearNames,
    fc1: LinearNames,
    fc2: LinearNames,
}

impl LayerNames {
    fn new(layer: usize) -> LayerNames {
        let p = format!("layers.{layer}");
        LayerNames {
            ln1_g: format!("{p}.ln1.g"),
            ln1_b: format!("{p}.ln1.b"),
            ln2_g: format!("{p}.ln2.g"),
            ln2_b: format!("{p}.ln2.b"),
            q: LinearNames::new(&p, "q"),
            k: LinearNames::new(&p, "k"),
            v: LinearNames::new(&p, "v"),
            o: LinearNames::new(&p, "o"),
            fc1: LinearNames::new(&p, "fc1"),
            fc2: LinearNames::new(&p, "fc2"),
        }
    }
}

/// Optional per-linear activation taps for [`Model::forward_with`]: maps
/// linear weight name → the (zero-padded) input activations that reached
/// that linear. Calibration and the μ-MoE overlap analysis consume this.
pub type ActivationTaps = HashMap<String, Mat>;

/// A loaded host model: config + named weight matrices/vectors.
pub struct Model {
    pub cfg: ModelConfig,
    mats: HashMap<String, Mat>,
    vecs: HashMap<String, Vec<f32>>,
    layer_names: Vec<LayerNames>,
}

impl Model {
    fn assemble(
        cfg: ModelConfig,
        mats: HashMap<String, Mat>,
        vecs: HashMap<String, Vec<f32>>,
    ) -> Model {
        let layer_names = (0..cfg.n_layers).map(LayerNames::new).collect();
        Model {
            cfg,
            mats,
            vecs,
            layer_names,
        }
    }

    pub fn from_checkpoint(cfg: &ModelConfig, ckpt: &Checkpoint) -> Result<Model, Error> {
        ckpt.validate_for(cfg)?;
        let mut mats = HashMap::new();
        let mut vecs = HashMap::new();
        for name in cfg.param_order() {
            let t = ckpt.get(&name)?;
            if t.dims.len() == 2 {
                mats.insert(name.clone(), t.as_mat()?);
            } else {
                vecs.insert(name.clone(), t.data.clone());
            }
        }
        Ok(Model::assemble(cfg.clone(), mats, vecs))
    }

    pub fn mat(&self, name: &str) -> &Mat {
        &self.mats[name]
    }

    pub fn vec(&self, name: &str) -> &[f32] {
        &self.vecs[name]
    }

    /// Replace a weight matrix (offline pruning writes pruned copies here).
    pub fn set_mat(&mut self, name: &str, m: Mat) {
        assert!(self.mats.contains_key(name), "unknown weight {name}");
        self.mats.insert(name.to_string(), m);
    }

    fn linear(&self, x: &Mat, names: &LinearNames, mode: PruneMode) -> Mat {
        self.linear_with_t(x, None, names, mode)
    }

    /// One linear under `mode`. `xt` may carry `x` already transposed so
    /// callers feeding several linears from the same activations (q/k/v)
    /// pay for one transpose instead of three on the sparse path.
    fn linear_with_t(
        &self,
        x: &Mat,
        xt: Option<&Mat>,
        names: &LinearNames,
        mode: PruneMode,
    ) -> Mat {
        let w = &self.mats[&names.w];
        let b = &self.vecs[&names.b];
        let mut y = match mode {
            PruneMode::Dense => x.matmul_nt(w),
            PruneMode::OnlineWanda { rho } => {
                // score against *this prompt's* activations, prune, and run
                // the compressed layout — the host mirror of the L1 fused
                // kernel. No dense zeroed copy of w is ever built.
                let mask = wanda::online_wanda_mask(w, x, rho);
                let rs = mask.compress(w);
                match xt {
                    Some(xt) => crate::tensor::matmul_tn_sparse(xt, &rs),
                    None => x.matmul_nt_sparse(&rs),
                }
            }
        };
        y.add_row_vec(b);
        y
    }

    /// Token + position embedding for a padded sequence.
    fn embed(&self, tokens: &[i32]) -> Mat {
        let d = self.cfg.d_model;
        let tok_emb = &self.mats["tok_emb"];
        let pos_emb = &self.mats["pos_emb"];
        let mut h = Mat::zeros(tokens.len(), d);
        for (i, &tok) in tokens.iter().enumerate() {
            let row = tok_emb.row(tok.clamp(0, self.cfg.vocab_size as i32 - 1) as usize);
            for j in 0..d {
                h.data[i * d + j] = row[j] + pos_emb.at(i, j);
            }
        }
        h
    }

    /// The single instrumented traversal every consumer shares.
    ///
    /// Runs one sequence through the model under `mode` and returns
    /// per-position logits (T, V). When `taps` is provided, the input
    /// activations of every prunable linear are recorded under the
    /// linear's weight name, zero-padded past `valid_len` — exactly what
    /// calibration and micro-expert selection need. Instrumentation costs
    /// nothing when `taps` is `None`.
    pub fn forward_with(
        &self,
        tokens: &[i32],
        valid_len: usize,
        mode: PruneMode,
        mut taps: Option<&mut ActivationTaps>,
    ) -> Mat {
        let cfg = &self.cfg;
        let t = tokens.len();
        assert!(t <= cfg.max_seq_len, "sequence too long");
        assert!(valid_len <= t);
        let mut h = self.embed(tokens);

        let record = |taps: &mut ActivationTaps, key: &str, x: &Mat| {
            let mut padded = x.clone();
            for i in valid_len..t {
                padded.row_mut(i).fill(0.0);
            }
            taps.insert(key.to_string(), padded);
        };

        for names in &self.layer_names {
            let y = layernorm_rows(&h, &self.vecs[&names.ln1_g], &self.vecs[&names.ln1_b], 1e-5);
            if let Some(taps) = taps.as_deref_mut() {
                for lin in [&names.q, &names.k, &names.v] {
                    record(taps, &lin.w, &y);
                }
            }
            // q/k/v consume the same activations: on the sparse path,
            // transpose y once and share it across the three linears
            let yt = match mode {
                PruneMode::OnlineWanda { .. } => Some(y.t()),
                PruneMode::Dense => None,
            };
            let q = self.linear_with_t(&y, yt.as_ref(), &names.q, mode);
            let k = self.linear_with_t(&y, yt.as_ref(), &names.k, mode);
            let v = self.linear_with_t(&y, yt.as_ref(), &names.v, mode);
            let attn = self.attention(&q, &k, &v, valid_len);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.o.w, &attn);
            }
            let o = self.linear(&attn, &names.o, mode);
            h.add_assign(&o);

            let y = layernorm_rows(&h, &self.vecs[&names.ln2_g], &self.vecs[&names.ln2_b], 1e-5);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.fc1.w, &y);
            }
            let mut z = self.linear(&y, &names.fc1, mode);
            relu(&mut z);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.fc2.w, &z);
            }
            let out = self.linear(&z, &names.fc2, mode);
            h.add_assign(&out);
        }

        let hidden = layernorm_rows(&h, &self.vecs["ln_f.g"], &self.vecs["ln_f.b"], 1e-5);
        // tied head -> (T, V); the largest matmul of the pass, worth the pool
        hidden.matmul_nt_auto(&self.mats["tok_emb"])
    }

    /// Forward one sequence (no batching host-side): returns per-position
    /// logits (T, V). `tokens` may include PAD; `valid_len` marks the
    /// boundary of real tokens.
    pub fn forward(&self, tokens: &[i32], valid_len: usize, mode: PruneMode) -> Mat {
        self.forward_with(tokens, valid_len, mode, None)
    }

    /// Collect per-linear input activations on a prompt (dense forward) —
    /// feeds host-side calibration and the μ-MoE overlap analysis.
    pub fn collect_activations(&self, tokens: &[i32], valid_len: usize) -> ActivationTaps {
        let mut taps = ActivationTaps::new();
        self.forward_with(tokens, valid_len, PruneMode::Dense, Some(&mut taps));
        taps
    }

    fn attention(&self, q: &Mat, k: &Mat, v: &Mat, valid_len: usize) -> Mat {
        let cfg = &self.cfg;
        let (t, d) = (q.rows, cfg.d_model);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Mat::zeros(t, d);
        let mut logits = vec![0.0f32; t];
        for h in 0..nh {
            let off = h * hd;
            for i in 0..t {
                let klim = (i + 1).min(t); // causal
                let qi = &q.row(i)[off..off + hd];
                let mut mx = f32::NEG_INFINITY;
                for (j, logit) in logits.iter_mut().enumerate().take(klim) {
                    if j >= valid_len && j != i {
                        *logit = f32::NEG_INFINITY;
                        continue;
                    }
                    let kj = &k.row(j)[off..off + hd];
                    let mut acc = 0.0f32;
                    for c in 0..hd {
                        acc += qi[c] * kj[c];
                    }
                    *logit = acc * scale;
                    mx = mx.max(*logit);
                }
                // softmax over 0..klim (padding rows attend to themselves)
                let mut denom = 0.0f32;
                for logit in logits.iter_mut().take(klim) {
                    if logit.is_finite() {
                        *logit = (*logit - mx).exp();
                        denom += *logit;
                    } else {
                        *logit = 0.0;
                    }
                }
                if denom <= 0.0 {
                    continue;
                }
                let orow = &mut out.data[i * d + off..i * d + off + hd];
                for j in 0..klim {
                    let p = logits[j] / denom;
                    if p == 0.0 {
                        continue;
                    }
                    let vj = &v.row(j)[off..off + hd];
                    for c in 0..hd {
                        orow[c] += p * vj[c];
                    }
                }
            }
        }
        out
    }

    /// Sum of next-token NLL + predicted count over the valid prefix —
    /// identical semantics to the `*_nll` artifacts.
    pub fn nll_sum(&self, tokens: &[i32], valid_len: usize, mode: PruneMode) -> (f64, usize) {
        let logits = self.forward(tokens, valid_len, mode);
        let mut sum = 0.0f64;
        let mut count = 0usize;
        for t in 0..valid_len.saturating_sub(1) {
            let target = tokens[t + 1];
            if target == PAD_ID {
                break;
            }
            let ls = log_softmax(logits.row(t));
            sum -= ls[target as usize] as f64;
            count += 1;
        }
        (sum, count)
    }

    /// All prunable linears' (name, weight) pairs — pruning engines iterate
    /// this to produce offline-pruned model variants.
    pub fn prunable(&self) -> Vec<(String, &Mat)> {
        self.cfg
            .linear_names()
            .into_iter()
            .map(|n| {
                let m = &self.mats[&n];
                (n, m)
            })
            .collect()
    }

    /// Apply offline Wanda pruning in place given per-linear calibrators.
    pub fn apply_offline_wanda(
        &mut self,
        calibs: &HashMap<String, wanda::WandaCalibrator>,
        rho: f64,
    ) -> Result<(), Error> {
        for name in self.cfg.linear_names() {
            let calib = calibs
                .get(&name)
                .ok_or_else(|| Error::invariant(format!("missing calibrator for {name}")))?;
            let w = self.mats.get_mut(&name).expect("linear weight present");
            let mask = wanda::wanda_mask(w, calib, rho);
            mask.apply_in_place(w);
        }
        Ok(())
    }

    /// Apply magnitude pruning in place.
    pub fn apply_magnitude(&mut self, rho: f64) {
        for name in self.cfg.linear_names() {
            let w = self.mats.get_mut(&name).expect("linear weight present");
            let mask = crate::pruning::magnitude::magnitude_mask(w, rho);
            mask.apply_in_place(w);
        }
    }
}

/// Deterministic random model for tests (no checkpoint needed).
pub fn random_model(cfg: &ModelConfig, seed: u64) -> Model {
    use crate::util::rng::Pcg32;
    let mut rng = Pcg32::new(seed, 99);
    let mut mats = HashMap::new();
    let mut vecs = HashMap::new();
    let (d, di) = (cfg.d_model, cfg.d_inner());
    for name in cfg.param_order() {
        if name.ends_with(".w") || name == "tok_emb" || name == "pos_emb" {
            let (r, c) = if name == "tok_emb" {
                (cfg.vocab_size, d)
            } else if name == "pos_emb" {
                (cfg.max_seq_len, d)
            } else if name.ends_with("fc1.w") {
                (di, d)
            } else if name.ends_with("fc2.w") {
                (d, di)
            } else {
                (d, d)
            };
            let mut data = rng.normal_vec(r * c);
            for x in &mut data {
                *x *= 0.05;
            }
            mats.insert(name, Mat::from_vec(r, c, data));
        } else if name.ends_with(".g") {
            vecs.insert(name.clone(), vec![1.0; ln_dim(cfg, &name)]);
        } else {
            vecs.insert(name.clone(), vec![0.0; bias_dim(cfg, &name)]);
        }
    }
    Model::assemble(cfg.clone(), mats, vecs)
}

fn ln_dim(cfg: &ModelConfig, _name: &str) -> usize {
    cfg.d_model
}

fn bias_dim(cfg: &ModelConfig, name: &str) -> usize {
    if name.ends_with("fc1.b") {
        cfg.d_inner()
    } else {
        cfg.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::new("test-tiny", 2, 2, 16)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = random_model(&tiny(), 1);
        let toks: Vec<i32> = vec![10, 20, 30, 40, PAD_ID, PAD_ID];
        let logits = m.forward(&toks, 4, PruneMode::Dense);
        assert_eq!(logits.rows, 6);
        assert_eq!(logits.cols, m.cfg.vocab_size);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn padding_does_not_change_valid_logits() {
        let m = random_model(&tiny(), 2);
        let a: Vec<i32> = vec![5, 6, 7, PAD_ID];
        let b: Vec<i32> = vec![5, 6, 7, 200];
        let la = m.forward(&a, 3, PruneMode::Dense);
        let lb = m.forward(&b, 3, PruneMode::Dense);
        for t in 0..3 {
            for v in 0..m.cfg.vocab_size {
                assert!((la.at(t, v) - lb.at(t, v)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn online_rho1_matches_dense() {
        let m = random_model(&tiny(), 3);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5];
        let d = m.forward(&toks, 5, PruneMode::Dense);
        let p = m.forward(&toks, 5, PruneMode::OnlineWanda { rho: 1.0 });
        for (x, y) in d.data.iter().zip(&p.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn online_pruning_changes_output() {
        let m = random_model(&tiny(), 4);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5];
        let d = m.forward(&toks, 5, PruneMode::Dense);
        let p = m.forward(&toks, 5, PruneMode::OnlineWanda { rho: 0.4 });
        let diff: f32 = d
            .data
            .iter()
            .zip(&p.data)
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn online_sparse_path_matches_masked_dense_reference() {
        // the sparse execution engine must be numerically identical to the
        // old dense-masked formulation, layer by layer
        use crate::pruning::wanda::online_wanda_mask;
        let m = random_model(&tiny(), 8);
        let toks: Vec<i32> = vec![3, 1, 4, 1, 5, 9];
        let acts = m.collect_activations(&toks, 6);
        for (name, w) in m.prunable() {
            let x = &acts[&name];
            let mask = online_wanda_mask(w, x, 0.5);
            let dense_ref = x.matmul_nt(&mask.apply(w));
            let sparse = x.matmul_nt_sparse(&mask.compress(w));
            for (a, b) in sparse.data.iter().zip(&dense_ref.data) {
                assert!((a - b).abs() < 1e-5, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn nll_counts_valid_predictions() {
        let m = random_model(&tiny(), 5);
        let toks: Vec<i32> = vec![1, 2, 3, 4, PAD_ID, PAD_ID];
        let (sum, count) = m.nll_sum(&toks, 4, PruneMode::Dense);
        assert_eq!(count, 3);
        assert!(sum > 0.0);
    }

    #[test]
    fn magnitude_pruning_applies() {
        let mut m = random_model(&tiny(), 6);
        m.apply_magnitude(0.5);
        for (name, w) in m.prunable() {
            assert!(
                (w.sparsity() - 0.5).abs() < 0.1,
                "{name}: {}",
                w.sparsity()
            );
        }
    }

    #[test]
    fn collect_activations_covers_all_linears() {
        let m = random_model(&tiny(), 7);
        let acts = m.collect_activations(&[1, 2, 3, 4], 4);
        for n in m.cfg.linear_names() {
            assert!(acts.contains_key(&n), "{n}");
        }
        // activation width matches the linear's input dim
        assert_eq!(acts["layers.0.fc2.w"].cols, m.cfg.d_inner());
    }

    #[test]
    fn instrumented_forward_matches_plain_forward() {
        // taps must be observation-only: same logits with and without
        let m = random_model(&tiny(), 9);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5, PAD_ID];
        let plain = m.forward(&toks, 5, PruneMode::Dense);
        let mut taps = ActivationTaps::new();
        let tapped = m.forward_with(&toks, 5, PruneMode::Dense, Some(&mut taps));
        assert_eq!(plain.data, tapped.data);
        assert_eq!(taps.len(), m.cfg.linear_names().len());
        // taps are zero-padded past valid_len
        for (name, x) in &taps {
            assert!(x.row(5).iter().all(|&v| v == 0.0), "{name}");
        }
    }
}
