//! Host-side reference μ-OPT forward pass (pure rust, no XLA).
//!
//! Three jobs:
//! 1. **Oracle** — integration tests cross-check the PJRT artifacts against
//!    this implementation on the same checkpoint (tests/runtime_oracle.rs).
//! 2. **CPU baseline** — the Figure 4 / Table 1 benches can run host-side
//!    when artifacts are absent, and `moe` uses it to extract per-layer
//!    activations for micro-expert analysis.
//! 3. **Offline-pruning substrate** — pruned-weight variants are plain
//!    weight edits before calling [`Model::forward`].
//!
//! Numerics mirror python/compile/model.py exactly: pre-LN blocks, causal
//! attention with right-padding masked, ReLU FFN, tied LM head.
//!
//! There is exactly one traversal, [`Model::forward_with`]; plain forwards
//! and activation collection are thin wrappers over it, so the two can
//! never drift apart. Parameter names are resolved once at construction
//! ([`LayerNames`]) — the hot loop allocates no format strings. The
//! `OnlineWanda` mode routes through the row-sparse kernels: score → mask
//! → [`crate::pruning::Mask::compress`] → `matmul_nt_sparse`, with no
//! dense zeroed weight copy anywhere.

pub mod kv;

use crate::model::checkpoint::Checkpoint;
use crate::model::{ModelConfig, PAD_ID};
use crate::pruning::wanda;
use crate::tensor::{
    layernorm_row_into, layernorm_rows, log_softmax, matmul_tn_sparse_auto,
    matmul_tn_sparse_auto_into, matvec_nt_sparse_into, quant_matmul_tn, quant_matmul_tn_into,
    quant_matvec_nt_into, relu, Mat, RowSparse,
};
use crate::trace::StepProfile;
use crate::util::error::Error;
pub use kv::KvCache;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Process-unique id generator for weight-set identity (see
/// [`Model::weights_id`]). Starts at 1 so 0 can serve as a "no model"
/// sentinel in tests.
static WEIGHTS_ID: AtomicU64 = AtomicU64::new(1);

fn next_weights_id() -> u64 {
    WEIGHTS_ID.fetch_add(1, Ordering::Relaxed)
}

/// Pruning mode for a host-side forward.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PruneMode {
    /// Full weights.
    Dense,
    /// μ-MoE: online Wanda per linear at the given active ratio, executed
    /// on the compressed row-sparse layout.
    OnlineWanda { rho: f64 },
}

/// Per-linear compressed layouts for a fixed-selection forward — what the
/// decode engine reuses across steps (see [`Model::forward_fixed`]).
pub type FixedLayouts = HashMap<String, Arc<RowSparse>>;

/// Lap timer behind the sampled kernel-attribution forwards
/// ([`Model::forward_step_profiled`] and the batch variant): constructed
/// only when a [`StepProfile`] is being filled, so the unprofiled step
/// path never reads the clock.
struct KernelLaps<'a> {
    prof: &'a mut StepProfile,
    mark: Instant,
}

impl<'a> KernelLaps<'a> {
    fn new(prof: &'a mut StepProfile) -> KernelLaps<'a> {
        KernelLaps {
            prof,
            mark: Instant::now(),
        }
    }

    /// Microseconds since the previous mark, advancing the mark.
    fn lap_us(&mut self) -> u64 {
        let now = Instant::now();
        let us = now.duration_since(self.mark).as_micros() as u64;
        self.mark = now;
        us
    }

    fn linear(&mut self) {
        let us = self.lap_us();
        self.prof.linear_us += us;
    }

    fn attention(&mut self) {
        let us = self.lap_us();
        self.prof.attention_us += us;
    }

    fn other(&mut self) {
        let us = self.lap_us();
        self.prof.other_us += us;
    }
}

/// Charge the time since the last lap to one [`StepProfile`] bucket, iff
/// the forward is being profiled (`$laps` is an `Option<KernelLaps>`).
macro_rules! lap {
    ($laps:expr, $bucket:ident) => {
        if let Some(l) = $laps.as_mut() {
            l.$bucket();
        }
    };
}

/// Reusable per-lane row buffers for [`Model::forward_step_with`].
///
/// A decode step's intermediates are a handful of `d_model`/`d_inner`-
/// sized rows; allocating them fresh every step (the PR-4 shape) made the
/// steady-state step path pay ~10 heap allocations per token. One
/// `StepScratch` per decode lane — owned alongside the lane's [`KvCache`]
/// and reused the same way — makes the step allocation-free except for
/// the returned logits row. Every buffer is fully overwritten before it
/// is read, so reuse is bit-identical to allocation (property-tested in
/// `proptest.rs::kv_props`, including across refresh rebuilds).
pub struct StepScratch {
    /// Residual stream row (`d_model`).
    h: Vec<f32>,
    /// Post-layernorm activations row (`d_model`).
    norm: Vec<f32>,
    /// Attention projections (`d_model` each).
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention output row (`d_model`).
    attn: Vec<f32>,
    /// o / fc2 projection output row (`d_model`).
    proj: Vec<f32>,
    /// FFN inner row (`d_inner`).
    inner: Vec<f32>,
    /// Attention score scratch (`max_seq_len`; the step uses `pos + 1`).
    attn_logits: Vec<f32>,
    /// Width this scratch was sized for (shape check against the model).
    d_model: usize,
}

impl StepScratch {
    /// Preallocate every step buffer for `cfg`'s widths.
    pub fn new(cfg: &ModelConfig) -> StepScratch {
        let (d, di) = (cfg.d_model, cfg.d_inner());
        StepScratch {
            h: Vec::with_capacity(d),
            norm: vec![0.0; d],
            q: Vec::with_capacity(d),
            k: Vec::with_capacity(d),
            v: Vec::with_capacity(d),
            attn: vec![0.0; d],
            proj: Vec::with_capacity(d),
            inner: Vec::with_capacity(di),
            attn_logits: vec![0.0; cfg.max_seq_len],
            d_model: d,
        }
    }

    /// Does this scratch match `cfg`'s widths?
    pub fn fits(&self, cfg: &ModelConfig) -> bool {
        self.d_model == cfg.d_model && self.attn_logits.len() >= cfg.max_seq_len
    }
}

/// Reusable matrix buffers for [`Model::forward_step_batch_with`] — the
/// matrix-major analogue of [`StepScratch`].
///
/// A fused sweep stacks N same-layout lanes' step rows into (N, width)
/// matrices so each linear runs as **one** sparse matmul instead of N
/// matvecs. All intermediates (residual stream, post-LN activations, the
/// q/k/v/attention/projection/FFN matrices, plus the transposed input and
/// output staging the sparse kernel's `*_into` forms consume) live here
/// and are reshaped per call via [`Mat::resize_zeroed`] /
/// [`Mat::transpose_into`], so a steady-state fused sweep allocates only
/// the returned logits matrix. Every buffer is fully overwritten before it
/// is read — reuse is bit-identical to allocation by construction.
pub struct StepBatchScratch {
    /// Residual stream rows, one per lane (N, d_model).
    h: Mat,
    /// Post-layernorm activation rows (N, d_model).
    norm: Mat,
    /// Transposed linear input (width, N) — shared across q/k/v.
    xt: Mat,
    /// Transposed linear output staging (d_out, N).
    yt: Mat,
    /// Attention projections (N, d_model each).
    q: Mat,
    k: Mat,
    v: Mat,
    /// Attention output rows (N, d_model).
    attn: Mat,
    /// o / fc2 projection outputs (N, d_model).
    proj: Mat,
    /// FFN inner rows (N, d_inner).
    inner: Mat,
    /// Attention score scratch (`max_seq_len`; shared across lanes — each
    /// lane's attention row overwrites it before reading).
    attn_logits: Vec<f32>,
    /// Per-lane window positions, captured at entry.
    pos: Vec<usize>,
    /// Width this scratch was sized for (shape check against the model).
    d_model: usize,
}

impl StepBatchScratch {
    /// Preallocate every buffer for `cfg`'s widths and up to `max_lanes`
    /// fused lanes (smaller groups reuse the same backing storage).
    pub fn new(cfg: &ModelConfig, max_lanes: usize) -> StepBatchScratch {
        let (d, di, n) = (cfg.d_model, cfg.d_inner(), max_lanes.max(1));
        StepBatchScratch {
            h: Mat::zeros(n, d),
            norm: Mat::zeros(n, d),
            xt: Mat::zeros(d, n),
            yt: Mat::zeros(di, n),
            q: Mat::zeros(n, d),
            k: Mat::zeros(n, d),
            v: Mat::zeros(n, d),
            attn: Mat::zeros(n, d),
            proj: Mat::zeros(n, d),
            inner: Mat::zeros(n, di),
            attn_logits: vec![0.0; cfg.max_seq_len],
            pos: Vec::with_capacity(n),
            d_model: d,
        }
    }

    /// Does this scratch match `cfg`'s widths?
    pub fn fits(&self, cfg: &ModelConfig) -> bool {
        self.d_model == cfg.d_model && self.attn_logits.len() >= cfg.max_seq_len
    }
}

/// Internal execution mode of the single traversal: how each prunable
/// linear runs. `PruneMode` is the stable public surface; `Exec` adds the
/// fixed-layout form the decode engine needs without making the public
/// enum carry lifetimes.
enum Exec<'a> {
    Dense,
    Online { rho: f64 },
    Fixed { layouts: &'a FixedLayouts },
}

impl Exec<'_> {
    /// Sparse-path linears consume pre-transposed activations.
    fn is_sparse(&self) -> bool {
        !matches!(self, Exec::Dense)
    }
}

/// Which logits rows the head computes. The LM head is the largest
/// matmul of the pass, so traversals that don't consume logits must not
/// pay for it.
#[derive(Clone, Copy, PartialEq)]
enum Head {
    /// Full (T, V) logits — evaluation and calibration.
    All,
    /// Only the last valid position's (1, V) row — the decode hot path.
    LastValid,
    /// No logits at all — taps-only traversals (activation collection for
    /// micro-expert selection) return an empty matrix and skip the final
    /// layernorm + head matmul entirely.
    None,
}

/// Pre-resolved parameter names of one linear (`{prefix}.w` / `{prefix}.b`).
#[derive(Clone, Debug)]
pub struct LinearNames {
    pub w: String,
    pub b: String,
}

impl LinearNames {
    fn new(prefix: &str, lin: &str) -> LinearNames {
        LinearNames {
            w: format!("{prefix}.{lin}.w"),
            b: format!("{prefix}.{lin}.b"),
        }
    }
}

/// All parameter names of one transformer block, built once per model so
/// the forward loop never formats strings.
#[derive(Clone, Debug)]
struct LayerNames {
    ln1_g: String,
    ln1_b: String,
    ln2_g: String,
    ln2_b: String,
    q: LinearNames,
    k: LinearNames,
    v: LinearNames,
    o: LinearNames,
    fc1: LinearNames,
    fc2: LinearNames,
}

impl LayerNames {
    fn new(layer: usize) -> LayerNames {
        let p = format!("layers.{layer}");
        LayerNames {
            ln1_g: format!("{p}.ln1.g"),
            ln1_b: format!("{p}.ln1.b"),
            ln2_g: format!("{p}.ln2.g"),
            ln2_b: format!("{p}.ln2.b"),
            q: LinearNames::new(&p, "q"),
            k: LinearNames::new(&p, "k"),
            v: LinearNames::new(&p, "v"),
            o: LinearNames::new(&p, "o"),
            fc1: LinearNames::new(&p, "fc1"),
            fc2: LinearNames::new(&p, "fc2"),
        }
    }
}

/// Optional per-linear activation taps for [`Model::forward_with`]: maps
/// linear weight name → the (zero-padded) input activations that reached
/// that linear. Calibration and the μ-MoE overlap analysis consume this.
pub type ActivationTaps = HashMap<String, Mat>;

/// A loaded host model: config + named weight matrices/vectors.
pub struct Model {
    pub cfg: ModelConfig,
    mats: HashMap<String, Mat>,
    vecs: HashMap<String, Vec<f32>>,
    layer_names: Vec<LayerNames>,
    weights_id: u64,
}

impl Model {
    fn assemble(
        cfg: ModelConfig,
        mats: HashMap<String, Mat>,
        vecs: HashMap<String, Vec<f32>>,
    ) -> Model {
        let layer_names = (0..cfg.n_layers).map(LayerNames::new).collect();
        Model {
            cfg,
            mats,
            vecs,
            layer_names,
            weights_id: next_weights_id(),
        }
    }

    /// Identity of this model's current weight values, for use in
    /// weight-derived cache keys ([`crate::tensor::LayoutKey`]): unique
    /// per live model and refreshed by every weight mutation, so a shared
    /// [`crate::tensor::LayoutCache`] can never serve one model's
    /// compressed layouts to another (or stale layouts after offline
    /// pruning edited the weights in place).
    pub fn weights_id(&self) -> u64 {
        self.weights_id
    }

    pub fn from_checkpoint(cfg: &ModelConfig, ckpt: &Checkpoint) -> Result<Model, Error> {
        ckpt.validate_for(cfg)?;
        let mut mats = HashMap::new();
        let mut vecs = HashMap::new();
        for name in cfg.param_order() {
            let t = ckpt.get(&name)?;
            if t.dims.len() == 2 {
                mats.insert(name.clone(), t.as_mat()?);
            } else {
                vecs.insert(name.clone(), t.data.clone());
            }
        }
        Ok(Model::assemble(cfg.clone(), mats, vecs))
    }

    pub fn mat(&self, name: &str) -> &Mat {
        &self.mats[name]
    }

    pub fn vec(&self, name: &str) -> &[f32] {
        &self.vecs[name]
    }

    /// Replace a weight matrix (offline pruning writes pruned copies here).
    pub fn set_mat(&mut self, name: &str, m: Mat) {
        assert!(self.mats.contains_key(name), "unknown weight {name}");
        self.mats.insert(name.to_string(), m);
        self.weights_id = next_weights_id();
    }

    fn linear(&self, x: &Mat, names: &LinearNames, exec: &Exec) -> Mat {
        self.linear_with_t(x, None, names, exec)
    }

    /// One linear under `exec`. `xt` may carry `x` already transposed so
    /// callers feeding several linears from the same activations (q/k/v)
    /// pay for one transpose instead of three on the sparse path.
    fn linear_with_t(&self, x: &Mat, xt: Option<&Mat>, names: &LinearNames, exec: &Exec) -> Mat {
        let w = &self.mats[&names.w];
        let b = &self.vecs[&names.b];
        // auto kernels: serial for decode-sized work, W-row-parallel for
        // prefill-sized layouts (bit-identical either way); layouts
        // carrying an int8 sidecar run the quantized kernels instead
        let sparse_mm = |rs: &RowSparse| {
            if let Some(q) = &rs.quant {
                return match xt {
                    Some(xt) => quant_matmul_tn(xt, q),
                    None => quant_matmul_tn(&x.t(), q),
                };
            }
            match xt {
                Some(xt) => matmul_tn_sparse_auto(xt, rs),
                None => x.matmul_nt_sparse_auto(rs),
            }
        };
        let mut y = match exec {
            Exec::Dense => x.matmul_nt(w),
            Exec::Online { rho } => {
                if crate::pruning::kc_for(w.cols, *rho) == 0 {
                    // full-density selection (rho=1.0 is a standard level):
                    // the mask would be all-ones whatever the scores, so
                    // skip scoring + compression and run the dense kernel
                    x.matmul_nt(w)
                } else {
                    // score against *this prompt's* activations, prune, and
                    // run the compressed layout — the host mirror of the L1
                    // fused kernel. No dense zeroed copy of w is ever built.
                    let mask = wanda::online_wanda_mask(w, x, *rho);
                    sparse_mm(&mask.compress(w))
                }
            }
            Exec::Fixed { layouts } => {
                // selection already happened (and was possibly cached);
                // execute the reused layout directly
                let rs = layouts
                    .get(&names.w)
                    .unwrap_or_else(|| panic!("no fixed layout for linear {}", names.w));
                sparse_mm(rs)
            }
        };
        y.add_row_vec(b);
        y
    }

    /// Token + position embedding for a padded sequence.
    fn embed(&self, tokens: &[i32]) -> Mat {
        let d = self.cfg.d_model;
        let tok_emb = &self.mats["tok_emb"];
        let pos_emb = &self.mats["pos_emb"];
        let mut h = Mat::zeros(tokens.len(), d);
        for (i, &tok) in tokens.iter().enumerate() {
            let row = tok_emb.row(tok.clamp(0, self.cfg.vocab_size as i32 - 1) as usize);
            for j in 0..d {
                h.data[i * d + j] = row[j] + pos_emb.at(i, j);
            }
        }
        h
    }

    /// The single instrumented traversal every consumer shares.
    ///
    /// Runs one sequence through the model under `mode` and returns
    /// per-position logits (T, V). When `taps` is provided, the input
    /// activations of every prunable linear are recorded under the
    /// linear's weight name, zero-padded past `valid_len` — exactly what
    /// calibration and micro-expert selection need. Instrumentation costs
    /// nothing when `taps` is `None`.
    pub fn forward_with(
        &self,
        tokens: &[i32],
        valid_len: usize,
        mode: PruneMode,
        taps: Option<&mut ActivationTaps>,
    ) -> Mat {
        let exec = match mode {
            PruneMode::Dense => Exec::Dense,
            PruneMode::OnlineWanda { rho } => Exec::Online { rho },
        };
        self.forward_exec(tokens, valid_len, &exec, taps, Head::All, None)
    }

    /// Forward under a *fixed* per-linear selection: every prunable linear
    /// executes a prebuilt [`RowSparse`] layout (see
    /// [`crate::decode`] for how these are selected and cached). Panics if
    /// a prunable linear has no layout — a partial map is a caller bug.
    pub fn forward_fixed(&self, tokens: &[i32], valid_len: usize, layouts: &FixedLayouts) -> Mat {
        self.forward_exec(tokens, valid_len, &Exec::Fixed { layouts }, None, Head::All, None)
    }

    /// [`Model::forward_fixed`] computing only the last valid position's
    /// logits row — the decode hot path. Row-for-row identical to slicing
    /// the full logits (each output row of the head matmul is accumulated
    /// independently, in the same k-order).
    pub fn forward_fixed_last(
        &self,
        tokens: &[i32],
        valid_len: usize,
        layouts: &FixedLayouts,
    ) -> Vec<f32> {
        self.forward_exec(
            tokens,
            valid_len,
            &Exec::Fixed { layouts },
            None,
            Head::LastValid,
            None,
        )
        .data
    }

    /// [`Model::forward_fixed_last`] that additionally records every
    /// block's K/V rows into `kv` — the *prefill* of an incremental
    /// decode. The cache is cleared first, so this is also how the decode
    /// engine **rebuilds** after a mask-plan refresh (new layouts ⇒ every
    /// cached row stale) or a window slide (absolute position embeddings
    /// ⇒ every cached row stale). Logits are bit-identical to
    /// `forward_fixed_last`: the recording only observes the k/v
    /// matrices the traversal already computed.
    ///
    /// `tokens` must be an unpadded window (`valid_len == tokens.len()`)
    /// — cached rows past the valid boundary would poison later steps.
    pub fn forward_prefill_last(
        &self,
        tokens: &[i32],
        valid_len: usize,
        layouts: &FixedLayouts,
        kv: &mut KvCache,
    ) -> Vec<f32> {
        assert_eq!(valid_len, tokens.len(), "prefill caches only unpadded windows");
        assert!(kv.fits(&self.cfg), "KvCache shape does not match model");
        kv.clear();
        self.forward_exec(
            tokens,
            valid_len,
            &Exec::Fixed { layouts },
            None,
            Head::LastValid,
            Some(kv),
        )
        .data
    }

    /// [`Model::forward_prefill_last`] for a cache whose first `from`
    /// positions were already **seeded** from a stored prefix
    /// (`KvCache::seed_from` / [`crate::kvstore`]): only the suffix
    /// `from..valid_len` is computed, by stepping each suffix token through
    /// [`Model::forward_step_with`]. Returns the last token's logits, like
    /// a full prefill would.
    ///
    /// Bit-identical to `forward_prefill_last` over the whole window when
    /// the seeded rows were produced at absolute positions `0..from` under
    /// the *same layouts*: each step is bit-identical to the full-window
    /// forward of its grown prefix (the `forward_step` ≡ full-window
    /// contract proven below and in `proptest.rs::kv_props`), and K/V rows
    /// for positions `0..from` depend only on those tokens and layouts.
    /// Cost: O((T−from)·T) attention instead of O(T²) — the whole point of
    /// the cross-request KV store.
    pub fn forward_prefill_suffix_last(
        &self,
        tokens: &[i32],
        valid_len: usize,
        from: usize,
        layouts: &FixedLayouts,
        kv: &mut KvCache,
        s: &mut StepScratch,
    ) -> Vec<f32> {
        assert_eq!(valid_len, tokens.len(), "prefill caches only unpadded windows");
        assert!(
            from >= 1 && from < valid_len,
            "suffix prefill needs 1 <= from < valid_len"
        );
        assert_eq!(kv.len(), from, "cache must hold exactly the seeded prefix");
        let mut logits = Vec::new();
        for &tok in &tokens[from..valid_len] {
            logits = self.forward_step_with(tok, layouts, kv, s);
        }
        logits
    }

    /// One incremental decode step: run a *single token* through every
    /// block, reading the window prefix's K/V from `kv` (populated by
    /// [`Model::forward_prefill_last`] and prior steps) and appending the
    /// new position's rows. Returns the next-token logits row.
    ///
    /// Allocating convenience form of [`Model::forward_step_with`]: builds
    /// a fresh [`StepScratch`] per call. Decode lanes hold one scratch and
    /// call `forward_step_with` instead, making the steady-state step path
    /// allocation-free apart from the returned logits row.
    pub fn forward_step(&self, token: i32, layouts: &FixedLayouts, kv: &mut KvCache) -> Vec<f32> {
        let mut scratch = StepScratch::new(&self.cfg);
        self.forward_step_with(token, layouts, kv, &mut scratch)
    }

    /// [`Model::forward_step`] through a caller-owned [`StepScratch`]:
    /// every per-layer row vector (post-LN activations, q/k/v, attention
    /// output, projections, the FFN inner row, attention score scratch)
    /// lives in reused buffers instead of fresh heap allocations — the
    /// same buffer-reuse discipline [`KvCache`] applies to K/V rows.
    ///
    /// Bit-identical both to `forward_fixed_last` over the grown window
    /// (every per-row operation — embedding add, layernorm, the
    /// [`crate::tensor::matvec_nt_sparse_into`] linears, the causal
    /// attention row, residual adds, the last-row LM head — accumulates in
    /// exactly the order the full traversal uses for its last row) and to
    /// a fresh scratch per step (every buffer is fully overwritten before
    /// it is read; `proptest.rs::kv_props` proves both compositions,
    /// including across a refresh rebuild).
    ///
    /// Cost: O(T) attention + O(nnz) linears per step, vs the full
    /// window's O(T²) + O(T·nnz).
    pub fn forward_step_with(
        &self,
        token: i32,
        layouts: &FixedLayouts,
        kv: &mut KvCache,
        s: &mut StepScratch,
    ) -> Vec<f32> {
        self.forward_step_profiled(token, layouts, kv, s, None)
    }

    /// [`Model::forward_step_with`] with optional sampled kernel
    /// attribution: when `prof` is `Some`, the step's wall time is split
    /// into the profile's linear / attention / other buckets
    /// ([`crate::trace::StepProfile`]) as it runs. `None` skips every
    /// clock read. Profiling only observes time — outputs are
    /// bit-identical either way.
    pub fn forward_step_profiled(
        &self,
        token: i32,
        layouts: &FixedLayouts,
        kv: &mut KvCache,
        s: &mut StepScratch,
        prof: Option<&mut StepProfile>,
    ) -> Vec<f32> {
        let cfg = &self.cfg;
        let pos = kv.len();
        assert!(pos >= 1, "forward_step needs a prefilled cache");
        assert!(
            pos < cfg.max_seq_len,
            "cache full: the window must slide — rebuild via forward_prefill_last"
        );
        assert!(kv.fits(cfg), "KvCache shape does not match model");
        assert!(s.fits(cfg), "StepScratch shape does not match model");
        let mut laps = prof.map(KernelLaps::new);

        // embed the one new token at its window-relative position
        let tok_row = self.mats["tok_emb"].row(token.clamp(0, cfg.vocab_size as i32 - 1) as usize);
        let pos_row = self.mats["pos_emb"].row(pos);
        s.h.clear();
        s.h.extend(tok_row.iter().zip(pos_row).map(|(a, b)| a + b));
        lap!(laps, other);

        for (li, names) in self.layer_names.iter().enumerate() {
            layernorm_row_into(
                &s.h,
                &self.vecs[&names.ln1_g],
                &self.vecs[&names.ln1_b],
                1e-5,
                &mut s.norm,
            );
            lap!(laps, other);
            self.linear_row_into(&s.norm, &names.q, layouts, &mut s.q);
            self.linear_row_into(&s.norm, &names.k, layouts, &mut s.k);
            self.linear_row_into(&s.norm, &names.v, layouts, &mut s.v);
            lap!(laps, linear);
            // the new row joins the cache first so attention sees
            // positions 0..=pos, exactly the full pass's causal row
            kv.write_row(li, pos, &s.k, &s.v);
            self.attention_row_into(kv, li, pos, &s.q, &mut s.attn, &mut s.attn_logits);
            lap!(laps, attention);
            self.linear_row_into(&s.attn, &names.o, layouts, &mut s.proj);
            lap!(laps, linear);
            for (a, b) in s.h.iter_mut().zip(&s.proj) {
                *a += b;
            }

            layernorm_row_into(
                &s.h,
                &self.vecs[&names.ln2_g],
                &self.vecs[&names.ln2_b],
                1e-5,
                &mut s.norm,
            );
            lap!(laps, other);
            self.linear_row_into(&s.norm, &names.fc1, layouts, &mut s.inner);
            lap!(laps, linear);
            for x in &mut s.inner {
                if *x < 0.0 {
                    *x = 0.0;
                }
            }
            lap!(laps, other);
            self.linear_row_into(&s.inner, &names.fc2, layouts, &mut s.proj);
            lap!(laps, linear);
            for (a, b) in s.h.iter_mut().zip(&s.proj) {
                *a += b;
            }
            lap!(laps, other);
        }
        kv.set_len(pos + 1);

        layernorm_row_into(
            &s.h,
            &self.vecs["ln_f.g"],
            &self.vecs["ln_f.b"],
            1e-5,
            &mut s.norm,
        );
        lap!(laps, other);
        // same last-row tied head as forward_fixed_last (the logits row is
        // the step's *product* and escapes the scratch, so it allocates)
        let last = Mat::from_vec(1, cfg.d_model, s.norm.clone());
        let logits = last.matmul_nt_auto(&self.mats["tok_emb"]).data;
        lap!(laps, linear);
        logits
    }

    /// One incremental decode step for N lanes *sharing the same layouts*,
    /// executed matrix-major: the lanes' step rows are stacked into
    /// (N, width) matrices so every linear runs as **one**
    /// [`crate::tensor::matmul_tn_sparse_auto_into`] over the shared
    /// layout instead of N independent matvecs. Attention stays per-lane —
    /// K/V rows are private history and are read from / appended to each
    /// lane's own [`KvCache`] — as do the embedding, layernorm and
    /// residual rows (all row-local ops on the stacked matrices).
    ///
    /// Returns the (N, vocab) next-token logits, row `i` for lane `i`.
    ///
    /// Row `i` is bit-identical to [`Model::forward_step_with`] on lane
    /// `i` by construction:
    /// - the AXPY sparse kernel accumulates each output element `(j, lane)`
    ///   over the row's active weights in ascending stored order — exactly
    ///   the order `matvec_nt_sparse_into` uses for that element (and the
    ///   W-row-parallel variant is bit-identical to serial);
    /// - layernorm and attention route through the same single workers
    ///   ([`crate::tensor::layernorm_row_into`], [`attention_head_pos`]);
    /// - the dense LM head accumulates each output row independently in
    ///   the same k-order, so an (N, d) head equals N (1, d) heads.
    ///
    /// Lanes may sit at *different* window positions — only the layouts
    /// must be shared. `proptest.rs::continuous_props` proves the
    /// composition over random arrival schedules, plans and refresh
    /// phases.
    pub fn forward_step_batch_with(
        &self,
        newest: &[i32],
        layouts: &FixedLayouts,
        kvs: &mut [&mut KvCache],
        s: &mut StepBatchScratch,
    ) -> Mat {
        self.forward_step_batch_profiled(newest, layouts, kvs, s, None)
    }

    /// [`Model::forward_step_batch_with`] with optional sampled kernel
    /// attribution — the fused-sweep mirror of
    /// [`Model::forward_step_profiled`]. The stack/scatter transposes of
    /// the matrix-major path are charged to the profile's `other_us`
    /// bucket. `None` skips every clock read; outputs are bit-identical
    /// either way.
    pub fn forward_step_batch_profiled(
        &self,
        newest: &[i32],
        layouts: &FixedLayouts,
        kvs: &mut [&mut KvCache],
        s: &mut StepBatchScratch,
        prof: Option<&mut StepProfile>,
    ) -> Mat {
        let cfg = &self.cfg;
        let n = newest.len();
        assert_eq!(n, kvs.len(), "one KvCache per fused lane");
        assert!(n >= 1, "batched step needs at least one lane");
        assert!(s.fits(cfg), "StepBatchScratch shape does not match model");
        s.pos.clear();
        for kv in kvs.iter() {
            let pos = kv.len();
            assert!(pos >= 1, "forward_step needs a prefilled cache");
            assert!(
                pos < cfg.max_seq_len,
                "cache full: the window must slide — rebuild via forward_prefill_last"
            );
            assert!(kv.fits(cfg), "KvCache shape does not match model");
            s.pos.push(pos);
        }
        let mut laps = prof.map(KernelLaps::new);

        // embed each lane's new token at its own window-relative position
        let d = cfg.d_model;
        let tok_emb = &self.mats["tok_emb"];
        let pos_emb = &self.mats["pos_emb"];
        s.h.resize_zeroed(n, d);
        s.attn.resize_zeroed(n, d);
        for (i, &tok) in newest.iter().enumerate() {
            let tok_row = tok_emb.row(tok.clamp(0, cfg.vocab_size as i32 - 1) as usize);
            let pos_row = pos_emb.row(s.pos[i]);
            for (dst, (a, b)) in s.h.row_mut(i).iter_mut().zip(tok_row.iter().zip(pos_row)) {
                *dst = a + b;
            }
        }
        lap!(laps, other);

        for (li, names) in self.layer_names.iter().enumerate() {
            s.norm.resize_zeroed(n, d);
            for i in 0..n {
                layernorm_row_into(
                    s.h.row(i),
                    &self.vecs[&names.ln1_g],
                    &self.vecs[&names.ln1_b],
                    1e-5,
                    s.norm.row_mut(i),
                );
            }
            // q/k/v consume the same activations: transpose once, run one
            // sparse matmul per linear over the whole group
            s.norm.transpose_into(&mut s.xt);
            lap!(laps, other);
            self.linear_batch_into(&s.xt, &names.q, layouts, &mut s.yt, &mut s.q);
            self.linear_batch_into(&s.xt, &names.k, layouts, &mut s.yt, &mut s.k);
            self.linear_batch_into(&s.xt, &names.v, layouts, &mut s.yt, &mut s.v);
            lap!(laps, linear);
            // each lane's new row joins its own cache first so attention
            // sees positions 0..=pos — exactly the per-lane step's order
            for i in 0..n {
                kvs[i].write_row(li, s.pos[i], s.k.row(i), s.v.row(i));
            }
            for i in 0..n {
                self.attention_row_into(
                    &*kvs[i],
                    li,
                    s.pos[i],
                    s.q.row(i),
                    s.attn.row_mut(i),
                    &mut s.attn_logits,
                );
            }
            lap!(laps, attention);
            s.attn.transpose_into(&mut s.xt);
            lap!(laps, other);
            self.linear_batch_into(&s.xt, &names.o, layouts, &mut s.yt, &mut s.proj);
            lap!(laps, linear);
            for i in 0..n {
                for (a, b) in s.h.row_mut(i).iter_mut().zip(s.proj.row(i)) {
                    *a += b;
                }
            }

            for i in 0..n {
                layernorm_row_into(
                    s.h.row(i),
                    &self.vecs[&names.ln2_g],
                    &self.vecs[&names.ln2_b],
                    1e-5,
                    s.norm.row_mut(i),
                );
            }
            s.norm.transpose_into(&mut s.xt);
            lap!(laps, other);
            self.linear_batch_into(&s.xt, &names.fc1, layouts, &mut s.yt, &mut s.inner);
            lap!(laps, linear);
            for x in &mut s.inner.data {
                if *x < 0.0 {
                    *x = 0.0;
                }
            }
            s.inner.transpose_into(&mut s.xt);
            lap!(laps, other);
            self.linear_batch_into(&s.xt, &names.fc2, layouts, &mut s.yt, &mut s.proj);
            lap!(laps, linear);
            for i in 0..n {
                for (a, b) in s.h.row_mut(i).iter_mut().zip(s.proj.row(i)) {
                    *a += b;
                }
            }
            lap!(laps, other);
        }
        for (i, kv) in kvs.iter_mut().enumerate() {
            kv.set_len(s.pos[i] + 1);
        }

        for i in 0..n {
            layernorm_row_into(
                s.h.row(i),
                &self.vecs["ln_f.g"],
                &self.vecs["ln_f.b"],
                1e-5,
                s.norm.row_mut(i),
            );
        }
        lap!(laps, other);
        // same tied head as the per-lane step; each output row of the
        // dense kernel is accumulated independently, so the (N, V) matrix
        // is row-for-row the N single-lane heads
        let logits = s.norm.matmul_nt_auto(&self.mats["tok_emb"]);
        lap!(laps, linear);
        logits
    }

    /// One linear over a *stacked group* of activation rows under fixed
    /// layouts — the matrix-major mirror of [`Model::linear_row_into`]
    /// (same layout lookup, same missing-layout panic, bias added per row
    /// in the same element order). `xt` carries the group's activations
    /// already transposed to (d_in, N); `yt` stages the kernel's natural
    /// transposed output; `out` receives the (N, d_out) result.
    fn linear_batch_into(
        &self,
        xt: &Mat,
        names: &LinearNames,
        layouts: &FixedLayouts,
        yt: &mut Mat,
        out: &mut Mat,
    ) {
        let rs = layouts
            .get(&names.w)
            .unwrap_or_else(|| panic!("no fixed layout for linear {}", names.w));
        if let Some(q) = &rs.quant {
            quant_matmul_tn_into(xt, q, yt);
        } else {
            matmul_tn_sparse_auto_into(xt, rs, yt);
        }
        yt.transpose_into(out);
        let b = &self.vecs[&names.b];
        for i in 0..out.rows {
            for (a, bv) in out.row_mut(i).iter_mut().zip(b) {
                *a += bv;
            }
        }
    }

    /// One linear on a single activation row under fixed layouts — the
    /// decode-step mirror of `linear_with_t` (same `Exec::Fixed` lookup,
    /// same missing-layout panic, bias added in the same element order),
    /// writing into a scratch buffer the matvec fully overwrites.
    fn linear_row_into(
        &self,
        x: &[f32],
        names: &LinearNames,
        layouts: &FixedLayouts,
        out: &mut Vec<f32>,
    ) {
        let rs = layouts
            .get(&names.w)
            .unwrap_or_else(|| panic!("no fixed layout for linear {}", names.w));
        if let Some(q) = &rs.quant {
            quant_matvec_nt_into(x, q, out);
        } else {
            matvec_nt_sparse_into(x, rs, out);
        }
        for (a, b) in out.iter_mut().zip(&self.vecs[&names.b]) {
            *a += b;
        }
    }

    /// The causal attention row for the newest position, reading K/V from
    /// the cache: the same [`attention_head_pos`] worker the full
    /// traversal runs, called at `i = pos` over a fully-valid window
    /// (decode windows are unpadded, so the padding mask can never
    /// trigger) — bit-identical outputs by construction. `out` is zeroed
    /// before the heads accumulate (the allocating form started from a
    /// fresh zero vector); `logits` is overwritten score scratch.
    fn attention_row_into(
        &self,
        kv: &KvCache,
        layer: usize,
        pos: usize,
        q: &[f32],
        out: &mut [f32],
        logits: &mut [f32],
    ) {
        let cfg = &self.cfg;
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        let t = pos + 1;
        let (kmat, vmat) = kv.layer(layer);
        out.fill(0.0);
        for h in 0..nh {
            let off = h * hd;
            let qi = &q[off..off + hd];
            let orow = &mut out[off..off + hd];
            attention_head_pos(qi, kmat, vmat, off, pos, t, scale, &mut logits[..t], orow);
        }
    }

    /// The worker behind every public forward: one traversal, any exec
    /// mode, optional taps, full or last-row head, optional K/V capture
    /// (`kv_out`, the prefill of an incremental decode — recording only
    /// copies matrices the pass computed anyway).
    fn forward_exec(
        &self,
        tokens: &[i32],
        valid_len: usize,
        exec: &Exec,
        mut taps: Option<&mut ActivationTaps>,
        head: Head,
        mut kv_out: Option<&mut KvCache>,
    ) -> Mat {
        let cfg = &self.cfg;
        let t = tokens.len();
        assert!(t <= cfg.max_seq_len, "sequence too long");
        assert!(valid_len <= t);
        if head == Head::LastValid {
            assert!(valid_len >= 1, "last-row head needs a valid token");
        }
        let mut h = self.embed(tokens);

        let record = |taps: &mut ActivationTaps, key: &str, x: &Mat| {
            let mut padded = x.clone();
            for i in valid_len..t {
                padded.row_mut(i).fill(0.0);
            }
            taps.insert(key.to_string(), padded);
        };

        for (li, names) in self.layer_names.iter().enumerate() {
            let y = layernorm_rows(&h, &self.vecs[&names.ln1_g], &self.vecs[&names.ln1_b], 1e-5);
            if let Some(taps) = taps.as_deref_mut() {
                for lin in [&names.q, &names.k, &names.v] {
                    record(taps, &lin.w, &y);
                }
            }
            // q/k/v consume the same activations: on the sparse path,
            // transpose y once and share it across the three linears
            let yt = if exec.is_sparse() { Some(y.t()) } else { None };
            let q = self.linear_with_t(&y, yt.as_ref(), &names.q, exec);
            let k = self.linear_with_t(&y, yt.as_ref(), &names.k, exec);
            let v = self.linear_with_t(&y, yt.as_ref(), &names.v, exec);
            if let Some(kv) = kv_out.as_deref_mut() {
                kv.record_prefill(li, &k, &v, t);
            }
            let attn = self.attention(&q, &k, &v, valid_len);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.o.w, &attn);
            }
            let o = self.linear(&attn, &names.o, exec);
            h.add_assign(&o);

            let y = layernorm_rows(&h, &self.vecs[&names.ln2_g], &self.vecs[&names.ln2_b], 1e-5);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.fc1.w, &y);
            }
            let mut z = self.linear(&y, &names.fc1, exec);
            relu(&mut z);
            if let Some(taps) = taps.as_deref_mut() {
                record(taps, &names.fc2.w, &z);
            }
            let out = self.linear(&z, &names.fc2, exec);
            h.add_assign(&out);
        }

        if let Some(kv) = kv_out {
            kv.set_len(t);
        }
        // taps-only traversals are done: everything past here exists only
        // to produce logits
        if matches!(head, Head::None) {
            return Mat::zeros(0, 0);
        }
        let hidden = layernorm_rows(&h, &self.vecs["ln_f.g"], &self.vecs["ln_f.b"], 1e-5);
        // tied head; the largest matmul of the pass, worth the pool
        match head {
            // full (T, V)
            Head::All => hidden.matmul_nt_auto(&self.mats["tok_emb"]),
            // decode only consumes the next-token row: (1, V)
            Head::LastValid => {
                let last = Mat::from_vec(1, hidden.cols, hidden.row(valid_len - 1).to_vec());
                last.matmul_nt_auto(&self.mats["tok_emb"])
            }
            Head::None => unreachable!("handled above"),
        }
    }

    /// Forward one sequence (no batching host-side): returns per-position
    /// logits (T, V). `tokens` may include PAD; `valid_len` marks the
    /// boundary of real tokens.
    pub fn forward(&self, tokens: &[i32], valid_len: usize, mode: PruneMode) -> Mat {
        self.forward_with(tokens, valid_len, mode, None)
    }

    /// Collect per-linear input activations on a prompt (dense forward) —
    /// feeds host-side calibration and the μ-MoE overlap analysis. Skips
    /// the LM head (`Head::None`): every tap is recorded before the final
    /// layernorm, and selection never consumes logits — this keeps the
    /// decode engine's per-refresh selection pass from paying the pass's
    /// largest matmul just to discard it.
    pub fn collect_activations(&self, tokens: &[i32], valid_len: usize) -> ActivationTaps {
        let mut taps = ActivationTaps::new();
        self.forward_exec(
            tokens,
            valid_len,
            &Exec::Dense,
            Some(&mut taps),
            Head::None,
            None,
        );
        taps
    }

    fn attention(&self, q: &Mat, k: &Mat, v: &Mat, valid_len: usize) -> Mat {
        let cfg = &self.cfg;
        let (t, d) = (q.rows, cfg.d_model);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = Mat::zeros(t, d);
        let mut logits = vec![0.0f32; t];
        for h in 0..nh {
            let off = h * hd;
            for i in 0..t {
                let qi = &q.row(i)[off..off + hd];
                let orow = &mut out.data[i * d + off..i * d + off + hd];
                attention_head_pos(qi, k, v, off, i, valid_len, scale, &mut logits, orow);
            }
        }
        out
    }

    /// Sum of next-token NLL + predicted count over the valid prefix —
    /// identical semantics to the `*_nll` artifacts.
    pub fn nll_sum(&self, tokens: &[i32], valid_len: usize, mode: PruneMode) -> (f64, usize) {
        let logits = self.forward(tokens, valid_len, mode);
        let mut sum = 0.0f64;
        let mut count = 0usize;
        for t in 0..valid_len.saturating_sub(1) {
            let target = tokens[t + 1];
            if target == PAD_ID {
                break;
            }
            let ls = log_softmax(logits.row(t));
            sum -= ls[target as usize] as f64;
            count += 1;
        }
        (sum, count)
    }

    /// All prunable linears' (name, weight) pairs — pruning engines iterate
    /// this to produce offline-pruned model variants.
    pub fn prunable(&self) -> Vec<(String, &Mat)> {
        self.cfg
            .linear_names()
            .into_iter()
            .map(|n| {
                let m = &self.mats[&n];
                (n, m)
            })
            .collect()
    }

    /// Apply offline Wanda pruning in place given per-linear calibrators.
    pub fn apply_offline_wanda(
        &mut self,
        calibs: &HashMap<String, wanda::WandaCalibrator>,
        rho: f64,
    ) -> Result<(), Error> {
        // validate before touching any weight: an early error must not
        // leave the model half-pruned (nor half-pruned under an unchanged
        // weights_id, which would let a shared LayoutCache serve stale
        // layouts for the mutated weights)
        for name in self.cfg.linear_names() {
            if !calibs.contains_key(&name) {
                return Err(Error::invariant(format!("missing calibrator for {name}")));
            }
        }
        for name in self.cfg.linear_names() {
            let calib = &calibs[&name];
            let w = self.mats.get_mut(&name).expect("linear weight present");
            let mask = wanda::wanda_mask(w, calib, rho);
            mask.apply_in_place(w);
        }
        self.weights_id = next_weights_id();
        Ok(())
    }

    /// Apply magnitude pruning in place.
    pub fn apply_magnitude(&mut self, rho: f64) {
        for name in self.cfg.linear_names() {
            let w = self.mats.get_mut(&name).expect("linear weight present");
            let mask = crate::pruning::magnitude::magnitude_mask(w, rho);
            mask.apply_in_place(w);
        }
        self.weights_id = next_weights_id();
    }
}

/// One (head, position) of causal attention: scores `qi` (the position's
/// query slice for head offset `off`) against K rows `0..=i`, masking
/// padded positions past `valid_len` (padding rows attend to themselves),
/// softmaxes, and accumulates the matching V row slices into `orow`.
/// `logits` is caller-provided scratch of length ≥ `i + 1`.
///
/// This is THE attention inner loop: both the full traversal
/// ([`Model::forward_with`] via `attention`) and the KV-decode step path
/// (`attention_row`, reading K/V from the cache) call it, so the two can
/// never drift numerically — the KV path's bit-identical contract is
/// structural, not maintained by hand.
#[allow(clippy::too_many_arguments)]
fn attention_head_pos(
    qi: &[f32],
    k: &Mat,
    v: &Mat,
    off: usize,
    i: usize,
    valid_len: usize,
    scale: f32,
    logits: &mut [f32],
    orow: &mut [f32],
) {
    let hd = qi.len();
    let klim = i + 1; // causal
    let mut mx = f32::NEG_INFINITY;
    for (j, logit) in logits.iter_mut().enumerate().take(klim) {
        if j >= valid_len && j != i {
            *logit = f32::NEG_INFINITY;
            continue;
        }
        let kj = &k.row(j)[off..off + hd];
        let mut acc = 0.0f32;
        for c in 0..hd {
            acc += qi[c] * kj[c];
        }
        *logit = acc * scale;
        mx = mx.max(*logit);
    }
    // softmax over 0..klim
    let mut denom = 0.0f32;
    for logit in logits.iter_mut().take(klim) {
        if logit.is_finite() {
            *logit = (*logit - mx).exp();
            denom += *logit;
        } else {
            *logit = 0.0;
        }
    }
    if denom <= 0.0 {
        return;
    }
    for j in 0..klim {
        let p = logits[j] / denom;
        if p == 0.0 {
            continue;
        }
        let vj = &v.row(j)[off..off + hd];
        for c in 0..hd {
            orow[c] += p * vj[c];
        }
    }
}

/// Deterministic random model for tests (no checkpoint needed).
pub fn random_model(cfg: &ModelConfig, seed: u64) -> Model {
    use crate::util::rng::Pcg32;
    let mut rng = Pcg32::new(seed, 99);
    let mut mats = HashMap::new();
    let mut vecs = HashMap::new();
    let (d, di) = (cfg.d_model, cfg.d_inner());
    for name in cfg.param_order() {
        if name.ends_with(".w") || name == "tok_emb" || name == "pos_emb" {
            let (r, c) = if name == "tok_emb" {
                (cfg.vocab_size, d)
            } else if name == "pos_emb" {
                (cfg.max_seq_len, d)
            } else if name.ends_with("fc1.w") {
                (di, d)
            } else if name.ends_with("fc2.w") {
                (d, di)
            } else {
                (d, d)
            };
            let mut data = rng.normal_vec(r * c);
            for x in &mut data {
                *x *= 0.05;
            }
            mats.insert(name, Mat::from_vec(r, c, data));
        } else if name.ends_with(".g") {
            vecs.insert(name.clone(), vec![1.0; ln_dim(cfg, &name)]);
        } else {
            vecs.insert(name.clone(), vec![0.0; bias_dim(cfg, &name)]);
        }
    }
    Model::assemble(cfg.clone(), mats, vecs)
}

fn ln_dim(cfg: &ModelConfig, _name: &str) -> usize {
    cfg.d_model
}

fn bias_dim(cfg: &ModelConfig, name: &str) -> usize {
    if name.ends_with("fc1.b") {
        cfg.d_inner()
    } else {
        cfg.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig::new("test-tiny", 2, 2, 16)
    }

    #[test]
    fn forward_shapes_and_finite() {
        let m = random_model(&tiny(), 1);
        let toks: Vec<i32> = vec![10, 20, 30, 40, PAD_ID, PAD_ID];
        let logits = m.forward(&toks, 4, PruneMode::Dense);
        assert_eq!(logits.rows, 6);
        assert_eq!(logits.cols, m.cfg.vocab_size);
        assert!(logits.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn padding_does_not_change_valid_logits() {
        let m = random_model(&tiny(), 2);
        let a: Vec<i32> = vec![5, 6, 7, PAD_ID];
        let b: Vec<i32> = vec![5, 6, 7, 200];
        let la = m.forward(&a, 3, PruneMode::Dense);
        let lb = m.forward(&b, 3, PruneMode::Dense);
        for t in 0..3 {
            for v in 0..m.cfg.vocab_size {
                assert!((la.at(t, v) - lb.at(t, v)).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn online_rho1_matches_dense() {
        let m = random_model(&tiny(), 3);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5];
        let d = m.forward(&toks, 5, PruneMode::Dense);
        let p = m.forward(&toks, 5, PruneMode::OnlineWanda { rho: 1.0 });
        for (x, y) in d.data.iter().zip(&p.data) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn online_pruning_changes_output() {
        let m = random_model(&tiny(), 4);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5];
        let d = m.forward(&toks, 5, PruneMode::Dense);
        let p = m.forward(&toks, 5, PruneMode::OnlineWanda { rho: 0.4 });
        let diff: f32 = d
            .data
            .iter()
            .zip(&p.data)
            .map(|(x, y)| (x - y).abs())
            .sum();
        assert!(diff > 1e-3);
    }

    #[test]
    fn online_sparse_path_matches_masked_dense_reference() {
        // the sparse execution engine must be numerically identical to the
        // old dense-masked formulation, layer by layer
        use crate::pruning::wanda::online_wanda_mask;
        let m = random_model(&tiny(), 8);
        let toks: Vec<i32> = vec![3, 1, 4, 1, 5, 9];
        let acts = m.collect_activations(&toks, 6);
        for (name, w) in m.prunable() {
            let x = &acts[&name];
            let mask = online_wanda_mask(w, x, 0.5);
            let dense_ref = x.matmul_nt(&mask.apply(w));
            let sparse = x.matmul_nt_sparse(&mask.compress(w));
            for (a, b) in sparse.data.iter().zip(&dense_ref.data) {
                assert!((a - b).abs() < 1e-5, "{name}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn headless_activation_collection_matches_instrumented_forward() {
        // collect_activations skips the LM head; the taps it records must
        // be exactly the ones a full instrumented forward records
        let m = random_model(&tiny(), 12);
        let toks: Vec<i32> = vec![4, 5, 6, 7, PAD_ID];
        let a = m.collect_activations(&toks, 4);
        let mut taps = ActivationTaps::new();
        m.forward_with(&toks, 4, PruneMode::Dense, Some(&mut taps));
        assert_eq!(a.len(), taps.len());
        for (name, x) in &a {
            assert_eq!(x.data, taps[name].data, "{name}");
        }
    }

    #[test]
    fn fixed_forward_matches_direct_compression() {
        // forward_fixed over layouts compressed from a selection must equal
        // running those same compressed layouts inline — the layouts fully
        // determine the pruned computation
        use crate::moe::select_experts;
        let m = random_model(&tiny(), 10);
        let toks: Vec<i32> = vec![2, 7, 1, 8, 2, 8];
        let sel = select_experts(&m, &toks, 6, 0.5);
        let layouts: FixedLayouts = m
            .prunable()
            .into_iter()
            .map(|(name, w)| {
                let rs = Arc::new(sel.masks[&name].compress(w));
                (name, rs)
            })
            .collect();
        let logits = m.forward_fixed(&toks, 6, &layouts);
        assert_eq!((logits.rows, logits.cols), (6, m.cfg.vocab_size));
        assert!(logits.data.iter().all(|x| x.is_finite()));
        // at rho=1.0 the selection keeps everything: fixed == dense
        let sel_full = select_experts(&m, &toks, 6, 1.0);
        let full: FixedLayouts = m
            .prunable()
            .into_iter()
            .map(|(name, w)| {
                let rs = Arc::new(sel_full.masks[&name].compress(w));
                (name, rs)
            })
            .collect();
        let fixed = m.forward_fixed(&toks, 6, &full);
        let dense = m.forward(&toks, 6, PruneMode::Dense);
        for (a, b) in fixed.data.iter().zip(&dense.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn last_row_head_bit_identical_to_full_head() {
        use crate::moe::select_experts;
        let m = random_model(&tiny(), 11);
        let toks: Vec<i32> = vec![5, 9, 3, 6, 4];
        let sel = select_experts(&m, &toks, 5, 0.6);
        let layouts: FixedLayouts = m
            .prunable()
            .into_iter()
            .map(|(name, w)| (name.clone(), Arc::new(sel.masks[&name].compress(w))))
            .collect();
        let full = m.forward_fixed(&toks, 5, &layouts);
        let last = m.forward_fixed_last(&toks, 5, &layouts);
        assert_eq!(last.len(), m.cfg.vocab_size);
        assert_eq!(last.as_slice(), full.row(4));
    }

    fn fixed_layouts(m: &Model, toks: &[i32], rho: f64) -> FixedLayouts {
        let sel = crate::moe::select_experts(m, toks, toks.len(), rho);
        m.prunable()
            .into_iter()
            .map(|(name, w)| {
                let rs = Arc::new(sel.masks[&name].compress(w));
                (name, rs)
            })
            .collect()
    }

    #[test]
    fn prefill_logits_bit_identical_to_fixed_last() {
        // K/V capture must be observation-only
        let m = random_model(&tiny(), 15);
        let toks: Vec<i32> = vec![3, 9, 27, 81, 243 % 256];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv = KvCache::new(&m.cfg);
        let prefill = m.forward_prefill_last(&toks, 5, &layouts, &mut kv);
        let plain = m.forward_fixed_last(&toks, 5, &layouts);
        assert_eq!(prefill, plain);
        assert_eq!(kv.len(), 5);
    }

    #[test]
    fn forward_step_bit_identical_to_full_window_forward() {
        // prefill on the prefix + one step on the last token must equal
        // the full-window fixed forward, logit for logit — the core
        // contract of the KV-decode subsystem
        let m = random_model(&tiny(), 16);
        let toks: Vec<i32> = vec![5, 11, 23, 47, 95, 191];
        let layouts = fixed_layouts(&m, &toks, 0.6);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks[..3], 3, &layouts, &mut kv);
        // step the remaining tokens one at a time, checking each against
        // the non-cached full-window forward
        for n in 4..=toks.len() {
            let stepped = m.forward_step(toks[n - 1], &layouts, &mut kv);
            let full = m.forward_fixed_last(&toks[..n], n, &layouts);
            assert_eq!(stepped, full, "position {n}");
            assert_eq!(kv.len(), n);
        }
    }

    #[test]
    fn seeded_suffix_prefill_bit_identical_to_full_prefill() {
        // seed a cache from an exported prefix, prefill only the suffix:
        // logits and every cached row must equal the full prefill — the
        // exactness contract the cross-request KV store rests on
        let m = random_model(&tiny(), 19);
        let toks: Vec<i32> = vec![5, 11, 23, 47, 95, 191];
        let layouts = fixed_layouts(&m, &toks, 0.6);

        let mut kv_full = KvCache::new(&m.cfg);
        let full = m.forward_prefill_last(&toks, toks.len(), &layouts, &mut kv_full);

        for n in 1..toks.len() {
            // export positions 0..n as a store entry would hold them
            let mut kv_prefix = KvCache::new(&m.cfg);
            m.forward_prefill_last(&toks[..n], n, &layouts, &mut kv_prefix);
            let (k, v) = kv_prefix.export_prefix(n);
            let entry = crate::kvstore::KvEntry {
                tokens: toks[..n].to_vec(),
                k,
                v,
                d_model: m.cfg.d_model,
            };

            let mut kv_seeded = KvCache::new(&m.cfg);
            kv_seeded.seed_from(&entry, n);
            let mut s = StepScratch::new(&m.cfg);
            let seeded = m.forward_prefill_suffix_last(
                &toks,
                toks.len(),
                n,
                &layouts,
                &mut kv_seeded,
                &mut s,
            );
            assert_eq!(seeded, full, "seed length {n}");
            assert_eq!(kv_seeded.len(), kv_full.len());
            for li in 0..m.cfg.n_layers {
                for t in 0..toks.len() {
                    assert_eq!(
                        kv_seeded.layer(li).0.row(t),
                        kv_full.layer(li).0.row(t),
                        "k layer {li} pos {t} seed {n}"
                    );
                    assert_eq!(kv_seeded.layer(li).1.row(t), kv_full.layer(li).1.row(t));
                }
            }
        }
    }

    #[test]
    fn reused_scratch_bit_identical_to_allocating_step() {
        // forward_step (fresh scratch per call) and forward_step_with over
        // one reused scratch must agree logit-for-logit on every step —
        // stale buffer contents can never leak into a step's output
        let m = random_model(&tiny(), 21);
        let toks: Vec<i32> = vec![7, 3, 11, 5, 13, 2];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv_a = KvCache::new(&m.cfg);
        let mut kv_b = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks[..2], 2, &layouts, &mut kv_a);
        m.forward_prefill_last(&toks[..2], 2, &layouts, &mut kv_b);
        let mut scratch = StepScratch::new(&m.cfg);
        for &t in &toks[2..] {
            let fresh = m.forward_step(t, &layouts, &mut kv_a);
            let reused = m.forward_step_with(t, &layouts, &mut kv_b, &mut scratch);
            assert_eq!(fresh, reused);
        }
    }

    #[test]
    fn profiled_steps_bit_identical_to_unprofiled() {
        // kernel attribution only observes time: the profiled step (and
        // its batch mirror) must agree logit-for-logit with the plain one
        let m = random_model(&tiny(), 29);
        let toks: Vec<i32> = vec![7, 3, 11, 5, 13];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv_a = KvCache::new(&m.cfg);
        let mut kv_b = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks[..2], 2, &layouts, &mut kv_a);
        m.forward_prefill_last(&toks[..2], 2, &layouts, &mut kv_b);
        let mut sa = StepScratch::new(&m.cfg);
        let mut sb = StepScratch::new(&m.cfg);
        let mut prof = StepProfile::default();
        for &t in &toks[2..] {
            let plain = m.forward_step_with(t, &layouts, &mut kv_a, &mut sa);
            let profiled =
                m.forward_step_profiled(t, &layouts, &mut kv_b, &mut sb, Some(&mut prof));
            assert_eq!(plain, profiled);
        }
        // timers on a debug-profile tiny model may legitimately read 0 µs;
        // the split only has to be structurally usable
        let _ = prof.total_us();

        let mut kv_c = KvCache::new(&m.cfg);
        let mut kv_d = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks, toks.len(), &layouts, &mut kv_c);
        m.forward_prefill_last(&toks, toks.len(), &layouts, &mut kv_d);
        let mut bs = StepBatchScratch::new(&m.cfg, 1);
        let plain = {
            let mut refs: Vec<&mut KvCache> = vec![&mut kv_c];
            m.forward_step_batch_with(&[42], &layouts, &mut refs, &mut bs)
        };
        let profiled = {
            let mut refs: Vec<&mut KvCache> = vec![&mut kv_d];
            let p = Some(&mut prof);
            m.forward_step_batch_profiled(&[42], &layouts, &mut refs, &mut bs, p)
        };
        assert_eq!(plain.data, profiled.data);
    }

    #[test]
    fn batched_step_bit_identical_to_per_lane_steps() {
        // the matrix-major step must agree logit-for-logit with N
        // independent per-lane steps over the same shared layouts, with
        // lanes at *different* window positions, and the reused batch
        // scratch must stay bit-identical across consecutive sweeps
        let m = random_model(&tiny(), 23);
        let prompts: [&[i32]; 3] = [&[5, 11, 23], &[7, 3], &[9, 8, 7, 6]];
        let sel_toks: Vec<i32> = vec![5, 11, 23, 47];
        let layouts = fixed_layouts(&m, &sel_toks, 0.5);

        let mut kv_solo: Vec<KvCache> = Vec::new();
        let mut kv_fused: Vec<KvCache> = Vec::new();
        for p in prompts {
            let mut a = KvCache::new(&m.cfg);
            let mut b = KvCache::new(&m.cfg);
            m.forward_prefill_last(p, p.len(), &layouts, &mut a);
            m.forward_prefill_last(p, p.len(), &layouts, &mut b);
            kv_solo.push(a);
            kv_fused.push(b);
        }

        let mut scratch = StepBatchScratch::new(&m.cfg, prompts.len());
        let mut newest: Vec<i32> = vec![42, 17, 31];
        for sweep in 0..3 {
            let solo: Vec<Vec<f32>> = newest
                .iter()
                .zip(kv_solo.iter_mut())
                .map(|(&t, kv)| m.forward_step(t, &layouts, kv))
                .collect();
            let mut refs: Vec<&mut KvCache> = kv_fused.iter_mut().collect();
            let fused = m.forward_step_batch_with(&newest, &layouts, &mut refs, &mut scratch);
            assert_eq!((fused.rows, fused.cols), (3, m.cfg.vocab_size));
            for (i, want) in solo.iter().enumerate() {
                assert_eq!(fused.row(i), want.as_slice(), "sweep {sweep} lane {i}");
                assert_eq!(kv_fused[i].len(), kv_solo[i].len());
            }
            // feed each lane its own argmax so positions keep diverging
            newest = solo
                .iter()
                .map(|l| {
                    l.iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(i, _)| i as i32)
                        .unwrap()
                })
                .collect();
        }
    }

    #[test]
    fn batched_step_single_lane_matches_row_step() {
        // a singleton group through the batch path is still exact
        let m = random_model(&tiny(), 24);
        let toks: Vec<i32> = vec![2, 4, 6];
        let layouts = fixed_layouts(&m, &toks, 0.6);
        let mut kv_a = KvCache::new(&m.cfg);
        let mut kv_b = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks, 3, &layouts, &mut kv_a);
        m.forward_prefill_last(&toks, 3, &layouts, &mut kv_b);
        let solo = m.forward_step(8, &layouts, &mut kv_a);
        let mut scratch = StepBatchScratch::new(&m.cfg, 1);
        let mut refs: Vec<&mut KvCache> = vec![&mut kv_b];
        let fused = m.forward_step_batch_with(&[8], &layouts, &mut refs, &mut scratch);
        assert_eq!(fused.row(0), solo.as_slice());
    }

    #[test]
    #[should_panic(expected = "StepScratch shape")]
    fn mismatched_scratch_rejected() {
        let m = random_model(&tiny(), 22);
        let toks: Vec<i32> = vec![1, 2, 3];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks, 3, &layouts, &mut kv);
        let mut wide = ModelConfig::new("wider", 2, 2, 32);
        wide.max_seq_len = m.cfg.max_seq_len;
        let mut scratch = StepScratch::new(&wide);
        m.forward_step_with(9, &layouts, &mut kv, &mut scratch);
    }

    #[test]
    fn prefill_rebuild_overwrites_stale_rows() {
        // after a clear + re-prefill on a different window the step path
        // must track the new window, not the old one
        let m = random_model(&tiny(), 17);
        let a: Vec<i32> = vec![1, 2, 3, 4];
        let b: Vec<i32> = vec![9, 8, 7];
        let layouts = fixed_layouts(&m, &a, 0.5);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_prefill_last(&a, 4, &layouts, &mut kv);
        m.forward_prefill_last(&b, 3, &layouts, &mut kv);
        assert_eq!(kv.len(), 3);
        let stepped = m.forward_step(42, &layouts, &mut kv);
        let mut grown = b.clone();
        grown.push(42);
        assert_eq!(stepped, m.forward_fixed_last(&grown, 4, &layouts));
    }

    #[test]
    #[should_panic(expected = "prefilled cache")]
    fn forward_step_rejects_empty_cache() {
        let m = random_model(&tiny(), 18);
        let layouts = fixed_layouts(&m, &[1, 2], 0.5);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_step(1, &layouts, &mut kv);
    }

    #[test]
    #[should_panic(expected = "unpadded windows")]
    fn prefill_rejects_padded_windows() {
        let m = random_model(&tiny(), 19);
        let toks: Vec<i32> = vec![1, 2, 3, PAD_ID];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks, 3, &layouts, &mut kv);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn forward_step_rejects_full_cache() {
        let mut cfg = tiny();
        cfg.max_seq_len = 4;
        let m = random_model(&cfg, 20);
        let toks: Vec<i32> = vec![1, 2, 3, 4];
        let layouts = fixed_layouts(&m, &toks, 0.5);
        let mut kv = KvCache::new(&m.cfg);
        m.forward_prefill_last(&toks, 4, &layouts, &mut kv);
        m.forward_step(5, &layouts, &mut kv);
    }

    #[test]
    fn nll_counts_valid_predictions() {
        let m = random_model(&tiny(), 5);
        let toks: Vec<i32> = vec![1, 2, 3, 4, PAD_ID, PAD_ID];
        let (sum, count) = m.nll_sum(&toks, 4, PruneMode::Dense);
        assert_eq!(count, 3);
        assert!(sum > 0.0);
    }

    #[test]
    fn magnitude_pruning_applies() {
        let mut m = random_model(&tiny(), 6);
        m.apply_magnitude(0.5);
        for (name, w) in m.prunable() {
            assert!(
                (w.sparsity() - 0.5).abs() < 0.1,
                "{name}: {}",
                w.sparsity()
            );
        }
    }

    #[test]
    fn failed_offline_wanda_mutates_nothing() {
        // missing calibrators must be detected before any weight is pruned
        let mut m = random_model(&tiny(), 13);
        let before = m.mat("layers.0.q.w").data.clone();
        let id = m.weights_id();
        let calibs: HashMap<String, wanda::WandaCalibrator> = HashMap::new();
        assert!(m.apply_offline_wanda(&calibs, 0.5).is_err());
        assert_eq!(m.mat("layers.0.q.w").data, before);
        assert_eq!(m.weights_id(), id);
    }

    #[test]
    fn weight_mutations_refresh_weights_id() {
        let mut m = random_model(&tiny(), 14);
        let id0 = m.weights_id();
        m.apply_magnitude(0.5);
        let id1 = m.weights_id();
        assert_ne!(id0, id1);
        m.set_mat("layers.0.q.w", Mat::zeros(16, 16));
        assert_ne!(m.weights_id(), id1);
    }

    #[test]
    fn collect_activations_covers_all_linears() {
        let m = random_model(&tiny(), 7);
        let acts = m.collect_activations(&[1, 2, 3, 4], 4);
        for n in m.cfg.linear_names() {
            assert!(acts.contains_key(&n), "{n}");
        }
        // activation width matches the linear's input dim
        assert_eq!(acts["layers.0.fc2.w"].cols, m.cfg.d_inner());
    }

    #[test]
    fn instrumented_forward_matches_plain_forward() {
        // taps must be observation-only: same logits with and without
        let m = random_model(&tiny(), 9);
        let toks: Vec<i32> = vec![1, 2, 3, 4, 5, PAD_ID];
        let plain = m.forward(&toks, 5, PruneMode::Dense);
        let mut taps = ActivationTaps::new();
        let tapped = m.forward_with(&toks, 5, PruneMode::Dense, Some(&mut taps));
        assert_eq!(plain.data, tapped.data);
        assert_eq!(taps.len(), m.cfg.linear_names().len());
        // taps are zero-padded past valid_len
        for (name, x) in &taps {
            assert!(x.row(5).iter().all(|&v| v == 0.0), "{name}");
        }
    }
}
