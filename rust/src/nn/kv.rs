//! Per-layer K/V cache for incremental decode.
//!
//! The decode engine's reused steps used to re-run the model over the
//! whole sliding window — O(T²) attention plus O(T·d) sparse matmul per
//! token for rows whose outputs never change. A [`KvCache`] holds every
//! block's key/value matrices for the already-processed window prefix so
//! a step only computes the *new* token's row through each linear
//! ([`crate::nn::Model::forward_step`]) and attends against the cached
//! rows: O(T) attention work per step.
//!
//! Why this composes exactly with prune-once layout reuse: a cached K/V
//! row is valid only while (a) the [`crate::tensor::RowSparse`] layouts
//! that produced it are still the ones executing — a mask-plan refresh
//! swaps layouts, so every cached row is stale — and (b) the token's
//! window-relative position is unchanged, because μ-OPT uses learned
//! absolute position embeddings, so a sliding window shifts every
//! position and invalidates every row (unlike rotary embeddings, there is
//! no cheap re-basing). The decode engine therefore rebuilds the cache
//! with one full prefill ([`crate::nn::Model::forward_prefill_last`]) on
//! refresh steps and window slides, and steps incrementally everywhere
//! else — keeping KV decode **bit-identical** to the non-cached path
//! under every [`crate::pruning::MaskPlan`] (`proptest.rs::kv_props`
//! proves this) rather than approximately right.
//!
//! Buffers are preallocated at `[max_seq_len × d_model]` per layer so the
//! steady-state step path never allocates for cache writes.

use crate::model::ModelConfig;
use crate::tensor::Mat;

/// Preallocated per-layer K/V buffers plus shared valid-length tracking.
///
/// One instance belongs to one decode lane (requests never share a cache
/// — cached rows encode one lane's window). Construction sizes it for a
/// specific model config; [`crate::nn::Model::forward_step`] asserts the
/// shape matches the model it runs on.
pub struct KvCache {
    /// Per layer: (max_seq_len, d_model) key rows.
    k: Vec<Mat>,
    /// Per layer: (max_seq_len, d_model) value rows.
    v: Vec<Mat>,
    /// Cached positions valid in every layer (rows `0..len`).
    len: usize,
}

impl KvCache {
    /// Preallocate for `cfg`'s layer count, window and width.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.max_seq_len, cfg.d_model))
                .collect(),
            v: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.max_seq_len, cfg.d_model))
                .collect(),
            len: 0,
        }
    }

    /// Cached positions (valid rows per layer).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum cacheable positions (the model's window).
    pub fn capacity(&self) -> usize {
        self.k.first().map_or(0, |m| m.rows)
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Invalidate every cached row (refresh / window-slide rebuild; the
    /// buffers stay allocated — rows are overwritten before reuse).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Does this cache match `cfg`'s shape?
    pub fn fits(&self, cfg: &ModelConfig) -> bool {
        self.n_layers() == cfg.n_layers
            && self.capacity() == cfg.max_seq_len
            && self.k.iter().all(|m| m.cols == cfg.d_model)
    }

    /// Cached K/V matrices of one layer (rows `0..len()` are valid).
    pub(crate) fn layer(&self, layer: usize) -> (&Mat, &Mat) {
        (&self.k[layer], &self.v[layer])
    }

    /// Copy a prefill's first `t` K/V rows for `layer` into the cache.
    pub(crate) fn record_prefill(&mut self, layer: usize, k: &Mat, v: &Mat, t: usize) {
        assert!(t <= self.capacity(), "prefill exceeds cache capacity");
        let d = self.k[layer].cols;
        self.k[layer].data[..t * d].copy_from_slice(&k.data[..t * d]);
        self.v[layer].data[..t * d].copy_from_slice(&v.data[..t * d]);
    }

    /// Write one new position's K/V row for `layer` at `pos`.
    pub(crate) fn write_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.k[layer].row_mut(pos).copy_from_slice(k);
        self.v[layer].row_mut(pos).copy_from_slice(v);
    }

    /// Commit the valid length after all layers recorded (prefill sets
    /// `t`; a step sets `pos + 1`).
    pub(crate) fn set_len(&mut self, len: usize) {
        assert!(len <= self.capacity(), "cache length exceeds capacity");
        self.len = len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::new("kv-tiny", 2, 2, 8);
        c.max_seq_len = 6;
        c
    }

    #[test]
    fn preallocates_model_shape() {
        let kv = KvCache::new(&cfg());
        assert_eq!(kv.n_layers(), 2);
        assert_eq!(kv.capacity(), 6);
        assert_eq!(kv.len(), 0);
        assert!(kv.is_empty());
        assert!(kv.fits(&cfg()));
        assert!(!kv.fits(&ModelConfig::new("other", 3, 2, 8)));
    }

    #[test]
    fn record_write_and_clear_track_len() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let k = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| (i * 10 + j) as f32);
        let v = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| -((i * 10 + j) as f32));
        for l in 0..c.n_layers {
            kv.record_prefill(l, &k, &v, 3);
        }
        kv.set_len(3);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.layer(1).0.row(2), k.row(2));
        assert_eq!(kv.layer(0).1.row(1), v.row(1));

        let new_k = vec![7.0f32; c.d_model];
        let new_v = vec![9.0f32; c.d_model];
        for l in 0..c.n_layers {
            kv.write_row(l, 3, &new_k, &new_v);
        }
        kv.set_len(4);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.layer(0).0.row(3), new_k.as_slice());
        assert_eq!(kv.layer(1).1.row(3), new_v.as_slice());

        kv.clear();
        assert!(kv.is_empty());
        // buffers survive a clear: the next prefill overwrites in place
        assert_eq!(kv.capacity(), 6);
    }

    #[test]
    #[should_panic(expected = "exceeds cache capacity")]
    fn overlong_prefill_rejected() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let k = Mat::zeros(c.max_seq_len + 2, c.d_model);
        kv.record_prefill(0, &k, &k, c.max_seq_len + 1);
    }
}
