//! Per-layer K/V cache for incremental decode.
//!
//! The decode engine's reused steps used to re-run the model over the
//! whole sliding window — O(T²) attention plus O(T·d) sparse matmul per
//! token for rows whose outputs never change. A [`KvCache`] holds every
//! block's key/value matrices for the already-processed window prefix so
//! a step only computes the *new* token's row through each linear
//! ([`crate::nn::Model::forward_step`]) and attends against the cached
//! rows: O(T) attention work per step.
//!
//! Why this composes exactly with prune-once layout reuse: a cached K/V
//! row is valid only while (a) the [`crate::tensor::RowSparse`] layouts
//! that produced it are still the ones executing — a mask-plan refresh
//! swaps layouts, so every cached row is stale — and (b) the token's
//! window-relative position is unchanged, because μ-OPT uses learned
//! absolute position embeddings, so a sliding window shifts every
//! position and invalidates every row (unlike rotary embeddings, there is
//! no cheap re-basing). The decode engine therefore rebuilds the cache
//! with one full prefill ([`crate::nn::Model::forward_prefill_last`]) on
//! refresh steps and window slides, and steps incrementally everywhere
//! else — keeping KV decode **bit-identical** to the non-cached path
//! under every [`crate::pruning::MaskPlan`] (`proptest.rs::kv_props`
//! proves this) rather than approximately right.
//!
//! Buffers are preallocated at `[max_seq_len × d_model]` per layer so the
//! steady-state step path never allocates for cache writes.

use crate::model::ModelConfig;
use crate::tensor::Mat;

/// Preallocated per-layer K/V buffers plus shared valid-length tracking.
///
/// One instance belongs to one decode lane (requests never share a cache
/// — cached rows encode one lane's window). Construction sizes it for a
/// specific model config; [`crate::nn::Model::forward_step`] asserts the
/// shape matches the model it runs on.
pub struct KvCache {
    /// Per layer: (max_seq_len, d_model) key rows.
    k: Vec<Mat>,
    /// Per layer: (max_seq_len, d_model) value rows.
    v: Vec<Mat>,
    /// Cached positions valid in every layer (rows `0..len`).
    len: usize,
}

impl KvCache {
    /// Preallocate for `cfg`'s layer count, window and width.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        KvCache {
            k: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.max_seq_len, cfg.d_model))
                .collect(),
            v: (0..cfg.n_layers)
                .map(|_| Mat::zeros(cfg.max_seq_len, cfg.d_model))
                .collect(),
            len: 0,
        }
    }

    /// Cached positions (valid rows per layer).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum cacheable positions (the model's window).
    pub fn capacity(&self) -> usize {
        self.k.first().map_or(0, |m| m.rows)
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }

    /// Invalidate every cached row (refresh / window-slide rebuild; the
    /// buffers stay allocated — rows are overwritten before reuse).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Does this cache match `cfg`'s shape?
    pub fn fits(&self, cfg: &ModelConfig) -> bool {
        self.n_layers() == cfg.n_layers
            && self.capacity() == cfg.max_seq_len
            && self.k.iter().all(|m| m.cols == cfg.d_model)
    }

    /// Cached K/V matrices of one layer (rows `0..len()` are valid).
    pub(crate) fn layer(&self, layer: usize) -> (&Mat, &Mat) {
        (&self.k[layer], &self.v[layer])
    }

    /// Copy a prefill's first `t` K/V rows for `layer` into the cache.
    pub(crate) fn record_prefill(&mut self, layer: usize, k: &Mat, v: &Mat, t: usize) {
        assert!(t <= self.capacity(), "prefill exceeds cache capacity");
        let d = self.k[layer].cols;
        self.k[layer].data[..t * d].copy_from_slice(&k.data[..t * d]);
        self.v[layer].data[..t * d].copy_from_slice(&v.data[..t * d]);
    }

    /// Write one new position's K/V row for `layer` at `pos`.
    pub(crate) fn write_row(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.k[layer].row_mut(pos).copy_from_slice(k);
        self.v[layer].row_mut(pos).copy_from_slice(v);
    }

    /// Commit the valid length after all layers recorded (prefill sets
    /// `t`; a step sets `pos + 1`).
    pub(crate) fn set_len(&mut self, len: usize) {
        assert!(len <= self.capacity(), "cache length exceeds capacity");
        self.len = len;
    }

    /// Row width (the model's `d_model`).
    pub fn d_model(&self) -> usize {
        self.k.first().map_or(0, |m| m.cols)
    }

    /// Seed this cache from a stored prefix entry: copy the first `n`
    /// positions of every layer's rows and mark them valid, replacing any
    /// prior contents. The rows must have been produced at absolute
    /// positions `0..n` under the layouts the lane will keep executing —
    /// the store's keying discipline (`crate::kvstore`) guarantees both,
    /// which is what makes a seeded suffix prefill bit-identical to a full
    /// one.
    pub fn seed_from(&mut self, entry: &crate::kvstore::KvEntry, n: usize) {
        assert_eq!(entry.n_layers(), self.n_layers(), "seed layer mismatch");
        assert_eq!(entry.d_model, self.d_model(), "seed width mismatch");
        assert!(n <= entry.len(), "seed beyond entry length");
        assert!(n <= self.capacity(), "seed exceeds cache capacity");
        let d = self.d_model();
        for layer in 0..self.k.len() {
            self.k[layer].data[..n * d].copy_from_slice(&entry.k[layer][..n * d]);
            self.v[layer].data[..n * d].copy_from_slice(&entry.v[layer][..n * d]);
        }
        self.len = n;
    }

    /// Clone the first `n` cached positions of every layer as flat
    /// per-layer row vectors — the publishing/parking half of
    /// [`KvCache::seed_from`].
    pub fn export_prefix(&self, n: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        assert!(n <= self.len, "export beyond valid rows");
        let d = self.d_model();
        let k = self.k.iter().map(|m| m.data[..n * d].to_vec()).collect();
        let v = self.v.iter().map(|m| m.data[..n * d].to_vec()).collect();
        (k, v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        let mut c = ModelConfig::new("kv-tiny", 2, 2, 8);
        c.max_seq_len = 6;
        c
    }

    #[test]
    fn preallocates_model_shape() {
        let kv = KvCache::new(&cfg());
        assert_eq!(kv.n_layers(), 2);
        assert_eq!(kv.capacity(), 6);
        assert_eq!(kv.len(), 0);
        assert!(kv.is_empty());
        assert!(kv.fits(&cfg()));
        assert!(!kv.fits(&ModelConfig::new("other", 3, 2, 8)));
    }

    #[test]
    fn record_write_and_clear_track_len() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let k = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| (i * 10 + j) as f32);
        let v = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| -((i * 10 + j) as f32));
        for l in 0..c.n_layers {
            kv.record_prefill(l, &k, &v, 3);
        }
        kv.set_len(3);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.layer(1).0.row(2), k.row(2));
        assert_eq!(kv.layer(0).1.row(1), v.row(1));

        let new_k = vec![7.0f32; c.d_model];
        let new_v = vec![9.0f32; c.d_model];
        for l in 0..c.n_layers {
            kv.write_row(l, 3, &new_k, &new_v);
        }
        kv.set_len(4);
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.layer(0).0.row(3), new_k.as_slice());
        assert_eq!(kv.layer(1).1.row(3), new_v.as_slice());

        kv.clear();
        assert!(kv.is_empty());
        // buffers survive a clear: the next prefill overwrites in place
        assert_eq!(kv.capacity(), 6);
    }

    #[test]
    fn seed_roundtrips_through_export() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let k = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| (i * 10 + j) as f32);
        let v = Mat::from_fn(c.max_seq_len, c.d_model, |i, j| -((i * 10 + j) as f32));
        for l in 0..c.n_layers {
            kv.record_prefill(l, &k, &v, 4);
        }
        kv.set_len(4);
        let (ek, ev) = kv.export_prefix(3);
        let entry = crate::kvstore::KvEntry {
            tokens: vec![1, 2, 3],
            k: ek,
            v: ev,
            d_model: c.d_model,
        };

        let mut seeded = KvCache::new(&c);
        seeded.seed_from(&entry, 3);
        assert_eq!(seeded.len(), 3);
        for l in 0..c.n_layers {
            for t in 0..3 {
                assert_eq!(seeded.layer(l).0.row(t), kv.layer(l).0.row(t));
                assert_eq!(seeded.layer(l).1.row(t), kv.layer(l).1.row(t));
            }
        }
        // partial seeds (shorter than the entry) take a strict prefix
        let mut short = KvCache::new(&c);
        short.seed_from(&entry, 2);
        assert_eq!(short.len(), 2);
        assert_eq!(short.layer(0).0.row(1), kv.layer(0).0.row(1));
    }

    #[test]
    #[should_panic(expected = "seed layer mismatch")]
    fn seed_rejects_foreign_shape() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let entry = crate::kvstore::KvEntry {
            tokens: vec![1],
            k: vec![vec![0.0; c.d_model]],
            v: vec![vec![0.0; c.d_model]],
            d_model: c.d_model,
        };
        kv.seed_from(&entry, 1); // 1 layer vs the config's 2
    }

    #[test]
    #[should_panic(expected = "exceeds cache capacity")]
    fn overlong_prefill_rejected() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let k = Mat::zeros(c.max_seq_len + 2, c.d_model);
        kv.record_prefill(0, &k, &k, c.max_seq_len + 1);
    }
}
