//! μ-MoE analysis lens: treat each weight as a single-parameter
//! micro-expert and measure how the active set behaves across prompts,
//! domains and sparsity levels.
//!
//! This module backs the repo's "is the MoE view real?" ablations: if
//! online pruning always picked the same experts, it would collapse to
//! offline pruning and the paper's premise would be empty. The overlap
//! statistics quantify prompt-dependence (paper §2, Figure 2).

use crate::nn::Model;
use crate::pruning::{wanda::online_wanda_mask, Mask};
use crate::util::error::Error;
use std::collections::HashMap;

/// Per-linear activation-statistics summary for one prompt.
#[derive(Clone, Debug)]
pub struct ExpertSelection {
    /// Linear name → active-set mask at the probe sparsity.
    pub masks: HashMap<String, Mask>,
    pub rho: f64,
}

/// Compute the micro-expert selection a prompt induces on a host model.
pub fn select_experts(model: &Model, tokens: &[i32], valid_len: usize, rho: f64) -> ExpertSelection {
    let acts = model.collect_activations(tokens, valid_len);
    let mut masks = HashMap::new();
    for (name, w) in model.prunable() {
        let x = &acts[&name];
        masks.insert(name.clone(), online_wanda_mask(w, x, rho));
    }
    ExpertSelection { masks, rho }
}

/// Pairwise expert-overlap summary across a set of selections.
#[derive(Clone, Debug)]
pub struct OverlapStats {
    /// Mean Jaccard overlap per linear across all pairs.
    pub mean_jaccard: HashMap<String, f64>,
    /// Grand mean over all linears.
    pub overall: f64,
}

/// Mean pairwise Jaccard overlap of the active micro-expert sets.
///
/// Robust to ragged inputs: a linear that is missing from some selection,
/// or whose mask shape disagrees across selections, is skipped with a
/// warning instead of panicking (selections may come from different model
/// snapshots when replaying mixed traces).
pub fn overlap(selections: &[ExpertSelection]) -> OverlapStats {
    let mut mean_jaccard = HashMap::new();
    let mut total = 0.0;
    let mut n_lin = 0usize;
    if selections.len() < 2 {
        return OverlapStats {
            mean_jaccard,
            overall: 1.0,
        };
    }
    let mut names: Vec<String> = selections[0].masks.keys().cloned().collect();
    names.sort();
    let mut extras: Vec<&String> = selections[1..]
        .iter()
        .flat_map(|s| s.masks.keys())
        .filter(|k| !selections[0].masks.contains_key(*k))
        .collect();
    extras.sort();
    extras.dedup();
    for extra in extras {
        crate::warn_!("overlap: '{extra}' absent from the first selection; skipping it");
    }
    for name in &names {
        let consistent = selections.iter().all(|s| {
            s.masks.get(name).is_some_and(|m| {
                (m.rows, m.cols)
                    == (selections[0].masks[name].rows, selections[0].masks[name].cols)
            })
        });
        if !consistent {
            crate::warn_!("overlap: '{name}' missing or mismatched in some selections; skipping");
            continue;
        }
        let mut acc = 0.0;
        let mut pairs = 0usize;
        for i in 0..selections.len() {
            for j in i + 1..selections.len() {
                acc += selections[i].masks[name].jaccard(&selections[j].masks[name]);
                pairs += 1;
            }
        }
        let mean = acc / pairs as f64;
        mean_jaccard.insert(name.clone(), mean);
        total += mean;
        n_lin += 1;
    }
    if n_lin == 0 {
        // nothing was comparable — 'no data' must not read as 'disjoint'
        crate::warn_!("overlap: no linear was comparable across all selections");
        return OverlapStats {
            mean_jaccard,
            overall: f64::NAN,
        };
    }
    OverlapStats {
        mean_jaccard,
        overall: total / n_lin as f64,
    }
}

/// Expert-utilization histogram: how often each micro-expert of one linear
/// is activated across prompts (dead-expert / hot-expert analysis).
///
/// Errors (instead of panicking) when the selection set is empty, the
/// linear is absent from any selection, or mask shapes disagree.
pub fn utilization(selections: &[ExpertSelection], linear: &str) -> Result<Vec<f64>, Error> {
    if selections.is_empty() {
        return Err(Error::invariant("utilization over an empty selection set"));
    }
    let mask0 = selections[0]
        .masks
        .get(linear)
        .ok_or_else(|| Error::invariant(format!("utilization: no mask for '{linear}'")))?;
    let (rows, cols) = (mask0.rows, mask0.cols);
    let mut counts = vec![0u32; rows * cols];
    for (si, s) in selections.iter().enumerate() {
        let m = s.masks.get(linear).ok_or_else(|| {
            Error::invariant(format!("utilization: selection {si} has no mask for '{linear}'"))
        })?;
        if (m.rows, m.cols) != (rows, cols) {
            return Err(Error::invariant(format!(
                "utilization: mask shape mismatch for '{linear}': \
                 ({rows},{cols}) vs ({},{})",
                m.rows, m.cols
            )));
        }
        for i in 0..rows {
            for j in 0..cols {
                if m.at(i, j) {
                    counts[i * cols + j] += 1;
                }
            }
        }
    }
    Ok(counts
        .into_iter()
        .map(|c| c as f64 / selections.len() as f64)
        .collect())
}

/// Snap a requested sparsity to the closest supported level — the router
/// uses this to keep the number of distinct batch keys bounded.
pub fn snap_rho(rho: f64, levels: &[f64]) -> f64 {
    assert!(!levels.is_empty());
    let mut best = levels[0];
    let mut best_d = (rho - best).abs();
    for &l in &levels[1..] {
        let d = (rho - l).abs();
        if d < best_d {
            best = l;
            best_d = d;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::nn::random_model;

    fn model() -> Model {
        random_model(&ModelConfig::new("t", 2, 2, 16), 11)
    }

    #[test]
    fn selection_covers_all_linears() {
        let m = model();
        let sel = select_experts(&m, &[1, 2, 3, 4, 5], 5, 0.5);
        assert_eq!(sel.masks.len(), m.cfg.linear_names().len());
        for mask in sel.masks.values() {
            let f = mask.active_fraction();
            assert!(f > 0.4 && f < 0.6, "{f}");
        }
    }

    #[test]
    fn identical_prompts_full_overlap() {
        let m = model();
        let a = select_experts(&m, &[9, 8, 7, 6], 4, 0.5);
        let b = select_experts(&m, &[9, 8, 7, 6], 4, 0.5);
        let st = overlap(&[a, b]);
        assert!((st.overall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn different_prompts_partial_overlap() {
        let m = model();
        let a = select_experts(&m, &[1, 2, 3, 4, 5, 6], 6, 0.5);
        let b = select_experts(&m, &[200, 210, 220, 230, 240, 250], 6, 0.5);
        let st = overlap(&[a, b]);
        assert!(st.overall < 1.0, "expected prompt-dependent selection");
        assert!(st.overall > 0.2, "masks should still share hot experts");
    }

    #[test]
    fn utilization_bounds() {
        let m = model();
        let sels: Vec<_> = (0..3)
            .map(|i| {
                select_experts(&m, &[i * 10 + 1, i * 10 + 2, i * 10 + 3], 3, 0.5)
            })
            .collect();
        let u = utilization(&sels, "layers.0.q.w").expect("utilization");
        assert!(u.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let mean: f64 = u.iter().sum::<f64>() / u.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean utilization {mean}");
    }

    #[test]
    fn utilization_rejects_bad_inputs() {
        let m = model();
        let sels = vec![select_experts(&m, &[1, 2, 3], 3, 0.5)];
        assert!(utilization(&[], "layers.0.q.w").is_err());
        assert!(utilization(&sels, "no.such.linear").is_err());
        // a selection missing the linear errors instead of panicking
        let mut broken = sels.clone();
        broken.push(sels[0].clone());
        broken[1].masks.remove("layers.0.q.w");
        assert!(utilization(&broken, "layers.0.q.w").is_err());
    }

    #[test]
    fn overlap_skips_inconsistent_linears() {
        let m = model();
        let a = select_experts(&m, &[1, 2, 3, 4], 4, 0.5);
        let mut b = select_experts(&m, &[1, 2, 3, 4], 4, 0.5);
        b.masks.remove("layers.0.q.w");
        let st = overlap(&[a, b]);
        // the dropped linear is skipped, the rest still report full overlap
        assert!(!st.mean_jaccard.contains_key("layers.0.q.w"));
        assert_eq!(st.mean_jaccard.len(), m.cfg.linear_names().len() - 1);
        assert!((st.overall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn snap_rho_picks_nearest() {
        let levels = [0.2, 0.5, 1.0];
        assert_eq!(snap_rho(0.55, &levels), 0.5);
        assert_eq!(snap_rho(0.9, &levels), 1.0);
        assert_eq!(snap_rho(0.0, &levels), 0.2);
    }
}
