//! μ-MoE analysis lens: treat each weight as a single-parameter
//! micro-expert and measure how the active set behaves across prompts,
//! domains and sparsity levels.
//!
//! This module backs the repo's "is the MoE view real?" ablations: if
//! online pruning always picked the same experts, it would collapse to
//! offline pruning and the paper's premise would be empty. The overlap
//! statistics quantify prompt-dependence (paper §2, Figure 2).

use crate::nn::{FixedLayouts, Model};
use crate::pruning::{wanda::online_wanda_mask, Mask};
use crate::tensor::{LayoutCache, LayoutKey};
use crate::util::error::Error;
use std::collections::HashMap;
use std::sync::Arc;

/// Per-linear activation-statistics summary for one prompt.
#[derive(Clone, Debug)]
pub struct ExpertSelection {
    /// Linear name → active-set mask at the probe sparsity.
    pub masks: HashMap<String, Mask>,
    pub rho: f64,
}

/// Compute the micro-expert selection a prompt induces on a host model.
pub fn select_experts(model: &Model, tokens: &[i32], valid_len: usize, rho: f64) -> ExpertSelection {
    let acts = model.collect_activations(tokens, valid_len);
    let mut masks = HashMap::new();
    for (name, w) in model.prunable() {
        let x = &acts[&name];
        masks.insert(name.clone(), online_wanda_mask(w, x, rho));
    }
    ExpertSelection { masks, rho }
}

/// Turn a selection into executable per-linear [`crate::tensor::RowSparse`]
/// layouts, compressing through the layout cache when one is supplied.
///
/// The cache key is `(model weights, linear, snapped-ρ level, mask
/// fingerprint)`: two prompts (or two decode steps, or two batch-mates at
/// the same snapped level) that select the same micro-experts on the same
/// model share one compressed layout instead of recompressing, while two
/// models sharing one cache can never collide. Without a cache every
/// linear is compressed directly — same result, no reuse.
pub fn layouts_for(
    model: &Model,
    sel: &ExpertSelection,
    cache: Option<&mut LayoutCache>,
) -> FixedLayouts {
    layouts_for_mode(model, sel, cache, false)
}

/// [`layouts_for`] with a kernel-mode switch: `quant` compresses through
/// [`crate::pruning::Mask::compress_quant`] instead, attaching the int8
/// sidecar the `nn` funnels dispatch on, and caches in the layout cache's
/// quant arm under the same key — f32 and quantized layouts for one mask
/// can be resident simultaneously without aliasing.
pub fn layouts_for_mode(
    model: &Model,
    sel: &ExpertSelection,
    mut cache: Option<&mut LayoutCache>,
    quant: bool,
) -> FixedLayouts {
    let mut out = FixedLayouts::new();
    for (name, w) in model.prunable() {
        let mask = &sel.masks[&name];
        let compress = || {
            if quant {
                mask.compress_quant(w)
            } else {
                mask.compress(w)
            }
        };
        let layout = match cache.as_deref_mut() {
            Some(c) => {
                let key =
                    LayoutKey::new(model.weights_id(), &*name, sel.rho, mask.fingerprint());
                if quant {
                    c.get_or_insert_quant_with(key, compress)
                } else {
                    c.get_or_insert_with(key, compress)
                }
            }
            None => Arc::new(compress()),
        };
        out.insert(name, layout);
    }
    out
}

/// Pairwise expert-overlap summary across a set of selections.
#[derive(Clone, Debug)]
pub struct OverlapStats {
    /// Mean Jaccard overlap per linear across all pairs.
    pub mean_jaccard: HashMap<String, f64>,
    /// Grand mean over all linears.
    pub overall: f64,
}

/// Mean pairwise Jaccard overlap of the active micro-expert sets.
///
/// Robust to ragged inputs: a linear that is missing from some selection,
/// or whose mask shape disagrees across selections, is skipped with a
/// warning instead of panicking (selections may come from different model
/// snapshots when replaying mixed traces).
pub fn overlap(selections: &[ExpertSelection]) -> OverlapStats {
    let mut mean_jaccard = HashMap::new();
    let mut total = 0.0;
    let mut n_lin = 0usize;
    if selections.len() < 2 {
        return OverlapStats {
            mean_jaccard,
            overall: 1.0,
        };
    }
    let mut names: Vec<String> = selections[0].masks.keys().cloned().collect();
    names.sort();
    let mut extras: Vec<&String> = selections[1..]
        .iter()
        .flat_map(|s| s.masks.keys())
        .filter(|k| !selections[0].masks.contains_key(*k))
        .collect();
    extras.sort();
    extras.dedup();
    for extra in extras {
        crate::warn_!("overlap: '{extra}' absent from the first selection; skipping it");
    }
    for name in &names {
        let consistent = selections.iter().all(|s| {
            s.masks.get(name).is_some_and(|m| {
                (m.rows, m.cols)
                    == (selections[0].masks[name].rows, selections[0].masks[name].cols)
            })
        });
        if !consistent {
            crate::warn_!("overlap: '{name}' missing or mismatched in some selections; skipping");
            continue;
        }
        let mut acc = 0.0;
        let mut pairs = 0usize;
        for i in 0..selections.len() {
            for j in i + 1..selections.len() {
                acc += selections[i].masks[name].jaccard(&selections[j].masks[name]);
                pairs += 1;
            }
        }
        let mean = acc / pairs as f64;
        mean_jaccard.insert(name.clone(), mean);
        total += mean;
        n_lin += 1;
    }
    if n_lin == 0 {
        // nothing was comparable — 'no data' must not read as 'disjoint'
        crate::warn_!("overlap: no linear was comparable across all selections");
        return OverlapStats {
            mean_jaccard,
            overall: f64::NAN,
        };
    }
    OverlapStats {
        mean_jaccard,
        overall: total / n_lin as f64,
    }
}

/// Expert-utilization histogram: how often each micro-expert of one linear
/// is activated across prompts (dead-expert / hot-expert analysis).
///
/// Errors (instead of panicking) when the selection set is empty, the
/// linear is absent from any selection, or mask shapes disagree.
pub fn utilization(selections: &[ExpertSelection], linear: &str) -> Result<Vec<f64>, Error> {
    if selections.is_empty() {
        return Err(Error::invariant("utilization over an empty selection set"));
    }
    let mask0 = selections[0]
        .masks
        .get(linear)
        .ok_or_else(|| Error::invariant(format!("utilization: no mask for '{linear}'")))?;
    let (rows, cols) = (mask0.rows, mask0.cols);
    let mut counts = vec![0u32; rows * cols];
    for (si, s) in selections.iter().enumerate() {
        let m = s.masks.get(linear).ok_or_else(|| {
            Error::invariant(format!("utilization: selection {si} has no mask for '{linear}'"))
        })?;
        if (m.rows, m.cols) != (rows, cols) {
            return Err(Error::invariant(format!(
                "utilization: mask shape mismatch for '{linear}': \
                 ({rows},{cols}) vs ({},{})",
                m.rows, m.cols
            )));
        }
        for i in 0..rows {
            for j in 0..cols {
                if m.at(i, j) {
                    counts[i * cols + j] += 1;
                }
            }
        }
    }
    Ok(counts
        .into_iter()
        .map(|c| c as f64 / selections.len() as f64)
        .collect())
}

/// Snap a requested sparsity to the closest supported level — the router
/// uses this to keep the number of distinct batch keys bounded.
pub fn snap_rho(rho: f64, levels: &[f64]) -> f64 {
    assert!(!levels.is_empty());
    let mut best = levels[0];
    let mut best_d = (rho - best).abs();
    for &l in &levels[1..] {
        let d = (rho - l).abs();
        if d < best_d {
            best = l;
            best_d = d;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::nn::random_model;

    fn model() -> Model {
        random_model(&ModelConfig::new("t", 2, 2, 16), 11)
    }

    #[test]
    fn selection_covers_all_linears() {
        let m = model();
        let sel = select_experts(&m, &[1, 2, 3, 4, 5], 5, 0.5);
        assert_eq!(sel.masks.len(), m.cfg.linear_names().len());
        for mask in sel.masks.values() {
            let f = mask.active_fraction();
            assert!(f > 0.4 && f < 0.6, "{f}");
        }
    }

    #[test]
    fn layouts_for_matches_direct_compression_and_caches() {
        let m = model();
        let sel = select_experts(&m, &[4, 2, 9, 7], 4, 0.5);
        let direct = layouts_for(&m, &sel, None);
        let mut cache = LayoutCache::new(64);
        let cached = layouts_for(&m, &sel, Some(&mut cache));
        assert_eq!(direct.len(), m.cfg.linear_names().len());
        for (name, a) in &direct {
            let b = &cached[name];
            assert_eq!(a.fingerprint(), b.fingerprint(), "{name}");
        }
        // first pass was all misses; an identical selection is all hits
        let n = m.cfg.linear_names().len() as u64;
        assert_eq!((cache.hits(), cache.misses()), (0, n));
        let again = layouts_for(&m, &sel, Some(&mut cache));
        assert_eq!((cache.hits(), cache.misses()), (n, n));
        for (name, a) in &cached {
            // cache hit returns the same Arc, not a recompression
            assert!(Arc::ptr_eq(a, &again[name]), "{name}");
        }
    }

    #[test]
    fn quant_layouts_carry_sidecars_and_cache_in_their_own_arm() {
        let m = model();
        let sel = select_experts(&m, &[4, 2, 9, 7], 4, 0.5);
        let mut cache = LayoutCache::new(64);
        let n = m.cfg.linear_names().len() as u64;
        let f32s = layouts_for_mode(&m, &sel, Some(&mut cache), false);
        let quants = layouts_for_mode(&m, &sel, Some(&mut cache), true);
        // same key, different arm: no cross-hits, both resident
        assert_eq!((cache.hits(), cache.misses()), (0, 2 * n));
        assert_eq!(cache.len(), 2 * n as usize);
        for (name, q) in &quants {
            assert!(q.quant.is_some(), "{name}: sidecar missing");
            assert!(f32s[name].quant.is_none(), "{name}: f32 arm got a sidecar");
            // same selection, same surviving weights under the sidecar
            assert_eq!(q.values, f32s[name].values, "{name}");
        }
        // repeat selections hit their respective arms without rebuilding
        let again = layouts_for_mode(&m, &sel, Some(&mut cache), true);
        assert_eq!((cache.hits(), cache.misses()), (n, 2 * n));
        for (name, q) in &quants {
            assert!(Arc::ptr_eq(q, &again[name]), "{name}");
        }
        // no-cache quant path attaches the sidecar too
        let direct = layouts_for_mode(&m, &sel, None, true);
        for (name, q) in &direct {
            assert_eq!(
                q.fingerprint(),
                quants[name].fingerprint(),
                "{name}: direct and cached quant layouts diverge"
            );
        }
    }

    #[test]
    fn shared_cache_never_mixes_models() {
        // regression: at rho=1.0 every mask is all-ones, so without weight
        // identity in the key two same-architecture models would collide
        // on every cache entry and one would execute the other's weights
        let m1 = random_model(&ModelConfig::new("t", 2, 2, 16), 11);
        let m2 = random_model(&ModelConfig::new("t", 2, 2, 16), 12);
        assert_ne!(m1.weights_id(), m2.weights_id());
        let s1 = select_experts(&m1, &[1, 2, 3], 3, 1.0);
        let s2 = select_experts(&m2, &[1, 2, 3], 3, 1.0);
        let mut cache = LayoutCache::new(128);
        let l1 = layouts_for(&m1, &s1, Some(&mut cache));
        let l2 = layouts_for(&m2, &s2, Some(&mut cache));
        assert_eq!(cache.hits(), 0, "distinct models must not share entries");
        for (name, a) in &l1 {
            assert_ne!(
                a.fingerprint(),
                l2[name].fingerprint(),
                "{name}: model B served model A's layout"
            );
        }
    }

    #[test]
    fn identical_prompts_full_overlap() {
        let m = model();
        let a = select_experts(&m, &[9, 8, 7, 6], 4, 0.5);
        let b = select_experts(&m, &[9, 8, 7, 6], 4, 0.5);
        let st = overlap(&[a, b]);
        assert!((st.overall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn different_prompts_partial_overlap() {
        let m = model();
        let a = select_experts(&m, &[1, 2, 3, 4, 5, 6], 6, 0.5);
        let b = select_experts(&m, &[200, 210, 220, 230, 240, 250], 6, 0.5);
        let st = overlap(&[a, b]);
        assert!(st.overall < 1.0, "expected prompt-dependent selection");
        assert!(st.overall > 0.2, "masks should still share hot experts");
    }

    #[test]
    fn utilization_bounds() {
        let m = model();
        let sels: Vec<_> = (0..3)
            .map(|i| {
                select_experts(&m, &[i * 10 + 1, i * 10 + 2, i * 10 + 3], 3, 0.5)
            })
            .collect();
        let u = utilization(&sels, "layers.0.q.w").expect("utilization");
        assert!(u.iter().all(|&x| (0.0..=1.0).contains(&x)));
        let mean: f64 = u.iter().sum::<f64>() / u.len() as f64;
        assert!((mean - 0.5).abs() < 0.05, "mean utilization {mean}");
    }

    #[test]
    fn utilization_rejects_bad_inputs() {
        let m = model();
        let sels = vec![select_experts(&m, &[1, 2, 3], 3, 0.5)];
        assert!(utilization(&[], "layers.0.q.w").is_err());
        assert!(utilization(&sels, "no.such.linear").is_err());
        // a selection missing the linear errors instead of panicking
        let mut broken = sels.clone();
        broken.push(sels[0].clone());
        broken[1].masks.remove("layers.0.q.w");
        assert!(utilization(&broken, "layers.0.q.w").is_err());
    }

    #[test]
    fn overlap_skips_inconsistent_linears() {
        let m = model();
        let a = select_experts(&m, &[1, 2, 3, 4], 4, 0.5);
        let mut b = select_experts(&m, &[1, 2, 3, 4], 4, 0.5);
        b.masks.remove("layers.0.q.w");
        let st = overlap(&[a, b]);
        // the dropped linear is skipped, the rest still report full overlap
        assert!(!st.mean_jaccard.contains_key("layers.0.q.w"));
        assert_eq!(st.mean_jaccard.len(), m.cfg.linear_names().len() - 1);
        assert!((st.overall - 1.0).abs() < 1e-12);
    }

    #[test]
    fn snap_rho_picks_nearest() {
        let levels = [0.2, 0.5, 1.0];
        assert_eq!(snap_rho(0.55, &levels), 0.5);
        assert_eq!(snap_rho(0.9, &levels), 1.0);
        assert_eq!(snap_rho(0.0, &levels), 0.2);
    }
}
