//! Magnitude pruning (Han et al., 2015) — the paper's weakest baseline.
//! Score is `|W|` alone; no activation awareness, hence the collapse the
//! paper shows below ~50% active weights.

use super::{mask_from_scores, selection::Selector, Mask};
use crate::tensor::Mat;

/// Per-row top-ρ mask from weight magnitudes.
pub fn magnitude_mask(w: &Mat, rho: f64) -> Mask {
    let scores = Mat {
        rows: w.rows,
        cols: w.cols,
        data: w.data.iter().map(|x| x.abs()).collect(),
    };
    mask_from_scores(&scores, rho, Selector::KthValue)
}

/// Convenience: return the pruned weight copy directly. Reference form
/// only — hot paths use `magnitude_mask` + `Mask::apply_in_place` (or
/// `Mask::compress`) to avoid the dense copy this allocates.
pub fn magnitude_prune(w: &Mat, rho: f64) -> Mat {
    magnitude_mask(w, rho).apply(w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::kc_for;
    use crate::util::rng::Pcg32;

    #[test]
    fn keeps_largest_by_row() {
        let w = Mat::from_vec(2, 4, vec![1.0, -5.0, 0.1, 3.0, -2.0, 0.5, 4.0, -0.2]);
        let m = magnitude_mask(&w, 0.5);
        assert_eq!(m.dense_bits(), vec![0, 1, 0, 1, 1, 0, 1, 0]);
    }

    #[test]
    fn row_counts_exact() {
        let mut rng = Pcg32::new(1, 0);
        let w = Mat::from_vec(16, 48, rng.normal_vec(16 * 48));
        let m = magnitude_mask(&w, 0.4);
        let keep = 48 - kc_for(48, 0.4);
        assert!(m.row_active_counts().iter().all(|&c| c == keep));
    }

    #[test]
    fn pruned_weights_match_mask() {
        let mut rng = Pcg32::new(2, 0);
        let w = Mat::from_vec(4, 8, rng.normal_vec(32));
        let pruned = magnitude_prune(&w, 0.5);
        let m = magnitude_mask(&w, 0.5);
        for i in 0..4 {
            for j in 0..8 {
                if m.at(i, j) {
                    assert_eq!(pruned.at(i, j), w.at(i, j));
                } else {
                    assert_eq!(pruned.at(i, j), 0.0);
                }
            }
        }
    }
}
