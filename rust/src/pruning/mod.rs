//! Pruning engines: the paper's methods as host-side reference
//! implementations.
//!
//! The runtime path executes μ-MoE pruning *inside* the AOT artifact
//! (L1/L2); these engines exist to (a) produce offline-pruned weights for
//! the baseline methods (magnitude / Wanda / SparseGPT feed host-modified
//! weights into the dense artifact), (b) oracle the in-graph behaviour, and
//! (c) regenerate the paper's Figure 3 selection-algorithm study.
//!
//! Scoring (paper eq. 2/3):
//! * magnitude:  `S = |W|`
//! * Wanda:      `S = |W| · ‖X_j‖₂`
//! * SparseGPT:  `S = W² / diag(Chol[(XXᵀ+λI)⁻¹])²` with OBS updates
//!
//! All produce per-output-row semi-structured sparsity: exactly
//! `k_c = ⌊(1−ρ)·d_in⌋` zeros per row.

pub mod magnitude;
pub mod selection;
pub mod sparsegpt;
pub mod wanda;

use crate::tensor::Mat;

/// Number of *inactive* weights per row for active ratio `rho`, clipped so
/// at least one weight per row survives (mirrors python `pruning.kc_for`).
pub fn kc_for(d_in: usize, rho: f64) -> usize {
    let kc = ((1.0 - rho) * d_in as f64).floor() as i64;
    kc.clamp(0, d_in as i64 - 1) as usize
}

/// A binary micro-expert activation mask with the same shape as a weight.
#[derive(Clone, Debug)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    /// 1 = micro-expert active, 0 = pruned. Stored as u8 to keep large
    /// masks cheap (the mask for mu-opt-small's fc1 is 1024x256).
    pub bits: Vec<u8>,
}

impl Mask {
    pub fn ones(rows: usize, cols: usize) -> Mask {
        Mask {
            rows,
            cols,
            bits: vec![1; rows * cols],
        }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> bool {
        self.bits[i * self.cols + j] != 0
    }

    pub fn active_count(&self) -> usize {
        self.bits.iter().filter(|b| **b != 0).count()
    }

    pub fn active_fraction(&self) -> f64 {
        self.active_count() as f64 / self.bits.len() as f64
    }

    pub fn row_active_counts(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|i| {
                self.bits[i * self.cols..(i + 1) * self.cols]
                    .iter()
                    .filter(|b| **b != 0)
                    .count()
            })
            .collect()
    }

    /// Apply to a weight matrix (returns the pruned copy).
    pub fn apply(&self, w: &Mat) -> Mat {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        let mut out = w.clone();
        for (x, &b) in out.data.iter_mut().zip(&self.bits) {
            if b == 0 {
                *x = 0.0;
            }
        }
        out
    }

    /// Jaccard overlap of active sets — used by `moe::overlap` to show how
    /// prompt-dependent the micro-expert selection is.
    pub fn jaccard(&self, other: &Mask) -> f64 {
        assert_eq!(self.bits.len(), other.bits.len());
        let mut inter = 0usize;
        let mut union = 0usize;
        for (&a, &b) in self.bits.iter().zip(&other.bits) {
            if a != 0 || b != 0 {
                union += 1;
                if a != 0 && b != 0 {
                    inter += 1;
                }
            }
        }
        if union == 0 {
            1.0
        } else {
            inter as f64 / union as f64
        }
    }
}

/// Build a mask keeping, per row, the weights with score *strictly above*
/// the row's `k_c`-th smallest score. Mirrors the kthvalue formulation
/// used by the L1 kernel (`kernels/ref.py::row_kth_threshold`).
pub fn mask_from_scores(scores: &Mat, rho: f64, sel: selection::Selector) -> Mask {
    let kc = kc_for(scores.cols, rho);
    let mut bits = vec![0u8; scores.rows * scores.cols];
    let mut scratch = vec![0.0f32; scores.cols];
    for i in 0..scores.rows {
        let row = scores.row(i);
        if kc == 0 {
            bits[i * scores.cols..(i + 1) * scores.cols].fill(1);
            continue;
        }
        let thr = sel.kth_smallest(row, kc, &mut scratch);
        for (j, &s) in row.iter().enumerate() {
            if s > thr {
                bits[i * scores.cols + j] = 1;
            }
        }
    }
    Mask {
        rows: scores.rows,
        cols: scores.cols,
        bits,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn kc_matches_python_reference() {
        assert_eq!(kc_for(10, 1.0), 0);
        assert_eq!(kc_for(10, 0.0), 9);
        assert_eq!(kc_for(100, 0.6), 40);
        assert_eq!(kc_for(128, 0.5), 64);
    }

    #[test]
    fn mask_row_counts_exact_without_ties() {
        let mut rng = Pcg32::new(1, 0);
        let s = Mat::from_vec(8, 32, rng.normal_vec(256).iter().map(|x| x.abs()).collect());
        let mask = mask_from_scores(&s, 0.5, selection::Selector::KthValue);
        let kc = kc_for(32, 0.5);
        for c in mask.row_active_counts() {
            assert_eq!(c, 32 - kc);
        }
    }

    #[test]
    fn rho_one_keeps_all() {
        let mut rng = Pcg32::new(2, 0);
        let s = Mat::from_vec(4, 16, rng.normal_vec(64));
        let mask = mask_from_scores(&s, 1.0, selection::Selector::Sort);
        assert_eq!(mask.active_count(), 64);
    }

    #[test]
    fn jaccard_bounds() {
        let a = Mask::ones(2, 4);
        let mut b = Mask::ones(2, 4);
        assert_eq!(a.jaccard(&b), 1.0);
        b.bits.fill(0);
        assert_eq!(a.jaccard(&b), 0.0);
    }

    #[test]
    fn apply_zeroes_pruned() {
        let w = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let mask = Mask {
            rows: 1,
            cols: 4,
            bits: vec![1, 0, 1, 0],
        };
        assert_eq!(mask.apply(&w).data, vec![1.0, 0.0, 3.0, 0.0]);
    }
}
