//! Pruning engines: the paper's methods as host-side reference
//! implementations.
//!
//! The runtime path executes μ-MoE pruning *inside* the AOT artifact
//! (L1/L2); these engines exist to (a) produce offline-pruned weights for
//! the baseline methods (magnitude / Wanda / SparseGPT feed host-modified
//! weights into the dense artifact), (b) oracle the in-graph behaviour, and
//! (c) regenerate the paper's Figure 3 selection-algorithm study.
//!
//! Scoring (paper eq. 2/3):
//! * magnitude:  `S = |W|`
//! * Wanda:      `S = |W| · ‖X_j‖₂`
//! * SparseGPT:  `S = W² / diag(Chol[(XXᵀ+λI)⁻¹])²` with OBS updates
//!
//! All produce per-output-row semi-structured sparsity: exactly
//! `k_c = ⌊(1−ρ)·d_in⌋` zeros per row.
//!
//! Execution pipeline: a [`Mask`] (one bit per micro-expert) is either
//! applied destructively to dense weights ([`Mask::apply_in_place`], the
//! offline path) or compressed to a [`crate::tensor::RowSparse`] layout
//! ([`Mask::compress`]) that the sparse matmul kernels consume directly —
//! the online μ-MoE path never materializes a zeroed dense copy.

pub mod magnitude;
pub mod plan;
pub mod selection;
pub mod sparsegpt;
pub mod wanda;

pub use plan::MaskPlan;

use crate::tensor::{fnv1a64, Mat, QuantRowSparse, RowSparse};
use std::sync::Arc;

/// Number of *inactive* weights per row for active ratio `rho`, clipped so
/// at least one weight per row survives (mirrors python `pruning.kc_for`).
pub fn kc_for(d_in: usize, rho: f64) -> usize {
    let kc = ((1.0 - rho) * d_in as f64).floor() as i64;
    kc.clamp(0, d_in as i64 - 1) as usize
}

/// A binary micro-expert activation mask with the same shape as a weight.
///
/// Bitset-backed: one bit per weight, rows padded to whole 64-bit words so
/// per-row operations (popcount, AND/OR for Jaccard) run word-at-a-time.
/// mu-opt-small's fc1 mask is 1024x256 = 32 KiB of words instead of the
/// 256 KiB the old byte-per-weight layout used.
///
/// Invariant: padding bits past `cols` in each row's last word are zero —
/// all constructors and [`Mask::set`] maintain this, which is what lets
/// the popcount-based queries skip per-bit bounds checks.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl Mask {
    fn words_per_row_for(cols: usize) -> usize {
        cols.max(1).div_ceil(64)
    }

    /// Value of a row's word `jw` when every in-bounds bit is set.
    fn full_word(&self, jw: usize) -> u64 {
        let base = jw * 64;
        let width = (self.cols - base).min(64);
        if width == 64 {
            u64::MAX
        } else {
            (1u64 << width) - 1
        }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mask {
        let wpr = Self::words_per_row_for(cols);
        Mask {
            rows,
            cols,
            words_per_row: wpr,
            words: vec![0; rows * wpr],
        }
    }

    pub fn ones(rows: usize, cols: usize) -> Mask {
        let mut m = Mask::zeros(rows, cols);
        for i in 0..rows {
            for jw in 0..m.words_per_row {
                let full = m.full_word(jw);
                m.words[i * m.words_per_row + jw] = full;
            }
        }
        m
    }

    /// Build from a dense byte mask (1 = active) — the interchange form
    /// shared with the python fixtures.
    pub fn from_bits(rows: usize, cols: usize, bits: &[u8]) -> Mask {
        assert_eq!(bits.len(), rows * cols, "mask shape/data mismatch");
        let mut m = Mask::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if bits[i * cols + j] != 0 {
                    m.set(i, j, true);
                }
            }
        }
        m
    }

    /// Expand to the dense byte form (1 = active).
    pub fn dense_bits(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.rows * self.cols];
        for i in 0..self.rows {
            for j in 0..self.cols {
                if self.at(i, j) {
                    out[i * self.cols + j] = 1;
                }
            }
        }
        out
    }

    fn row_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> bool {
        debug_assert!(i < self.rows && j < self.cols);
        let w = self.words[i * self.words_per_row + j / 64];
        w >> (j % 64) & 1 != 0
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, active: bool) {
        debug_assert!(i < self.rows && j < self.cols);
        let w = &mut self.words[i * self.words_per_row + j / 64];
        if active {
            *w |= 1u64 << (j % 64);
        } else {
            *w &= !(1u64 << (j % 64));
        }
    }

    pub fn active_count(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    pub fn active_fraction(&self) -> f64 {
        if self.rows * self.cols == 0 {
            return 0.0;
        }
        self.active_count() as f64 / (self.rows * self.cols) as f64
    }

    pub fn row_active_counts(&self) -> Vec<usize> {
        (0..self.rows)
            .map(|i| {
                self.row_words(i)
                    .iter()
                    .map(|w| w.count_ones() as usize)
                    .sum()
            })
            .collect()
    }

    /// Apply to a weight matrix (returns the pruned copy). Prefer
    /// [`Mask::apply_in_place`] or [`Mask::compress`] on hot paths.
    pub fn apply(&self, w: &Mat) -> Mat {
        let mut out = w.clone();
        self.apply_in_place(&mut out);
        out
    }

    /// Zero the pruned weights of `w` in place — no allocation.
    pub fn apply_in_place(&self, w: &mut Mat) {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        for i in 0..self.rows {
            let row = w.row_mut(i);
            for (jw, &word) in self.row_words(i).iter().enumerate() {
                if word == self.full_word(jw) {
                    continue; // fully-active word: nothing to zero
                }
                let base = jw * 64;
                let end = (base + 64).min(self.cols);
                for (b, x) in row[base..end].iter_mut().enumerate() {
                    if word >> b & 1 == 0 {
                        *x = 0.0;
                    }
                }
            }
        }
    }

    /// Compress the active weights of `w` into the row-sparse layout the
    /// sparse matmul kernels execute — the mask → layout → kernel handoff.
    pub fn compress(&self, w: &Mat) -> RowSparse {
        assert_eq!((w.rows, w.cols), (self.rows, self.cols));
        assert!(self.cols <= u32::MAX as usize, "cols overflow u32 index");
        let nnz = self.active_count();
        let mut row_ptr = Vec::with_capacity(self.rows + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        row_ptr.push(0);
        for i in 0..self.rows {
            let w_row = w.row(i);
            for (jw, &word) in self.row_words(i).iter().enumerate() {
                let base = jw * 64;
                let mut rest = word;
                while rest != 0 {
                    let j = base + rest.trailing_zeros() as usize;
                    col_idx.push(j as u32);
                    values.push(w_row[j]);
                    rest &= rest - 1;
                }
            }
            row_ptr.push(col_idx.len());
        }
        RowSparse {
            rows: self.rows,
            cols: self.cols,
            row_ptr,
            col_idx,
            values,
            quant: None,
        }
    }

    /// [`Mask::compress`] plus an int8 sidecar: the compressed f32 layout
    /// with a per-row absmax-quantized [`QuantRowSparse`] attached, which
    /// the `nn` execution funnels dispatch to. Like the mask itself, the
    /// quantizer is calibration-free — scales come from the surviving
    /// weights at compression time.
    pub fn compress_quant(&self, w: &Mat) -> RowSparse {
        let mut rs = self.compress(w);
        rs.quant = Some(Arc::new(QuantRowSparse::from_sparse(&rs)));
        rs
    }

    /// Content hash of the active set (shape + bit words). Two masks with
    /// equal fingerprints select (collision aside) the same micro-experts,
    /// which is what makes the fingerprint a valid
    /// [`crate::tensor::LayoutKey`] component: same mask + same weights ⇒
    /// same compressed layout. The padding-bits-are-zero invariant keeps
    /// the word hash canonical.
    pub fn fingerprint(&self) -> u64 {
        fnv1a64(
            [self.rows as u64, self.cols as u64]
                .into_iter()
                .chain(self.words.iter().copied()),
        )
    }

    /// Jaccard overlap of active sets — used by `moe::overlap` to show how
    /// prompt-dependent the micro-expert selection is.
    pub fn jaccard(&self, other: &Mask) -> f64 {
        assert_eq!(
            (self.rows, self.cols),
            (other.rows, other.cols),
            "jaccard shape mismatch"
        );
        let mut inter = 0u64;
        let mut union = 0u64;
        for (&a, &b) in self.words.iter().zip(&other.words) {
            inter += (a & b).count_ones() as u64;
            union += (a | b).count_ones() as u64;
        }
        if union == 0 {
            1.0
        } else {
            inter as f64 / union as f64
        }
    }
}

/// Build a mask keeping, per row, the weights with score *strictly above*
/// the row's `k_c`-th smallest score. Mirrors the kthvalue formulation
/// used by the L1 kernel (`kernels/ref.py::row_kth_threshold`).
pub fn mask_from_scores(scores: &Mat, rho: f64, sel: selection::Selector) -> Mask {
    let kc = kc_for(scores.cols, rho);
    if kc == 0 {
        return Mask::ones(scores.rows, scores.cols);
    }
    let mut mask = Mask::zeros(scores.rows, scores.cols);
    let mut scratch = vec![0.0f32; scores.cols];
    for i in 0..scores.rows {
        let row = scores.row(i);
        let thr = sel.kth_smallest(row, kc, &mut scratch);
        for (j, &s) in row.iter().enumerate() {
            if s > thr {
                mask.set(i, j, true);
            }
        }
    }
    mask
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn kc_matches_python_reference() {
        assert_eq!(kc_for(10, 1.0), 0);
        assert_eq!(kc_for(10, 0.0), 9);
        assert_eq!(kc_for(100, 0.6), 40);
        assert_eq!(kc_for(128, 0.5), 64);
    }

    #[test]
    fn mask_row_counts_exact_without_ties() {
        let mut rng = Pcg32::new(1, 0);
        let s = Mat::from_vec(8, 32, rng.normal_vec(256).iter().map(|x| x.abs()).collect());
        let mask = mask_from_scores(&s, 0.5, selection::Selector::KthValue);
        let kc = kc_for(32, 0.5);
        for c in mask.row_active_counts() {
            assert_eq!(c, 32 - kc);
        }
    }

    #[test]
    fn rho_one_keeps_all() {
        let mut rng = Pcg32::new(2, 0);
        let s = Mat::from_vec(4, 16, rng.normal_vec(64));
        let mask = mask_from_scores(&s, 1.0, selection::Selector::Sort);
        assert_eq!(mask.active_count(), 64);
    }

    #[test]
    fn jaccard_bounds() {
        let a = Mask::ones(2, 4);
        let mut b = Mask::ones(2, 4);
        assert_eq!(a.jaccard(&b), 1.0);
        b = Mask::zeros(2, 4);
        assert_eq!(a.jaccard(&b), 0.0);
    }

    #[test]
    fn apply_zeroes_pruned() {
        let w = Mat::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let mask = Mask::from_bits(1, 4, &[1, 0, 1, 0]);
        assert_eq!(mask.apply(&w).data, vec![1.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn apply_in_place_matches_apply() {
        let mut rng = Pcg32::new(3, 0);
        let w = Mat::from_vec(6, 70, rng.normal_vec(6 * 70)); // spans word tail
        let s = Mat::from_vec(6, 70, rng.normal_vec(6 * 70));
        let mask = mask_from_scores(&s, 0.4, selection::Selector::KthValue);
        let a = mask.apply(&w);
        let mut b = w.clone();
        mask.apply_in_place(&mut b);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn bitset_roundtrip_and_counts() {
        let mut rng = Pcg32::new(4, 0);
        for cols in [1usize, 63, 64, 65, 130] {
            let bits: Vec<u8> = (0..3 * cols).map(|_| (rng.next_u32() & 1) as u8).collect();
            let m = Mask::from_bits(3, cols, &bits);
            assert_eq!(m.dense_bits(), bits, "cols={cols}");
            let want: usize = bits.iter().map(|&b| b as usize).sum();
            assert_eq!(m.active_count(), want, "cols={cols}");
            assert_eq!(
                m.row_active_counts().iter().sum::<usize>(),
                want,
                "cols={cols}"
            );
        }
    }

    #[test]
    fn set_and_at_agree_across_word_boundaries() {
        let mut m = Mask::zeros(2, 100);
        m.set(0, 0, true);
        m.set(0, 63, true);
        m.set(0, 64, true);
        m.set(1, 99, true);
        assert!(m.at(0, 0) && m.at(0, 63) && m.at(0, 64) && m.at(1, 99));
        assert!(!m.at(0, 1) && !m.at(1, 0));
        m.set(0, 63, false);
        assert!(!m.at(0, 63));
        assert_eq!(m.active_count(), 3);
    }

    #[test]
    fn compress_preserves_active_weights_in_order() {
        let w = Mat::from_vec(2, 5, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]);
        let mask = Mask::from_bits(2, 5, &[1, 0, 0, 1, 1, 0, 1, 0, 1, 0]);
        let rs = mask.compress(&w);
        assert_eq!(rs.row_ptr, vec![0, 3, 5]);
        assert_eq!(rs.col_idx, vec![0, 3, 4, 1, 3]);
        assert_eq!(rs.values, vec![1.0, 4.0, 5.0, 7.0, 9.0]);
        // explicit zeros that are *active* must survive compression
        let w2 = Mat::from_vec(1, 2, vec![0.0, 3.0]);
        let m2 = Mask::from_bits(1, 2, &[1, 0]);
        let rs2 = m2.compress(&w2);
        assert_eq!(rs2.values, vec![0.0]);
        assert_eq!(rs2.nnz(), 1);
    }

    #[test]
    fn compress_quant_attaches_matching_sidecar() {
        let mut rng = Pcg32::new(12, 0);
        let w = Mat::from_vec(6, 70, rng.normal_vec(6 * 70)); // spans word tail
        let s = Mat::from_vec(6, 70, rng.normal_vec(6 * 70));
        let mask = mask_from_scores(&s, 0.4, selection::Selector::KthValue);
        let plain = mask.compress(&w);
        let quant = mask.compress_quant(&w);
        // identical f32 CSR; the sidecar is exactly the quantization of it
        assert_eq!(plain.row_ptr, quant.row_ptr);
        assert_eq!(plain.col_idx, quant.col_idx);
        assert_eq!(plain.values, quant.values);
        let q = quant.quant.as_ref().expect("sidecar attached");
        assert_eq!(**q, QuantRowSparse::from_sparse(&plain));
        assert_ne!(plain.fingerprint(), quant.fingerprint());
    }

    #[test]
    fn fingerprint_is_content_addressed() {
        let mut rng = Pcg32::new(9, 0);
        let s = Mat::from_vec(4, 70, rng.normal_vec(4 * 70)); // spans word tail
        let a = mask_from_scores(&s, 0.5, selection::Selector::KthValue);
        let b = mask_from_scores(&s, 0.5, selection::Selector::Sort);
        // same scores, same rho, any selector: same active set, same hash
        assert_eq!(a, b);
        assert_eq!(a.fingerprint(), b.fingerprint());
        // flip one bit: different hash
        let mut c = a.clone();
        let flip = c.at(0, 0);
        c.set(0, 0, !flip);
        assert_ne!(a.fingerprint(), c.fingerprint());
        // same bits, different shape: different hash
        let ones_a = Mask::ones(2, 64);
        let ones_b = Mask::ones(1, 128);
        assert_ne!(ones_a.fingerprint(), ones_b.fingerprint());
    }

    #[test]
    fn ones_padding_bits_are_clear() {
        // active_count over a ones mask must equal rows*cols even when
        // cols is not a multiple of 64 (padding must stay zero)
        for cols in [1usize, 5, 64, 65, 127, 128] {
            let m = Mask::ones(3, cols);
            assert_eq!(m.active_count(), 3 * cols, "cols={cols}");
        }
    }
}
