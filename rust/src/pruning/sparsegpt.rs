//! SparseGPT (Frantar & Alistarh, 2023) — optimal-brain-surgeon pruning
//! with weight updates, paper eq. 2.
//!
//! Unlike the mask-only methods, SparseGPT *compensates* surviving weights
//! column-by-column with Gaussian elimination over the Cholesky factor of
//! the damped inverse Hessian, so it needs the full `X Xᵀ` calibration
//! statistic (returned by the `calib_stats` artifact) and cubic host work —
//! exactly why the paper rules it out for online/test-time use (§2) and we
//! only ship it as an offline baseline.

use super::kc_for;
use crate::tensor::{cholesky_lower, invert_spd, Mat};
use crate::util::error::Error;

/// Accumulates the empirical Hessian `H = Σ X Xᵀ` for one linear layer.
#[derive(Clone, Debug)]
pub struct HessianCalibrator {
    pub h: Mat,
    pub tokens_seen: usize,
}

impl HessianCalibrator {
    pub fn new(d_in: usize) -> Self {
        Self {
            h: Mat::zeros(d_in, d_in),
            tokens_seen: 0,
        }
    }

    /// Fold in one batch of activations (tokens, d_in).
    pub fn update(&mut self, x: &Mat) {
        self.h.add_assign(&x.gram());
        self.tokens_seen += x.rows;
    }

    /// Fold in a pre-reduced Hessian block from the calib artifact.
    pub fn update_from_gram(&mut self, gram: &Mat, tokens: usize) {
        self.h.add_assign(gram);
        self.tokens_seen += tokens;
    }
}

/// Configuration for the OBS sweep.
#[derive(Clone, Copy, Debug)]
pub struct SparseGptConfig {
    /// λ = damp_ratio · mean(diag H) added to the diagonal.
    pub damp_ratio: f64,
    /// Lazy-update block width (the reference uses 128).
    pub blocksize: usize,
}

impl Default for SparseGptConfig {
    fn default() -> Self {
        Self {
            damp_ratio: 0.01,
            blocksize: 64,
        }
    }
}

/// One-shot SparseGPT prune of `w` (d_out, d_in) at active ratio `rho`
/// given the accumulated Hessian. Returns the *updated* weights.
///
/// Mirrors python/compile/pruning.py::sparsegpt_prune (the cross-language
/// equivalence is pinned by tests/cross_validation.rs).
pub fn sparsegpt_prune(
    w: &Mat,
    calib: &HessianCalibrator,
    rho: f64,
    cfg: SparseGptConfig,
) -> Result<Mat, Error> {
    let (d_out, d_in) = (w.rows, w.cols);
    assert_eq!(calib.h.rows, d_in);
    let kc = kc_for(d_in, rho);
    let mut w = w.clone();
    let mut h = calib.h.clone();

    // dead features: no activation mass -> weight is free to prune
    for i in 0..d_in {
        if h.at(i, i) == 0.0 {
            *h.at_mut(i, i) = 1.0;
            for r in 0..d_out {
                *w.at_mut(r, i) = 0.0;
            }
        }
    }

    // damping: λ = ratio * mean diag
    let mean_diag: f64 =
        (0..d_in).map(|i| h.at(i, i) as f64).sum::<f64>() / d_in as f64;
    let damp = (cfg.damp_ratio * mean_diag) as f32;
    for i in 0..d_in {
        *h.at_mut(i, i) += damp;
    }

    // Hinv, then its *upper* Cholesky factor U with Hinv = U^T U (what
    // torch.linalg.cholesky(Hinv, upper=True) returns in the reference):
    // U is simply the transpose of the lower factor.
    let hinv = invert_spd(&h)?;
    let u = cholesky_lower(&hinv)?.t();

    let bs = cfg.blocksize.max(1);
    let mut i1 = 0;
    while i1 < d_in {
        let i2 = (i1 + bs).min(d_in);
        let count = i2 - i1;

        // per-block quota of zeros, proportional to block width
        let n_zero =
            ((kc as f64) * (count as f64) / (d_in as f64)).round() as usize;

        // score block: S = w² / diag(U)²  (paper eq. 2)
        let mut mask = vec![1u8; d_out * count];
        if n_zero > 0 {
            let mut idx: Vec<usize> = Vec::with_capacity(count);
            for r in 0..d_out {
                idx.clear();
                idx.extend(0..count);
                let scores: Vec<f32> = (0..count)
                    .map(|j| {
                        let du = u.at(i1 + j, i1 + j);
                        let wv = w.at(r, i1 + j);
                        (wv * wv) / (du * du)
                    })
                    .collect();
                idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap());
                for &j in idx.iter().take(n_zero.min(count)) {
                    mask[r * count + j] = 0;
                }
            }
        }

        // column-wise OBS elimination inside the block
        let mut err = Mat::zeros(d_out, count);
        for j in 0..count {
            let dj = u.at(i1 + j, i1 + j);
            for r in 0..d_out {
                let col = w.at(r, i1 + j);
                let q = if mask[r * count + j] == 1 { col } else { 0.0 };
                let e = (col - q) / dj;
                // propagate within the remainder of the block
                for j2 in j..count {
                    *w.at_mut(r, i1 + j2) -= e * u.at(i1 + j, i1 + j2);
                }
                *w.at_mut(r, i1 + j) = q;
                *err.at_mut(r, j) = e;
            }
        }

        // lazy update of all later columns: W[:, i2:] -= err @ U[i1:i2, i2:]
        for r in 0..d_out {
            for j in 0..count {
                let e = err.at(r, j);
                if e == 0.0 {
                    continue;
                }
                for j2 in i2..d_in {
                    *w.at_mut(r, j2) -= e * u.at(i1 + j, j2);
                }
            }
        }
        i1 = i2;
    }

    Ok(w)
}

/// Reconstruction loss `‖(W − Ŵ) X‖²` given raw activations — the metric
/// SparseGPT minimizes (used in tests to verify it beats mask-only Wanda).
pub fn reconstruction_loss(w: &Mat, w_hat: &Mat, x_t: &Mat) -> f64 {
    // x_t: (tokens, d_in); loss over ((W - What) @ X^T)
    let mut diff = w.clone();
    for (a, b) in diff.data.iter_mut().zip(&w_hat.data) {
        *a -= b;
    }
    let y = diff.matmul_nt(x_t); // (d_out, tokens)
    y.data.iter().map(|v| (*v as f64) * (*v as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::wanda::online_wanda_mask;
    use crate::util::rng::Pcg32;

    fn setup(seed: u64, d_out: usize, d_in: usize, t: usize) -> (Mat, Mat) {
        let mut rng = Pcg32::new(seed, 0);
        let w = Mat::from_vec(d_out, d_in, rng.normal_vec(d_out * d_in));
        let mut x = Mat::from_vec(t, d_in, rng.normal_vec(t * d_in));
        // diverse per-feature scales make activation-awareness matter.
        // Scales are assigned in *random* feature order: SparseGPT's
        // per-block zero quota (faithful to the reference) degrades when
        // feature importance is sorted along the column axis, which real
        // activations are not.
        let scales: Vec<f32> = (0..d_in).map(|_| 0.2 + 2.8 * rng.next_f32()).collect();
        for tt in 0..t {
            for j in 0..d_in {
                *x.at_mut(tt, j) *= scales[j];
            }
        }
        (w, x)
    }

    #[test]
    fn rho_one_round_trips() {
        let (w, x) = setup(1, 8, 32, 64);
        let mut c = HessianCalibrator::new(32);
        c.update(&x);
        let w2 = sparsegpt_prune(&w, &c, 1.0, SparseGptConfig::default()).unwrap();
        for (a, b) in w.data.iter().zip(&w2.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn sparsity_near_target() {
        let (w, x) = setup(2, 16, 64, 128);
        let mut c = HessianCalibrator::new(64);
        c.update(&x);
        for rho in [0.3, 0.5, 0.8] {
            let w2 =
                sparsegpt_prune(&w, &c, rho, SparseGptConfig::default()).unwrap();
            let active = 1.0 - w2.sparsity();
            assert!(
                (active - rho).abs() < 0.12,
                "rho {rho} -> active {active}"
            );
        }
    }

    #[test]
    fn beats_mask_only_wanda_on_reconstruction() {
        let (w, x) = setup(3, 24, 48, 256);
        let mut c = HessianCalibrator::new(48);
        c.update(&x);
        // single block = canonical OBS; per-block quotas trade a little
        // fidelity for the reference's lazy-update batching
        let cfg = SparseGptConfig {
            blocksize: 48,
            ..Default::default()
        };
        for rho in [0.4, 0.6] {
            let w_gpt = sparsegpt_prune(&w, &c, rho, cfg).unwrap();
            let w_wanda = online_wanda_mask(&w, &x, rho).apply(&w);
            let l_gpt = reconstruction_loss(&w, &w_gpt, &x);
            let l_wanda = reconstruction_loss(&w, &w_wanda, &x);
            assert!(
                l_gpt < l_wanda,
                "rho {rho}: sparsegpt {l_gpt:.3} !< wanda {l_wanda:.3}"
            );
        }
    }

    #[test]
    fn dead_features_are_pruned() {
        let (w, mut x) = setup(4, 6, 16, 32);
        for t in 0..32 {
            *x.at_mut(t, 3) = 0.0; // feature 3 never fires
        }
        let mut c = HessianCalibrator::new(16);
        c.update(&x);
        let w2 = sparsegpt_prune(&w, &c, 0.5, SparseGptConfig::default()).unwrap();
        for r in 0..6 {
            assert_eq!(w2.at(r, 3), 0.0);
        }
    }

    #[test]
    fn calibrator_accumulates() {
        let mut rng = Pcg32::new(5, 0);
        let x1 = Mat::from_vec(10, 8, rng.normal_vec(80));
        let x2 = Mat::from_vec(6, 8, rng.normal_vec(48));
        let mut inc = HessianCalibrator::new(8);
        inc.update(&x1);
        inc.update(&x2);
        let mut g = x1.gram();
        g.add_assign(&x2.gram());
        for (a, b) in inc.h.data.iter().zip(&g.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }
}
