//! Top-ρ selection strategies — the paper's Appendix B / Figure 3 study.
//!
//! The paper compares `torch.sort` (O(d log d)), `torch.topk`
//! (heap, O(d log k_c)) and `torch.kthvalue` (quickselect, O(d) average)
//! for finding the per-row threshold. We implement all three natively so
//! `benches/fig3_selection.rs` regenerates the runtime comparison on this
//! host, and the coordinator can pick a strategy per layer shape.

/// Which algorithm finds the k-th smallest score of a row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Selector {
    /// Full sort, then index — `torch.sort`.
    Sort,
    /// Binary max-heap of the k smallest — `torch.topk` on the complement.
    TopK,
    /// Quickselect (`select_nth_unstable`) — `torch.kthvalue`.
    KthValue,
}

impl Selector {
    pub const ALL: [Selector; 3] = [Selector::Sort, Selector::TopK, Selector::KthValue];

    pub fn name(self) -> &'static str {
        match self {
            Selector::Sort => "sort",
            Selector::TopK => "topk",
            Selector::KthValue => "kthvalue",
        }
    }

    /// The `k`-th smallest value of `row` (1-indexed semantics: `k >= 1`;
    /// `k = row.len()` is the maximum). `scratch` must be at least
    /// `row.len()` long and is clobbered — callers reuse it across rows to
    /// keep the hot loop allocation-free.
    pub fn kth_smallest(self, row: &[f32], k: usize, scratch: &mut [f32]) -> f32 {
        debug_assert!(k >= 1 && k <= row.len());
        let buf = &mut scratch[..row.len()];
        buf.copy_from_slice(row);
        match self {
            Selector::Sort => {
                buf.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
                buf[k - 1]
            }
            Selector::TopK => kth_via_heap(buf, k),
            Selector::KthValue => {
                let (_, v, _) =
                    buf.select_nth_unstable_by(k - 1, |a, b| a.partial_cmp(b).unwrap());
                *v
            }
        }
    }
}

/// Max-heap of size k over the k smallest elements; the root is the k-th
/// smallest. Mirrors the heap strategy behind `torch.topk`.
fn kth_via_heap(vals: &[f32], k: usize) -> f32 {
    // Build heap over the first k values.
    let mut heap: Vec<f32> = vals[..k].to_vec();
    for i in (0..k / 2).rev() {
        sift_down(&mut heap, i);
    }
    for &v in &vals[k..] {
        if v < heap[0] {
            heap[0] = v;
            sift_down(&mut heap, 0);
        }
    }
    heap[0]
}

fn sift_down(heap: &mut [f32], mut i: usize) {
    let n = heap.len();
    loop {
        let (l, r) = (2 * i + 1, 2 * i + 2);
        let mut largest = i;
        if l < n && heap[l] > heap[largest] {
            largest = l;
        }
        if r < n && heap[r] > heap[largest] {
            largest = r;
        }
        if largest == i {
            return;
        }
        heap.swap(i, largest);
        i = largest;
    }
}

/// One full Wanda pruning pass over a weight matrix with the given
/// selector: score, per-row threshold, zero-out. This is the exact
/// operation Figure 3 times (it excludes the downstream matmul).
pub fn wanda_prune_with(
    sel: Selector,
    w: &mut [f32],
    d_out: usize,
    d_in: usize,
    col_norms: &[f32],
    rho: f64,
    scratch: &mut Vec<f32>,
) {
    let kc = super::kc_for(d_in, rho);
    if kc == 0 {
        return;
    }
    scratch.resize(2 * d_in, 0.0);
    let (scores, tmp) = scratch.split_at_mut(d_in);
    for r in 0..d_out {
        let row = &mut w[r * d_in..(r + 1) * d_in];
        for j in 0..d_in {
            scores[j] = row[j].abs() * col_norms[j];
        }
        let thr = sel.kth_smallest(scores, kc, tmp);
        for j in 0..d_in {
            if scores[j] <= thr {
                row[j] = 0.0;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn selectors_agree_on_random_rows() {
        let mut rng = Pcg32::new(3, 1);
        let mut scratch = vec![0.0; 257];
        for _ in 0..50 {
            let n = 2 + rng.gen_range_usize(255);
            let row = rng.normal_vec(n);
            let k = 1 + rng.gen_range_usize(n);
            let a = Selector::Sort.kth_smallest(&row, k, &mut scratch);
            let b = Selector::TopK.kth_smallest(&row, k, &mut scratch);
            let c = Selector::KthValue.kth_smallest(&row, k, &mut scratch);
            assert_eq!(a, b);
            assert_eq!(a, c);
        }
    }

    #[test]
    fn kth_smallest_known() {
        let row = [5.0f32, 1.0, 4.0, 2.0, 3.0];
        let mut scratch = vec![0.0; 5];
        for sel in Selector::ALL {
            assert_eq!(sel.kth_smallest(&row, 1, &mut scratch), 1.0);
            assert_eq!(sel.kth_smallest(&row, 3, &mut scratch), 3.0);
            assert_eq!(sel.kth_smallest(&row, 5, &mut scratch), 5.0);
        }
    }

    #[test]
    fn wanda_prune_zeroes_kc_per_row() {
        let mut rng = Pcg32::new(4, 0);
        let (d_out, d_in) = (8, 64);
        let orig = rng.normal_vec(d_out * d_in);
        let norms: Vec<f32> = (0..d_in).map(|_| rng.next_f32() + 0.1).collect();
        for sel in Selector::ALL {
            let mut w = orig.clone();
            let mut scratch = Vec::new();
            wanda_prune_with(sel, &mut w, d_out, d_in, &norms, 0.6, &mut scratch);
            let kc = super::super::kc_for(d_in, 0.6);
            for r in 0..d_out {
                let zeros = w[r * d_in..(r + 1) * d_in]
                    .iter()
                    .filter(|x| **x == 0.0)
                    .count();
                assert_eq!(zeros, kc, "{}", sel.name());
            }
        }
    }

    #[test]
    fn selectors_give_identical_pruning() {
        let mut rng = Pcg32::new(5, 0);
        let (d_out, d_in) = (4, 32);
        let orig = rng.normal_vec(d_out * d_in);
        let norms: Vec<f32> = (0..d_in).map(|_| rng.next_f32() + 0.1).collect();
        let mut results = Vec::new();
        for sel in Selector::ALL {
            let mut w = orig.clone();
            let mut scratch = Vec::new();
            wanda_prune_with(sel, &mut w, d_out, d_in, &norms, 0.5, &mut scratch);
            results.push(w);
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[0], results[2]);
    }

    #[test]
    fn rho_one_is_noop() {
        let mut rng = Pcg32::new(6, 0);
        let orig = rng.normal_vec(32);
        let mut w = orig.clone();
        let norms = vec![1.0; 8];
        let mut scratch = Vec::new();
        wanda_prune_with(Selector::KthValue, &mut w, 4, 8, &norms, 1.0, &mut scratch);
        assert_eq!(w, orig);
    }
}
