//! Mask reuse policy for autoregressive decode.
//!
//! μ-MoE selects micro-experts per prompt; during decode the question is
//! *when to re-select* as the context grows. Re-selecting every step tracks
//! the context exactly but pays a full selection pass per token;
//! prune-once reuses the prompt's selection (and its compressed layouts)
//! for the whole generation. `MaskPlan` names the policy; the decode
//! engine ([`crate::decode`]) executes it and
//! [`crate::eval::host::decode_drift`] measures what the reuse costs in
//! logit divergence.

use crate::util::error::Error;

/// When the decode loop re-runs micro-expert selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MaskPlan {
    /// Re-select on every decode step (the adaptive baseline — maximal
    /// quality tracking, no reuse).
    EveryStep,
    /// Select once on the prompt and reuse the compressed layouts for the
    /// whole generation (maximal reuse).
    PruneOnce,
    /// Re-select every `k` steps (`k >= 1`). `Refresh(1)` is equivalent to
    /// [`MaskPlan::EveryStep`]; `Refresh(usize::MAX)` is equivalent to
    /// [`MaskPlan::PruneOnce`] for any practical generation length.
    Refresh(usize),
}

impl MaskPlan {
    /// Does step `step` (0-based; step 0 is the prompt) re-run selection?
    /// Every plan refreshes at step 0 — there is nothing to reuse yet.
    pub fn refreshes_at(&self, step: usize) -> bool {
        match *self {
            MaskPlan::EveryStep => true,
            MaskPlan::PruneOnce => step == 0,
            // k = 0 is not constructible via parse(); treat it as 1 rather
            // than dividing by zero if someone builds it by hand
            MaskPlan::Refresh(k) => step % k.max(1) == 0,
        }
    }

    /// Parse a CLI/config spelling: `every-step`, `prune-once` or
    /// `refresh:<k>` with `k >= 1`.
    pub fn parse(s: &str) -> Result<MaskPlan, Error> {
        match s {
            "every-step" => Ok(MaskPlan::EveryStep),
            "prune-once" => Ok(MaskPlan::PruneOnce),
            _ => {
                if let Some(k) = s.strip_prefix("refresh:") {
                    let k: usize = k.parse().map_err(|_| {
                        Error::config(format!("bad refresh interval in plan '{s}'"))
                    })?;
                    if k == 0 {
                        return Err(Error::config("refresh interval must be >= 1"));
                    }
                    return Ok(MaskPlan::Refresh(k));
                }
                Err(Error::config(format!(
                    "unknown mask plan '{s}' (expected every-step | prune-once | refresh:<k>)"
                )))
            }
        }
    }

    /// Stable display name (bench tables, JSON dumps).
    pub fn label(&self) -> String {
        match *self {
            MaskPlan::EveryStep => "every-step".to_string(),
            MaskPlan::PruneOnce => "prune-once".to_string(),
            MaskPlan::Refresh(k) => format!("refresh:{k}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refresh_schedule() {
        assert!(MaskPlan::EveryStep.refreshes_at(0));
        assert!(MaskPlan::EveryStep.refreshes_at(7));
        assert!(MaskPlan::PruneOnce.refreshes_at(0));
        assert!(!MaskPlan::PruneOnce.refreshes_at(1));
        let r3 = MaskPlan::Refresh(3);
        assert!(r3.refreshes_at(0));
        assert!(!r3.refreshes_at(1));
        assert!(!r3.refreshes_at(2));
        assert!(r3.refreshes_at(3));
        assert!(r3.refreshes_at(6));
    }

    #[test]
    fn refresh_one_is_every_step_and_max_is_prune_once() {
        for step in 0..50 {
            assert_eq!(
                MaskPlan::Refresh(1).refreshes_at(step),
                MaskPlan::EveryStep.refreshes_at(step)
            );
            assert_eq!(
                MaskPlan::Refresh(usize::MAX).refreshes_at(step),
                MaskPlan::PruneOnce.refreshes_at(step)
            );
        }
    }

    #[test]
    fn parse_roundtrip() {
        for plan in [MaskPlan::EveryStep, MaskPlan::PruneOnce, MaskPlan::Refresh(4)] {
            assert_eq!(MaskPlan::parse(&plan.label()).unwrap(), plan);
        }
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(MaskPlan::parse("refresh:0").is_err());
        assert!(MaskPlan::parse("refresh:x").is_err());
        assert!(MaskPlan::parse("sometimes").is_err());
    }

    #[test]
    fn hand_built_refresh_zero_does_not_panic() {
        assert!(MaskPlan::Refresh(0).refreshes_at(0));
        assert!(MaskPlan::Refresh(0).refreshes_at(5));
    }
}
