//! Wanda pruning (Sun et al., 2023) — paper eq. 3: `S = |W| · ‖X_j‖₂`.
//!
//! Two deployment modes, matching the paper's Figure 2:
//! * **offline** — [`WandaCalibrator`] accumulates per-feature activation
//!   square-sums over a calibration set (via the `calib_stats` artifact);
//!   the resulting mask is frozen and applied to the weights once.
//! * **online (μ-MoE)** — the same scoring runs per prompt *inside* the
//!   AOT artifact; [`online_wanda_mask`] is the host-side oracle used in
//!   tests and in `moe::overlap` analysis.

use super::{mask_from_scores, selection::Selector, Mask};
use crate::tensor::Mat;

/// Accumulates activation statistics for one linear layer across
/// calibration batches: `sq_sums[j] = Σ_t X[t,j]²`.
#[derive(Clone, Debug)]
pub struct WandaCalibrator {
    pub sq_sums: Vec<f64>,
    pub tokens_seen: usize,
}

impl WandaCalibrator {
    pub fn new(d_in: usize) -> Self {
        Self {
            sq_sums: vec![0.0; d_in],
            tokens_seen: 0,
        }
    }

    /// Fold in one batch of activations (tokens, d_in).
    pub fn update(&mut self, x: &Mat) {
        assert_eq!(x.cols, self.sq_sums.len());
        for t in 0..x.rows {
            for (j, &v) in x.row(t).iter().enumerate() {
                self.sq_sums[j] += (v as f64) * (v as f64);
            }
        }
        self.tokens_seen += x.rows;
    }

    /// Fold in pre-reduced square-sums (what the `calib_stats` artifact
    /// returns — the activations themselves never leave the device).
    pub fn update_from_sq_sums(&mut self, sq: &[f32], tokens: usize) {
        assert_eq!(sq.len(), self.sq_sums.len());
        for (a, &b) in self.sq_sums.iter_mut().zip(sq) {
            *a += b as f64;
        }
        self.tokens_seen += tokens;
    }

    /// `‖X_j‖₂` per input feature.
    pub fn col_norms(&self) -> Vec<f32> {
        self.sq_sums.iter().map(|s| s.sqrt() as f32).collect()
    }
}

/// Wanda scores for a weight matrix given per-feature activation norms.
pub fn wanda_scores(w: &Mat, col_norms: &[f32]) -> Mat {
    assert_eq!(col_norms.len(), w.cols);
    Mat::from_fn(w.rows, w.cols, |i, j| {
        w.at(i, j).abs() * col_norms[j]
    })
}

/// Offline Wanda mask from accumulated calibration statistics.
pub fn wanda_mask(w: &Mat, calib: &WandaCalibrator, rho: f64) -> Mask {
    mask_from_scores(&wanda_scores(w, &calib.col_norms()), rho, Selector::KthValue)
}

/// Online (test-time / μ-MoE) Wanda mask straight from prompt activations.
pub fn online_wanda_mask(w: &Mat, x: &Mat, rho: f64) -> Mask {
    let mut calib = WandaCalibrator::new(w.cols);
    calib.update(x);
    wanda_mask(w, &calib, rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::kc_for;
    use crate::util::rng::Pcg32;

    #[test]
    fn hot_feature_beats_large_weight() {
        // small weight on a hot feature survives; big weight on a cold one dies
        let w = Mat::from_vec(1, 2, vec![0.5, 1.0]);
        let x = Mat::from_vec(4, 2, vec![10.0, 0.01, 10.0, 0.01, 10.0, 0.0, 10.0, 0.0]);
        let m = online_wanda_mask(&w, &x, 0.5);
        assert_eq!(m.dense_bits(), vec![1, 0]);
    }

    #[test]
    fn uniform_activations_reduce_to_magnitude() {
        let mut rng = Pcg32::new(1, 0);
        let w = Mat::from_vec(6, 24, rng.normal_vec(6 * 24));
        let ones = Mat::from_vec(1, 24, vec![1.0; 24]);
        let m_wanda = online_wanda_mask(&w, &ones, 0.5);
        let m_mag = super::super::magnitude::magnitude_mask(&w, 0.5);
        assert_eq!(m_wanda, m_mag);
    }

    #[test]
    fn calibrator_accumulates_across_batches() {
        let mut rng = Pcg32::new(2, 0);
        let x1 = Mat::from_vec(5, 8, rng.normal_vec(40));
        let x2 = Mat::from_vec(3, 8, rng.normal_vec(24));
        let mut c_inc = WandaCalibrator::new(8);
        c_inc.update(&x1);
        c_inc.update(&x2);
        let mut all = x1.data.clone();
        all.extend_from_slice(&x2.data);
        let mut c_once = WandaCalibrator::new(8);
        c_once.update(&Mat::from_vec(8, 8, all));
        for (a, b) in c_inc.col_norms().iter().zip(c_once.col_norms()) {
            assert!((a - b).abs() < 1e-4);
        }
        assert_eq!(c_inc.tokens_seen, 8);
    }

    #[test]
    fn update_from_sq_sums_matches_update() {
        let mut rng = Pcg32::new(3, 0);
        let x = Mat::from_vec(10, 6, rng.normal_vec(60));
        let mut a = WandaCalibrator::new(6);
        a.update(&x);
        let mut b = WandaCalibrator::new(6);
        b.update_from_sq_sums(&x.col_sq_sums(), 10);
        for (p, q) in a.col_norms().iter().zip(b.col_norms()) {
            assert!((p - q).abs() < 1e-4);
        }
    }

    #[test]
    fn mask_respects_rho() {
        let mut rng = Pcg32::new(4, 0);
        let w = Mat::from_vec(12, 64, rng.normal_vec(12 * 64));
        let x = Mat::from_vec(32, 64, rng.normal_vec(32 * 64));
        for rho in [0.25, 0.5, 0.75] {
            let m = online_wanda_mask(&w, &x, rho);
            let keep = 64 - kc_for(64, rho);
            assert!(m.row_active_counts().iter().all(|&c| c == keep));
        }
    }

    #[test]
    fn different_prompts_different_masks() {
        // mu-MoE's premise: micro-expert selection is prompt-dependent
        let mut rng = Pcg32::new(5, 0);
        let w = Mat::from_vec(16, 32, rng.normal_vec(512));
        let x1 = Mat::from_vec(20, 32, rng.normal_vec(640));
        let mut x2 = Mat::from_vec(20, 32, rng.normal_vec(640));
        for t in 0..20 {
            for j in 0..16 {
                *x2.at_mut(t, j) *= 8.0;
            }
        }
        let m1 = online_wanda_mask(&w, &x1, 0.5);
        let m2 = online_wanda_mask(&w, &x2, 0.5);
        assert!(m1.jaccard(&m2) < 0.999);
    }
}
