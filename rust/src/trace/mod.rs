//! Per-request tracing: a span recorder, a bounded flight recorder and a
//! Chrome trace-event (catapult JSON, Perfetto-loadable) serializer —
//! dependency-free, consistent with the pure-std policy.
//!
//! μ-MoE picks structured sparsity *per prompt*, so where a request's
//! wall-clock goes (admission, queue wait, seed-vs-prefill, fused vs
//! per-lane sweeps, refresh rebuilds, stream writes) varies request by
//! request and cannot be read off the aggregate `Metrics` counters. The
//! [`FlightRecorder`] holds the last N *completed* request timelines in a
//! ring buffer; every lifecycle phase lands as a [`Span`] with monotonic
//! start/end microseconds on the recorder's single epoch clock, so spans
//! from different threads order correctly in one trace.
//!
//! **Hot-path contract.** When the recorder is disabled every mutating
//! method returns after a single relaxed atomic load — no allocation, no
//! lock, no `Instant::now()`. The serve loop additionally guards its own
//! span *assembly* behind [`FlightRecorder::enabled`], so a disabled
//! recorder costs exactly one branch per call site
//! (`benches/trace_overhead.rs` gates this).
//!
//! Kernel attribution (time in sparse linears vs attention vs the
//! stack/scatter glue) is sampled: every `kernel_sample_every`-th sweep
//! threads a [`StepProfile`] through the forward, and the sample lands in
//! a separate bounded ring ([`KernelSample`]) rather than on a request —
//! a sweep's compute is shared by its fused group, not owned by one
//! request.

use crate::util::json::Json;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Root phase name: one per request, brackets every child span.
pub const ROOT_PHASE: &str = "request";

/// A span attribute value: small numeric or static-label payloads only,
/// so recording never formats or allocates strings on the hot path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AttrValue {
    Num(u64),
    Label(&'static str),
}

impl AttrValue {
    fn to_json(self) -> Json {
        match self {
            AttrValue::Num(n) => Json::Num(n as f64),
            AttrValue::Label(s) => Json::Str(s.into()),
        }
    }
}

/// One completed lifecycle phase of a request.
#[derive(Clone, Debug)]
pub struct Span {
    pub phase: &'static str,
    /// Lane-pool slot the phase ran on (`None` for pre-lane phases:
    /// admission, queue wait, drain-mode execution).
    pub lane: Option<usize>,
    /// Monotonic microseconds on the recorder's epoch clock.
    pub start_us: u64,
    pub end_us: u64,
    pub attrs: Vec<(&'static str, AttrValue)>,
}

/// The full recorded timeline of one request.
#[derive(Clone, Debug)]
pub struct RequestTrace {
    pub id: u64,
    /// When the request entered the recorder (admission).
    pub begin_us: u64,
    /// When it finished (0 while still active).
    pub end_us: u64,
    /// Terminal outcome ("done" | "cancelled" | "rejected"; "" while
    /// active).
    pub outcome: &'static str,
    pub spans: Vec<Span>,
}

impl RequestTrace {
    /// Total recorded wall-clock (0 while active).
    pub fn total_us(&self) -> u64 {
        self.end_us.saturating_sub(self.begin_us)
    }

    /// Sum of child span durations — the accounted-for share of
    /// `total_us` (phases may legitimately leave gaps: batching windows,
    /// sweeps serving other lanes).
    pub fn span_sum_us(&self) -> u64 {
        self.spans
            .iter()
            .map(|s| s.end_us.saturating_sub(s.start_us))
            .sum()
    }

    /// JSON timeline for `GET /requests/:id`.
    pub fn to_json(&self) -> Json {
        let spans = self
            .spans
            .iter()
            .map(|s| {
                let mut m = HashMap::from([
                    ("phase".into(), Json::Str(s.phase.into())),
                    ("start_us".into(), Json::Num(s.start_us as f64)),
                    ("end_us".into(), Json::Num(s.end_us as f64)),
                    (
                        "dur_us".into(),
                        Json::Num(s.end_us.saturating_sub(s.start_us) as f64),
                    ),
                ]);
                if let Some(lane) = s.lane {
                    m.insert("lane".into(), Json::Num(lane as f64));
                }
                if !s.attrs.is_empty() {
                    m.insert(
                        "attrs".into(),
                        Json::Obj(
                            s.attrs
                                .iter()
                                .map(|(k, v)| ((*k).into(), v.to_json()))
                                .collect(),
                        ),
                    );
                }
                Json::Obj(m)
            })
            .collect();
        Json::Obj(HashMap::from([
            ("id".into(), Json::Num(self.id as f64)),
            ("begin_us".into(), Json::Num(self.begin_us as f64)),
            ("end_us".into(), Json::Num(self.end_us as f64)),
            ("total_us".into(), Json::Num(self.total_us() as f64)),
            ("span_sum_us".into(), Json::Num(self.span_sum_us() as f64)),
            ("outcome".into(), Json::Str(self.outcome.into())),
            ("spans".into(), Json::Arr(spans)),
        ]))
    }
}

/// Sampled per-sweep kernel-time attribution, accumulated inside the
/// forward pass (`nn::Model::forward_step*` profiled variants).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StepProfile {
    /// Time in sparse/dense linear kernels (q,k,v,o,fc1,fc2 + LM head).
    pub linear_us: u64,
    /// Time writing K/V rows and attending against the cache.
    pub attention_us: u64,
    /// Everything else: embed, layernorms, residuals, stack/scatter
    /// transposes on the fused path.
    pub other_us: u64,
}

impl StepProfile {
    pub fn total_us(&self) -> u64 {
        self.linear_us + self.attention_us + self.other_us
    }
}

/// One sampled sweep's kernel split.
#[derive(Clone, Copy, Debug)]
pub struct KernelSample {
    /// Sweep end time on the recorder's epoch clock.
    pub at_us: u64,
    /// Active lanes the sampled sweep stepped.
    pub lanes: usize,
    pub profile: StepProfile,
}

/// What kind of work a lane's step did this sweep — the per-sweep
/// classification `decode::LanePool::sweep` exposes so the serve loop can
/// span each lane's phase without re-deriving decode internals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepKind {
    /// Cold full-window KV prefill (first step of a lane).
    Prefill,
    /// Prefill with a prefix seeded from the KV store or a session.
    SeededPrefill,
    /// Selection refresh: new layouts + full cache rebuild.
    Refresh,
    /// Window slide: position re-base forced a full cache rebuild.
    Slide,
    /// Reused incremental step on the per-lane path.
    Step,
    /// Reused incremental step executed inside a fused group.
    Fused,
}

impl StepKind {
    pub fn phase(self) -> &'static str {
        match self {
            StepKind::Prefill => "prefill",
            StepKind::SeededPrefill => "seeded_prefill",
            StepKind::Refresh => "refresh",
            StepKind::Slide => "slide",
            StepKind::Step => "step",
            StepKind::Fused => "fused_step",
        }
    }
}

/// One lane's step record for a single sweep (reported by
/// `LanePool::last_sweep_lane_steps`).
#[derive(Clone, Copy, Debug)]
pub struct SweepLaneStep {
    pub slot: usize,
    pub kind: StepKind,
    pub elapsed_us: u64,
    /// Lanes in the execution group (1 on the per-lane path).
    pub width: usize,
    /// Window tokens seeded from the store/session by this step
    /// (prefill-class steps only).
    pub seeded: usize,
    /// Window tokens prefilled by full forward work in this step
    /// (prefill-class steps only).
    pub prefilled: usize,
}

struct Inner {
    active: HashMap<u64, RequestTrace>,
    done: VecDeque<RequestTrace>,
    kernel: VecDeque<KernelSample>,
}

/// Bounded ring-buffer recorder of per-request span timelines.
///
/// All methods take `&self`; the single mutex guards cold-path maps only
/// and is never touched when disabled.
pub struct FlightRecorder {
    enabled: AtomicBool,
    capacity: usize,
    kernel_sample_every: u64,
    epoch: Instant,
    inner: Mutex<Inner>,
}

impl FlightRecorder {
    pub fn new(enabled: bool, capacity: usize, kernel_sample_every: u64) -> FlightRecorder {
        FlightRecorder {
            enabled: AtomicBool::new(enabled),
            capacity: capacity.max(1),
            kernel_sample_every,
            epoch: Instant::now(),
            inner: Mutex::new(Inner {
                active: HashMap::new(),
                done: VecDeque::new(),
                kernel: VecDeque::new(),
            }),
        }
    }

    /// A recorder that records nothing (every call is one branch).
    pub fn disabled() -> FlightRecorder {
        FlightRecorder::new(false, 1, 0)
    }

    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Flip recording at runtime (bench/test hook; config decides the
    /// serving default).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Sampling period for kernel attribution (0 = never; forced to 0
    /// while disabled so callers need no second check).
    pub fn kernel_sample_every(&self) -> u64 {
        if self.enabled() {
            self.kernel_sample_every
        } else {
            0
        }
    }

    /// Microseconds since the recorder's epoch — the clock every span
    /// uses.
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Open a request timeline (admission).
    pub fn begin(&self, id: u64) {
        if !self.enabled() {
            return;
        }
        self.begin_at(id, self.now_us());
    }

    /// Open a request timeline backdated to `begin_us` — the router
    /// stamps the instant admission *started*, so the admit span itself
    /// nests within the root.
    pub fn begin_at(&self, id: u64, begin_us: u64) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().expect("trace recorder poisoned");
        inner.active.insert(
            id,
            RequestTrace {
                id,
                begin_us,
                end_us: 0,
                outcome: "",
                spans: Vec::new(),
            },
        );
    }

    /// Record one completed phase of an active request. Unknown ids are
    /// ignored (request began while the recorder was off, or was already
    /// evicted). The start is clamped to the root's begin: reconstructed
    /// spans (`now - elapsed`, with the elapsed measured from a stamp
    /// taken just before `begin`) can round a microsecond past the
    /// window, and nesting must hold by construction.
    pub fn span(
        &self,
        id: u64,
        phase: &'static str,
        lane: Option<usize>,
        start_us: u64,
        end_us: u64,
        attrs: &[(&'static str, AttrValue)],
    ) {
        if !self.enabled() {
            return;
        }
        let mut inner = self.inner.lock().expect("trace recorder poisoned");
        if let Some(t) = inner.active.get_mut(&id) {
            let start_us = start_us.max(t.begin_us);
            t.spans.push(Span {
                phase,
                lane,
                start_us,
                end_us: end_us.max(start_us),
                attrs: attrs.to_vec(),
            });
        }
    }

    /// Close a request timeline and move it into the completed ring,
    /// evicting the oldest entry beyond `capacity`.
    pub fn finish(&self, id: u64, outcome: &'static str) {
        if !self.enabled() {
            return;
        }
        let now = self.now_us();
        let mut inner = self.inner.lock().expect("trace recorder poisoned");
        if let Some(mut t) = inner.active.remove(&id) {
            t.end_us = now.max(t.begin_us);
            t.outcome = outcome;
            inner.done.push_back(t);
            while inner.done.len() > self.capacity {
                inner.done.pop_front();
            }
        }
    }

    /// Record one sampled sweep's kernel split (same ring bound as the
    /// request timelines).
    pub fn record_kernel_sample(&self, lanes: usize, profile: StepProfile) {
        if !self.enabled() {
            return;
        }
        let at_us = self.now_us();
        let mut inner = self.inner.lock().expect("trace recorder poisoned");
        inner.kernel.push_back(KernelSample {
            at_us,
            lanes,
            profile,
        });
        while inner.kernel.len() > self.capacity {
            inner.kernel.pop_front();
        }
    }

    /// Span every lane's step of one just-finished sweep. `id_of` maps a
    /// pool slot to the live request occupying it; lanes whose request is
    /// unknown (already delivered) are skipped. Each step's span ends
    /// "now" and starts `elapsed_us` earlier — sweep steps are recorded
    /// immediately after they run, so the reconstruction error is the
    /// sweep's own bookkeeping, not queuing.
    pub fn record_sweep<F: Fn(usize) -> Option<u64>>(
        &self,
        id_of: F,
        steps: &[SweepLaneStep],
        sample: Option<(usize, StepProfile)>,
    ) {
        if !self.enabled() {
            return;
        }
        let now = self.now_us();
        for st in steps {
            let Some(id) = id_of(st.slot) else {
                continue;
            };
            let mut attrs = vec![("width", AttrValue::Num(st.width as u64))];
            if st.seeded > 0 {
                attrs.push(("seeded", AttrValue::Num(st.seeded as u64)));
            }
            if st.prefilled > 0 {
                attrs.push(("prefilled", AttrValue::Num(st.prefilled as u64)));
            }
            self.span(
                id,
                st.kind.phase(),
                Some(st.slot),
                now.saturating_sub(st.elapsed_us),
                now,
                &attrs,
            );
        }
        if let Some((lanes, profile)) = sample {
            self.record_kernel_sample(lanes, profile);
        }
    }

    /// A completed request's timeline by id (falls back to the active
    /// map so an in-flight request is inspectable too).
    pub fn timeline(&self, id: u64) -> Option<RequestTrace> {
        let inner = self.inner.lock().expect("trace recorder poisoned");
        inner
            .done
            .iter()
            .rev()
            .find(|t| t.id == id)
            .or_else(|| inner.active.get(&id))
            .cloned()
    }

    /// The last `n` completed timelines, oldest first.
    pub fn last(&self, n: usize) -> Vec<RequestTrace> {
        let inner = self.inner.lock().expect("trace recorder poisoned");
        let skip = inner.done.len().saturating_sub(n);
        inner.done.iter().skip(skip).cloned().collect()
    }

    pub fn kernel_samples(&self) -> Vec<KernelSample> {
        let inner = self.inner.lock().expect("trace recorder poisoned");
        inner.kernel.iter().copied().collect()
    }

    /// Completed timelines currently resident.
    pub fn completed(&self) -> usize {
        self.inner.lock().expect("trace recorder poisoned").done.len()
    }

    /// True when nothing was ever recorded (the disabled-mode guarantee
    /// `benches/trace_overhead.rs` asserts).
    pub fn is_empty(&self) -> bool {
        let inner = self.inner.lock().expect("trace recorder poisoned");
        inner.active.is_empty() && inner.done.is_empty() && inner.kernel.is_empty()
    }
}

/// Serialize timelines + kernel samples as Chrome trace-event JSON
/// (catapult "X" complete events; load in Perfetto / `chrome://tracing`).
/// One track (`tid`) per request under `pid` 1; kernel samples render on
/// `pid` 0 with their split in `args`.
pub fn chrome_trace(traces: &[RequestTrace], kernel: &[KernelSample]) -> Json {
    fn event(
        name: &str,
        pid: u64,
        tid: u64,
        start_us: u64,
        dur_us: u64,
        args: HashMap<String, Json>,
    ) -> Json {
        let mut m = HashMap::from([
            ("name".into(), Json::Str(name.into())),
            ("cat".into(), Json::Str("serve".into())),
            ("ph".into(), Json::Str("X".into())),
            ("pid".into(), Json::Num(pid as f64)),
            ("tid".into(), Json::Num(tid as f64)),
            ("ts".into(), Json::Num(start_us as f64)),
            ("dur".into(), Json::Num(dur_us as f64)),
        ]);
        if !args.is_empty() {
            m.insert("args".into(), Json::Obj(args));
        }
        Json::Obj(m)
    }

    let mut events = Vec::new();
    for t in traces {
        events.push(event(
            ROOT_PHASE,
            1,
            t.id,
            t.begin_us,
            t.total_us(),
            HashMap::from([("outcome".into(), Json::Str(t.outcome.into()))]),
        ));
        for s in &t.spans {
            let mut args: HashMap<String, Json> = s
                .attrs
                .iter()
                .map(|(k, v)| ((*k).into(), v.to_json()))
                .collect();
            if let Some(lane) = s.lane {
                args.insert("lane".into(), Json::Num(lane as f64));
            }
            events.push(event(
                s.phase,
                1,
                t.id,
                s.start_us,
                s.end_us.saturating_sub(s.start_us),
                args,
            ));
        }
    }
    for k in kernel {
        let total = k.profile.total_us();
        events.push(event(
            "kernel_sample",
            0,
            0,
            k.at_us.saturating_sub(total),
            total,
            HashMap::from([
                ("linear_us".into(), Json::Num(k.profile.linear_us as f64)),
                (
                    "attention_us".into(),
                    Json::Num(k.profile.attention_us as f64),
                ),
                ("other_us".into(), Json::Num(k.profile.other_us as f64)),
                ("lanes".into(), Json::Num(k.lanes as f64)),
            ]),
        ));
    }
    Json::Obj(HashMap::from([
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record_one(r: &FlightRecorder, id: u64) {
        r.begin(id);
        let t0 = r.now_us();
        r.span(id, "admit", None, t0, t0 + 5, &[]);
        r.span(
            id,
            "prefill",
            Some(0),
            t0 + 5,
            t0 + 40,
            &[("prefilled", AttrValue::Num(9))],
        );
        r.span(id, "step", Some(0), t0 + 40, t0 + 50, &[("width", AttrValue::Num(1))]);
        r.finish(id, "done");
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = FlightRecorder::disabled();
        assert!(!r.enabled());
        assert_eq!(r.kernel_sample_every(), 0);
        record_one(&r, 1);
        r.record_kernel_sample(2, StepProfile::default());
        r.record_sweep(
            |_| Some(1),
            &[SweepLaneStep {
                slot: 0,
                kind: StepKind::Step,
                elapsed_us: 3,
                width: 1,
                seeded: 0,
                prefilled: 0,
            }],
            None,
        );
        assert!(r.is_empty());
        assert!(r.timeline(1).is_none());
        assert!(r.last(8).is_empty());
    }

    #[test]
    fn ring_buffer_bounded_and_ordered() {
        let r = FlightRecorder::new(true, 3, 0);
        for id in 1..=5 {
            record_one(&r, id);
        }
        assert_eq!(r.completed(), 3, "capacity bounds the ring");
        let last = r.last(3);
        let ids: Vec<u64> = last.iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![3, 4, 5], "oldest evicted, oldest-first order");
        assert!(r.timeline(1).is_none(), "evicted timeline gone");
        let t = r.timeline(4).expect("resident timeline");
        assert_eq!(t.outcome, "done");
        assert_eq!(t.spans.len(), 3);
        assert!(t.end_us >= t.begin_us);
        // last(n) with n < resident returns the newest n
        let ids: Vec<u64> = r.last(2).iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![4, 5]);
    }

    #[test]
    fn spans_nest_within_the_request_bounds() {
        let r = FlightRecorder::new(true, 8, 0);
        record_one(&r, 7);
        let t = r.timeline(7).unwrap();
        for s in &t.spans {
            assert!(s.start_us >= t.begin_us, "{} starts before begin", s.phase);
            assert!(s.end_us <= t.end_us, "{} ends after finish", s.phase);
            assert!(s.end_us >= s.start_us);
        }
        assert!(t.span_sum_us() <= t.total_us() + 50);
    }

    #[test]
    fn active_timeline_visible_before_finish() {
        let r = FlightRecorder::new(true, 8, 0);
        r.begin(9);
        r.span(9, "queue_wait", None, 0, 10, &[]);
        let t = r.timeline(9).expect("active request inspectable");
        assert_eq!(t.outcome, "");
        assert_eq!(t.end_us, 0);
        assert_eq!(t.spans.len(), 1);
        assert_eq!(r.completed(), 0);
        r.finish(9, "cancelled");
        assert_eq!(r.timeline(9).unwrap().outcome, "cancelled");
    }

    #[test]
    fn unknown_ids_and_double_finish_are_noops() {
        let r = FlightRecorder::new(true, 4, 0);
        r.span(42, "step", None, 0, 1, &[]);
        r.finish(42, "done");
        assert!(r.is_empty());
        record_one(&r, 1);
        r.finish(1, "done"); // second finish: already moved to the ring
        assert_eq!(r.completed(), 1);
    }

    #[test]
    fn record_sweep_spans_live_lanes_and_samples_kernels() {
        let r = FlightRecorder::new(true, 4, 2);
        assert_eq!(r.kernel_sample_every(), 2);
        r.begin(11);
        let steps = [
            SweepLaneStep {
                slot: 0,
                kind: StepKind::Fused,
                elapsed_us: 12,
                width: 3,
                seeded: 0,
                prefilled: 0,
            },
            SweepLaneStep {
                slot: 1,
                kind: StepKind::SeededPrefill,
                elapsed_us: 80,
                width: 1,
                seeded: 6,
                prefilled: 2,
            },
        ];
        // slot 1 has no live request mapping: skipped, not misattributed
        r.record_sweep(
            |slot| (slot == 0).then_some(11),
            &steps,
            Some((
                2,
                StepProfile {
                    linear_us: 30,
                    attention_us: 10,
                    other_us: 5,
                },
            )),
        );
        r.finish(11, "done");
        let t = r.timeline(11).unwrap();
        assert_eq!(t.spans.len(), 1);
        assert_eq!(t.spans[0].phase, "fused_step");
        assert_eq!(t.spans[0].lane, Some(0));
        assert_eq!(t.spans[0].attrs, vec![("width", AttrValue::Num(3))]);
        let samples = r.kernel_samples();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].lanes, 2);
        assert_eq!(samples[0].profile.total_us(), 45);
    }

    #[test]
    fn chrome_trace_shape_and_nesting() {
        let r = FlightRecorder::new(true, 8, 1);
        record_one(&r, 5);
        r.record_kernel_sample(
            1,
            StepProfile {
                linear_us: 20,
                attention_us: 5,
                other_us: 1,
            },
        );
        let j = chrome_trace(&r.last(8), &r.kernel_samples());
        // the dump must round-trip through the parser (valid JSON)
        let parsed = Json::parse(&j.dump()).expect("valid trace JSON");
        let events = parsed.req("traceEvents").unwrap().as_arr().unwrap();
        // root + 3 child spans + 1 kernel sample
        assert_eq!(events.len(), 5);
        let root = events
            .iter()
            .find(|e| e.req("name").unwrap().as_str() == Some(ROOT_PHASE))
            .expect("root span present");
        let root_ts = root.req("ts").unwrap().as_f64().unwrap();
        let root_end = root_ts + root.req("dur").unwrap().as_f64().unwrap();
        for e in events {
            assert_eq!(e.req("ph").unwrap().as_str(), Some("X"));
            let pid = e.req("pid").unwrap().as_f64().unwrap();
            if pid != 1.0 {
                continue; // kernel track
            }
            let ts = e.req("ts").unwrap().as_f64().unwrap();
            let end = ts + e.req("dur").unwrap().as_f64().unwrap();
            assert!(ts >= root_ts && end <= root_end, "child within root bounds");
            assert_eq!(e.req("tid").unwrap().as_f64(), Some(5.0));
        }
        let kernel = events
            .iter()
            .find(|e| e.req("name").unwrap().as_str() == Some("kernel_sample"))
            .expect("kernel sample event");
        let args = kernel.req("args").unwrap();
        assert_eq!(args.req("linear_us").unwrap().as_f64(), Some(20.0));
        assert_eq!(args.req("lanes").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn timeline_json_carries_spans_and_sums() {
        let r = FlightRecorder::new(true, 4, 0);
        record_one(&r, 3);
        let j = r.timeline(3).unwrap().to_json();
        let parsed = Json::parse(&j.dump()).expect("valid timeline JSON");
        assert_eq!(parsed.req("id").unwrap().as_f64(), Some(3.0));
        assert_eq!(parsed.req("outcome").unwrap().as_str(), Some("done"));
        let spans = parsed.req("spans").unwrap().as_arr().unwrap();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[1].req("phase").unwrap().as_str(), Some("prefill"));
        assert_eq!(spans[1].req("lane").unwrap().as_f64(), Some(0.0));
        assert_eq!(
            spans[1].req("attrs").unwrap().req("prefilled").unwrap().as_f64(),
            Some(9.0)
        );
        let span_sum = parsed.req("span_sum_us").unwrap().as_f64().unwrap();
        assert_eq!(span_sum, 50.0, "5 + 35 + 10");
    }
}
