//! Analytical FLOPs/MACs counter — regenerates the paper's Table 4
//! (complexity of OPT-scale models under μ-MoE at varying active ratios).
//!
//! The paper used the `calflops` library and *included* the pruning
//! overhead — ℓ₂-norm, top-ρ search and comparators — in the counts. We
//! count the same operation classes analytically from the architecture:
//!
//! * linear layers: `2·d_out·d_in·T` FLOPs (MACs = half), scaled by ρ for
//!   the active-weight fraction (the μ-MoE saving);
//! * attention score/value matmuls: `2·T²·d` per layer (not prunable);
//! * Wanda overhead per linear: norms `2·d_in·T`, scoring `d_out·d_in`
//!   (product; counted as MAC-free multiplies), selection ~`d_out·d_in`
//!   comparisons, masking comparators `d_out·d_in`;
//! * layernorm / softmax / embeddings: elementwise terms.
//!
//! Absolute numbers differ from calflops by bookkeeping conventions, but
//! the Table-4 *shape* — FLOPs ≈ affine in ρ, MACs ≈ proportional to ρ —
//! is what the reproduction checks.

use crate::model::ModelConfig;
use crate::pruning::Mask;
use std::collections::HashMap;

/// FLOPs/MACs tally for one forward pass.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCount {
    pub flops: f64,
    pub macs: f64,
}

impl OpCount {
    fn add_matmul(&mut self, m: f64, k: f64, n: f64, active: f64) {
        // matmul (m,k)x(k,n): k MACs per output, 2k FLOPs
        self.macs += m * k * n * active;
        self.flops += 2.0 * m * k * n * active;
    }

    fn add_elementwise(&mut self, n: f64, flops_per: f64) {
        self.flops += n * flops_per;
    }

    pub fn tflops(&self) -> f64 {
        self.flops / 1e12
    }

    pub fn gmacs(&self) -> f64 {
        self.macs / 1e9
    }
}

/// Architecture shape for counting (decoupled from ModelConfig so paper
/// scale OPT shapes can be evaluated without instantiating weights).
#[derive(Clone, Copy, Debug)]
pub struct ArchShape {
    pub n_layers: usize,
    pub d_model: usize,
    pub vocab: usize,
}

impl ArchShape {
    pub fn of(cfg: &ModelConfig) -> ArchShape {
        ArchShape {
            n_layers: cfg.n_layers,
            d_model: cfg.d_model,
            vocab: cfg.vocab_size,
        }
    }

    /// Paper-scale OPT entry by (layers, d_model); vocab 50272 (OPT BPE).
    pub fn opt(layers: usize, d_model: usize) -> ArchShape {
        ArchShape {
            n_layers: layers,
            d_model,
            vocab: 50_272,
        }
    }
}

/// Count one forward pass of `t` tokens at active ratio `rho`, including
/// the instant-Wanda pruning overhead when `online_prune` is set.
pub fn count_forward(shape: ArchShape, t: usize, rho: f64, online_prune: bool) -> OpCount {
    let (d, di) = (shape.d_model as f64, 4.0 * shape.d_model as f64);
    let tf = t as f64;
    let mut c = OpCount::default();

    // per layer: the prunable linears, rho-active
    for _ in 0..shape.n_layers {
        // q, k, v, o projections: (T, d) x (d, d)
        for _ in 0..4 {
            c.add_matmul(tf, d, d, rho);
        }
        // fc1 (T,d)x(d,4d) + fc2 (T,4d)x(4d,d)
        c.add_matmul(tf, d, di, rho);
        c.add_matmul(tf, di, d, rho);

        if online_prune {
            let linears: [(f64, f64); 6] =
                [(d, d), (d, d), (d, d), (d, d), (di, d), (d, di)];
            for (d_out, d_in) in linears {
                add_wanda_overhead(&mut c, d_out, d_in, tf);
            }
        }
    }
    add_non_prunable_terms(&mut c, shape, tf);
    c
}

/// Everything a forward pass spends outside the prunable linears:
/// attention score/value matmuls, softmax, layernorms, relu, final LN and
/// the tied LM head. Shared by the analytic and the achieved counters so
/// the two can never drift apart.
fn add_non_prunable_terms(c: &mut OpCount, shape: ArchShape, tf: f64) {
    let (d, di) = (shape.d_model as f64, 4.0 * shape.d_model as f64);
    for _ in 0..shape.n_layers {
        // attention scores + weighted values: (T,hd)x(hd,T) per head = T^2 d
        c.add_matmul(tf, d, tf, 1.0);
        c.add_matmul(tf, tf, d, 1.0);
        // softmax (~5 flops/elt) + 2 layernorms (~8 flops/elt) + relu
        c.add_elementwise(tf * tf, 5.0);
        c.add_elementwise(2.0 * tf * d, 8.0);
        c.add_elementwise(tf * di, 1.0);
    }
    // final layernorm + tied LM head (dense: the head is not pruned)
    c.add_elementwise(tf * d, 8.0);
    c.add_matmul(tf, d, shape.vocab as f64, 1.0);
}

/// Instant-Wanda pruning overhead for one linear (paper S2:
/// O[3 d d' + d T]):
///   norms: 2 d_in T flops (square + accumulate; d_in T MACs)
///   score: d_out d_in multiplies
///   kth-value selection: ~d_out d_in comparisons
///   gate comparators: d_out d_in
fn add_wanda_overhead(c: &mut OpCount, d_out: f64, d_in: f64, tf: f64) {
    c.flops += 2.0 * d_in * tf; // norm accumulate
    c.macs += d_in * tf;
    c.flops += d_out * d_in; // scores
    c.flops += d_out * d_in; // selection comparisons
    c.flops += d_out * d_in; // gating comparators
}

/// *Achieved* op counts of one forward pass given the micro-expert masks a
/// prompt actually induced (e.g. `moe::select_experts(..).masks`), rather
/// than the analytic `rho`-scaled estimate. The non-prunable terms
/// (attention, softmax, layernorms, embeddings, LM head) and the pruning
/// overhead come from the architecture exactly as in [`count_forward`];
/// the linear-layer terms charge `t · active_count` MACs per linear.
///
/// `benches/sparse_speedup.rs` reports achieved vs theoretical FLOP
/// reduction from this — the gap quantifies how much of the paper's
/// complexity claim the sparse execution engine actually realizes.
pub fn achieved_forward(
    shape: ArchShape,
    t: usize,
    masks: &HashMap<String, Mask>,
    online_prune: bool,
) -> OpCount {
    let tf = t as f64;
    let mut c = OpCount::default();

    // prunable linears: exact active-weight counts from the masks
    for mask in masks.values() {
        let active = mask.active_count() as f64;
        c.macs += tf * active;
        c.flops += 2.0 * tf * active;
        if online_prune {
            add_wanda_overhead(&mut c, mask.rows as f64, mask.cols as f64, tf);
        }
    }
    add_non_prunable_terms(&mut c, shape, tf);
    c
}

/// Table 4 row: counts at a given active ratio for token length 128.
pub fn table4_row(shape: ArchShape, rho: f64) -> OpCount {
    count_forward(shape, 128, rho, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_17b_like() -> ArchShape {
        // the paper's "OPT-17B" table; closest published config is 13B
        // (40 layers, d=5120) — Table 4 scale is what matters
        ArchShape::opt(40, 5120)
    }

    #[test]
    fn macs_roughly_proportional_to_rho() {
        // the paper's headline observation on Table 4
        let s = paper_17b_like();
        let full = table4_row(s, 1.0);
        let half = table4_row(s, 0.5);
        let fifth = table4_row(s, 0.2);
        let r_half = half.macs / full.macs;
        let r_fifth = fifth.macs / full.macs;
        assert!((r_half - 0.5).abs() < 0.1, "{r_half}");
        assert!((r_fifth - 0.2).abs() < 0.12, "{r_fifth}");
    }

    #[test]
    fn flops_affine_in_rho_with_overhead_floor() {
        let s = paper_17b_like();
        let r100 = table4_row(s, 1.0).flops;
        let r20 = table4_row(s, 0.2).flops;
        // attention + overhead keep the floor well above 20%
        assert!(r20 / r100 > 0.2);
        assert!(r20 / r100 < 0.65);
    }

    #[test]
    fn monotone_in_rho() {
        let s = ArchShape::opt(12, 768);
        let mut last = 0.0;
        for rho in [0.2, 0.4, 0.6, 0.8, 1.0] {
            let c = table4_row(s, rho);
            assert!(c.flops > last);
            last = c.flops;
        }
    }

    #[test]
    fn online_overhead_is_small_at_long_t() {
        // paper S2: overhead ratio ~ 3/T + 1/d' -> negligible for T=128
        let s = ArchShape::opt(24, 2048);
        let with = count_forward(s, 128, 1.0, true);
        let without = count_forward(s, 128, 1.0, false);
        let overhead = (with.flops - without.flops) / without.flops;
        assert!(overhead < 0.05, "overhead {overhead}");
    }

    #[test]
    fn paper_scale_magnitudes() {
        // Table 4 reports ~3.3 TFLOPs at 100% for "OPT-17B", T=128.
        // Our conventions put a 40L/5120d model in the same ballpark.
        let c = table4_row(paper_17b_like(), 1.0);
        assert!(c.tflops() > 1.0 && c.tflops() < 8.0, "{}", c.tflops());
    }

    #[test]
    fn achieved_matches_analytic_at_exact_rho() {
        // masks with exactly rho-active rows must reproduce count_forward
        use crate::pruning::Mask;
        let cfg = crate::model::config_by_name("mu-opt-micro").unwrap();
        let shape = ArchShape::of(&cfg);
        let t = 32;
        // rho = 0.5 divides every linear width evenly -> analytic == exact
        let mut masks = HashMap::new();
        for name in cfg.linear_names() {
            let lin = name.split('.').nth(2).unwrap();
            let (d_out, d_in) = cfg.linear_shape(lin);
            let mut m = Mask::zeros(d_out, d_in);
            for i in 0..d_out {
                for j in 0..d_in / 2 {
                    m.set(i, j, true);
                }
            }
            masks.insert(name, m);
        }
        let achieved = achieved_forward(shape, t, &masks, true);
        let analytic = count_forward(shape, t, 0.5, true);
        assert!(
            (achieved.macs - analytic.macs).abs() / analytic.macs < 1e-9,
            "{} vs {}",
            achieved.macs,
            analytic.macs
        );
        assert!((achieved.flops - analytic.flops).abs() / analytic.flops < 1e-9);
    }

    #[test]
    fn achieved_from_real_selection_tracks_rho() {
        use crate::moe::select_experts;
        use crate::nn::random_model;
        let cfg = crate::model::config_by_name("mu-opt-micro").unwrap();
        let model = random_model(&cfg, 3);
        let toks: Vec<i32> = (1..17).collect();
        let shape = ArchShape::of(&cfg);
        let dense = achieved_forward(
            shape,
            16,
            &select_experts(&model, &toks, 16, 1.0).masks,
            false,
        );
        let half = achieved_forward(
            shape,
            16,
            &select_experts(&model, &toks, 16, 0.5).masks,
            false,
        );
        let ratio = half.macs / dense.macs;
        // linear MACs halve; attention/head floor keeps the ratio above 0.5
        assert!(ratio > 0.45 && ratio < 0.95, "{ratio}");
    }

    #[test]
    fn micro_counts_positive() {
        let cfg = crate::model::config_by_name("mu-opt-micro").unwrap();
        let c = count_forward(ArchShape::of(&cfg), 128, 0.5, true);
        assert!(c.flops > 0.0 && c.macs > 0.0);
        assert!(c.macs < c.flops);
    }
}
