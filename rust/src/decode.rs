//! Host-side autoregressive decode engine with mask-plan reuse.
//!
//! The μ-MoE serving question this module answers: *how often must the
//! micro-expert selection be refreshed while decoding?* Each refresh costs
//! a selection pass (a dense forward to collect activations plus Wanda
//! scoring per linear) and a recompression per linear; each reused step
//! costs only one sparse forward over the cached
//! [`crate::tensor::RowSparse`] layouts. [`MaskPlan`] names the policy:
//!
//! * `EveryStep` — re-select per token (adaptive baseline, no reuse);
//! * `PruneOnce` — select on the prompt, reuse for the whole generation;
//! * `Refresh(k)` — re-select every `k` tokens.
//!
//! Layout compression goes through an optional [`LayoutCache`], keyed by
//! `(model weights, linear, snapped-ρ level, mask fingerprint)`, so a
//! repeated prompt — or the unchanged selection of a `PruneOnce`
//! generation — skips recompression entirely. The cache is *transparent*: decoding with or
//! without it is bit-identical (`proptest.rs::decode_props` proves this).
//!
//! Quality cost of reuse is measured by
//! [`crate::eval::host::decode_drift`] and tracked by
//! `benches/decode_reuse.rs`.

use crate::coordinator::request::argmax;
use crate::model::EOS_ID;
use crate::moe::{self, layouts_for};
use crate::nn::{FixedLayouts, Model};
use crate::pruning::MaskPlan;
use crate::tensor::LayoutCache;

/// Knobs of one greedy decode.
#[derive(Clone, Copy, Debug)]
pub struct DecodeConfig {
    /// Active-weight ratio for micro-expert selection.
    pub rho: f64,
    /// When to re-run selection (see [`MaskPlan`]).
    pub plan: MaskPlan,
    /// Maximum new tokens to generate.
    pub max_new: usize,
    /// Stop when the model emits EOS (off for benches so every plan
    /// generates exactly `max_new` steps).
    pub stop_at_eos: bool,
}

/// One decode step's observable state (drift analysis consumes the
/// logits; everything downstream of them is deterministic).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Greedy-argmax token of this step.
    pub token: i32,
    /// Next-token logits at the last valid position (vocab-sized).
    pub logits: Vec<f32>,
    /// Whether this step re-ran micro-expert selection.
    pub refreshed: bool,
}

/// Result of one greedy decode.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Prompt followed by generated tokens (EOS, if hit, is not appended).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-step traces, in generation order.
    pub steps: Vec<StepTrace>,
    /// How many steps re-ran selection (1 for `PruneOnce`, `steps.len()`
    /// for `EveryStep`).
    pub refresh_count: usize,
    /// Layout-cache hits/misses attributable to this decode (0/0 when no
    /// cache was supplied).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl DecodeOutput {
    /// The generated suffix (without the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// Greedy autoregressive decode under a mask plan.
///
/// Each step runs the model over a sliding window of the most recent
/// `max_seq_len` tokens. On refresh steps the current window's selection
/// is computed ([`moe::select_experts`]) and compressed to per-linear
/// layouts (through `cache` when given); all other steps reuse the held
/// layouts and pay only one fixed-selection sparse forward with a
/// last-row-only LM head ([`Model::forward_fixed_last`]).
pub fn decode_greedy(
    model: &Model,
    prompt: &[i32],
    cfg: &DecodeConfig,
    mut cache: Option<&mut LayoutCache>,
) -> DecodeOutput {
    assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
    let seq = model.cfg.max_seq_len;
    let (hits0, misses0) = cache
        .as_deref()
        .map_or((0, 0), |c| (c.hits(), c.misses()));

    let mut tokens = prompt.to_vec();
    let mut steps: Vec<StepTrace> = Vec::with_capacity(cfg.max_new);
    let mut refresh_count = 0usize;
    let mut layouts = FixedLayouts::new();

    for step in 0..cfg.max_new {
        let start = tokens.len().saturating_sub(seq);
        let window = &tokens[start..];
        let valid = window.len();
        let refreshed = cfg.plan.refreshes_at(step);
        if refreshed {
            let sel = moe::select_experts(model, window, valid, cfg.rho);
            layouts = layouts_for(model, &sel, cache.as_deref_mut());
            refresh_count += 1;
        }
        let logits = model.forward_fixed_last(window, valid, &layouts);
        let token = argmax(&logits);
        steps.push(StepTrace {
            token,
            logits,
            refreshed,
        });
        if cfg.stop_at_eos && token == EOS_ID {
            break;
        }
        tokens.push(token);
    }

    let (hits1, misses1) = cache
        .as_deref()
        .map_or((0, 0), |c| (c.hits(), c.misses()));
    DecodeOutput {
        tokens,
        prompt_len: prompt.len(),
        steps,
        refresh_count,
        cache_hits: hits1 - hits0,
        cache_misses: misses1 - misses0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::nn::random_model;

    fn tiny_model() -> Model {
        random_model(&ModelConfig::new("dec-tiny", 2, 2, 16), 41)
    }

    fn cfg(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho: 0.5,
            plan,
            max_new,
            stop_at_eos: false,
        }
    }

    #[test]
    fn decode_extends_prompt_by_max_new() {
        let m = tiny_model();
        let out = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.tokens.len(), 8);
        assert_eq!(out.new_tokens().len(), 5);
        assert_eq!(out.steps.len(), 5);
        for (s, &t) in out.steps.iter().zip(out.new_tokens()) {
            assert_eq!(s.token, t);
            assert_eq!(s.logits.len(), m.cfg.vocab_size);
        }
    }

    #[test]
    fn refresh_counts_follow_plan() {
        let m = tiny_model();
        let every = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::EveryStep, 4), None);
        assert_eq!(every.refresh_count, 4);
        assert!(every.steps.iter().all(|s| s.refreshed));
        let once = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::PruneOnce, 4), None);
        assert_eq!(once.refresh_count, 1);
        assert!(once.steps[0].refreshed);
        assert!(once.steps[1..].iter().all(|s| !s.refreshed));
        let periodic = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::Refresh(2), 4), None);
        assert_eq!(periodic.refresh_count, 2);
    }

    #[test]
    fn prune_once_reuses_cache_across_identical_requests() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let mut cache = crate::tensor::LayoutCache::new(64);
        let cold = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(cold.cache_misses, n_linears);
        assert_eq!(cold.cache_hits, 0);
        let warm = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(warm.cache_misses, 0, "repeated prompt must not recompress");
        assert_eq!(warm.cache_hits, n_linears);
        assert_eq!(cold.tokens, warm.tokens);
    }

    #[test]
    fn window_slides_past_max_seq_len() {
        let m = tiny_model();
        let long: Vec<i32> = (0..m.cfg.max_seq_len as i32 + 5).map(|i| i % 250).collect();
        let out = decode_greedy(&m, &long, &cfg(MaskPlan::PruneOnce, 2), None);
        assert_eq!(out.new_tokens().len(), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_panics() {
        let m = tiny_model();
        decode_greedy(&m, &[], &cfg(MaskPlan::PruneOnce, 1), None);
    }
}
