//! Host-side autoregressive decode engine with mask-plan reuse and
//! KV-cached incremental attention.
//!
//! The μ-MoE serving question this module answers: *how often must the
//! micro-expert selection be refreshed while decoding?* Each refresh costs
//! a selection pass (a dense forward to collect activations plus Wanda
//! scoring per linear) and a recompression per linear; each reused step
//! costs only one sparse forward over the cached
//! [`crate::tensor::RowSparse`] layouts. [`MaskPlan`] names the policy:
//!
//! * `EveryStep` — re-select per token (adaptive baseline, no reuse);
//! * `PruneOnce` — select on the prompt, reuse for the whole generation;
//! * `Refresh(k)` — re-select every `k` tokens.
//!
//! Layout compression goes through an optional [`LayoutCache`], keyed by
//! `(model weights, linear, snapped-ρ level, mask fingerprint)`, so a
//! repeated prompt — or the unchanged selection of a `PruneOnce`
//! generation — skips recompression entirely. The cache is *transparent*:
//! decoding with or without it is bit-identical
//! (`proptest.rs::decode_props` proves this).
//!
//! # Prefill-then-step (the KV cache)
//!
//! With `DecodeConfig::kv_cache` on (the default), reused steps no longer
//! re-run the model over the whole sliding window. Instead each lane
//! carries a per-layer [`KvCache`]: one full
//! [`crate::nn::Model::forward_prefill_last`] populates it (the
//! *prefill*), then every subsequent step is a single-token
//! [`crate::nn::Model::forward_step`] — O(T) attention against the cached
//! prefix instead of the full window's O(T²). The cache is **rebuilt**
//! (a fresh prefill) whenever its rows would go stale:
//!
//! * on every refresh step — new layouts mean every cached K/V row was
//!   computed by the wrong weights;
//! * on every window slide — μ-OPT's learned absolute position
//!   embeddings shift with the window, so every row changes.
//!
//! Rebuild-on-refresh keeps KV decode **bit-identical** to the non-cached
//! path under `EveryStep`, `PruneOnce` and `Refresh(k)` alike, including
//! across the slide boundary (`proptest.rs::kv_props`); `EveryStep`
//! rebuilds every step, so the cache could buy it nothing — by design it
//! is the no-reuse baseline, and lanes that can never read a cached row
//! (`EveryStep`, or `max_new <= 1`) skip allocating one entirely
//! ([`lane_wants_kv`]).
//!
//! Quality cost of reuse is measured by
//! [`crate::eval::host::decode_drift`] and tracked by
//! `benches/decode_reuse.rs`; per-step cost vs position (flat with the
//! cache, growing without) by the same bench's `BENCH_kv_decode.json`.
//!
//! Three entry points share these semantics: [`decode_greedy`] (one
//! request, the reference implementation), [`decode_batch`] (the
//! drain-to-completion serving form: N requests at one snapped ρ through
//! one shared layout cache, each lane owning its private `KvCache`,
//! per-request bit-identical to `decode_greedy` — what
//! `coordinator::engine::HostEngine` executes), and the [`LanePool`]
//! both are built on (the continuous-batching form: the serve loop holds
//! the pool across requests, admitting a queued request into a freed
//! lane between sweeps and evicting cancelled lanes mid-flight). All of
//! them run every lane's steps through one internal stepper
//! ([`Lane::step`]), so none can drift apart — admission order and lane
//! reuse are invisible in the decoded tokens
//! (`proptest.rs::continuous_props`).
//!
//! # Matrix-major fused sweeps
//!
//! [`LanePool::sweep`] executes matrix-major, not lane-major: active
//! lanes whose next step is a pure incremental step and whose per-linear
//! layouts are the *same shared objects* (the sharing a common
//! [`LayoutCache`] gives same-selection batch-mates) are grouped, their
//! step rows stacked into one (N, d_model) matrix, and each linear runs
//! as **one** batched sparse matmul over the shared layout instead of N
//! matvecs ([`crate::nn::Model::forward_step_batch_with`]). Attention,
//! K/V appends and logits stay per-lane — K/V rows encode private
//! history and are never shareable. Refresh steps, slide rebuilds and
//! singleton groups take the per-lane path unchanged. Fusion is a pure
//! execution-schedule change: tokens, logits and traces are bit-identical
//! to per-lane sweeps (and to `decode_greedy`) for any arrival schedule,
//! proven over random group compositions — mixed plans, refresh steps
//! splitting a group mid-flight, lanes at different window positions —
//! by `proptest.rs::continuous_props`.

use crate::coordinator::request::argmax;
use crate::kvstore::{self, KvEntry, KvStore};
use crate::moe;
use crate::nn::{FixedLayouts, KvCache, Model, StepBatchScratch, StepScratch};
use crate::pruning::MaskPlan;
use crate::tensor::{fnv1a64, LayoutCache};
use crate::trace::{StepKind, StepProfile, SweepLaneStep};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::Arc;
use std::time::Instant;

/// Knobs of one greedy decode.
#[derive(Clone, Copy, Debug)]
pub struct DecodeConfig {
    /// Active-weight ratio for micro-expert selection.
    pub rho: f64,
    /// When to re-run selection (see [`MaskPlan`]).
    pub plan: MaskPlan,
    /// Maximum new tokens to generate.
    pub max_new: usize,
    /// Stop when the model emits its configured EOS
    /// ([`crate::model::ModelConfig::eos_id`]; off for benches so every
    /// plan generates exactly `max_new` steps).
    pub stop_at_eos: bool,
    /// Reuse per-layer K/V of the unchanged window prefix across steps
    /// (prefill-then-step; see the module docs). Off re-runs the full
    /// window every step — kept selectable for A/B benching; outputs are
    /// bit-identical either way.
    pub kv_cache: bool,
}

/// One decode step's observable state (drift analysis consumes the
/// logits; everything downstream of them is deterministic).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Greedy-argmax token of this step.
    pub token: i32,
    /// Next-token logits at the last valid position (vocab-sized).
    pub logits: Vec<f32>,
    /// Whether this step re-ran micro-expert selection.
    pub refreshed: bool,
    /// Wall time of this step (selection + forward). Feeds the per-step
    /// latency-vs-position curve in `benches/decode_reuse.rs`.
    pub elapsed_us: u64,
}

/// Result of one greedy decode.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Prompt followed by generated tokens (EOS, if hit, is not appended).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-step traces, in generation order.
    pub steps: Vec<StepTrace>,
    /// How many steps re-ran selection (1 for `PruneOnce`, `steps.len()`
    /// for `EveryStep`).
    pub refresh_count: usize,
    /// Time spent in full-window work: selection passes plus prefill /
    /// rebuild forwards (and, with the KV cache off, every refresh step's
    /// forward).
    pub prefill_us: u64,
    /// Time spent in reused steps: single-token `forward_step`s with the
    /// cache on, full-window reused forwards with it off. The
    /// prefill/step split is surfaced per ρ level by
    /// `coordinator::metrics`.
    pub step_us: u64,
    /// Layout-cache hits/misses attributable to this decode (0/0 when no
    /// cache was supplied).
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Window tokens this decode actually ran prefill-class forwards over
    /// (full-window prefills/rebuilds plus seeded suffix steps). The
    /// prefill/seed split is surfaced per ρ level by
    /// `coordinator::metrics`.
    pub prefilled_tokens: usize,
    /// Window tokens *seeded* — copied from the cross-request KV store or
    /// a parked session ([`crate::kvstore`]) instead of being computed. A
    /// warm same-prefix admission shows `seeded_tokens = T − 1`,
    /// `prefilled_tokens = 1`.
    pub seeded_tokens: usize,
    /// Continuable state for session parking: present iff the admission
    /// asked for it ([`LaneSeed::park`]) and the lane held cached rows.
    pub parked: Option<Box<ParkedLaneState>>,
}

impl DecodeOutput {
    /// The generated suffix (without the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// A finished (or cancelled mid-flight) lane's continuable state, exported
/// into [`DecodeOutput::parked`] when the admission asked for parking: the
/// final decode window, the layouts in force at the last step, and the
/// cached K/V rows covering the window's prefix — everything
/// `coordinator::server` needs to park a session for multi-turn
/// continuation.
#[derive(Clone, Debug)]
pub struct ParkedLaneState {
    /// The full final window (post-slide): prompt + generated suffix,
    /// truncated to the model's window if the generation slid it.
    pub tokens: Vec<i32>,
    /// Per-linear layouts in force when the lane stopped — a continuation
    /// pins these ([`SessionResume`]).
    pub layouts: FixedLayouts,
    /// Cached rows covering `tokens[..entry.len()]` (the final generated
    /// token is part of `tokens` but was never consumed by a forward, so
    /// `entry.len()` is typically `tokens.len() - 1`).
    pub entry: KvEntry,
}

/// The resume half of a session continuation, built by the coordinator
/// from a parked [`crate::kvstore::SessionState`]: the lane decodes the
/// concatenated window (parked tokens + new turn) under exactly these
/// pinned `layouts` — every plan refresh is skipped — and seeds its cache
/// from `entry` instead of prefilling the parked prefix.
pub struct SessionResume {
    pub layouts: FixedLayouts,
    pub entry: Arc<KvEntry>,
}

/// Cross-request KV state for one admission ([`LanePool::admit_with`]).
/// [`LanePool::admit`] uses the cold default: no store, no session, no
/// parking — byte-for-byte the pre-kvstore behavior.
#[derive(Default)]
pub struct LaneSeed {
    /// Shared prefix store to consult at position-0 prefills (seed the
    /// longest matching prefix, step only the suffix) and to publish
    /// freshly prefilled prefixes back to.
    pub store: Option<Arc<KvStore>>,
    /// Parked session to continue (pins its layouts).
    pub resume: Option<SessionResume>,
    /// Export the lane's final window + rows into
    /// [`DecodeOutput::parked`] for session parking.
    pub park: bool,
}

impl LaneSeed {
    /// No cross-request state: a plain admission.
    pub fn cold() -> LaneSeed {
        LaneSeed::default()
    }
}

/// Per-lane state of a decode: one lane per request. `decode_greedy` is a
/// single lane driven to completion; `decode_batch` drives N lanes
/// step-major. All stepping logic lives in [`Lane::step`] so the two
/// entry points cannot diverge.
struct Lane {
    tokens: Vec<i32>,
    prompt_len: usize,
    steps: Vec<StepTrace>,
    refresh_count: usize,
    layouts: FixedLayouts,
    /// Per-layer K/V of the current window prefix (`None` ⇒ kv disabled:
    /// reused steps re-run the full window).
    kv: Option<KvCache>,
    /// Reused per-step row buffers (allocated iff `kv` is — only the
    /// incremental step path consumes them).
    scratch: Option<StepScratch>,
    /// Window start of the previous step — a change means the window
    /// slid, so every cached position embedding (and thus K/V row) is
    /// stale and the cache must be rebuilt.
    prev_start: usize,
    prefill_us: u64,
    step_us: u64,
    cache_hits: u64,
    cache_misses: u64,
    /// Shared cross-request prefix store ([`crate::kvstore`]) consulted
    /// (and published to) at prefills of windows starting at absolute
    /// position 0 — slid windows rebuild as before.
    store: Option<Arc<KvStore>>,
    /// Session continuation: the lane's layouts were pinned at admission,
    /// so every plan refresh is skipped and no selection ever runs.
    pinned: bool,
    /// One-shot session seed, consumed by the first prefill.
    pending_seed: Option<Arc<KvEntry>>,
    /// Export the final window + rows into [`DecodeOutput::parked`].
    park: bool,
    prefilled_tokens: usize,
    seeded_tokens: usize,
    /// Classification of the most recent step (`crate::trace` phase
    /// reporting; the fused sweep path classifies its members itself).
    last_kind: StepKind,
    /// Seeded / prefilled window-token deltas of the most recent step.
    last_seeded: usize,
    last_prefilled: usize,
    /// Refreshes compress with an int8 sidecar ([`moe::layouts_for_mode`])
    /// so the forwards run the quantized kernels.
    quant: bool,
}

impl Lane {
    fn new(model: &Model, prompt: &[i32], use_kv: bool) -> Lane {
        assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
        Lane {
            tokens: prompt.to_vec(),
            prompt_len: prompt.len(),
            steps: Vec::new(),
            refresh_count: 0,
            layouts: FixedLayouts::new(),
            kv: use_kv.then(|| KvCache::new(&model.cfg)),
            scratch: use_kv.then(|| StepScratch::new(&model.cfg)),
            // "no previous window": the first step always prefills
            prev_start: usize::MAX,
            prefill_us: 0,
            step_us: 0,
            cache_hits: 0,
            cache_misses: 0,
            store: None,
            pinned: false,
            pending_seed: None,
            park: false,
            prefilled_tokens: 0,
            seeded_tokens: 0,
            last_kind: StepKind::Step,
            last_seeded: 0,
            last_prefilled: 0,
            quant: false,
        }
    }

    /// Run decode step `step` for this lane: refresh selection if the
    /// plan says so, produce the next-token logits (incrementally when
    /// the KV cache is valid, via full-window prefill otherwise), record
    /// the trace and return the greedy token. The caller decides EOS
    /// stopping and appends the token.
    fn step(
        &mut self,
        model: &Model,
        step: usize,
        rho: f64,
        plan: MaskPlan,
        cache: &mut Option<&mut LayoutCache>,
    ) -> i32 {
        self.step_profiled(model, step, rho, plan, cache, None)
    }

    /// [`Lane::step`] with optional sampled kernel attribution: an
    /// incremental step's forward splits its time into the profile's
    /// linear/attention/other buckets. Prefill-class forwards (and the
    /// kv-disabled path) are not instrumented kernel-by-kernel, so their
    /// whole elapsed time lands in `other_us`.
    fn step_profiled(
        &mut self,
        model: &Model,
        step: usize,
        rho: f64,
        plan: MaskPlan,
        cache: &mut Option<&mut LayoutCache>,
        mut prof: Option<&mut StepProfile>,
    ) -> i32 {
        let seq = model.cfg.max_seq_len;
        let start = self.tokens.len().saturating_sub(seq);
        let window = &self.tokens[start..];
        let valid = window.len();
        // pinned lanes (session continuations) decode entirely under the
        // layouts they were admitted with: no refresh ever runs
        let refreshed = !self.pinned && plan.refreshes_at(step);
        let cold = self.prev_start == usize::MAX;
        let slide = !cold && start != self.prev_start;
        let seeded_before = self.seeded_tokens;
        let prefilled_before = self.prefilled_tokens;
        let t0 = Instant::now();
        if refreshed {
            let (h0, m0) = cache.as_deref().map_or((0, 0), |c| (c.hits(), c.misses()));
            let sel = moe::select_experts(model, window, valid, rho);
            self.layouts = moe::layouts_for_mode(model, &sel, cache.as_deref_mut(), self.quant);
            let (h1, m1) = cache.as_deref().map_or((0, 0), |c| (c.hits(), c.misses()));
            self.cache_hits += h1 - h0;
            self.cache_misses += m1 - m0;
            self.refresh_count += 1;
        }
        let (logits, full_window) = match self.kv.as_mut() {
            Some(kv) => {
                // the cache is reusable only if the layouts are unchanged
                // (no refresh), the window grew by exactly the one token
                // the last step appended, and it did not slide
                let stale = refreshed || start != self.prev_start || kv.len() + 1 != valid;
                if stale {
                    // Cross-request reuse applies only to windows starting
                    // at absolute position 0 (absolute pos-emb: a slid
                    // window's rows exist nowhere else). The layout chain
                    // binds any reuse to the exact layouts this prefill
                    // would execute, which is what keeps seeding bit-exact.
                    let store = self.store.as_ref().filter(|_| start == 0);
                    let chain = store.and_then(|_| {
                        kvstore::layout_chain(&model.cfg.linear_names(), &self.layouts)
                    });
                    // clamped so at least one suffix token remains to step
                    // (a seeded prefill still has to produce logits)
                    let seed_cap = valid - 1;
                    let mut seeded = 0usize;
                    if start == 0 {
                        if let Some(entry) = self.pending_seed.take() {
                            // session continuation: the server built this
                            // window from the parked tokens, so the entry
                            // covers its prefix; verify defensively and
                            // fall back to a full prefill on any mismatch
                            // (e.g. the concatenated window slid)
                            let n = entry.len().min(seed_cap);
                            if n >= 1 && entry.tokens[..n] == window[..n] {
                                kv.seed_from(&entry, n);
                                seeded = n;
                            }
                        }
                    }
                    if seeded == 0 {
                        if let (Some(store), Some(chain)) = (store, chain) {
                            if let Some((entry, n)) =
                                store.lookup(model.weights_id(), chain, window)
                            {
                                let n = n.min(seed_cap);
                                if n >= 1 {
                                    kv.seed_from(&entry, n);
                                    seeded = n;
                                }
                            }
                        }
                    }
                    let logits = if seeded > 0 {
                        self.seeded_tokens += seeded;
                        self.prefilled_tokens += valid - seeded;
                        let scratch = self.scratch.as_mut().expect("kv lanes carry scratch");
                        model.forward_prefill_suffix_last(
                            window,
                            valid,
                            seeded,
                            &self.layouts,
                            kv,
                            scratch,
                        )
                    } else {
                        self.prefilled_tokens += valid;
                        model.forward_prefill_last(window, valid, &self.layouts, kv)
                    };
                    // publish the now fully-cached prefix so later
                    // same-prefix admissions can skip it (republishing an
                    // existing key only refreshes its recency)
                    if let (Some(store), Some(chain)) = (self.store.as_ref(), chain) {
                        let (k, v) = kv.export_prefix(valid);
                        store.publish(
                            model.weights_id(),
                            chain,
                            KvEntry {
                                tokens: window.to_vec(),
                                k,
                                v,
                                d_model: kv.d_model(),
                            },
                        );
                    }
                    (logits, true)
                } else {
                    let newest = *window.last().expect("non-empty window");
                    let scratch = self.scratch.as_mut().expect("kv lanes carry scratch");
                    (
                        model.forward_step_profiled(
                            newest,
                            &self.layouts,
                            kv,
                            scratch,
                            prof.as_deref_mut(),
                        ),
                        false,
                    )
                }
            }
            // kv disabled: every step is a full-window forward; refresh
            // steps count as prefill-class work, reused steps as step work
            None => (model.forward_fixed_last(window, valid, &self.layouts), refreshed),
        };
        self.prev_start = start;
        let elapsed_us = t0.elapsed().as_micros() as u64;
        if full_window {
            self.prefill_us += elapsed_us;
        } else {
            self.step_us += elapsed_us;
        }
        self.last_seeded = self.seeded_tokens - seeded_before;
        self.last_prefilled = self.prefilled_tokens - prefilled_before;
        // cold full-window work is the lane's prefill even when selection
        // also ran (every plan refreshes at step 0); Refresh is reserved
        // for re-selections after the lane is warm
        self.last_kind = if !full_window {
            StepKind::Step
        } else if refreshed && !cold {
            StepKind::Refresh
        } else if slide {
            StepKind::Slide
        } else if self.last_seeded > 0 {
            StepKind::SeededPrefill
        } else {
            StepKind::Prefill
        };
        // only the incremental kv branch splits its time internally
        let inline_profiled = !full_window && self.kv.is_some();
        if let Some(p) = prof {
            if !inline_profiled {
                p.other_us += elapsed_us;
            }
        }
        let token = argmax(&logits);
        self.steps.push(StepTrace {
            token,
            logits,
            refreshed,
            elapsed_us,
        });
        token
    }

    /// Can this lane's next step run through the fused matrix-major path?
    /// Exactly the lanes whose [`Lane::step`] would take the incremental
    /// `forward_step_with` branch: no refresh due at `step`, a KV cache
    /// present, and the cache valid for the current window (it did not
    /// slide and grew by exactly the one appended token) — i.e. the
    /// `stale` predicate below, negated. Refresh steps (selection +
    /// prefill) and slide rebuilds stay on the per-lane path.
    fn fusible(&self, seq: usize, step: usize, plan: MaskPlan) -> bool {
        // pinned lanes never refresh, mirroring [`Lane::step`]
        if !self.pinned && plan.refreshes_at(step) {
            return false;
        }
        let Some(kv) = self.kv.as_ref() else {
            return false;
        };
        let start = self.tokens.len().saturating_sub(seq);
        start == self.prev_start && kv.len() + 1 == self.tokens.len() - start
    }

    /// Clone the lane's continuable state for session parking: the
    /// current window plus the cached rows covering its prefix. `None`
    /// when there is nothing to continue from (no cache, or the lane
    /// never ran a step).
    fn export_parked(&self) -> Option<Box<ParkedLaneState>> {
        let kv = self.kv.as_ref()?;
        if kv.is_empty() || self.prev_start == usize::MAX || self.layouts.is_empty() {
            return None;
        }
        let window = &self.tokens[self.prev_start..];
        // rows 0..n cover window[..n]; the final generated token (if any)
        // was appended after the last forward and has no row yet
        let n = kv.len().min(window.len());
        let (k, v) = kv.export_prefix(n);
        Some(Box::new(ParkedLaneState {
            tokens: window.to_vec(),
            layouts: self.layouts.clone(),
            entry: KvEntry {
                tokens: window[..n].to_vec(),
                k,
                v,
                d_model: kv.d_model(),
            },
        }))
    }

    fn into_output(self) -> DecodeOutput {
        let parked = if self.park { self.export_parked() } else { None };
        DecodeOutput {
            tokens: self.tokens,
            prompt_len: self.prompt_len,
            steps: self.steps,
            refresh_count: self.refresh_count,
            prefill_us: self.prefill_us,
            step_us: self.step_us,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
            prefilled_tokens: self.prefilled_tokens,
            seeded_tokens: self.seeded_tokens,
            parked,
        }
    }
}

/// Should a lane carry a [`KvCache`]? A cache that can never be *read*
/// is pure overhead (allocation + per-prefill K/V copies): a `<= 1`-step
/// lane only ever prefills, and a plan that refreshes every step
/// (`EveryStep`, `Refresh(1)`) rebuilds every step by construction —
/// `refreshes_at(1)` identifies exactly those plans. Skipping the cache
/// for them is output-identical (the stale path and the no-kv path run
/// the same full-window forward and classify its time the same way).
fn lane_wants_kv(use_kv: bool, max_new: usize, plan: MaskPlan) -> bool {
    use_kv && max_new > 1 && !plan.refreshes_at(1)
}

/// Greedy autoregressive decode under a mask plan.
///
/// Each step operates on a sliding window of the most recent
/// `max_seq_len` tokens. On refresh steps the current window's selection
/// is computed ([`moe::select_experts`]) and compressed to per-linear
/// layouts (through `cache` when given). With the KV cache on, refresh
/// steps (and window slides) run one full prefill that repopulates the
/// lane's per-layer K/V; every other step is a single-token
/// [`Model::forward_step`]. With it off, all other steps reuse the held
/// layouts and pay one fixed-selection full-window forward with a
/// last-row-only LM head ([`Model::forward_fixed_last`]). Token-for-token
/// and logit-for-logit identical either way.
pub fn decode_greedy(
    model: &Model,
    prompt: &[i32],
    cfg: &DecodeConfig,
    mut cache: Option<&mut LayoutCache>,
) -> DecodeOutput {
    let mut lane = Lane::new(model, prompt, lane_wants_kv(cfg.kv_cache, cfg.max_new, cfg.plan));
    for step in 0..cfg.max_new {
        let token = lane.step(model, step, cfg.rho, cfg.plan, &mut cache);
        if cfg.stop_at_eos && token == model.cfg.eos_id {
            break;
        }
        lane.tokens.push(token);
    }
    lane.into_output()
}

/// One request of a batched decode: its prompt and per-request knobs. The
/// batch-level invariants (one snapped ρ, one KV on/off mode per batch)
/// live on the [`decode_batch`] call instead.
#[derive(Clone, Copy, Debug)]
pub struct BatchRequest<'a> {
    pub prompt: &'a [i32],
    /// Maximum new tokens for this request (may differ across batch-mates).
    pub max_new: usize,
    /// Refresh policy for this request.
    pub plan: MaskPlan,
}

/// A persistent pool of decode lanes — the unit of **continuous
/// batching**. Where [`decode_batch`] admits a fixed set of requests and
/// runs the pool until it drains, a caller holding a `LanePool` directly
/// (the continuous serve loop, `generate --stream`) can [`admit`]
/// requests into freed slots *between sweeps* while other lanes are
/// mid-generation, and [`evict`] a lane mid-flight (cancellation).
///
/// Invariants that make admission-order invisible in the tokens:
///
/// * every lane owns all of its decode state (tokens, layouts, `KvCache`,
///   scratch, per-lane step counter) — admitting a newcomer touches no
///   in-flight lane;
/// * the only shared state is the optional [`LayoutCache`], which is
///   *transparent* (hit counters may rise, outputs cannot change —
///   `proptest.rs::decode_props`);
/// * every slot runs the same [`Lane::step`] as [`decode_greedy`], with a
///   per-lane step index starting at 0 on admission, so a lane admitted
///   into a running pool refreshes/prefills exactly like a fresh
///   single-request decode.
///
/// Hence the pool contract, property-tested over random arrival schedules
/// in `proptest.rs::continuous_props`: **for any admission order, lane
/// count and sweep interleaving, each request's output is bit-identical
/// to an independent `decode_greedy` call**. One pool runs one snapped ρ
/// (the coordinator's batch key); the caller passes it to every
/// [`sweep`].
///
/// [`admit`]: LanePool::admit
/// [`evict`]: LanePool::evict
/// [`sweep`]: LanePool::sweep
pub struct LanePool {
    slots: Vec<Option<PoolLane>>,
    /// Occupied-slot count, maintained on admit/evict/finish so the serve
    /// hot loop's occupancy checks never rescan the slots.
    active_count: usize,
    /// Free slot indices as a min-heap, so admission still fills the
    /// lowest-index slot first (the pre-heap scan's order) in O(log n).
    free_slots: BinaryHeap<Reverse<usize>>,
    /// Run fusible same-layout lanes through the matrix-major batched
    /// step (default). Off = the per-lane A/B baseline the fused-sweep
    /// bench and the fusion property test compare against; outputs are
    /// bit-identical either way.
    fuse: bool,
    /// Lazily-built matrix buffers for fused steps, reused across sweeps.
    batch_scratch: Option<StepBatchScratch>,
    /// Per-group fused widths of the most recent sweep (see
    /// [`LanePool::last_sweep_groups`]).
    last_groups: Vec<usize>,
    /// Per-lane step records of the most recent sweep (see
    /// [`LanePool::last_sweep_lane_steps`]).
    last_lane_steps: Vec<SweepLaneStep>,
    /// Sample kernel attribution every N sweeps (0 = never, the default).
    kernel_sample_every: u64,
    sweep_counter: u64,
    /// The most recent sampled sweep's (stepped lanes, kernel split),
    /// consumed by [`LanePool::take_kernel_sample`].
    kernel_sample: Option<(usize, StepProfile)>,
    /// Admit lanes in int8-quantized kernel mode (see [`Lane::quant`]).
    quant: bool,
}

/// Identity of a lane's per-linear layouts for fused-group formation: an
/// FNV-1a hash over the `Arc` *pointers* of each linear's compressed
/// layout, in `linear_names` order. Two lanes hash equal exactly when
/// every linear executes the same shared layout object — the sharing a
/// common [`LayoutCache`] already establishes for same-selection
/// batch-mates (`identical_batch_mates_share_compressed_layouts`).
/// Pointer identity is deliberately conservative: equal-content layouts
/// compressed separately (no cache) group apart, which costs fusion
/// opportunity but can never group lanes whose layouts differ. `None` if
/// a linear has no layout yet (a lane that never refreshed — such lanes
/// are not fusible anyway).
fn layout_identity(layouts: &FixedLayouts, names: &[String]) -> Option<u64> {
    let mut words = Vec::with_capacity(names.len());
    for n in names {
        words.push(Arc::as_ptr(layouts.get(n)?) as usize as u64);
    }
    Some(fnv1a64(words))
}

/// One occupied slot: the lane plus its per-request knobs and private
/// step counter.
struct PoolLane {
    lane: Lane,
    plan: MaskPlan,
    max_new: usize,
    /// Next step index *for this lane* (0 = its first decode step,
    /// regardless of how long the pool has been running).
    step: usize,
}

/// What one [`LanePool::sweep`] observed on one lane.
#[derive(Clone, Debug)]
pub enum LaneEvent {
    /// One decode step ran on `slot` and `token` was appended. `index` is
    /// the token's 0-based position in the generation: a request's
    /// `Token` events concatenate, in order, to exactly the final
    /// output's `new_tokens()`. An EOS-stopped step emits no `Token`
    /// (EOS is never part of the output tokens) — its trace is still in
    /// the final [`DecodeOutput::steps`].
    Token {
        slot: usize,
        index: usize,
        token: i32,
    },
    /// Lane `slot` finished (reached `max_new` or stopped at EOS) and its
    /// slot is free for the next admission.
    Done { slot: usize, output: DecodeOutput },
}

impl LanePool {
    /// An empty pool with `capacity` lanes.
    pub fn new(capacity: usize) -> LanePool {
        assert!(capacity > 0, "a lane pool needs at least one lane");
        LanePool {
            slots: (0..capacity).map(|_| None).collect(),
            active_count: 0,
            free_slots: (0..capacity).map(Reverse).collect(),
            fuse: true,
            batch_scratch: None,
            last_groups: Vec::new(),
            last_lane_steps: Vec::new(),
            kernel_sample_every: 0,
            sweep_counter: 0,
            kernel_sample: None,
            quant: false,
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Occupied lanes — O(1), tracked across admit/evict/finish.
    pub fn active(&self) -> usize {
        self.active_count
    }

    pub fn is_idle(&self) -> bool {
        self.active_count == 0
    }

    /// Lowest-index free slot, if any — O(1) peek at the free-slot heap.
    pub fn free_slot(&self) -> Option<usize> {
        self.free_slots.peek().map(|r| r.0)
    }

    /// Enable or disable matrix-major fusion of same-layout lanes
    /// (default on). The off position is the per-lane A/B baseline:
    /// tokens, logits and traces are bit-identical either way
    /// (`proptest.rs::continuous_props` proves it over random schedules).
    pub fn set_fuse(&mut self, fuse: bool) {
        self.fuse = fuse;
    }

    /// Admit subsequent lanes in int8-quantized kernel mode: every plan
    /// refresh compresses with [`crate::pruning::Mask::compress_quant`],
    /// so forwards run the quantized kernels. Off by default; quality vs
    /// f32 is measured by the decode-drift machinery, not assumed.
    pub fn set_quant(&mut self, quant: bool) {
        self.quant = quant;
    }

    /// Widths of the step groups the most recent [`LanePool::sweep`] ran:
    /// one entry ≥ 2 per fused group (one batched matmul per linear served
    /// that many lanes) and one `1` per lane stepped on the per-lane path.
    /// Zero-step finishes contribute nothing. Feeds the fused-width
    /// metrics histogram and the fused-sweep bench's structural assertion.
    pub fn last_sweep_groups(&self) -> &[usize] {
        &self.last_groups
    }

    /// Per-lane step records of the most recent [`LanePool::sweep`]: slot,
    /// step kind (prefill / seeded prefill / refresh / slide / step /
    /// fused step), elapsed time, fused-group width and the
    /// seeded/prefilled token split. Feeds the serve loop's per-request
    /// span recording ([`crate::trace::FlightRecorder::record_sweep`]).
    /// Zero-step finishes contribute nothing.
    pub fn last_sweep_lane_steps(&self) -> &[SweepLaneStep] {
        &self.last_lane_steps
    }

    /// Sample kernel-time attribution every `every` sweeps (0 = never,
    /// the default). A sampled sweep runs its forwards through the
    /// profiled variants (bit-identical outputs, a handful of extra
    /// timer reads); every other sweep pays one integer test.
    pub fn set_kernel_sampling(&mut self, every: u64) {
        self.kernel_sample_every = every;
    }

    /// The most recent sweep's (stepped lanes, kernel split) if that
    /// sweep was sampled; unsampled sweeps clear it. Consuming resets it.
    pub fn take_kernel_sample(&mut self) -> Option<(usize, StepProfile)> {
        self.kernel_sample.take()
    }

    /// Bookkeeping for a slot going empty (evict or finish).
    fn release_slot(&mut self, slot: usize) {
        self.active_count -= 1;
        self.free_slots.push(Reverse(slot));
    }

    /// Admit a request into the lowest free slot (fresh lane: its first
    /// sweep step runs selection + a full `KvCache` prefill, exactly like
    /// a fresh `decode_greedy` — in-flight lanes are untouched). Returns
    /// the slot. Panics if the pool is full; callers gate on
    /// [`LanePool::free_slot`].
    pub fn admit(
        &mut self,
        model: &Model,
        prompt: &[i32],
        max_new: usize,
        plan: MaskPlan,
        use_kv: bool,
    ) -> usize {
        self.admit_with(model, prompt, max_new, plan, use_kv, LaneSeed::cold())
    }

    /// [`LanePool::admit`] with cross-request KV state ([`LaneSeed`]):
    /// consult/publish a shared [`KvStore`] at the lane's prefill,
    /// continue a parked session (pinning its layouts, seeding its rows,
    /// skipping every refresh), and/or export the lane's final state into
    /// [`DecodeOutput::parked`]. A cold seed is byte-for-byte [`admit`]:
    /// `proptest.rs::kvstore_props` proves the store itself is
    /// *transparent* — seeded and cold decodes are bit-identical.
    ///
    /// [`admit`]: LanePool::admit
    pub fn admit_with(
        &mut self,
        model: &Model,
        prompt: &[i32],
        max_new: usize,
        plan: MaskPlan,
        use_kv: bool,
        seed: LaneSeed,
    ) -> usize {
        let slot = self.free_slots.pop().expect("admit into a full lane pool").0;
        // a continuation reads cached rows no matter what the plan says
        // (its refreshes are skipped), so it keeps the cache whenever kv
        // is on; plain lanes keep the can-this-cache-ever-be-read gate
        let wants_kv = if seed.resume.is_some() {
            use_kv
        } else {
            lane_wants_kv(use_kv, max_new, plan)
        };
        let mut lane = Lane::new(model, prompt, wants_kv);
        lane.quant = self.quant;
        lane.park = seed.park;
        if wants_kv {
            lane.store = seed.store;
        }
        if let Some(resume) = seed.resume {
            lane.pinned = true;
            lane.layouts = resume.layouts;
            if wants_kv {
                lane.pending_seed = Some(resume.entry);
            }
        }
        self.slots[slot] = Some(PoolLane {
            lane,
            plan,
            max_new,
            step: 0,
        });
        self.active_count += 1;
        slot
    }

    /// Remove a lane mid-flight (cancellation), freeing its slot and
    /// returning the partial output (tokens decoded so far). Panics on an
    /// empty slot — cancelling nothing is a caller bug.
    pub fn evict(&mut self, slot: usize) -> DecodeOutput {
        let pl = self.slots[slot].take().expect("evict from an empty lane");
        self.release_slot(slot);
        pl.lane.into_output()
    }

    /// One step-major sweep: run one decode step on every active lane,
    /// emitting a [`LaneEvent::Token`] per appended token and a
    /// [`LaneEvent::Done`] for each lane that finished (in slot order) —
    /// finished slots are free for admission as soon as `sweep` returns.
    /// All lanes run at one snapped `rho` (the pool's batch key) through
    /// one shared `cache`.
    ///
    /// Execution is **matrix-major**: lanes whose next step is a pure
    /// incremental step ([`Lane::fusible`]) are grouped by layout identity
    /// ([`layout_identity`] — the snapped ρ is already the pool's batch
    /// key), and each group of ≥ 2 runs as one
    /// [`Model::forward_step_batch_with`]: its step rows stacked into an
    /// (N, d_model) matrix, **one** batched sparse matmul per linear
    /// instead of N matvecs, K/V and logits scattered back per lane.
    /// Singleton groups, refresh steps and slide rebuilds take the
    /// existing per-lane [`Lane::step`] path. Per lane, tokens / logits /
    /// traces are bit-identical to an all-per-lane sweep (and hence to
    /// `decode_greedy`) — the batched step shares the per-lane path's
    /// kernels and accumulation orders by construction; a fused batch's
    /// wall time is split evenly across its lanes' step-time accounting.
    pub fn sweep(
        &mut self,
        model: &Model,
        rho: f64,
        stop_at_eos: bool,
        cache: &mut Option<&mut LayoutCache>,
    ) -> Vec<LaneEvent> {
        self.last_groups.clear();
        self.last_lane_steps.clear();
        self.kernel_sample = None;
        self.sweep_counter += 1;
        // sampled sweeps accumulate a kernel-time split; profiled and
        // unprofiled forwards are bit-identical
        let mut profile = (self.kernel_sample_every > 0
            && self.sweep_counter % self.kernel_sample_every == 0)
            .then(StepProfile::default);
        let n_slots = self.slots.len();
        // token produced by this sweep's step, per slot (None = no step:
        // empty slot or a zero-step lane finishing below)
        let mut stepped: Vec<Option<i32>> = vec![None; n_slots];

        // group fusible lanes by shared layout identity, preserving slot
        // order within each group and ordering groups by first member
        let mut groups: Vec<(u64, Vec<usize>)> = Vec::new();
        if self.fuse {
            let seq = model.cfg.max_seq_len;
            let names = model.cfg.linear_names();
            for slot in 0..n_slots {
                let Some(pl) = self.slots[slot].as_ref() else {
                    continue;
                };
                if pl.step >= pl.max_new || !pl.lane.fusible(seq, pl.step, pl.plan) {
                    continue;
                }
                let Some(key) = layout_identity(&pl.lane.layouts, &names) else {
                    continue;
                };
                match groups.iter_mut().find(|(k, _)| *k == key) {
                    Some((_, members)) => members.push(slot),
                    None => groups.push((key, vec![slot])),
                }
            }
            // singletons gain nothing from the matrix path; let them ride
            // the per-lane stepper below
            groups.retain(|(_, g)| g.len() >= 2);
        }

        // fused matrix-major steps: one batched sparse matmul per linear
        // per group
        for (_, group) in &groups {
            if !self.batch_scratch.as_ref().is_some_and(|s| s.fits(&model.cfg)) {
                self.batch_scratch = Some(StepBatchScratch::new(&model.cfg, n_slots));
            }
            let scratch = self.batch_scratch.as_mut().expect("batch scratch ensured");
            // all members execute the same layout objects (that is the
            // group key); cloning the map is n_linears Arc bumps
            let layouts = self.slots[group[0]]
                .as_ref()
                .expect("grouped slot occupied")
                .lane
                .layouts
                .clone();
            let newest: Vec<i32> = group
                .iter()
                .map(|&s| {
                    *self.slots[s]
                        .as_ref()
                        .expect("grouped slot occupied")
                        .lane
                        .tokens
                        .last()
                        .expect("lanes hold non-empty prompts")
                })
                .collect();
            let mut kvs: Vec<&mut KvCache> = Vec::with_capacity(group.len());
            let mut gi = 0;
            for (si, slot) in self.slots.iter_mut().enumerate() {
                if gi < group.len() && group[gi] == si {
                    let pl = slot.as_mut().expect("grouped slot occupied");
                    kvs.push(pl.lane.kv.as_mut().expect("fusible lanes carry kv"));
                    gi += 1;
                }
            }
            let t0 = Instant::now();
            let logits = model.forward_step_batch_profiled(
                &newest,
                &layouts,
                &mut kvs,
                scratch,
                profile.as_mut(),
            );
            // one batch wall time, split evenly: each lane's step-time
            // share sums (with its trace) to the same partition the
            // per-lane path records
            let share = t0.elapsed().as_micros() as u64 / group.len() as u64;
            drop(kvs);
            for (i, &slot) in group.iter().enumerate() {
                let pl = self.slots[slot].as_mut().expect("grouped slot occupied");
                let row = logits.row(i);
                let token = argmax(row);
                pl.lane.step_us += share;
                pl.lane.steps.push(StepTrace {
                    token,
                    logits: row.to_vec(),
                    refreshed: false,
                    elapsed_us: share,
                });
                pl.step += 1;
                stepped[slot] = Some(token);
                self.last_lane_steps.push(SweepLaneStep {
                    slot,
                    kind: StepKind::Fused,
                    elapsed_us: share,
                    width: group.len(),
                    seeded: 0,
                    prefilled: 0,
                });
            }
            self.last_groups.push(group.len());
        }

        // per-lane path: refresh / rebuild steps, kv-off lanes, singleton
        // groups — in slot order, so shared-cache touch order is stable
        for slot in 0..n_slots {
            if stepped[slot].is_some() {
                continue;
            }
            let Some(pl) = self.slots[slot].as_mut() else {
                continue;
            };
            // zero-step lanes (max_new = 0) finish without ever stepping
            if pl.step >= pl.max_new {
                continue;
            }
            let token =
                pl.lane.step_profiled(model, pl.step, rho, pl.plan, cache, profile.as_mut());
            pl.step += 1;
            stepped[slot] = Some(token);
            self.last_groups.push(1);
            self.last_lane_steps.push(SweepLaneStep {
                slot,
                kind: pl.lane.last_kind,
                elapsed_us: pl.lane.steps.last().map_or(0, |s| s.elapsed_us),
                width: 1,
                seeded: pl.lane.last_seeded,
                prefilled: pl.lane.last_prefilled,
            });
        }
        if let Some(p) = profile {
            let lanes = stepped.iter().filter(|s| s.is_some()).count();
            self.kernel_sample = Some((lanes, p));
        }

        // deliver events in slot order, exactly as the lane-major sweep
        // did: append-or-EOS, then Done for finished lanes
        let mut events = Vec::new();
        for slot in 0..n_slots {
            let Some(pl) = self.slots[slot].as_mut() else {
                continue;
            };
            let Some(token) = stepped[slot] else {
                let pl = self.slots[slot].take().expect("occupied slot");
                self.release_slot(slot);
                events.push(LaneEvent::Done {
                    slot,
                    output: pl.lane.into_output(),
                });
                continue;
            };
            let mut finished = pl.step >= pl.max_new;
            if stop_at_eos && token == model.cfg.eos_id {
                // EOS terminates the lane and is not appended: no Token
                finished = true;
            } else {
                let index = pl.lane.tokens.len() - pl.lane.prompt_len;
                pl.lane.tokens.push(token);
                events.push(LaneEvent::Token { slot, index, token });
            }
            if finished {
                let pl = self.slots[slot].take().expect("occupied slot");
                self.release_slot(slot);
                events.push(LaneEvent::Done {
                    slot,
                    output: pl.lane.into_output(),
                });
            }
        }
        events
    }
}

/// Batched greedy decode: every request shares one snapped ρ (the
/// coordinator's batch key) and one [`LayoutCache`], so batch-mates whose
/// refresh steps select the same micro-experts share one set of
/// compressed [`crate::tensor::RowSparse`] layouts instead of each
/// recompressing — while each lane owns a private [`KvCache`] (cached K/V
/// rows encode one lane's window and are never shareable). Per request,
/// the result is **bit-identical** to an independent [`decode_greedy`]
/// call (`proptest.rs::decode_props` proves this).
///
/// This is the **drain-to-completion** form: it admits all of `items`
/// into a [`LanePool`] up front and sweeps until every lane finishes
/// (what `HostEngine::execute` runs per `DecodeBatch`, and the
/// `continuous = false` A/B baseline of the continuous serve loop, which
/// drives the same pool but refills freed lanes between sweeps).
pub fn decode_batch(
    model: &Model,
    items: &[BatchRequest<'_>],
    rho: f64,
    stop_at_eos: bool,
    use_kv: bool,
    cache: Option<&mut LayoutCache>,
) -> Vec<DecodeOutput> {
    decode_batch_observed(model, items, rho, stop_at_eos, use_kv, false, cache, |_| {})
}

/// [`decode_batch`] with a per-sweep observer: after every pool sweep,
/// `on_sweep` receives the sweep's step-group widths
/// ([`LanePool::last_sweep_groups`]). The coordinator's drain path feeds
/// these into the per-ρ fused-width metrics histogram; observation cannot
/// change the decode (the observer runs between sweeps, after all state
/// updates). `quant` admits every lane in int8-quantized kernel mode
/// (see [`LanePool::set_quant`]).
#[allow(clippy::too_many_arguments)]
pub fn decode_batch_observed(
    model: &Model,
    items: &[BatchRequest<'_>],
    rho: f64,
    stop_at_eos: bool,
    use_kv: bool,
    quant: bool,
    mut cache: Option<&mut LayoutCache>,
    mut on_sweep: impl FnMut(&[usize]),
) -> Vec<DecodeOutput> {
    if items.is_empty() {
        return Vec::new();
    }
    let mut pool = LanePool::new(items.len());
    pool.set_quant(quant);
    for it in items {
        pool.admit(model, it.prompt, it.max_new, it.plan, use_kv);
    }
    let mut outs: Vec<Option<DecodeOutput>> = items.iter().map(|_| None).collect();
    while !pool.is_idle() {
        for ev in pool.sweep(model, rho, stop_at_eos, &mut cache) {
            if let LaneEvent::Done { slot, output } = ev {
                outs[slot] = Some(output);
            }
        }
        on_sweep(pool.last_sweep_groups());
    }
    outs.into_iter()
        .map(|o| o.expect("every admitted lane finishes"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, EOS_ID};
    use crate::nn::random_model;

    fn tiny_model() -> Model {
        random_model(&ModelConfig::new("dec-tiny", 2, 2, 16), 41)
    }

    fn cfg(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho: 0.5,
            plan,
            max_new,
            stop_at_eos: false,
            kv_cache: true,
        }
    }

    fn cfg_nokv(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            kv_cache: false,
            ..cfg(plan, max_new)
        }
    }

    fn assert_outputs_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) {
        assert_eq!(a.tokens, b.tokens, "{label}: tokens");
        assert_eq!(a.steps.len(), b.steps.len(), "{label}: step count");
        assert_eq!(a.refresh_count, b.refresh_count, "{label}: refreshes");
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            assert_eq!(sa.token, sb.token, "{label}: step {i} token");
            assert_eq!(sa.logits, sb.logits, "{label}: step {i} logits");
            assert_eq!(sa.refreshed, sb.refreshed, "{label}: step {i} refreshed");
        }
    }

    #[test]
    fn decode_extends_prompt_by_max_new() {
        let m = tiny_model();
        let out = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.tokens.len(), 8);
        assert_eq!(out.new_tokens().len(), 5);
        assert_eq!(out.steps.len(), 5);
        for (s, &t) in out.steps.iter().zip(out.new_tokens()) {
            assert_eq!(s.token, t);
            assert_eq!(s.logits.len(), m.cfg.vocab_size);
        }
    }

    #[test]
    fn refresh_counts_follow_plan() {
        let m = tiny_model();
        let every = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::EveryStep, 4), None);
        assert_eq!(every.refresh_count, 4);
        assert!(every.steps.iter().all(|s| s.refreshed));
        let once = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::PruneOnce, 4), None);
        assert_eq!(once.refresh_count, 1);
        assert!(once.steps[0].refreshed);
        assert!(once.steps[1..].iter().all(|s| !s.refreshed));
        let periodic = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::Refresh(2), 4), None);
        assert_eq!(periodic.refresh_count, 2);
    }

    #[test]
    fn kv_decode_bit_identical_to_full_window_decode() {
        // the tentpole contract, unit form: prefill-then-step equals the
        // non-cached path token-for-token and logit-for-logit under every
        // plan (the property test widens this over random shapes)
        let m = tiny_model();
        let prompt: &[i32] = &[9, 1, 7, 4];
        for plan in [MaskPlan::EveryStep, MaskPlan::PruneOnce, MaskPlan::Refresh(2)] {
            let with_kv = decode_greedy(&m, prompt, &cfg(plan, 6), None);
            let without = decode_greedy(&m, prompt, &cfg_nokv(plan, 6), None);
            assert_outputs_identical(&plan.label(), &with_kv, &without);
        }
    }

    #[test]
    fn refresh_rebuilds_cache_bit_identically() {
        // Refresh(k)'s cache rebuild must reproduce the PR-2 (full
        // re-forward) semantics exactly: steps after a refresh see
        // layouts *and* K/V consistent with the refreshed selection
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let with_kv = decode_greedy(&m, prompt, &cfg(MaskPlan::Refresh(3), 9), None);
        let without = decode_greedy(&m, prompt, &cfg_nokv(MaskPlan::Refresh(3), 9), None);
        assert_outputs_identical("refresh:3 rebuild", &with_kv, &without);
        assert_eq!(with_kv.refresh_count, 3);
    }

    #[test]
    fn kv_decode_identical_across_window_slide() {
        // shrink the window so the generation slides it: every sliding
        // step must rebuild (absolute positions shift) and still match
        // the non-cached path bit for bit
        let mut mc = ModelConfig::new("dec-slide", 2, 2, 16);
        mc.max_seq_len = 6;
        let m = random_model(&mc, 43);
        let prompt: &[i32] = &[8, 6, 7, 5];
        for plan in [MaskPlan::PruneOnce, MaskPlan::Refresh(2)] {
            let with_kv = decode_greedy(&m, prompt, &cfg(plan, 8), None);
            let without = decode_greedy(&m, prompt, &cfg_nokv(plan, 8), None);
            assert_outputs_identical(&format!("slide {}", plan.label()), &with_kv, &without);
            assert!(with_kv.tokens.len() > mc.max_seq_len, "generation must slide");
        }
    }

    #[test]
    fn timing_split_partitions_step_time() {
        // every step's elapsed time lands in exactly one bucket, so the
        // two buckets must sum to the per-step total (timers on a tiny
        // debug-profile model may legitimately read 0µs, so the test is
        // structural, not threshold-based)
        let m = tiny_model();
        let out = decode_greedy(&m, &[2, 4, 6], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.refresh_count, 1);
        let total: u64 = out.steps.iter().map(|s| s.elapsed_us).sum();
        assert_eq!(out.prefill_us + out.step_us, total);
        // no-kv EveryStep: every step refreshes, so all work is
        // prefill-class and nothing may be classified as a reused step
        let every = decode_greedy(&m, &[2, 4, 6], &cfg_nokv(MaskPlan::EveryStep, 3), None);
        assert_eq!(every.step_us, 0);
        let total: u64 = every.steps.iter().map(|s| s.elapsed_us).sum();
        assert_eq!(every.prefill_us, total);
    }

    #[test]
    fn eos_id_comes_from_model_config() {
        // regression: EOS used to be the hard-coded constant; a checkpoint
        // with a different vocabulary must stop at *its* EOS. Same
        // weights, different configured eos_id ⇒ different stopping.
        let mc = ModelConfig::new("dec-eos", 2, 2, 16);
        assert_eq!(mc.eos_id, EOS_ID, "random-model default keeps the constant");
        let m = random_model(&mc, 41);
        // what this model actually emits in 3 unstopped steps
        let probe = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 3), None);
        let first = probe.steps[0].token;
        let unused = (0..mc.vocab_size as i32)
            .find(|t| !probe.steps.iter().any(|s| s.token == *t))
            .expect("some token is never emitted");
        let stopping = DecodeConfig {
            stop_at_eos: true,
            ..cfg(MaskPlan::PruneOnce, 3)
        };
        // same weights, but the config declares the first emission as EOS
        let mut mc_hit = mc.clone();
        mc_hit.eos_id = first;
        let out = decode_greedy(&random_model(&mc_hit, 41), &[1, 2, 3], &stopping, None);
        assert_eq!(out.steps.len(), 1, "must stop at the configured EOS");
        assert!(out.new_tokens().is_empty(), "EOS is not appended");
        // same weights, EOS set to a token never emitted: runs all steps
        let mut mc_miss = mc.clone();
        mc_miss.eos_id = unused;
        let out = decode_greedy(&random_model(&mc_miss, 41), &[1, 2, 3], &stopping, None);
        assert_eq!(out.steps.len(), 3);
        assert_eq!(out.tokens, probe.tokens);
    }

    #[test]
    fn prune_once_reuses_cache_across_identical_requests() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let mut cache = crate::tensor::LayoutCache::new(64);
        let cold = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(cold.cache_misses, n_linears);
        assert_eq!(cold.cache_hits, 0);
        let warm = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(warm.cache_misses, 0, "repeated prompt must not recompress");
        assert_eq!(warm.cache_hits, n_linears);
        assert_eq!(cold.tokens, warm.tokens);
    }

    #[test]
    fn window_slides_past_max_seq_len() {
        let m = tiny_model();
        let long: Vec<i32> = (0..m.cfg.max_seq_len as i32 + 5).map(|i| i % 250).collect();
        let out = decode_greedy(&m, &long, &cfg(MaskPlan::PruneOnce, 2), None);
        assert_eq!(out.new_tokens().len(), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_panics() {
        let m = tiny_model();
        decode_greedy(&m, &[], &cfg(MaskPlan::PruneOnce, 1), None);
    }

    // ---- decode_batch -----------------------------------------------------

    fn batch_item(prompt: &[i32], max_new: usize, plan: MaskPlan) -> BatchRequest<'_> {
        BatchRequest {
            prompt,
            max_new,
            plan,
        }
    }

    #[test]
    fn batch_matches_independent_greedy_mixed_max_new() {
        let m = tiny_model();
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9, 1, 7, 4], &[5, 6]];
        let plans = [MaskPlan::PruneOnce, MaskPlan::EveryStep, MaskPlan::Refresh(2)];
        let max_news = [4usize, 2, 5];
        let items: Vec<BatchRequest> = prompts
            .iter()
            .zip(plans)
            .zip(max_news)
            .map(|((&p, plan), max_new)| batch_item(p, max_new, plan))
            .collect();
        let mut cache = crate::tensor::LayoutCache::new(128);
        let batched = decode_batch(&m, &items, 0.5, false, true, Some(&mut cache));
        assert_eq!(batched.len(), 3);
        for (i, item) in items.iter().enumerate() {
            // reference lanes run without kv: the batch must match the
            // plain full-window semantics, not just its own code path
            let single = decode_greedy(
                &m,
                item.prompt,
                &DecodeConfig {
                    rho: 0.5,
                    plan: item.plan,
                    max_new: item.max_new,
                    stop_at_eos: false,
                    kv_cache: false,
                },
                None,
            );
            assert_eq!(batched[i].tokens, single.tokens, "lane {i} tokens");
            assert_eq!(batched[i].refresh_count, single.refresh_count, "lane {i}");
            assert_eq!(batched[i].steps.len(), single.steps.len(), "lane {i}");
            for (s, (a, b)) in batched[i].steps.iter().zip(&single.steps).enumerate() {
                assert_eq!(a.logits, b.logits, "lane {i} step {s} logits");
            }
        }
    }

    #[test]
    fn identical_batch_mates_share_compressed_layouts() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let prompt: &[i32] = &[9, 1, 7];
        let items = [
            batch_item(prompt, 3, MaskPlan::PruneOnce),
            batch_item(prompt, 3, MaskPlan::PruneOnce),
        ];
        let mut cache = crate::tensor::LayoutCache::new(64);
        let outs = decode_batch(&m, &items, 0.5, false, true, Some(&mut cache));
        // lane 0 compresses every linear once; lane 1's identical prompt
        // selection hits every one of those entries instead
        assert_eq!(outs[0].cache_misses, n_linears);
        assert_eq!(outs[1].cache_misses, 0, "batch-mate recompressed");
        assert_eq!(outs[1].cache_hits, n_linears);
        assert_eq!(outs[0].tokens, outs[1].tokens);
    }

    #[test]
    fn batch_eos_stop_mirrors_greedy() {
        // with stop_at_eos on, batch lanes must stop exactly where the
        // single-request engine stops
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let single = decode_greedy(
            &m,
            prompt,
            &DecodeConfig {
                rho: 0.6,
                plan: MaskPlan::PruneOnce,
                max_new: 6,
                stop_at_eos: true,
                kv_cache: true,
            },
            None,
        );
        let outs = decode_batch(
            &m,
            &[batch_item(prompt, 6, MaskPlan::PruneOnce)],
            0.6,
            true,
            true,
            None,
        );
        assert_eq!(outs[0].tokens, single.tokens);
        assert_eq!(outs[0].steps.len(), single.steps.len());
    }

    #[test]
    fn batch_kv_off_matches_kv_on() {
        let m = tiny_model();
        let items = [
            batch_item(&[1, 2, 3], 4, MaskPlan::PruneOnce),
            batch_item(&[7, 7], 3, MaskPlan::Refresh(2)),
        ];
        let on = decode_batch(&m, &items, 0.5, false, true, None);
        let off = decode_batch(&m, &items, 0.5, false, false, None);
        for (i, (a, b)) in on.iter().zip(&off).enumerate() {
            assert_outputs_identical(&format!("lane {i}"), a, b);
        }
    }

    #[test]
    fn empty_batch_and_zero_max_new() {
        let m = tiny_model();
        assert!(decode_batch(&m, &[], 0.5, false, true, None).is_empty());
        let items = [batch_item(&[1, 2], 0, MaskPlan::PruneOnce)];
        let outs = decode_batch(&m, &items, 0.5, false, true, None);
        assert_eq!(outs[0].new_tokens().len(), 0);
        assert_eq!(outs[0].steps.len(), 0);
        assert_eq!(outs[0].refresh_count, 0);
    }

    // ---- LanePool (continuous batching) -----------------------------------

    fn greedy_ref(m: &Model, prompt: &[i32], max_new: usize) -> DecodeOutput {
        decode_greedy(m, prompt, &cfg_nokv(MaskPlan::PruneOnce, max_new), None)
    }

    #[test]
    fn pool_admission_into_running_pool_matches_greedy() {
        // B is admitted while A is mid-generation; both must still equal
        // their independent decode_greedy outputs, and each lane's Token
        // events must concatenate to exactly its new_tokens()
        let m = tiny_model();
        let mut cache = crate::tensor::LayoutCache::new(64);
        let mut copt = Some(&mut cache);
        let mut pool = LanePool::new(1);
        let a_slot = pool.admit(&m, &[1, 2, 3], 3, MaskPlan::PruneOnce, true);
        assert_eq!(a_slot, 0);
        assert_eq!(pool.active(), 1);
        assert!(pool.free_slot().is_none());

        let mut outputs: Vec<(usize, DecodeOutput)> = Vec::new();
        let mut streamed: std::collections::HashMap<usize, Vec<i32>> = Default::default();
        let mut admitted_b = false;
        let mut guard = 0;
        while !pool.is_idle() || !admitted_b {
            if !admitted_b && pool.free_slot().is_some() {
                // the slot A finishes in is immediately reusable
                let b_slot = pool.admit(&m, &[9, 8], 2, MaskPlan::PruneOnce, true);
                assert_eq!(b_slot, 0, "freed lane must be reused");
                admitted_b = true;
            }
            for ev in pool.sweep(&m, 0.5, false, &mut copt) {
                match ev {
                    LaneEvent::Token { slot, index, token } => {
                        let toks = streamed.entry(slot).or_default();
                        assert_eq!(index, toks.len(), "indices must be dense");
                        toks.push(token);
                    }
                    LaneEvent::Done { slot, output } => outputs.push((slot, output)),
                }
            }
            guard += 1;
            assert!(guard < 20, "pool failed to drain");
        }
        assert_eq!(outputs.len(), 2);
        let a = greedy_ref(&m, &[1, 2, 3], 3);
        let b = greedy_ref(&m, &[9, 8], 2);
        assert_outputs_identical("lane A", &outputs[0].1, &a);
        assert_outputs_identical("lane B (admitted into running pool)", &outputs[1].1, &b);
        // the streamed tokens ARE the outputs (both rode slot 0 in turn,
        // so the stream interleaves; per Done-order they partition)
        let all_streamed = &streamed[&0];
        let concat: Vec<i32> = a
            .new_tokens()
            .iter()
            .chain(b.new_tokens())
            .copied()
            .collect();
        assert_eq!(*all_streamed, concat);
    }

    #[test]
    fn pool_evict_frees_lane_and_returns_partial_output() {
        let m = tiny_model();
        let mut pool = LanePool::new(1);
        pool.admit(&m, &[3, 1, 4], 6, MaskPlan::PruneOnce, true);
        let mut none = None;
        pool.sweep(&m, 0.5, false, &mut none);
        pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(pool.active(), 1, "6-step lane still mid-flight");
        let partial = pool.evict(0);
        assert_eq!(partial.steps.len(), 2, "two sweeps ran");
        // the partial prefix is exactly the full decode's prefix
        let full = greedy_ref(&m, &[3, 1, 4], 6);
        assert_eq!(partial.tokens[..], full.tokens[..partial.tokens.len()]);
        assert!(pool.is_idle(), "evict must free the lane");
        // the freed slot admits a newcomer that decodes untouched
        pool.admit(&m, &[9, 8], 2, MaskPlan::PruneOnce, true);
        let mut outs = Vec::new();
        while !pool.is_idle() {
            for ev in pool.sweep(&m, 0.5, false, &mut none) {
                if let LaneEvent::Done { output, .. } = ev {
                    outs.push(output);
                }
            }
        }
        assert_outputs_identical("post-evict newcomer", &outs[0], &greedy_ref(&m, &[9, 8], 2));
    }

    #[test]
    fn pool_zero_step_lane_finishes_without_stepping() {
        let m = tiny_model();
        let mut pool = LanePool::new(2);
        pool.admit(&m, &[1, 2], 0, MaskPlan::PruneOnce, true);
        let mut none = None;
        let events = pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(events.len(), 1);
        match &events[0] {
            LaneEvent::Done { slot, output } => {
                assert_eq!(*slot, 0);
                assert!(output.steps.is_empty());
                assert_eq!(output.refresh_count, 0);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        assert!(pool.is_idle());
    }

    #[test]
    #[should_panic(expected = "full lane pool")]
    fn pool_admit_beyond_capacity_panics() {
        let m = tiny_model();
        let mut pool = LanePool::new(1);
        pool.admit(&m, &[1], 2, MaskPlan::PruneOnce, true);
        pool.admit(&m, &[2], 2, MaskPlan::PruneOnce, true);
    }

    // ---- matrix-major fused sweeps -----------------------------------------

    /// Drain a pool of identical-knob lanes, returning (outputs in slot
    /// order, all per-sweep group widths).
    fn drain_pool(
        m: &Model,
        prompts: &[&[i32]],
        max_new: usize,
        plan: MaskPlan,
        fuse: bool,
        cache: &mut LayoutCache,
    ) -> (Vec<DecodeOutput>, Vec<Vec<usize>>) {
        let mut pool = LanePool::new(prompts.len());
        pool.set_fuse(fuse);
        for p in prompts {
            pool.admit(m, p, max_new, plan, true);
        }
        let mut copt = Some(&mut *cache);
        let mut outs: Vec<Option<DecodeOutput>> = prompts.iter().map(|_| None).collect();
        let mut widths = Vec::new();
        while !pool.is_idle() {
            for ev in pool.sweep(m, 0.5, false, &mut copt) {
                if let LaneEvent::Done { slot, output } = ev {
                    outs[slot] = Some(output);
                }
            }
            widths.push(pool.last_sweep_groups().to_vec());
        }
        (
            outs.into_iter().map(|o| o.expect("drained")).collect(),
            widths,
        )
    }

    #[test]
    fn fused_sweep_bit_identical_to_per_lane_and_actually_fuses() {
        // three same-prompt PruneOnce lanes through a shared cache share
        // layout Arcs, so every post-prefill sweep must run one 3-wide
        // fused group — and the outputs must equal both the unfused pool
        // and independent decode_greedy, logit for logit
        let m = tiny_model();
        let prompts: [&[i32]; 3] = [&[9, 1, 7], &[9, 1, 7], &[9, 1, 7]];
        let mut cache_a = crate::tensor::LayoutCache::new(64);
        let mut cache_b = crate::tensor::LayoutCache::new(64);
        let (fused, widths) = drain_pool(&m, &prompts, 5, MaskPlan::PruneOnce, true, &mut cache_a);
        let (plain, _) = drain_pool(&m, &prompts, 5, MaskPlan::PruneOnce, false, &mut cache_b);
        for (i, (a, b)) in fused.iter().zip(&plain).enumerate() {
            assert_outputs_identical(&format!("lane {i} fused vs per-lane"), a, b);
            assert_outputs_identical(
                &format!("lane {i} fused vs greedy"),
                a,
                &greedy_ref(&m, prompts[i], 5),
            );
        }
        // sweep 0 is all prefills (3 per-lane widths); sweeps 1..=4 must
        // each be exactly one 3-wide group
        assert_eq!(widths[0], vec![1, 1, 1], "prefill sweep is per-lane");
        for (s, w) in widths.iter().enumerate().skip(1) {
            assert_eq!(*w, vec![3], "sweep {s} must fuse all lanes");
        }
    }

    #[test]
    fn refresh_splits_fused_group_mid_flight() {
        // Refresh(3) lanes admitted one sweep apart refresh on different
        // sweeps: the fused group forms (both between refreshes, same
        // cached selection), splits whenever either lane refreshes, and
        // re-forms after — and every token must still equal an
        // independent greedy decode
        let m = tiny_model();
        let mut cache = crate::tensor::LayoutCache::new(64);
        let mut copt = Some(&mut cache);
        let mut pool = LanePool::new(2);
        let prompt: &[i32] = &[3, 1, 4, 1];
        pool.admit(&m, prompt, 6, MaskPlan::Refresh(3), true);
        pool.sweep(&m, 0.5, false, &mut copt); // lane 0 prefills alone
        pool.admit(&m, prompt, 6, MaskPlan::Refresh(3), true);
        let mut outs: Vec<Option<DecodeOutput>> = vec![None, None];
        let mut widths: Vec<Vec<usize>> = Vec::new();
        let mut guard = 0;
        while !pool.is_idle() {
            for ev in pool.sweep(&m, 0.5, false, &mut copt) {
                if let LaneEvent::Done { slot, output } = ev {
                    outs[slot] = Some(output);
                }
            }
            widths.push(pool.last_sweep_groups().to_vec());
            guard += 1;
            assert!(guard < 20, "pool failed to drain");
        }
        let reference = decode_greedy(&m, prompt, &cfg_nokv(MaskPlan::Refresh(3), 6), None);
        for (i, o) in outs.iter().enumerate() {
            assert_outputs_identical(
                &format!("offset-phase lane {i}"),
                o.as_ref().expect("drained"),
                &reference,
            );
        }
        // the schedule: lane 0 refreshes at its steps 0/3 (sweeps 1/4
        // counting from this loop's first sweep... structural claim only:
        // some sweep fused both lanes AND some mid-generation sweep with
        // both lanes active ran them apart — refresh split the group
        let fused_sweeps = widths.iter().filter(|w| w.contains(&2)).count();
        let split_sweeps = widths.iter().filter(|w| w.len() == 2).count();
        assert!(fused_sweeps > 0, "offset Refresh(3) lanes never fused");
        assert!(split_sweeps > 0, "refresh never split the fused group");
    }

    #[test]
    fn unshared_layouts_never_fuse() {
        // without a shared LayoutCache each lane compresses privately:
        // equal-content layouts in distinct Arcs must group apart
        let m = tiny_model();
        let mut pool = LanePool::new(2);
        let prompt: &[i32] = &[5, 6, 7];
        pool.admit(&m, prompt, 4, MaskPlan::PruneOnce, true);
        pool.admit(&m, prompt, 4, MaskPlan::PruneOnce, true);
        let mut none = None;
        while !pool.is_idle() {
            pool.sweep(&m, 0.5, false, &mut none);
            assert!(
                pool.last_sweep_groups().iter().all(|&w| w == 1),
                "privately-compressed lanes must not fuse"
            );
        }
    }

    #[test]
    fn pool_slot_tracking_stays_consistent() {
        // O(1) active/free bookkeeping must agree with the slots under
        // admit / evict / zero-step finish / drain, and admission must
        // keep filling the lowest free slot
        let m = tiny_model();
        let mut pool = LanePool::new(3);
        assert_eq!((pool.active(), pool.free_slot()), (0, Some(0)));
        let a = pool.admit(&m, &[1, 2], 3, MaskPlan::PruneOnce, true);
        let b = pool.admit(&m, &[3, 4], 3, MaskPlan::PruneOnce, true);
        let c = pool.admit(&m, &[5, 6], 0, MaskPlan::PruneOnce, true);
        assert_eq!((a, b, c), (0, 1, 2));
        assert_eq!(pool.active(), 3);
        assert!(pool.free_slot().is_none());
        pool.evict(1);
        assert_eq!((pool.active(), pool.free_slot()), (2, Some(1)));
        let mut none = None;
        pool.sweep(&m, 0.5, false, &mut none); // zero-step lane 2 finishes
        assert_eq!(pool.active(), 1);
        assert_eq!(pool.free_slot(), Some(1), "lowest free slot first");
        let d = pool.admit(&m, &[7], 1, MaskPlan::PruneOnce, true);
        assert_eq!(d, 1, "freed slot 1 reused before slot 2");
        while !pool.is_idle() {
            pool.sweep(&m, 0.5, false, &mut none);
        }
        assert_eq!((pool.active(), pool.free_slot()), (0, Some(0)));
    }

    #[test]
    fn quant_decode_is_deterministic_and_kv_transparent() {
        // within quant mode the bit-identity ladder must keep holding:
        // the quant matvec (KV step) and quant matmul (prefill) share one
        // accumulation order, so KV on/off cannot change tokens or logits
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let items = [
            batch_item(prompt, 5, MaskPlan::Refresh(2)),
            batch_item(prompt, 5, MaskPlan::PruneOnce),
        ];
        let kv_on = decode_batch_observed(&m, &items, 0.5, false, true, true, None, |_| {});
        let kv_off = decode_batch_observed(&m, &items, 0.5, false, false, true, None, |_| {});
        for (i, (a, b)) in kv_on.iter().zip(&kv_off).enumerate() {
            assert_outputs_identical(&format!("quant lane {i}"), a, b);
        }
        // and a repeat run is bit-identical (determinism)
        let again = decode_batch_observed(&m, &items, 0.5, false, true, true, None, |_| {});
        for (i, (a, b)) in kv_on.iter().zip(&again).enumerate() {
            assert_outputs_identical(&format!("quant repeat lane {i}"), a, b);
        }
    }

    #[test]
    fn decode_batch_observed_reports_group_widths() {
        let m = tiny_model();
        let prompt: &[i32] = &[9, 1, 7];
        let items = [
            batch_item(prompt, 4, MaskPlan::PruneOnce),
            batch_item(prompt, 4, MaskPlan::PruneOnce),
        ];
        let mut cache = crate::tensor::LayoutCache::new(64);
        let mut sweeps: Vec<Vec<usize>> = Vec::new();
        let outs =
            decode_batch_observed(&m, &items, 0.5, false, true, false, Some(&mut cache), |g| {
                sweeps.push(g.to_vec())
            });
        assert_eq!(outs.len(), 2);
        assert_eq!(sweeps.len(), 4, "one observation per sweep");
        assert_eq!(sweeps[0], vec![1, 1], "prefill sweep per-lane");
        for s in &sweeps[1..] {
            assert_eq!(*s, vec![2], "shared-cache mates fuse");
        }
        // observed run must equal the unobserved entry point
        let plain = decode_batch(
            &m,
            &items,
            0.5,
            false,
            true,
            Some(&mut crate::tensor::LayoutCache::new(64)),
        );
        for (i, (a, b)) in outs.iter().zip(&plain).enumerate() {
            assert_outputs_identical(&format!("observed lane {i}"), a, b);
        }
    }

    // ---- cross-request kv reuse --------------------------------------------

    /// Drain one lane admitted with `seed` through a single-lane pool.
    fn drain_seeded(
        m: &Model,
        prompt: &[i32],
        max_new: usize,
        plan: MaskPlan,
        cache: &mut LayoutCache,
        seed: LaneSeed,
    ) -> DecodeOutput {
        let mut pool = LanePool::new(1);
        pool.admit_with(m, prompt, max_new, plan, true, seed);
        let mut copt = Some(&mut *cache);
        let mut out = None;
        while !pool.is_idle() {
            for ev in pool.sweep(m, 0.5, false, &mut copt) {
                if let LaneEvent::Done { output, .. } = ev {
                    out = Some(output);
                }
            }
        }
        out.expect("drained")
    }

    #[test]
    fn warm_same_prefix_admission_is_suffix_only() {
        // acceptance, unit form: re-admitting an identical prompt through
        // a shared store must do zero full-prefix prefill work — seed the
        // T−1 cached rows, prefill exactly the one remaining suffix token
        // — and still decode bit-identically to the cold lane
        let m = tiny_model();
        let prompt: &[i32] = &[5, 11, 23, 47];
        let store = Arc::new(KvStore::new(4096));
        let mut cache = LayoutCache::new(64);
        let seed = || LaneSeed {
            store: Some(store.clone()),
            resume: None,
            park: false,
        };
        let cold = drain_seeded(&m, prompt, 4, MaskPlan::PruneOnce, &mut cache, seed());
        assert_eq!((cold.seeded_tokens, cold.prefilled_tokens), (0, 4));
        assert_eq!((store.hits(), store.misses()), (0, 1), "cold lookup misses");
        let warm = drain_seeded(&m, prompt, 4, MaskPlan::PruneOnce, &mut cache, seed());
        assert_eq!((warm.seeded_tokens, warm.prefilled_tokens), (3, 1));
        assert_eq!((store.hits(), store.misses()), (1, 1), "warm lookup hits");
        assert_outputs_identical("warm vs cold", &warm, &cold);
        assert_outputs_identical("warm vs greedy", &warm, &greedy_ref(&m, prompt, 4));
    }

    #[test]
    fn parked_session_continuation_pins_layouts_and_skips_prefix() {
        // turn 1 parks its window + cache rows; turn 2 resumes from them:
        // prefix rows seeded (no store needed), only the new turn's
        // suffix prefills, zero refreshes — and the whole decode equals a
        // hand-rolled fixed-layout decode of the concatenated window
        // under the parked selection (the documented exactness contract)
        let m = tiny_model();
        let prompt: &[i32] = &[9, 1, 7, 4];
        let mut cache = LayoutCache::new(64);
        let first = drain_seeded(
            &m,
            prompt,
            3,
            MaskPlan::PruneOnce,
            &mut cache,
            LaneSeed {
                store: None,
                resume: None,
                park: true,
            },
        );
        let parked = *first.parked.clone().expect("finished lane parks");
        assert_eq!(parked.tokens, first.tokens, "park captures the full window");
        // the last generated token was never stepped, so it has no row
        assert_eq!(parked.entry.len(), first.tokens.len() - 1);

        let mut full = parked.tokens.clone();
        full.extend_from_slice(&[7, 9]);
        let cont = drain_seeded(
            &m,
            &full,
            3,
            MaskPlan::PruneOnce,
            &mut cache,
            LaneSeed {
                store: None,
                resume: Some(SessionResume {
                    layouts: parked.layouts.clone(),
                    entry: Arc::new(parked.entry.clone()),
                }),
                park: true,
            },
        );
        assert_eq!(cont.seeded_tokens, parked.entry.len(), "prefix rows seeded");
        assert_eq!(
            cont.prefilled_tokens,
            full.len() - parked.entry.len(),
            "only the new turn's suffix prefills"
        );
        assert_eq!(cont.refresh_count, 0, "pinned lanes skip every refresh");
        assert_eq!((cont.cache_hits, cont.cache_misses), (0, 0));

        // hand-rolled reference under the pinned layouts
        let mut toks = full.clone();
        let mut kv = KvCache::new(&m.cfg);
        let mut s = StepScratch::new(&m.cfg);
        let mut logits = m.forward_prefill_last(&toks, toks.len(), &parked.layouts, &mut kv);
        for step in 0..3 {
            let t = argmax(&logits);
            assert_eq!(cont.steps[step].token, t, "step {step} token");
            assert_eq!(cont.steps[step].logits, logits, "step {step} logits");
            toks.push(t);
            if step + 1 < 3 {
                logits = m.forward_step_with(t, &parked.layouts, &mut kv, &mut s);
            }
        }
        assert_eq!(cont.tokens, toks, "continuation tokens");
        // the continuation re-parks the grown window for turn 3
        let reparked = cont.parked.expect("continuation re-parks");
        assert_eq!(reparked.tokens, cont.tokens);
        assert_eq!(reparked.entry.len(), cont.tokens.len() - 1);
    }

    // ---- sweep step classification + kernel sampling -----------------------

    #[test]
    fn sweep_lane_steps_classify_prefill_fused_and_step() {
        let m = tiny_model();
        let mut cache = LayoutCache::new(64);
        let mut copt = Some(&mut cache);
        let prompt: &[i32] = &[9, 1, 7];
        let mut pool = LanePool::new(2);
        pool.admit(&m, prompt, 3, MaskPlan::PruneOnce, true);
        pool.admit(&m, prompt, 3, MaskPlan::PruneOnce, true);
        pool.sweep(&m, 0.5, false, &mut copt);
        let steps = pool.last_sweep_lane_steps();
        assert_eq!(steps.len(), 2);
        assert!(steps.iter().all(|s| s.kind == StepKind::Prefill));
        assert!(steps.iter().all(|s| s.prefilled == prompt.len() && s.seeded == 0));
        assert!(steps.iter().all(|s| s.width == 1));
        // post-prefill, the shared-cache mates fuse
        pool.sweep(&m, 0.5, false, &mut copt);
        let steps = pool.last_sweep_lane_steps();
        assert_eq!(steps.len(), 2);
        assert!(
            steps.iter().all(|s| s.kind == StepKind::Fused && s.width == 2),
            "shared-cache mates fuse: {steps:?}"
        );
        // a lone lane's incremental step stays on the per-lane path
        let mut pool = LanePool::new(1);
        pool.admit(&m, prompt, 3, MaskPlan::PruneOnce, true);
        pool.sweep(&m, 0.5, false, &mut copt);
        pool.sweep(&m, 0.5, false, &mut copt);
        let steps = pool.last_sweep_lane_steps();
        assert_eq!(steps.len(), 1);
        assert_eq!((steps[0].kind, steps[0].width), (StepKind::Step, 1));
    }

    #[test]
    fn sweep_lane_steps_classify_refresh_and_seeded_prefill() {
        let m = tiny_model();
        let mut none = None;
        let mut pool = LanePool::new(1);
        pool.admit(&m, &[3, 1, 4, 1], 4, MaskPlan::Refresh(2), true);
        // step 0 refreshes too, but cold full-window work is Prefill
        pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(pool.last_sweep_lane_steps()[0].kind, StepKind::Prefill);
        pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(pool.last_sweep_lane_steps()[0].kind, StepKind::Step);
        // step 2: Refresh(2) re-selects on a warm lane
        pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(pool.last_sweep_lane_steps()[0].kind, StepKind::Refresh);

        // a warm store admission seeds its prefix: SeededPrefill
        let prompt: &[i32] = &[5, 11, 23, 47];
        let store = Arc::new(KvStore::new(4096));
        let mut cache = LayoutCache::new(64);
        let seed = || LaneSeed {
            store: Some(store.clone()),
            resume: None,
            park: false,
        };
        drain_seeded(&m, prompt, 3, MaskPlan::PruneOnce, &mut cache, seed());
        let mut pool = LanePool::new(1);
        pool.admit_with(&m, prompt, 3, MaskPlan::PruneOnce, true, seed());
        let mut copt = Some(&mut cache);
        pool.sweep(&m, 0.5, false, &mut copt);
        let st = pool.last_sweep_lane_steps()[0];
        assert_eq!(st.kind, StepKind::SeededPrefill);
        assert_eq!((st.seeded, st.prefilled), (3, 1));
    }

    #[test]
    fn kernel_sampling_profiles_every_nth_sweep_only() {
        let m = tiny_model();
        let mut cache = LayoutCache::new(64);
        let mut copt = Some(&mut cache);
        let prompt: &[i32] = &[9, 1, 7];
        let mut pool = LanePool::new(2);
        pool.set_kernel_sampling(2);
        pool.admit(&m, prompt, 4, MaskPlan::PruneOnce, true);
        pool.admit(&m, prompt, 4, MaskPlan::PruneOnce, true);
        pool.sweep(&m, 0.5, false, &mut copt); // sweep 1: unsampled
        assert!(pool.take_kernel_sample().is_none());
        pool.sweep(&m, 0.5, false, &mut copt); // sweep 2: sampled (fused)
        let (lanes, prof) = pool.take_kernel_sample().expect("sampled sweep");
        assert_eq!(lanes, 2);
        // structural only — timers on a debug-profile tiny model may read 0
        let _ = prof.total_us();
        assert!(pool.take_kernel_sample().is_none(), "consumed");
        pool.sweep(&m, 0.5, false, &mut copt); // sweep 3: unsampled again
        assert!(pool.take_kernel_sample().is_none());
    }

    #[test]
    fn kernel_sampling_is_output_transparent() {
        let m = tiny_model();
        let prompt: &[i32] = &[1, 2, 3];
        let run = |every: u64| {
            let mut pool = LanePool::new(1);
            pool.set_kernel_sampling(every);
            pool.admit(&m, prompt, 5, MaskPlan::PruneOnce, true);
            let mut none = None;
            let mut out = None;
            while !pool.is_idle() {
                for ev in pool.sweep(&m, 0.5, false, &mut none) {
                    if let LaneEvent::Done { output, .. } = ev {
                        out = Some(output);
                    }
                }
            }
            out.expect("drained")
        };
        assert_outputs_identical("sampled vs unsampled", &run(1), &run(0));
    }
}
