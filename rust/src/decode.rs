//! Host-side autoregressive decode engine with mask-plan reuse and
//! KV-cached incremental attention.
//!
//! The μ-MoE serving question this module answers: *how often must the
//! micro-expert selection be refreshed while decoding?* Each refresh costs
//! a selection pass (a dense forward to collect activations plus Wanda
//! scoring per linear) and a recompression per linear; each reused step
//! costs only one sparse forward over the cached
//! [`crate::tensor::RowSparse`] layouts. [`MaskPlan`] names the policy:
//!
//! * `EveryStep` — re-select per token (adaptive baseline, no reuse);
//! * `PruneOnce` — select on the prompt, reuse for the whole generation;
//! * `Refresh(k)` — re-select every `k` tokens.
//!
//! Layout compression goes through an optional [`LayoutCache`], keyed by
//! `(model weights, linear, snapped-ρ level, mask fingerprint)`, so a
//! repeated prompt — or the unchanged selection of a `PruneOnce`
//! generation — skips recompression entirely. The cache is *transparent*:
//! decoding with or without it is bit-identical
//! (`proptest.rs::decode_props` proves this).
//!
//! # Prefill-then-step (the KV cache)
//!
//! With `DecodeConfig::kv_cache` on (the default), reused steps no longer
//! re-run the model over the whole sliding window. Instead each lane
//! carries a per-layer [`KvCache`]: one full
//! [`crate::nn::Model::forward_prefill_last`] populates it (the
//! *prefill*), then every subsequent step is a single-token
//! [`crate::nn::Model::forward_step`] — O(T) attention against the cached
//! prefix instead of the full window's O(T²). The cache is **rebuilt**
//! (a fresh prefill) whenever its rows would go stale:
//!
//! * on every refresh step — new layouts mean every cached K/V row was
//!   computed by the wrong weights;
//! * on every window slide — μ-OPT's learned absolute position
//!   embeddings shift with the window, so every row changes.
//!
//! Rebuild-on-refresh keeps KV decode **bit-identical** to the non-cached
//! path under `EveryStep`, `PruneOnce` and `Refresh(k)` alike, including
//! across the slide boundary (`proptest.rs::kv_props`); `EveryStep`
//! rebuilds every step, so the cache could buy it nothing — by design it
//! is the no-reuse baseline, and lanes that can never read a cached row
//! (`EveryStep`, or `max_new <= 1`) skip allocating one entirely
//! ([`lane_wants_kv`]).
//!
//! Quality cost of reuse is measured by
//! [`crate::eval::host::decode_drift`] and tracked by
//! `benches/decode_reuse.rs`; per-step cost vs position (flat with the
//! cache, growing without) by the same bench's `BENCH_kv_decode.json`.
//!
//! Three entry points share these semantics: [`decode_greedy`] (one
//! request, the reference implementation), [`decode_batch`] (the
//! drain-to-completion serving form: N requests at one snapped ρ through
//! one shared layout cache, each lane owning its private `KvCache`,
//! per-request bit-identical to `decode_greedy` — what
//! `coordinator::engine::HostEngine` executes), and the [`LanePool`]
//! both are built on (the continuous-batching form: the serve loop holds
//! the pool across requests, admitting a queued request into a freed
//! lane between sweeps and evicting cancelled lanes mid-flight). All of
//! them run every lane's steps through one internal stepper
//! ([`Lane::step`]), so none can drift apart — admission order and lane
//! reuse are invisible in the decoded tokens
//! (`proptest.rs::continuous_props`).

use crate::coordinator::request::argmax;
use crate::moe::{self, layouts_for};
use crate::nn::{FixedLayouts, KvCache, Model, StepScratch};
use crate::pruning::MaskPlan;
use crate::tensor::LayoutCache;
use std::time::Instant;

/// Knobs of one greedy decode.
#[derive(Clone, Copy, Debug)]
pub struct DecodeConfig {
    /// Active-weight ratio for micro-expert selection.
    pub rho: f64,
    /// When to re-run selection (see [`MaskPlan`]).
    pub plan: MaskPlan,
    /// Maximum new tokens to generate.
    pub max_new: usize,
    /// Stop when the model emits its configured EOS
    /// ([`crate::model::ModelConfig::eos_id`]; off for benches so every
    /// plan generates exactly `max_new` steps).
    pub stop_at_eos: bool,
    /// Reuse per-layer K/V of the unchanged window prefix across steps
    /// (prefill-then-step; see the module docs). Off re-runs the full
    /// window every step — kept selectable for A/B benching; outputs are
    /// bit-identical either way.
    pub kv_cache: bool,
}

/// One decode step's observable state (drift analysis consumes the
/// logits; everything downstream of them is deterministic).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Greedy-argmax token of this step.
    pub token: i32,
    /// Next-token logits at the last valid position (vocab-sized).
    pub logits: Vec<f32>,
    /// Whether this step re-ran micro-expert selection.
    pub refreshed: bool,
    /// Wall time of this step (selection + forward). Feeds the per-step
    /// latency-vs-position curve in `benches/decode_reuse.rs`.
    pub elapsed_us: u64,
}

/// Result of one greedy decode.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Prompt followed by generated tokens (EOS, if hit, is not appended).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-step traces, in generation order.
    pub steps: Vec<StepTrace>,
    /// How many steps re-ran selection (1 for `PruneOnce`, `steps.len()`
    /// for `EveryStep`).
    pub refresh_count: usize,
    /// Time spent in full-window work: selection passes plus prefill /
    /// rebuild forwards (and, with the KV cache off, every refresh step's
    /// forward).
    pub prefill_us: u64,
    /// Time spent in reused steps: single-token `forward_step`s with the
    /// cache on, full-window reused forwards with it off. The
    /// prefill/step split is surfaced per ρ level by
    /// `coordinator::metrics`.
    pub step_us: u64,
    /// Layout-cache hits/misses attributable to this decode (0/0 when no
    /// cache was supplied).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl DecodeOutput {
    /// The generated suffix (without the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// Per-lane state of a decode: one lane per request. `decode_greedy` is a
/// single lane driven to completion; `decode_batch` drives N lanes
/// step-major. All stepping logic lives in [`Lane::step`] so the two
/// entry points cannot diverge.
struct Lane {
    tokens: Vec<i32>,
    prompt_len: usize,
    steps: Vec<StepTrace>,
    refresh_count: usize,
    layouts: FixedLayouts,
    /// Per-layer K/V of the current window prefix (`None` ⇒ kv disabled:
    /// reused steps re-run the full window).
    kv: Option<KvCache>,
    /// Reused per-step row buffers (allocated iff `kv` is — only the
    /// incremental step path consumes them).
    scratch: Option<StepScratch>,
    /// Window start of the previous step — a change means the window
    /// slid, so every cached position embedding (and thus K/V row) is
    /// stale and the cache must be rebuilt.
    prev_start: usize,
    prefill_us: u64,
    step_us: u64,
    cache_hits: u64,
    cache_misses: u64,
}

impl Lane {
    fn new(model: &Model, prompt: &[i32], use_kv: bool) -> Lane {
        assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
        Lane {
            tokens: prompt.to_vec(),
            prompt_len: prompt.len(),
            steps: Vec::new(),
            refresh_count: 0,
            layouts: FixedLayouts::new(),
            kv: use_kv.then(|| KvCache::new(&model.cfg)),
            scratch: use_kv.then(|| StepScratch::new(&model.cfg)),
            // "no previous window": the first step always prefills
            prev_start: usize::MAX,
            prefill_us: 0,
            step_us: 0,
            cache_hits: 0,
            cache_misses: 0,
        }
    }

    /// Run decode step `step` for this lane: refresh selection if the
    /// plan says so, produce the next-token logits (incrementally when
    /// the KV cache is valid, via full-window prefill otherwise), record
    /// the trace and return the greedy token. The caller decides EOS
    /// stopping and appends the token.
    fn step(
        &mut self,
        model: &Model,
        step: usize,
        rho: f64,
        plan: MaskPlan,
        cache: &mut Option<&mut LayoutCache>,
    ) -> i32 {
        let seq = model.cfg.max_seq_len;
        let start = self.tokens.len().saturating_sub(seq);
        let window = &self.tokens[start..];
        let valid = window.len();
        let refreshed = plan.refreshes_at(step);
        let t0 = Instant::now();
        if refreshed {
            let (h0, m0) = cache.as_deref().map_or((0, 0), |c| (c.hits(), c.misses()));
            let sel = moe::select_experts(model, window, valid, rho);
            self.layouts = layouts_for(model, &sel, cache.as_deref_mut());
            let (h1, m1) = cache.as_deref().map_or((0, 0), |c| (c.hits(), c.misses()));
            self.cache_hits += h1 - h0;
            self.cache_misses += m1 - m0;
            self.refresh_count += 1;
        }
        let (logits, full_window) = match self.kv.as_mut() {
            Some(kv) => {
                // the cache is reusable only if the layouts are unchanged
                // (no refresh), the window grew by exactly the one token
                // the last step appended, and it did not slide
                let stale = refreshed || start != self.prev_start || kv.len() + 1 != valid;
                if stale {
                    let logits = model.forward_prefill_last(window, valid, &self.layouts, kv);
                    (logits, true)
                } else {
                    let newest = *window.last().expect("non-empty window");
                    let scratch = self.scratch.as_mut().expect("kv lanes carry scratch");
                    (
                        model.forward_step_with(newest, &self.layouts, kv, scratch),
                        false,
                    )
                }
            }
            // kv disabled: every step is a full-window forward; refresh
            // steps count as prefill-class work, reused steps as step work
            None => (model.forward_fixed_last(window, valid, &self.layouts), refreshed),
        };
        self.prev_start = start;
        let elapsed_us = t0.elapsed().as_micros() as u64;
        if full_window {
            self.prefill_us += elapsed_us;
        } else {
            self.step_us += elapsed_us;
        }
        let token = argmax(&logits);
        self.steps.push(StepTrace {
            token,
            logits,
            refreshed,
            elapsed_us,
        });
        token
    }

    fn into_output(self) -> DecodeOutput {
        DecodeOutput {
            tokens: self.tokens,
            prompt_len: self.prompt_len,
            steps: self.steps,
            refresh_count: self.refresh_count,
            prefill_us: self.prefill_us,
            step_us: self.step_us,
            cache_hits: self.cache_hits,
            cache_misses: self.cache_misses,
        }
    }
}

/// Should a lane carry a [`KvCache`]? A cache that can never be *read*
/// is pure overhead (allocation + per-prefill K/V copies): a `<= 1`-step
/// lane only ever prefills, and a plan that refreshes every step
/// (`EveryStep`, `Refresh(1)`) rebuilds every step by construction —
/// `refreshes_at(1)` identifies exactly those plans. Skipping the cache
/// for them is output-identical (the stale path and the no-kv path run
/// the same full-window forward and classify its time the same way).
fn lane_wants_kv(use_kv: bool, max_new: usize, plan: MaskPlan) -> bool {
    use_kv && max_new > 1 && !plan.refreshes_at(1)
}

/// Greedy autoregressive decode under a mask plan.
///
/// Each step operates on a sliding window of the most recent
/// `max_seq_len` tokens. On refresh steps the current window's selection
/// is computed ([`moe::select_experts`]) and compressed to per-linear
/// layouts (through `cache` when given). With the KV cache on, refresh
/// steps (and window slides) run one full prefill that repopulates the
/// lane's per-layer K/V; every other step is a single-token
/// [`Model::forward_step`]. With it off, all other steps reuse the held
/// layouts and pay one fixed-selection full-window forward with a
/// last-row-only LM head ([`Model::forward_fixed_last`]). Token-for-token
/// and logit-for-logit identical either way.
pub fn decode_greedy(
    model: &Model,
    prompt: &[i32],
    cfg: &DecodeConfig,
    mut cache: Option<&mut LayoutCache>,
) -> DecodeOutput {
    let mut lane = Lane::new(model, prompt, lane_wants_kv(cfg.kv_cache, cfg.max_new, cfg.plan));
    for step in 0..cfg.max_new {
        let token = lane.step(model, step, cfg.rho, cfg.plan, &mut cache);
        if cfg.stop_at_eos && token == model.cfg.eos_id {
            break;
        }
        lane.tokens.push(token);
    }
    lane.into_output()
}

/// One request of a batched decode: its prompt and per-request knobs. The
/// batch-level invariants (one snapped ρ, one KV on/off mode per batch)
/// live on the [`decode_batch`] call instead.
#[derive(Clone, Copy, Debug)]
pub struct BatchRequest<'a> {
    pub prompt: &'a [i32],
    /// Maximum new tokens for this request (may differ across batch-mates).
    pub max_new: usize,
    /// Refresh policy for this request.
    pub plan: MaskPlan,
}

/// A persistent pool of decode lanes — the unit of **continuous
/// batching**. Where [`decode_batch`] admits a fixed set of requests and
/// runs the pool until it drains, a caller holding a `LanePool` directly
/// (the continuous serve loop, `generate --stream`) can [`admit`]
/// requests into freed slots *between sweeps* while other lanes are
/// mid-generation, and [`evict`] a lane mid-flight (cancellation).
///
/// Invariants that make admission-order invisible in the tokens:
///
/// * every lane owns all of its decode state (tokens, layouts, `KvCache`,
///   scratch, per-lane step counter) — admitting a newcomer touches no
///   in-flight lane;
/// * the only shared state is the optional [`LayoutCache`], which is
///   *transparent* (hit counters may rise, outputs cannot change —
///   `proptest.rs::decode_props`);
/// * every slot runs the same [`Lane::step`] as [`decode_greedy`], with a
///   per-lane step index starting at 0 on admission, so a lane admitted
///   into a running pool refreshes/prefills exactly like a fresh
///   single-request decode.
///
/// Hence the pool contract, property-tested over random arrival schedules
/// in `proptest.rs::continuous_props`: **for any admission order, lane
/// count and sweep interleaving, each request's output is bit-identical
/// to an independent `decode_greedy` call**. One pool runs one snapped ρ
/// (the coordinator's batch key); the caller passes it to every
/// [`sweep`].
///
/// [`admit`]: LanePool::admit
/// [`evict`]: LanePool::evict
/// [`sweep`]: LanePool::sweep
pub struct LanePool {
    slots: Vec<Option<PoolLane>>,
}

/// One occupied slot: the lane plus its per-request knobs and private
/// step counter.
struct PoolLane {
    lane: Lane,
    plan: MaskPlan,
    max_new: usize,
    /// Next step index *for this lane* (0 = its first decode step,
    /// regardless of how long the pool has been running).
    step: usize,
}

/// What one [`LanePool::sweep`] observed on one lane.
#[derive(Clone, Debug)]
pub enum LaneEvent {
    /// One decode step ran on `slot` and `token` was appended. `index` is
    /// the token's 0-based position in the generation: a request's
    /// `Token` events concatenate, in order, to exactly the final
    /// output's `new_tokens()`. An EOS-stopped step emits no `Token`
    /// (EOS is never part of the output tokens) — its trace is still in
    /// the final [`DecodeOutput::steps`].
    Token {
        slot: usize,
        index: usize,
        token: i32,
    },
    /// Lane `slot` finished (reached `max_new` or stopped at EOS) and its
    /// slot is free for the next admission.
    Done { slot: usize, output: DecodeOutput },
}

impl LanePool {
    /// An empty pool with `capacity` lanes.
    pub fn new(capacity: usize) -> LanePool {
        assert!(capacity > 0, "a lane pool needs at least one lane");
        LanePool {
            slots: (0..capacity).map(|_| None).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Occupied lanes.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_idle(&self) -> bool {
        self.active() == 0
    }

    /// Lowest-index free slot, if any.
    pub fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Admit a request into the lowest free slot (fresh lane: its first
    /// sweep step runs selection + a full `KvCache` prefill, exactly like
    /// a fresh `decode_greedy` — in-flight lanes are untouched). Returns
    /// the slot. Panics if the pool is full; callers gate on
    /// [`LanePool::free_slot`].
    pub fn admit(
        &mut self,
        model: &Model,
        prompt: &[i32],
        max_new: usize,
        plan: MaskPlan,
        use_kv: bool,
    ) -> usize {
        let slot = self.free_slot().expect("admit into a full lane pool");
        self.slots[slot] = Some(PoolLane {
            lane: Lane::new(model, prompt, lane_wants_kv(use_kv, max_new, plan)),
            plan,
            max_new,
            step: 0,
        });
        slot
    }

    /// Remove a lane mid-flight (cancellation), freeing its slot and
    /// returning the partial output (tokens decoded so far). Panics on an
    /// empty slot — cancelling nothing is a caller bug.
    pub fn evict(&mut self, slot: usize) -> DecodeOutput {
        let pl = self.slots[slot].take().expect("evict from an empty lane");
        pl.lane.into_output()
    }

    /// One step-major sweep: run one decode step on every active lane (in
    /// slot order), emitting a [`LaneEvent::Token`] per appended token and
    /// a [`LaneEvent::Done`] for each lane that finished — finished slots
    /// are free for admission as soon as `sweep` returns. All lanes run
    /// at one snapped `rho` (the pool's batch key) through one shared
    /// `cache`.
    pub fn sweep(
        &mut self,
        model: &Model,
        rho: f64,
        stop_at_eos: bool,
        cache: &mut Option<&mut LayoutCache>,
    ) -> Vec<LaneEvent> {
        let mut events = Vec::new();
        for slot in 0..self.slots.len() {
            let Some(pl) = self.slots[slot].as_mut() else {
                continue;
            };
            // zero-step lanes (max_new = 0) finish without ever stepping
            if pl.step >= pl.max_new {
                let pl = self.slots[slot].take().expect("occupied slot");
                events.push(LaneEvent::Done {
                    slot,
                    output: pl.lane.into_output(),
                });
                continue;
            }
            let token = pl.lane.step(model, pl.step, rho, pl.plan, cache);
            pl.step += 1;
            let mut finished = pl.step >= pl.max_new;
            if stop_at_eos && token == model.cfg.eos_id {
                // EOS terminates the lane and is not appended: no Token
                finished = true;
            } else {
                let index = pl.lane.tokens.len() - pl.lane.prompt_len;
                pl.lane.tokens.push(token);
                events.push(LaneEvent::Token { slot, index, token });
            }
            if finished {
                let pl = self.slots[slot].take().expect("occupied slot");
                events.push(LaneEvent::Done {
                    slot,
                    output: pl.lane.into_output(),
                });
            }
        }
        events
    }
}

/// Batched greedy decode: every request shares one snapped ρ (the
/// coordinator's batch key) and one [`LayoutCache`], so batch-mates whose
/// refresh steps select the same micro-experts share one set of
/// compressed [`crate::tensor::RowSparse`] layouts instead of each
/// recompressing — while each lane owns a private [`KvCache`] (cached K/V
/// rows encode one lane's window and are never shareable). Per request,
/// the result is **bit-identical** to an independent [`decode_greedy`]
/// call (`proptest.rs::decode_props` proves this).
///
/// This is the **drain-to-completion** form: it admits all of `items`
/// into a [`LanePool`] up front and sweeps until every lane finishes
/// (what `HostEngine::execute` runs per `DecodeBatch`, and the
/// `continuous = false` A/B baseline of the continuous serve loop, which
/// drives the same pool but refills freed lanes between sweeps).
pub fn decode_batch(
    model: &Model,
    items: &[BatchRequest<'_>],
    rho: f64,
    stop_at_eos: bool,
    use_kv: bool,
    mut cache: Option<&mut LayoutCache>,
) -> Vec<DecodeOutput> {
    if items.is_empty() {
        return Vec::new();
    }
    let mut pool = LanePool::new(items.len());
    for it in items {
        pool.admit(model, it.prompt, it.max_new, it.plan, use_kv);
    }
    let mut outs: Vec<Option<DecodeOutput>> = items.iter().map(|_| None).collect();
    while !pool.is_idle() {
        for ev in pool.sweep(model, rho, stop_at_eos, &mut cache) {
            if let LaneEvent::Done { slot, output } = ev {
                outs[slot] = Some(output);
            }
        }
    }
    outs.into_iter()
        .map(|o| o.expect("every admitted lane finishes"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ModelConfig, EOS_ID};
    use crate::nn::random_model;

    fn tiny_model() -> Model {
        random_model(&ModelConfig::new("dec-tiny", 2, 2, 16), 41)
    }

    fn cfg(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho: 0.5,
            plan,
            max_new,
            stop_at_eos: false,
            kv_cache: true,
        }
    }

    fn cfg_nokv(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            kv_cache: false,
            ..cfg(plan, max_new)
        }
    }

    fn assert_outputs_identical(label: &str, a: &DecodeOutput, b: &DecodeOutput) {
        assert_eq!(a.tokens, b.tokens, "{label}: tokens");
        assert_eq!(a.steps.len(), b.steps.len(), "{label}: step count");
        assert_eq!(a.refresh_count, b.refresh_count, "{label}: refreshes");
        for (i, (sa, sb)) in a.steps.iter().zip(&b.steps).enumerate() {
            assert_eq!(sa.token, sb.token, "{label}: step {i} token");
            assert_eq!(sa.logits, sb.logits, "{label}: step {i} logits");
            assert_eq!(sa.refreshed, sb.refreshed, "{label}: step {i} refreshed");
        }
    }

    #[test]
    fn decode_extends_prompt_by_max_new() {
        let m = tiny_model();
        let out = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.tokens.len(), 8);
        assert_eq!(out.new_tokens().len(), 5);
        assert_eq!(out.steps.len(), 5);
        for (s, &t) in out.steps.iter().zip(out.new_tokens()) {
            assert_eq!(s.token, t);
            assert_eq!(s.logits.len(), m.cfg.vocab_size);
        }
    }

    #[test]
    fn refresh_counts_follow_plan() {
        let m = tiny_model();
        let every = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::EveryStep, 4), None);
        assert_eq!(every.refresh_count, 4);
        assert!(every.steps.iter().all(|s| s.refreshed));
        let once = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::PruneOnce, 4), None);
        assert_eq!(once.refresh_count, 1);
        assert!(once.steps[0].refreshed);
        assert!(once.steps[1..].iter().all(|s| !s.refreshed));
        let periodic = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::Refresh(2), 4), None);
        assert_eq!(periodic.refresh_count, 2);
    }

    #[test]
    fn kv_decode_bit_identical_to_full_window_decode() {
        // the tentpole contract, unit form: prefill-then-step equals the
        // non-cached path token-for-token and logit-for-logit under every
        // plan (the property test widens this over random shapes)
        let m = tiny_model();
        let prompt: &[i32] = &[9, 1, 7, 4];
        for plan in [MaskPlan::EveryStep, MaskPlan::PruneOnce, MaskPlan::Refresh(2)] {
            let with_kv = decode_greedy(&m, prompt, &cfg(plan, 6), None);
            let without = decode_greedy(&m, prompt, &cfg_nokv(plan, 6), None);
            assert_outputs_identical(&plan.label(), &with_kv, &without);
        }
    }

    #[test]
    fn refresh_rebuilds_cache_bit_identically() {
        // Refresh(k)'s cache rebuild must reproduce the PR-2 (full
        // re-forward) semantics exactly: steps after a refresh see
        // layouts *and* K/V consistent with the refreshed selection
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let with_kv = decode_greedy(&m, prompt, &cfg(MaskPlan::Refresh(3), 9), None);
        let without = decode_greedy(&m, prompt, &cfg_nokv(MaskPlan::Refresh(3), 9), None);
        assert_outputs_identical("refresh:3 rebuild", &with_kv, &without);
        assert_eq!(with_kv.refresh_count, 3);
    }

    #[test]
    fn kv_decode_identical_across_window_slide() {
        // shrink the window so the generation slides it: every sliding
        // step must rebuild (absolute positions shift) and still match
        // the non-cached path bit for bit
        let mut mc = ModelConfig::new("dec-slide", 2, 2, 16);
        mc.max_seq_len = 6;
        let m = random_model(&mc, 43);
        let prompt: &[i32] = &[8, 6, 7, 5];
        for plan in [MaskPlan::PruneOnce, MaskPlan::Refresh(2)] {
            let with_kv = decode_greedy(&m, prompt, &cfg(plan, 8), None);
            let without = decode_greedy(&m, prompt, &cfg_nokv(plan, 8), None);
            assert_outputs_identical(&format!("slide {}", plan.label()), &with_kv, &without);
            assert!(with_kv.tokens.len() > mc.max_seq_len, "generation must slide");
        }
    }

    #[test]
    fn timing_split_partitions_step_time() {
        // every step's elapsed time lands in exactly one bucket, so the
        // two buckets must sum to the per-step total (timers on a tiny
        // debug-profile model may legitimately read 0µs, so the test is
        // structural, not threshold-based)
        let m = tiny_model();
        let out = decode_greedy(&m, &[2, 4, 6], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.refresh_count, 1);
        let total: u64 = out.steps.iter().map(|s| s.elapsed_us).sum();
        assert_eq!(out.prefill_us + out.step_us, total);
        // no-kv EveryStep: every step refreshes, so all work is
        // prefill-class and nothing may be classified as a reused step
        let every = decode_greedy(&m, &[2, 4, 6], &cfg_nokv(MaskPlan::EveryStep, 3), None);
        assert_eq!(every.step_us, 0);
        let total: u64 = every.steps.iter().map(|s| s.elapsed_us).sum();
        assert_eq!(every.prefill_us, total);
    }

    #[test]
    fn eos_id_comes_from_model_config() {
        // regression: EOS used to be the hard-coded constant; a checkpoint
        // with a different vocabulary must stop at *its* EOS. Same
        // weights, different configured eos_id ⇒ different stopping.
        let mc = ModelConfig::new("dec-eos", 2, 2, 16);
        assert_eq!(mc.eos_id, EOS_ID, "random-model default keeps the constant");
        let m = random_model(&mc, 41);
        // what this model actually emits in 3 unstopped steps
        let probe = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 3), None);
        let first = probe.steps[0].token;
        let unused = (0..mc.vocab_size as i32)
            .find(|t| !probe.steps.iter().any(|s| s.token == *t))
            .expect("some token is never emitted");
        let stopping = DecodeConfig {
            stop_at_eos: true,
            ..cfg(MaskPlan::PruneOnce, 3)
        };
        // same weights, but the config declares the first emission as EOS
        let mut mc_hit = mc.clone();
        mc_hit.eos_id = first;
        let out = decode_greedy(&random_model(&mc_hit, 41), &[1, 2, 3], &stopping, None);
        assert_eq!(out.steps.len(), 1, "must stop at the configured EOS");
        assert!(out.new_tokens().is_empty(), "EOS is not appended");
        // same weights, EOS set to a token never emitted: runs all steps
        let mut mc_miss = mc.clone();
        mc_miss.eos_id = unused;
        let out = decode_greedy(&random_model(&mc_miss, 41), &[1, 2, 3], &stopping, None);
        assert_eq!(out.steps.len(), 3);
        assert_eq!(out.tokens, probe.tokens);
    }

    #[test]
    fn prune_once_reuses_cache_across_identical_requests() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let mut cache = crate::tensor::LayoutCache::new(64);
        let cold = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(cold.cache_misses, n_linears);
        assert_eq!(cold.cache_hits, 0);
        let warm = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(warm.cache_misses, 0, "repeated prompt must not recompress");
        assert_eq!(warm.cache_hits, n_linears);
        assert_eq!(cold.tokens, warm.tokens);
    }

    #[test]
    fn window_slides_past_max_seq_len() {
        let m = tiny_model();
        let long: Vec<i32> = (0..m.cfg.max_seq_len as i32 + 5).map(|i| i % 250).collect();
        let out = decode_greedy(&m, &long, &cfg(MaskPlan::PruneOnce, 2), None);
        assert_eq!(out.new_tokens().len(), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_panics() {
        let m = tiny_model();
        decode_greedy(&m, &[], &cfg(MaskPlan::PruneOnce, 1), None);
    }

    // ---- decode_batch -----------------------------------------------------

    fn batch_item(prompt: &[i32], max_new: usize, plan: MaskPlan) -> BatchRequest<'_> {
        BatchRequest {
            prompt,
            max_new,
            plan,
        }
    }

    #[test]
    fn batch_matches_independent_greedy_mixed_max_new() {
        let m = tiny_model();
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9, 1, 7, 4], &[5, 6]];
        let plans = [MaskPlan::PruneOnce, MaskPlan::EveryStep, MaskPlan::Refresh(2)];
        let max_news = [4usize, 2, 5];
        let items: Vec<BatchRequest> = prompts
            .iter()
            .zip(plans)
            .zip(max_news)
            .map(|((&p, plan), max_new)| batch_item(p, max_new, plan))
            .collect();
        let mut cache = crate::tensor::LayoutCache::new(128);
        let batched = decode_batch(&m, &items, 0.5, false, true, Some(&mut cache));
        assert_eq!(batched.len(), 3);
        for (i, item) in items.iter().enumerate() {
            // reference lanes run without kv: the batch must match the
            // plain full-window semantics, not just its own code path
            let single = decode_greedy(
                &m,
                item.prompt,
                &DecodeConfig {
                    rho: 0.5,
                    plan: item.plan,
                    max_new: item.max_new,
                    stop_at_eos: false,
                    kv_cache: false,
                },
                None,
            );
            assert_eq!(batched[i].tokens, single.tokens, "lane {i} tokens");
            assert_eq!(batched[i].refresh_count, single.refresh_count, "lane {i}");
            assert_eq!(batched[i].steps.len(), single.steps.len(), "lane {i}");
            for (s, (a, b)) in batched[i].steps.iter().zip(&single.steps).enumerate() {
                assert_eq!(a.logits, b.logits, "lane {i} step {s} logits");
            }
        }
    }

    #[test]
    fn identical_batch_mates_share_compressed_layouts() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let prompt: &[i32] = &[9, 1, 7];
        let items = [
            batch_item(prompt, 3, MaskPlan::PruneOnce),
            batch_item(prompt, 3, MaskPlan::PruneOnce),
        ];
        let mut cache = crate::tensor::LayoutCache::new(64);
        let outs = decode_batch(&m, &items, 0.5, false, true, Some(&mut cache));
        // lane 0 compresses every linear once; lane 1's identical prompt
        // selection hits every one of those entries instead
        assert_eq!(outs[0].cache_misses, n_linears);
        assert_eq!(outs[1].cache_misses, 0, "batch-mate recompressed");
        assert_eq!(outs[1].cache_hits, n_linears);
        assert_eq!(outs[0].tokens, outs[1].tokens);
    }

    #[test]
    fn batch_eos_stop_mirrors_greedy() {
        // with stop_at_eos on, batch lanes must stop exactly where the
        // single-request engine stops
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let single = decode_greedy(
            &m,
            prompt,
            &DecodeConfig {
                rho: 0.6,
                plan: MaskPlan::PruneOnce,
                max_new: 6,
                stop_at_eos: true,
                kv_cache: true,
            },
            None,
        );
        let outs = decode_batch(
            &m,
            &[batch_item(prompt, 6, MaskPlan::PruneOnce)],
            0.6,
            true,
            true,
            None,
        );
        assert_eq!(outs[0].tokens, single.tokens);
        assert_eq!(outs[0].steps.len(), single.steps.len());
    }

    #[test]
    fn batch_kv_off_matches_kv_on() {
        let m = tiny_model();
        let items = [
            batch_item(&[1, 2, 3], 4, MaskPlan::PruneOnce),
            batch_item(&[7, 7], 3, MaskPlan::Refresh(2)),
        ];
        let on = decode_batch(&m, &items, 0.5, false, true, None);
        let off = decode_batch(&m, &items, 0.5, false, false, None);
        for (i, (a, b)) in on.iter().zip(&off).enumerate() {
            assert_outputs_identical(&format!("lane {i}"), a, b);
        }
    }

    #[test]
    fn empty_batch_and_zero_max_new() {
        let m = tiny_model();
        assert!(decode_batch(&m, &[], 0.5, false, true, None).is_empty());
        let items = [batch_item(&[1, 2], 0, MaskPlan::PruneOnce)];
        let outs = decode_batch(&m, &items, 0.5, false, true, None);
        assert_eq!(outs[0].new_tokens().len(), 0);
        assert_eq!(outs[0].steps.len(), 0);
        assert_eq!(outs[0].refresh_count, 0);
    }

    // ---- LanePool (continuous batching) -----------------------------------

    fn greedy_ref(m: &Model, prompt: &[i32], max_new: usize) -> DecodeOutput {
        decode_greedy(m, prompt, &cfg_nokv(MaskPlan::PruneOnce, max_new), None)
    }

    #[test]
    fn pool_admission_into_running_pool_matches_greedy() {
        // B is admitted while A is mid-generation; both must still equal
        // their independent decode_greedy outputs, and each lane's Token
        // events must concatenate to exactly its new_tokens()
        let m = tiny_model();
        let mut cache = crate::tensor::LayoutCache::new(64);
        let mut copt = Some(&mut cache);
        let mut pool = LanePool::new(1);
        let a_slot = pool.admit(&m, &[1, 2, 3], 3, MaskPlan::PruneOnce, true);
        assert_eq!(a_slot, 0);
        assert_eq!(pool.active(), 1);
        assert!(pool.free_slot().is_none());

        let mut outputs: Vec<(usize, DecodeOutput)> = Vec::new();
        let mut streamed: std::collections::HashMap<usize, Vec<i32>> = Default::default();
        let mut admitted_b = false;
        let mut guard = 0;
        while !pool.is_idle() || !admitted_b {
            if !admitted_b && pool.free_slot().is_some() {
                // the slot A finishes in is immediately reusable
                let b_slot = pool.admit(&m, &[9, 8], 2, MaskPlan::PruneOnce, true);
                assert_eq!(b_slot, 0, "freed lane must be reused");
                admitted_b = true;
            }
            for ev in pool.sweep(&m, 0.5, false, &mut copt) {
                match ev {
                    LaneEvent::Token { slot, index, token } => {
                        let toks = streamed.entry(slot).or_default();
                        assert_eq!(index, toks.len(), "indices must be dense");
                        toks.push(token);
                    }
                    LaneEvent::Done { slot, output } => outputs.push((slot, output)),
                }
            }
            guard += 1;
            assert!(guard < 20, "pool failed to drain");
        }
        assert_eq!(outputs.len(), 2);
        let a = greedy_ref(&m, &[1, 2, 3], 3);
        let b = greedy_ref(&m, &[9, 8], 2);
        assert_outputs_identical("lane A", &outputs[0].1, &a);
        assert_outputs_identical("lane B (admitted into running pool)", &outputs[1].1, &b);
        // the streamed tokens ARE the outputs (both rode slot 0 in turn,
        // so the stream interleaves; per Done-order they partition)
        let all_streamed = &streamed[&0];
        let concat: Vec<i32> = a
            .new_tokens()
            .iter()
            .chain(b.new_tokens())
            .copied()
            .collect();
        assert_eq!(*all_streamed, concat);
    }

    #[test]
    fn pool_evict_frees_lane_and_returns_partial_output() {
        let m = tiny_model();
        let mut pool = LanePool::new(1);
        pool.admit(&m, &[3, 1, 4], 6, MaskPlan::PruneOnce, true);
        let mut none = None;
        pool.sweep(&m, 0.5, false, &mut none);
        pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(pool.active(), 1, "6-step lane still mid-flight");
        let partial = pool.evict(0);
        assert_eq!(partial.steps.len(), 2, "two sweeps ran");
        // the partial prefix is exactly the full decode's prefix
        let full = greedy_ref(&m, &[3, 1, 4], 6);
        assert_eq!(partial.tokens[..], full.tokens[..partial.tokens.len()]);
        assert!(pool.is_idle(), "evict must free the lane");
        // the freed slot admits a newcomer that decodes untouched
        pool.admit(&m, &[9, 8], 2, MaskPlan::PruneOnce, true);
        let mut outs = Vec::new();
        while !pool.is_idle() {
            for ev in pool.sweep(&m, 0.5, false, &mut none) {
                if let LaneEvent::Done { output, .. } = ev {
                    outs.push(output);
                }
            }
        }
        assert_outputs_identical("post-evict newcomer", &outs[0], &greedy_ref(&m, &[9, 8], 2));
    }

    #[test]
    fn pool_zero_step_lane_finishes_without_stepping() {
        let m = tiny_model();
        let mut pool = LanePool::new(2);
        pool.admit(&m, &[1, 2], 0, MaskPlan::PruneOnce, true);
        let mut none = None;
        let events = pool.sweep(&m, 0.5, false, &mut none);
        assert_eq!(events.len(), 1);
        match &events[0] {
            LaneEvent::Done { slot, output } => {
                assert_eq!(*slot, 0);
                assert!(output.steps.is_empty());
                assert_eq!(output.refresh_count, 0);
            }
            other => panic!("expected Done, got {other:?}"),
        }
        assert!(pool.is_idle());
    }

    #[test]
    #[should_panic(expected = "full lane pool")]
    fn pool_admit_beyond_capacity_panics() {
        let m = tiny_model();
        let mut pool = LanePool::new(1);
        pool.admit(&m, &[1], 2, MaskPlan::PruneOnce, true);
        pool.admit(&m, &[2], 2, MaskPlan::PruneOnce, true);
    }
}
