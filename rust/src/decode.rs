//! Host-side autoregressive decode engine with mask-plan reuse.
//!
//! The μ-MoE serving question this module answers: *how often must the
//! micro-expert selection be refreshed while decoding?* Each refresh costs
//! a selection pass (a dense forward to collect activations plus Wanda
//! scoring per linear) and a recompression per linear; each reused step
//! costs only one sparse forward over the cached
//! [`crate::tensor::RowSparse`] layouts. [`MaskPlan`] names the policy:
//!
//! * `EveryStep` — re-select per token (adaptive baseline, no reuse);
//! * `PruneOnce` — select on the prompt, reuse for the whole generation;
//! * `Refresh(k)` — re-select every `k` tokens.
//!
//! Layout compression goes through an optional [`LayoutCache`], keyed by
//! `(model weights, linear, snapped-ρ level, mask fingerprint)`, so a
//! repeated prompt — or the unchanged selection of a `PruneOnce`
//! generation — skips recompression entirely. The cache is *transparent*: decoding with or
//! without it is bit-identical (`proptest.rs::decode_props` proves this).
//!
//! Quality cost of reuse is measured by
//! [`crate::eval::host::decode_drift`] and tracked by
//! `benches/decode_reuse.rs`.
//!
//! Two entry points share these semantics: [`decode_greedy`] (one
//! request, the reference implementation) and [`decode_batch`] (the
//! serving form: N requests at one snapped ρ through one shared cache,
//! per-request bit-identical to `decode_greedy` — this is what
//! `coordinator::engine::HostEngine` executes).

use crate::coordinator::request::argmax;
use crate::model::EOS_ID;
use crate::moe::{self, layouts_for};
use crate::nn::{FixedLayouts, Model};
use crate::pruning::MaskPlan;
use crate::tensor::LayoutCache;

/// Knobs of one greedy decode.
#[derive(Clone, Copy, Debug)]
pub struct DecodeConfig {
    /// Active-weight ratio for micro-expert selection.
    pub rho: f64,
    /// When to re-run selection (see [`MaskPlan`]).
    pub plan: MaskPlan,
    /// Maximum new tokens to generate.
    pub max_new: usize,
    /// Stop when the model emits EOS (off for benches so every plan
    /// generates exactly `max_new` steps).
    pub stop_at_eos: bool,
}

/// One decode step's observable state (drift analysis consumes the
/// logits; everything downstream of them is deterministic).
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Greedy-argmax token of this step.
    pub token: i32,
    /// Next-token logits at the last valid position (vocab-sized).
    pub logits: Vec<f32>,
    /// Whether this step re-ran micro-expert selection.
    pub refreshed: bool,
}

/// Result of one greedy decode.
#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// Prompt followed by generated tokens (EOS, if hit, is not appended).
    pub tokens: Vec<i32>,
    pub prompt_len: usize,
    /// Per-step traces, in generation order.
    pub steps: Vec<StepTrace>,
    /// How many steps re-ran selection (1 for `PruneOnce`, `steps.len()`
    /// for `EveryStep`).
    pub refresh_count: usize,
    /// Layout-cache hits/misses attributable to this decode (0/0 when no
    /// cache was supplied).
    pub cache_hits: u64,
    pub cache_misses: u64,
}

impl DecodeOutput {
    /// The generated suffix (without the prompt).
    pub fn new_tokens(&self) -> &[i32] {
        &self.tokens[self.prompt_len..]
    }
}

/// Greedy autoregressive decode under a mask plan.
///
/// Each step runs the model over a sliding window of the most recent
/// `max_seq_len` tokens. On refresh steps the current window's selection
/// is computed ([`moe::select_experts`]) and compressed to per-linear
/// layouts (through `cache` when given); all other steps reuse the held
/// layouts and pay only one fixed-selection sparse forward with a
/// last-row-only LM head ([`Model::forward_fixed_last`]).
pub fn decode_greedy(
    model: &Model,
    prompt: &[i32],
    cfg: &DecodeConfig,
    mut cache: Option<&mut LayoutCache>,
) -> DecodeOutput {
    assert!(!prompt.is_empty(), "decode needs a non-empty prompt");
    let seq = model.cfg.max_seq_len;
    let (hits0, misses0) = cache
        .as_deref()
        .map_or((0, 0), |c| (c.hits(), c.misses()));

    let mut tokens = prompt.to_vec();
    let mut steps: Vec<StepTrace> = Vec::with_capacity(cfg.max_new);
    let mut refresh_count = 0usize;
    let mut layouts = FixedLayouts::new();

    for step in 0..cfg.max_new {
        let start = tokens.len().saturating_sub(seq);
        let window = &tokens[start..];
        let valid = window.len();
        let refreshed = cfg.plan.refreshes_at(step);
        if refreshed {
            let sel = moe::select_experts(model, window, valid, cfg.rho);
            layouts = layouts_for(model, &sel, cache.as_deref_mut());
            refresh_count += 1;
        }
        let logits = model.forward_fixed_last(window, valid, &layouts);
        let token = argmax(&logits);
        steps.push(StepTrace {
            token,
            logits,
            refreshed,
        });
        if cfg.stop_at_eos && token == EOS_ID {
            break;
        }
        tokens.push(token);
    }

    let (hits1, misses1) = cache
        .as_deref()
        .map_or((0, 0), |c| (c.hits(), c.misses()));
    DecodeOutput {
        tokens,
        prompt_len: prompt.len(),
        steps,
        refresh_count,
        cache_hits: hits1 - hits0,
        cache_misses: misses1 - misses0,
    }
}

/// One request of a batched decode: its prompt and per-request knobs. The
/// batch-level invariant (one snapped ρ per batch) lives on the
/// [`decode_batch`] call instead.
#[derive(Clone, Copy, Debug)]
pub struct BatchRequest<'a> {
    pub prompt: &'a [i32],
    /// Maximum new tokens for this request (may differ across batch-mates).
    pub max_new: usize,
    /// Refresh policy for this request.
    pub plan: MaskPlan,
}

/// Per-lane state of a batched decode (one lane per [`BatchRequest`]).
struct Lane {
    tokens: Vec<i32>,
    prompt_len: usize,
    steps: Vec<StepTrace>,
    refresh_count: usize,
    layouts: FixedLayouts,
    cache_hits: u64,
    cache_misses: u64,
    done: bool,
}

/// Batched greedy decode: every request shares one snapped ρ (the
/// coordinator's batch key) and one [`LayoutCache`], so batch-mates whose
/// refresh steps select the same micro-experts share one set of
/// compressed [`crate::tensor::RowSparse`] layouts instead of each
/// recompressing. Per request, the result is **bit-identical** to an
/// independent [`decode_greedy`] call (`proptest.rs::decode_props` proves
/// this): the loop is step-major across lanes, but each lane's forwards
/// run in the same order, over the same windows, with the same kernels —
/// the batching only changes *when* work happens and *how often* layouts
/// are compressed, never what executes.
pub fn decode_batch(
    model: &Model,
    items: &[BatchRequest<'_>],
    rho: f64,
    stop_at_eos: bool,
    mut cache: Option<&mut LayoutCache>,
) -> Vec<DecodeOutput> {
    let seq = model.cfg.max_seq_len;
    let mut lanes: Vec<Lane> = items
        .iter()
        .map(|it| {
            assert!(!it.prompt.is_empty(), "decode needs a non-empty prompt");
            Lane {
                tokens: it.prompt.to_vec(),
                prompt_len: it.prompt.len(),
                steps: Vec::with_capacity(it.max_new),
                refresh_count: 0,
                layouts: FixedLayouts::new(),
                cache_hits: 0,
                cache_misses: 0,
                done: false,
            }
        })
        .collect();

    let max_steps = items.iter().map(|it| it.max_new).max().unwrap_or(0);
    for step in 0..max_steps {
        for (lane, item) in lanes.iter_mut().zip(items) {
            if lane.done || step >= item.max_new {
                continue;
            }
            let start = lane.tokens.len().saturating_sub(seq);
            let window = &lane.tokens[start..];
            let valid = window.len();
            let refreshed = item.plan.refreshes_at(step);
            if refreshed {
                let (h0, m0) = cache
                    .as_deref()
                    .map_or((0, 0), |c| (c.hits(), c.misses()));
                let sel = moe::select_experts(model, window, valid, rho);
                lane.layouts = layouts_for(model, &sel, cache.as_deref_mut());
                let (h1, m1) = cache
                    .as_deref()
                    .map_or((0, 0), |c| (c.hits(), c.misses()));
                lane.cache_hits += h1 - h0;
                lane.cache_misses += m1 - m0;
                lane.refresh_count += 1;
            }
            let logits = model.forward_fixed_last(window, valid, &lane.layouts);
            let token = argmax(&logits);
            lane.steps.push(StepTrace {
                token,
                logits,
                refreshed,
            });
            if stop_at_eos && token == EOS_ID {
                lane.done = true;
                continue;
            }
            lane.tokens.push(token);
        }
    }

    lanes
        .into_iter()
        .map(|lane| DecodeOutput {
            tokens: lane.tokens,
            prompt_len: lane.prompt_len,
            steps: lane.steps,
            refresh_count: lane.refresh_count,
            cache_hits: lane.cache_hits,
            cache_misses: lane.cache_misses,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;
    use crate::nn::random_model;

    fn tiny_model() -> Model {
        random_model(&ModelConfig::new("dec-tiny", 2, 2, 16), 41)
    }

    fn cfg(plan: MaskPlan, max_new: usize) -> DecodeConfig {
        DecodeConfig {
            rho: 0.5,
            plan,
            max_new,
            stop_at_eos: false,
        }
    }

    #[test]
    fn decode_extends_prompt_by_max_new() {
        let m = tiny_model();
        let out = decode_greedy(&m, &[1, 2, 3], &cfg(MaskPlan::PruneOnce, 5), None);
        assert_eq!(out.tokens.len(), 8);
        assert_eq!(out.new_tokens().len(), 5);
        assert_eq!(out.steps.len(), 5);
        for (s, &t) in out.steps.iter().zip(out.new_tokens()) {
            assert_eq!(s.token, t);
            assert_eq!(s.logits.len(), m.cfg.vocab_size);
        }
    }

    #[test]
    fn refresh_counts_follow_plan() {
        let m = tiny_model();
        let every = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::EveryStep, 4), None);
        assert_eq!(every.refresh_count, 4);
        assert!(every.steps.iter().all(|s| s.refreshed));
        let once = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::PruneOnce, 4), None);
        assert_eq!(once.refresh_count, 1);
        assert!(once.steps[0].refreshed);
        assert!(once.steps[1..].iter().all(|s| !s.refreshed));
        let periodic = decode_greedy(&m, &[5, 6], &cfg(MaskPlan::Refresh(2), 4), None);
        assert_eq!(periodic.refresh_count, 2);
    }

    #[test]
    fn prune_once_reuses_cache_across_identical_requests() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let mut cache = crate::tensor::LayoutCache::new(64);
        let cold = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(cold.cache_misses, n_linears);
        assert_eq!(cold.cache_hits, 0);
        let warm = decode_greedy(&m, &[9, 1, 7], &cfg(MaskPlan::PruneOnce, 3), Some(&mut cache));
        assert_eq!(warm.cache_misses, 0, "repeated prompt must not recompress");
        assert_eq!(warm.cache_hits, n_linears);
        assert_eq!(cold.tokens, warm.tokens);
    }

    #[test]
    fn window_slides_past_max_seq_len() {
        let m = tiny_model();
        let long: Vec<i32> = (0..m.cfg.max_seq_len as i32 + 5).map(|i| i % 250).collect();
        let out = decode_greedy(&m, &long, &cfg(MaskPlan::PruneOnce, 2), None);
        assert_eq!(out.new_tokens().len(), 2);
    }

    #[test]
    #[should_panic(expected = "non-empty prompt")]
    fn empty_prompt_panics() {
        let m = tiny_model();
        decode_greedy(&m, &[], &cfg(MaskPlan::PruneOnce, 1), None);
    }

    // ---- decode_batch -----------------------------------------------------

    fn batch_item(prompt: &[i32], max_new: usize, plan: MaskPlan) -> BatchRequest<'_> {
        BatchRequest {
            prompt,
            max_new,
            plan,
        }
    }

    #[test]
    fn batch_matches_independent_greedy_mixed_max_new() {
        let m = tiny_model();
        let prompts: [&[i32]; 3] = [&[1, 2, 3], &[9, 1, 7, 4], &[5, 6]];
        let plans = [MaskPlan::PruneOnce, MaskPlan::EveryStep, MaskPlan::Refresh(2)];
        let max_news = [4usize, 2, 5];
        let items: Vec<BatchRequest> = prompts
            .iter()
            .zip(plans)
            .zip(max_news)
            .map(|((&p, plan), max_new)| batch_item(p, max_new, plan))
            .collect();
        let mut cache = crate::tensor::LayoutCache::new(128);
        let batched = decode_batch(&m, &items, 0.5, false, Some(&mut cache));
        assert_eq!(batched.len(), 3);
        for (i, item) in items.iter().enumerate() {
            let single = decode_greedy(
                &m,
                item.prompt,
                &DecodeConfig {
                    rho: 0.5,
                    plan: item.plan,
                    max_new: item.max_new,
                    stop_at_eos: false,
                },
                None,
            );
            assert_eq!(batched[i].tokens, single.tokens, "lane {i} tokens");
            assert_eq!(batched[i].refresh_count, single.refresh_count, "lane {i}");
            assert_eq!(batched[i].steps.len(), single.steps.len(), "lane {i}");
            for (s, (a, b)) in batched[i].steps.iter().zip(&single.steps).enumerate() {
                assert_eq!(a.logits, b.logits, "lane {i} step {s} logits");
            }
        }
    }

    #[test]
    fn identical_batch_mates_share_compressed_layouts() {
        let m = tiny_model();
        let n_linears = m.cfg.linear_names().len() as u64;
        let prompt: &[i32] = &[9, 1, 7];
        let items = [
            batch_item(prompt, 3, MaskPlan::PruneOnce),
            batch_item(prompt, 3, MaskPlan::PruneOnce),
        ];
        let mut cache = crate::tensor::LayoutCache::new(64);
        let outs = decode_batch(&m, &items, 0.5, false, Some(&mut cache));
        // lane 0 compresses every linear once; lane 1's identical prompt
        // selection hits every one of those entries instead
        assert_eq!(outs[0].cache_misses, n_linears);
        assert_eq!(outs[1].cache_misses, 0, "batch-mate recompressed");
        assert_eq!(outs[1].cache_hits, n_linears);
        assert_eq!(outs[0].tokens, outs[1].tokens);
    }

    #[test]
    fn batch_eos_stop_mirrors_greedy() {
        // with stop_at_eos on, batch lanes must stop exactly where the
        // single-request engine stops
        let m = tiny_model();
        let prompt: &[i32] = &[3, 1, 4, 1, 5];
        let single = decode_greedy(
            &m,
            prompt,
            &DecodeConfig {
                rho: 0.6,
                plan: MaskPlan::PruneOnce,
                max_new: 6,
                stop_at_eos: true,
            },
            None,
        );
        let outs = decode_batch(
            &m,
            &[batch_item(prompt, 6, MaskPlan::PruneOnce)],
            0.6,
            true,
            None,
        );
        assert_eq!(outs[0].tokens, single.tokens);
        assert_eq!(outs[0].steps.len(), single.steps.len());
    }

    #[test]
    fn empty_batch_and_zero_max_new() {
        let m = tiny_model();
        assert!(decode_batch(&m, &[], 0.5, false, None).is_empty());
        let items = [batch_item(&[1, 2], 0, MaskPlan::PruneOnce)];
        let outs = decode_batch(&m, &items, 0.5, false, None);
        assert_eq!(outs[0].new_tokens().len(), 0);
        assert_eq!(outs[0].steps.len(), 0);
        assert_eq!(outs[0].refresh_count, 0);
    }
}
