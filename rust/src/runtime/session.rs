//! Session: one compiled artifact bound to resident device weights —
//! the unit the coordinator schedules batches onto.

use super::registry::{ArtifactMeta, Registry};
use super::weights::DeviceWeights;
use crate::util::error::{Error, ResultExt};
use std::sync::Arc;

/// A runtime input appended after the weight buffers.
#[derive(Clone, Debug)]
pub enum Input {
    I32(Vec<i32>, Vec<usize>),
    F32(Vec<f32>, Vec<usize>),
    ScalarF32(f32),
}

/// One executable + its weights, ready to run batches.
pub struct Session {
    pub meta: ArtifactMeta,
    exe: Arc<xla::PjRtLoadedExecutable>,
    weights: Arc<DeviceWeights>,
    client: super::Client,
}

impl Session {
    /// Bind `artifact` (by name) to uploaded weights. Validates that the
    /// weight set matches the artifact's parameter signature.
    pub fn bind(
        registry: &Registry,
        artifact: &str,
        weights: Arc<DeviceWeights>,
    ) -> Result<Session, Error> {
        let meta = registry.meta(artifact)?.clone();
        if meta.params != weights.param_names {
            return Err(Error::invariant(format!(
                "weight set ({} tensors) does not match artifact '{}' params \
                 ({} tensors)",
                weights.param_names.len(),
                artifact,
                meta.params.len()
            )));
        }
        let exe = registry.executable(artifact)?;
        Ok(Session {
            meta,
            exe,
            weights,
            client: registry.client().clone(),
        })
    }

    /// Execute with the given extra inputs; returns the flattened output
    /// tuple as literals.
    pub fn run(&self, extras: &[Input]) -> Result<Vec<xla::Literal>, Error> {
        if extras.len() != self.meta.extra_inputs.len() {
            return Err(Error::invariant(format!(
                "artifact '{}' wants {} extra inputs ({:?}), got {}",
                self.meta.name,
                self.meta.extra_inputs.len(),
                self.meta.extra_inputs,
                extras.len()
            )));
        }
        // upload extras (small: tokens/lengths/rho)
        let mut extra_bufs = Vec::with_capacity(extras.len());
        for (i, e) in extras.iter().enumerate() {
            let buf = match e {
                Input::I32(data, dims) => self.client.upload_i32(data, dims),
                Input::F32(data, dims) => self.client.upload_f32(data, dims),
                Input::ScalarF32(x) => self.client.upload_f32(&[*x], &[]),
            }
            .with_context(|| {
                format!("uploading extra input {i} for '{}'", self.meta.name)
            })?;
            extra_bufs.push(buf);
        }
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.weights.len() + extra_bufs.len());
        args.extend(self.weights.buffers().iter());
        args.extend(extra_bufs.iter());

        let outs = self
            .exe
            .execute_b(&args)
            .with_context(|| format!("executing '{}'", self.meta.name))?;
        let lit = outs
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| Error::invariant("no output buffer"))?
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unpack the tuple
        let parts = lit.to_tuple()?;
        if parts.len() != self.meta.outputs {
            return Err(Error::invariant(format!(
                "artifact '{}' returned {} outputs, manifest says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs
            )));
        }
        Ok(parts)
    }

    pub fn weights(&self) -> &Arc<DeviceWeights> {
        &self.weights
    }
}

/// Decode helpers for artifact outputs.
pub fn literal_f32(lit: &xla::Literal) -> Result<Vec<f32>, Error> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn literal_i32(lit: &xla::Literal) -> Result<Vec<i32>, Error> {
    Ok(lit.to_vec::<i32>()?)
}
