//! Artifact registry: parses `artifacts/manifest.json` (written by
//! python/compile/aot.py) and lazily compiles executables on first use.

use super::Client;
use crate::util::error::{Error, ResultExt};
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Static metadata for one AOT artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    pub model: String,
    /// Ordered parameter-tensor names fed as leading inputs.
    pub params: Vec<String>,
    /// Names of the trailing runtime inputs (tokens, lengths, rho, ...).
    pub extra_inputs: Vec<String>,
    pub batch: usize,
    pub seq_len: usize,
    pub outputs: usize,
    /// For calib_stats artifacts: linear names in output order.
    pub linears: Vec<String>,
}

/// The registry: manifest metadata + executable cache + model configs.
pub struct Registry {
    pub dir: PathBuf,
    artifacts: HashMap<String, ArtifactMeta>,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    client: Client,
}

impl Registry {
    /// Load `<dir>/manifest.json` and bind to a PJRT client.
    pub fn open(dir: &Path, client: Client) -> Result<Registry, Error> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let json = Json::parse(&text).context("parsing manifest.json")?;
        let mut artifacts = HashMap::new();
        for a in json
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| Error::parse("manifest artifacts not an array"))?
        {
            let name = a
                .req("name")?
                .as_str()
                .ok_or_else(|| Error::parse("artifact name"))?
                .to_string();
            let meta = ArtifactMeta {
                name: name.clone(),
                path: dir.join(
                    a.req("path")?
                        .as_str()
                        .ok_or_else(|| Error::parse("artifact path"))?,
                ),
                kind: a
                    .req("kind")?
                    .as_str()
                    .ok_or_else(|| Error::parse("artifact kind"))?
                    .to_string(),
                model: a
                    .req("model")?
                    .as_str()
                    .ok_or_else(|| Error::parse("artifact model"))?
                    .to_string(),
                params: a
                    .req("params")?
                    .str_arr()
                    .ok_or_else(|| Error::parse("artifact params"))?,
                extra_inputs: a
                    .get("extra_inputs")
                    .and_then(Json::str_arr)
                    .unwrap_or_default(),
                batch: a.req("batch")?.as_usize().unwrap_or(0),
                seq_len: a.req("seq_len")?.as_usize().unwrap_or(0),
                outputs: a.req("outputs")?.as_usize().unwrap_or(1),
                linears: a.get("linears").and_then(Json::str_arr).unwrap_or_default(),
            };
            artifacts.insert(name, meta);
        }
        crate::info!(
            "registry: {} artifacts from {}",
            artifacts.len(),
            dir.display()
        );
        Ok(Registry {
            dir: dir.to_path_buf(),
            artifacts,
            cache: Mutex::new(HashMap::new()),
            client,
        })
    }

    pub fn client(&self) -> &Client {
        &self.client
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(String::as_str).collect()
    }

    pub fn meta(&self, name: &str) -> Result<&ArtifactMeta, Error> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::config(format!("unknown artifact '{name}'")))
    }

    /// Find the artifact of a kind for a model (e.g. "mumoe_nll").
    pub fn meta_for(&self, kind: &str, model: &str) -> Result<&ArtifactMeta, Error> {
        self.artifacts
            .values()
            .find(|a| a.kind == kind && a.model == model)
            .ok_or_else(|| {
                Error::config(format!("no artifact kind={kind} model={model}"))
            })
    }

    /// Compile (or fetch cached) an executable.
    pub fn executable(
        &self,
        name: &str,
    ) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>, Error> {
        {
            let cache = self.cache.lock().expect("registry cache poisoned");
            if let Some(e) = cache.get(name) {
                return Ok(e.clone());
            }
        }
        let meta = self.meta(name)?;
        let t0 = std::time::Instant::now();
        let exe = self
            .client
            .compile_hlo_file(&meta.path)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        crate::info!(
            "compiled {name} in {:.1}s",
            t0.elapsed().as_secs_f64()
        );
        let exe = std::sync::Arc::new(exe);
        self.cache
            .lock()
            .expect("registry cache poisoned")
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Path to a data file under the artifact dir.
    pub fn data_path(&self, file: &str) -> PathBuf {
        self.dir.join("data").join(file)
    }

    /// Path to a checkpoint under the artifact dir.
    pub fn ckpt_path(&self, model: &str) -> PathBuf {
        self.dir.join("ckpt").join(format!("{model}.ckpt"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Registry parsing is unit-tested on a synthetic manifest; executing
    // real artifacts is covered by tests/runtime_oracle.rs (integration).
    fn fake_manifest_dir() -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("mumoe-registry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version":1,"artifacts":[
                {"name":"dense_nll_x","path":"hlo/dense_nll_x.hlo.txt",
                 "kind":"dense_nll","model":"x","params":["tok_emb"],
                 "extra_inputs":["tokens","lengths"],
                 "batch":8,"seq_len":128,"outputs":2}]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = fake_manifest_dir();
        let client = Client::cpu().unwrap();
        let reg = Registry::open(&dir, client).unwrap();
        let m = reg.meta("dense_nll_x").unwrap();
        assert_eq!(m.kind, "dense_nll");
        assert_eq!(m.batch, 8);
        assert_eq!(m.params, vec!["tok_emb"]);
        assert!(reg.meta("nope").is_err());
        assert_eq!(reg.meta_for("dense_nll", "x").unwrap().name, "dense_nll_x");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
