//! PJRT runtime: load AOT HLO-text artifacts and execute them with model
//! weights held resident as device buffers.
//!
//! Flow (see /opt/xla-example/load_hlo for the minimal pattern):
//! ```text
//! manifest.json ──> Registry (artifact metadata, lazy executable cache)
//! *.hlo.txt     ──> HloModuleProto::from_text_file -> compile (once)
//! *.ckpt        ──> WeightStore (host + device-buffer copies, upload once)
//! Session::run(tokens, lengths, rho) -> outputs (Literals -> Vec<f32>)
//! ```
//!
//! HLO *text* is the interchange format: jax ≥ 0.5 emits 64-bit instruction
//! ids in serialized protos which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see python/compile/aot.py docstring).

pub mod registry;
pub mod session;
pub mod weights;

use crate::util::error::Error;
use std::sync::Arc;

/// Shared PJRT CPU client. One per process; cheap to clone (Arc inside the
/// xla crate's wrapper is not public, so we wrap in our own Arc).
#[derive(Clone)]
pub struct Client {
    inner: Arc<xla::PjRtClient>,
}

impl Client {
    pub fn cpu() -> Result<Client, Error> {
        let inner = xla::PjRtClient::cpu()?;
        crate::info!(
            "PJRT client up: platform={} devices={}",
            inner.platform_name(),
            inner.device_count()
        );
        Ok(Client {
            inner: Arc::new(inner),
        })
    }

    pub fn raw(&self) -> &xla::PjRtClient {
        &self.inner
    }

    /// Compile HLO text from a file into a loaded executable.
    pub fn compile_hlo_file(
        &self,
        path: &std::path::Path,
    ) -> Result<xla::PjRtLoadedExecutable, Error> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::config("non-utf8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.inner.compile(&comp)?)
    }

    /// Upload a host f32 tensor as a device buffer.
    pub fn upload_f32(
        &self,
        data: &[f32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer, Error> {
        Ok(self
            .inner
            .buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a host i32 tensor as a device buffer.
    pub fn upload_i32(
        &self,
        data: &[i32],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer, Error> {
        Ok(self
            .inner
            .buffer_from_host_buffer(data, dims, None)?)
    }
}
