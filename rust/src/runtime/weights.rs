//! Weight store: host checkpoint tensors mirrored as device buffers,
//! uploaded once per (model, variant) and reused across every request —
//! weights never cross the host/device boundary on the hot path.
//!
//! Offline-pruned variants (magnitude / Wanda / SparseGPT) are host-side
//! weight edits followed by a fresh `upload`, served through the *dense*
//! artifact; μ-MoE needs no variant at all (pruning happens in-graph).

use super::Client;
use crate::model::checkpoint::{Checkpoint, TensorEntry};
use crate::util::error::{Error, ResultExt};

/// One uploaded weight set, ready to splice into `execute_b` calls.
pub struct DeviceWeights {
    /// Buffers in artifact parameter order.
    buffers: Vec<xla::PjRtBuffer>,
    pub param_names: Vec<String>,
    pub total_params: usize,
}

impl DeviceWeights {
    /// Upload `ckpt` tensors in `param_order` to the device.
    pub fn upload(
        client: &Client,
        ckpt: &Checkpoint,
        param_order: &[String],
    ) -> Result<DeviceWeights, Error> {
        let mut buffers = Vec::with_capacity(param_order.len());
        let mut total = 0usize;
        for name in param_order {
            let t = ckpt.get(name)?;
            total += t.numel();
            buffers.push(
                client
                    .upload_f32(&t.data, &t.dims)
                    .with_context(|| format!("uploading '{name}'"))?,
            );
        }
        Ok(DeviceWeights {
            buffers,
            param_names: param_order.to_vec(),
            total_params: total,
        })
    }

    pub fn buffers(&self) -> &[xla::PjRtBuffer] {
        &self.buffers
    }

    pub fn len(&self) -> usize {
        self.buffers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buffers.is_empty()
    }
}

/// Host-side weight editing for the offline-pruned baselines.
pub struct VariantBuilder {
    pub base: Checkpoint,
}

impl VariantBuilder {
    pub fn new(base: Checkpoint) -> Self {
        Self { base }
    }

    /// Produce a checkpoint with `edit` applied to each named 2-D weight.
    pub fn with_edits(
        &self,
        names: &[String],
        mut edit: impl FnMut(&str, &TensorEntry) -> Result<TensorEntry, Error>,
    ) -> Result<Checkpoint, Error> {
        let mut out = self.base.clone();
        for n in names {
            let t = out.get(n)?.clone();
            let new = edit(n, &t)?;
            if new.dims != t.dims {
                return Err(Error::invariant(format!(
                    "edit changed shape of '{n}'"
                )));
            }
            out.tensors.insert(n.clone(), new);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ckpt() -> Checkpoint {
        let mut c = Checkpoint::default();
        c.tensors.insert(
            "w".into(),
            TensorEntry {
                dims: vec![2, 2],
                data: vec![1.0, -2.0, 3.0, -4.0],
            },
        );
        c.tensors.insert(
            "b".into(),
            TensorEntry {
                dims: vec![2],
                data: vec![0.5, 0.5],
            },
        );
        c
    }

    #[test]
    fn upload_roundtrip_via_execute() {
        // identity executable isn't available standalone; assert the
        // upload path produces buffers with the right count/shape instead.
        let client = Client::cpu().unwrap();
        let dw = DeviceWeights::upload(
            &client,
            &ckpt(),
            &["w".to_string(), "b".to_string()],
        )
        .unwrap();
        assert_eq!(dw.len(), 2);
        assert_eq!(dw.total_params, 6);
        let shape = dw.buffers()[0].on_device_shape().unwrap();
        let dims = match shape {
            xla::Shape::Array(a) => a.dims().to_vec(),
            _ => vec![],
        };
        assert_eq!(dims, vec![2i64, 2]);
    }

    #[test]
    fn upload_missing_tensor_errors() {
        let client = Client::cpu().unwrap();
        assert!(
            DeviceWeights::upload(&client, &ckpt(), &["nope".to_string()]).is_err()
        );
    }

    #[test]
    fn variant_builder_edits() {
        let vb = VariantBuilder::new(ckpt());
        let out = vb
            .with_edits(&["w".to_string()], |_, t| {
                let mut t2 = t.clone();
                for x in &mut t2.data {
                    if x.abs() < 2.5 {
                        *x = 0.0;
                    }
                }
                Ok(t2)
            })
            .unwrap();
        assert_eq!(out.tensors["w"].data, vec![0.0, 0.0, 3.0, -4.0]);
        // base untouched
        assert_eq!(vb.base.tensors["w"].data[0], 1.0);
    }

    #[test]
    fn variant_builder_rejects_shape_change() {
        let vb = VariantBuilder::new(ckpt());
        let r = vb.with_edits(&["w".to_string()], |_, t| {
            Ok(TensorEntry {
                dims: vec![4],
                data: t.data.clone(),
            })
        });
        assert!(r.is_err());
    }
}
