//! Benchmark harness (criterion substitute): warmup, adaptive iteration
//! count, robust statistics, and the table renderer every `benches/*` file
//! uses to print paper tables/figures.

use std::time::{Duration, Instant};

/// Statistics over one benchmark run.
#[derive(Clone, Debug)]
pub struct Stats {
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub stddev_ns: f64,
}

impl Stats {
    pub fn from_samples(mut ns: Vec<f64>) -> Stats {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Stats {
            iters: n,
            mean_ns: mean,
            median_ns: ns[n / 2],
            p95_ns: ns[((n as f64 * 0.95) as usize).min(n - 1)],
            min_ns: ns[0],
            stddev_ns: var.sqrt(),
        }
    }

    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
}

/// Benchmark runner with a time budget per case.
pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub min_iters: usize,
    pub max_iters: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(100),
            budget: Duration::from_millis(800),
            min_iters: 5,
            max_iters: 10_000,
        }
    }
}

impl Bencher {
    /// Quick-profile bencher for slow end-to-end cases.
    pub fn coarse() -> Self {
        Self {
            warmup: Duration::from_millis(0),
            budget: Duration::from_millis(200),
            min_iters: 2,
            max_iters: 50,
        }
    }

    /// Run `f` repeatedly, returning timing stats. The closure's return
    /// value is black-boxed to stop the optimizer deleting the work.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        // warmup
        let w0 = Instant::now();
        while w0.elapsed() < self.warmup {
            black_box(f());
        }
        let mut samples = Vec::new();
        let b0 = Instant::now();
        while (b0.elapsed() < self.budget || samples.len() < self.min_iters)
            && samples.len() < self.max_iters
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_nanos() as f64);
        }
        Stats::from_samples(samples)
    }
}

/// Optimizer barrier (std::hint::black_box exists on this toolchain but we
/// keep a local alias so benches read uniformly).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.into(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "ragged table row");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = format!("\n## {}\n\n", self.title);
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:>w$} |", c, w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with sensible precision for perplexity-style tables.
pub fn fmt_f(x: f64) -> String {
    if x >= 1000.0 {
        format!("{x:.0}")
    } else if x >= 100.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.2}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_samples() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0, 100.0]);
        assert_eq!(s.iters, 5);
        assert_eq!(s.median_ns, 3.0);
        assert_eq!(s.min_ns, 1.0);
        assert!((s.mean_ns - 22.0).abs() < 1e-9);
    }

    #[test]
    fn bencher_measures_work() {
        let b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(20),
            min_iters: 3,
            max_iters: 1000,
        };
        let s = b.run(|| {
            let mut acc = 0u64;
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(s.iters >= 3);
        assert!(s.mean_ns > 0.0);
        assert!(s.min_ns <= s.median_ns && s.median_ns <= s.p95_ns);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["method", "ppl"]);
        t.row(vec!["wanda".into(), "30.12".into()]);
        t.row(vec!["mu-moe".into(), "28.90".into()]);
        let r = t.render();
        assert!(r.contains("## Demo"));
        assert!(r.contains("| method |"));
        assert!(r.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_precision() {
        assert_eq!(fmt_f(1234.6), "1235");
        assert_eq!(fmt_f(123.45), "123.5");
        assert_eq!(fmt_f(12.345), "12.35");
    }
}
