//! Command-line argument parser (clap substitute).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, positional
//! args, typed accessors with defaults, and auto-generated usage text.

use crate::util::error::Error;
use std::collections::HashMap;

/// Declarative spec for one option.
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    pub cmd: String,
    values: HashMap<String, String>,
    flags: Vec<String>,
    /// Option names the user actually typed (either spelling), as opposed
    /// to spec defaults — lets config-file merging distinguish "explicit
    /// override" from "untouched default".
    given: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) against a spec.
    pub fn parse(
        argv: &[String],
        spec: &[OptSpec],
    ) -> Result<Args, Error> {
        let mut out = Args::default();
        for s in spec {
            if let (Some(d), false) = (s.default, s.is_flag) {
                out.values.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let s = spec
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| Error::config(format!("unknown option --{key}")))?;
                if s.is_flag {
                    if inline_val.is_some() {
                        return Err(Error::config(format!(
                            "flag --{key} takes no value"
                        )));
                    }
                    out.given.push(key.clone());
                    out.flags.push(key);
                } else {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| {
                                Error::config(format!("--{key} needs a value"))
                            })?
                            .clone(),
                    };
                    out.given.push(key.clone());
                    out.values.insert(key, v);
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Was this option explicitly provided (either `--name value` or
    /// `--name=value`), rather than filled from its spec default?
    pub fn given(&self, name: &str) -> bool {
        self.given.iter().any(|g| g == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn req(&self, name: &str) -> Result<&str, Error> {
        self.get(name)
            .ok_or_else(|| Error::config(format!("missing required --{name}")))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, Error> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, Error> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, Error> {
        self.req(name)?
            .parse()
            .map_err(|_| Error::config(format!("--{name} must be a number")))
    }

    /// Comma-separated f64 list (e.g. `--rhos 0.4,0.5,0.6`).
    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>, Error> {
        self.req(name)?
            .split(',')
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| Error::config(format!("bad number in --{name}")))
            })
            .collect()
    }

    pub fn get_str_list(&self, name: &str) -> Result<Vec<String>, Error> {
        Ok(self
            .req(name)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

/// Render usage text for a subcommand.
pub fn usage(cmd: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut s = format!("{cmd} — {about}\n\noptions:\n");
    for o in spec {
        let head = if o.is_flag {
            format!("  --{}", o.name)
        } else {
            format!("  --{} <v>", o.name)
        };
        let def = o
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("{head:26} {}{def}\n", o.help));
    }
    s
}

pub const fn opt(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: Some(default),
        is_flag: false,
    }
}

pub const fn req_opt(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: false,
    }
}

pub const fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        default: None,
        is_flag: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    const SPEC: &[OptSpec] = &[
        opt("model", "model name", "mu-opt-micro"),
        req_opt("rho", "active ratio"),
        flag("verbose", "chatty"),
    ];

    #[test]
    fn defaults_and_values() {
        let a = Args::parse(&sv(&["--rho", "0.5"]), SPEC).unwrap();
        assert_eq!(a.get("model"), Some("mu-opt-micro"));
        assert_eq!(a.get_f64("rho").unwrap(), 0.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn given_tracks_explicit_options_in_both_spellings() {
        let a = Args::parse(&sv(&["--rho", "0.5", "--model=mu-opt-mini"]), SPEC).unwrap();
        assert!(a.given("rho"), "space spelling");
        assert!(a.given("model"), "equals spelling");
        assert!(!a.given("verbose"), "untyped flag is not given");
        // defaulted option has a value but was never given
        let b = Args::parse(&sv(&["--rho", "0.5"]), SPEC).unwrap();
        assert_eq!(b.get("model"), Some("mu-opt-micro"));
        assert!(!b.given("model"));
        assert!(Args::parse(&sv(&["--verbose", "--rho", "1"]), SPEC)
            .unwrap()
            .given("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = Args::parse(&sv(&["--rho=0.4", "--verbose", "pos1"]), SPEC).unwrap();
        assert_eq!(a.get_f64("rho").unwrap(), 0.4);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(Args::parse(&sv(&["--nope", "1"]), SPEC).is_err());
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&sv(&[]), SPEC).unwrap();
        assert!(a.req("rho").is_err());
    }

    #[test]
    fn lists() {
        let spec = &[opt("rhos", "list", "0.4,0.5")];
        let a = Args::parse(&sv(&[]), spec).unwrap();
        assert_eq!(a.get_f64_list("rhos").unwrap(), vec![0.4, 0.5]);
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(Args::parse(&sv(&["--verbose=1", "--rho", "1"]), SPEC).is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = usage("eval", "run eval", SPEC);
        assert!(u.contains("--model"));
        assert!(u.contains("default: mu-opt-micro"));
    }
}
