//! Cross-request KV reuse: a prefix-keyed KV store plus a session registry.
//!
//! Chat-style traffic resends long shared prefixes (system prompts,
//! few-shot preambles, conversation history), yet a lane's
//! [`crate::nn::kv::KvCache`] dies with its request and every admission
//! pays a full O(T²) prefill. This module is the layer between decode and
//! the coordinator that keeps prefix K/V alive across requests:
//!
//! - [`KvStore`]: a shared, token-budget LRU map from
//!   `(weights-id, token-prefix FNV hash + length, layout chain)` to cloned
//!   per-layer K/V rows for absolute positions `0..n`. Admission consults
//!   it; a hit seeds the lane's cache and only the suffix is prefilled.
//! - [`SessionRegistry`]: named parking spots so a multi-turn client can
//!   continue a finished lane's cache (and its pinned layouts) with zero
//!   prefix prefill, guarded by a generation counter so deleting or
//!   re-creating a session can never let a stale mid-flight lane resurrect
//!   freed state.
//!
//! ## Keying discipline
//!
//! μ-MoE selects micro-experts per prompt, so cached K/V is only reusable
//! when the *layouts that produced it* match — the same
//! calibration-dependence insight behind [`crate::tensor::LayoutCache`]
//! applies to cached activations. A key therefore binds three things:
//!
//! 1. `weights`: [`crate::nn::Model::weights_id`] — two same-architecture
//!    models must never share rows.
//! 2. the token prefix: FNV-1a hash *and* exact length; the entry also
//!    stores the tokens themselves so a lookup verifies them and a hash
//!    collision can never seed a lane with another prompt's cache.
//! 3. [`layout_chain`]: FNV over each prunable linear's
//!    [`RowSparse::fingerprint`] content hash in `linear_names()` order —
//!    content, not `Arc` identity, so independently rebuilt but identical
//!    layouts still hit.
//!
//! ## Exactness
//!
//! Under the model's absolute position embeddings, K/V rows for window
//! positions `0..n` depend only on the tokens at `0..n` and the layouts —
//! so seeding a fresh cache with a matching prefix and stepping the suffix
//! is bit-identical to a full prefill (`forward_step` ≡ full-window
//! forward is proven in `nn`; `proptest.rs::kvstore_props` proves the
//! composition at the decode level). Seeding only applies to windows that
//! start at absolute position 0; slid windows rebuild as before.

use crate::nn::FixedLayouts;
use crate::tensor::fnv1a64;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Incremental FNV-1a prefix hashes: `out[n]` is the hash of `tokens[..n]`
/// under the same byte stream [`fnv1a64`] consumes, i.e.
/// `out[n] == fnv1a64(tokens[..n].iter().map(|&t| t as u64))`. One O(T)
/// pass gives a lookup every probe length for free.
pub fn prefix_hashes(tokens: &[i32]) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() + 1);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    out.push(h);
    for &t in tokens {
        for b in (t as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        out.push(h);
    }
    out
}

/// FNV over each linear's [`crate::tensor::RowSparse::fingerprint`] in the
/// caller-supplied (canonical `linear_names()`) order. Content hashes, not
/// `Arc` pointers: two lanes that rebuilt byte-identical layouts chain
/// equal, which is what makes store hits possible across requests. `None`
/// when a linear is missing from the map (never the case for layouts
/// produced by `moe::layouts_for`).
pub fn layout_chain(linear_names: &[String], layouts: &FixedLayouts) -> Option<u64> {
    let mut fps = Vec::with_capacity(linear_names.len());
    for name in linear_names {
        fps.push(layouts.get(name)?.fingerprint());
    }
    Some(fnv1a64(fps))
}

/// Store key: which weights, which exact token prefix (hash + length), and
/// which per-linear layout chain produced the rows.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PrefixKey {
    pub weights: u64,
    pub prefix_hash: u64,
    pub prefix_len: usize,
    pub layout_chain: u64,
}

/// One cached prefix: the exact tokens it covers and cloned per-layer K/V
/// rows for absolute positions `0..len`. Entries are immutable once
/// published and shared out as `Arc`, so a hit costs one refcount bump and
/// the row copy into the lane's private cache.
#[derive(Clone, Debug, PartialEq)]
pub struct KvEntry {
    /// The exact prefix tokens — re-verified on every lookup so an FNV
    /// collision can never seed a lane with another prompt's rows.
    pub tokens: Vec<i32>,
    /// Per-layer K rows, each `len * d_model` long (row `t` at
    /// `t * d_model ..`).
    pub k: Vec<Vec<f32>>,
    /// Per-layer V rows, parallel to `k`.
    pub v: Vec<Vec<f32>>,
    pub d_model: usize,
}

impl KvEntry {
    /// Number of cached positions (tokens).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }
}

struct StoreInner {
    entries: HashMap<PrefixKey, (Arc<KvEntry>, u64)>,
    /// Published prefix lengths per `(weights, layout_chain)`:
    /// `length → resident entries of that length`. Lookups probe only
    /// these lengths (longest first) instead of every `T..1`, so a
    /// two-entry store costs two probes however long the window is.
    lengths: HashMap<(u64, u64), BTreeMap<usize, u32>>,
    tick: u64,
    resident_tokens: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

impl StoreInner {
    fn index_insert(&mut self, weights: u64, chain: u64, len: usize) {
        *self
            .lengths
            .entry((weights, chain))
            .or_default()
            .entry(len)
            .or_insert(0) += 1;
    }

    fn index_remove(&mut self, weights: u64, chain: u64, len: usize) {
        if let Some(m) = self.lengths.get_mut(&(weights, chain)) {
            if let Some(c) = m.get_mut(&len) {
                *c -= 1;
                if *c == 0 {
                    m.remove(&len);
                }
            }
            if m.is_empty() {
                self.lengths.remove(&(weights, chain));
            }
        }
    }
}

/// Shared, capacity-bounded prefix-keyed KV store. The budget is in
/// *tokens* (summed entry lengths), not entries — one 4k-token system
/// prompt costs what 64 short prefixes cost. Eviction is
/// least-recently-used by lookup/publish recency. Internally synchronized;
/// share as `Arc<KvStore>`.
pub struct KvStore {
    token_budget: usize,
    inner: Mutex<StoreInner>,
}

impl KvStore {
    pub fn new(token_budget: usize) -> KvStore {
        assert!(token_budget > 0, "kv store token budget must be > 0");
        KvStore {
            token_budget,
            inner: Mutex::new(StoreInner {
                entries: HashMap::new(),
                lengths: HashMap::new(),
                tick: 0,
                resident_tokens: 0,
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
            }),
        }
    }

    pub fn token_budget(&self) -> usize {
        self.token_budget
    }

    /// Longest cached prefix of `window` under (`weights`, `chain`).
    /// Probes only the prefix lengths actually published for this
    /// (`weights`, `chain`) pair — longest first, via the store's length
    /// index — and verifies the stored tokens on a hash match. Returns the
    /// entry and its matched length `n ≤ window.len()` — callers seeding a
    /// decode cache clamp the seeded rows to `window.len() - 1` so at
    /// least one token remains to step for logits. Counts exactly one hit
    /// or one miss per call.
    pub fn lookup(&self, weights: u64, chain: u64, window: &[i32]) -> Option<(Arc<KvEntry>, usize)> {
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        let candidates: Vec<usize> = match g.lengths.get(&(weights, chain)) {
            Some(m) => m.range(1..=window.len()).rev().map(|(&n, _)| n).collect(),
            None => Vec::new(),
        };
        if candidates.is_empty() {
            g.misses += 1;
            return None;
        }
        let hashes = prefix_hashes(window);
        for n in candidates {
            let key = PrefixKey {
                weights,
                prefix_hash: hashes[n],
                prefix_len: n,
                layout_chain: chain,
            };
            if let Some((arc, t)) = g.entries.get_mut(&key) {
                if arc.tokens[..] == window[..n] {
                    *t = tick;
                    let found = arc.clone();
                    g.hits += 1;
                    return Some((found, n));
                }
            }
        }
        g.misses += 1;
        None
    }

    /// Insert a freshly prefilled prefix, evicting least-recently-used
    /// entries until the resident-token total fits the budget. An entry
    /// larger than the whole budget is dropped rather than flushing the
    /// store for a row set nothing else can share space with. Re-publishing
    /// an existing key verifies the resident tokens first: equal tokens
    /// only refresh recency, while a mismatch (a hash collision parked a
    /// foreign prefix under this key) replaces the resident entry so the
    /// fresh rows win — collisions must never serve another prompt's rows.
    pub fn publish(&self, weights: u64, chain: u64, entry: KvEntry) {
        if entry.is_empty() || entry.len() > self.token_budget {
            return;
        }
        let key = PrefixKey {
            weights,
            prefix_hash: fnv1a64(entry.tokens.iter().map(|&t| t as u64)),
            prefix_len: entry.len(),
            layout_chain: chain,
        };
        self.publish_keyed(key, entry);
    }

    /// Core of [`publish`], operating on a pre-built key. Split out so the
    /// collision regression test can hand-forge a key whose hash does not
    /// match its tokens (real 64-bit FNV-1a collisions are impractical to
    /// construct from token streams).
    fn publish_keyed(&self, key: PrefixKey, entry: KvEntry) {
        let weights = key.weights;
        let chain = key.layout_chain;
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(slot) = g.entries.get_mut(&key) {
            if slot.0.tokens == entry.tokens {
                slot.1 = tick;
                return;
            }
            // Collision: same key, different prefix. Replace in place —
            // the key pins prefix_len, so the token lengths are equal and
            // neither resident_tokens nor the length index moves.
            *slot = (Arc::new(entry), tick);
            g.insertions += 1;
            return;
        }
        g.resident_tokens += entry.len();
        g.insertions += 1;
        g.index_insert(weights, chain, entry.len());
        g.entries.insert(key, (Arc::new(entry), tick));
        while g.resident_tokens > self.token_budget {
            let victim = g
                .entries
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            if let Some((e, _)) = g.entries.remove(&k) {
                g.resident_tokens -= e.len();
                g.evictions += 1;
                g.index_remove(k.weights, k.layout_chain, e.len());
            }
        }
    }

    /// Resident entry count (a `/metrics` gauge).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Summed token length of resident entries (the LRU budget's unit).
    pub fn resident_tokens(&self) -> usize {
        self.inner.lock().unwrap().resident_tokens
    }

    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    pub fn insertions(&self) -> u64 {
        self.inner.lock().unwrap().insertions
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

/// A finished (or cancelled-with-partials) lane's continuable state: the
/// final decode window, the snapped ρ it ran at, the layouts active when
/// it parked, and the cached rows. A continuation *pins* `layouts` — it
/// skips every refresh and decodes the concatenated window under exactly
/// these layouts, which is what makes continuation bit-exact against a
/// fixed-layout reference decode (`kvstore_props` seed series 503).
#[derive(Clone, Debug)]
pub struct SessionState {
    /// The full final window (post-slide) — the continuation's prompt is
    /// `tokens ++ new_turn`.
    pub tokens: Vec<i32>,
    /// Snapped active ratio the session decoded at (introspection only;
    /// layouts are pinned regardless).
    pub rho: f64,
    /// Per-linear layouts in force when the lane parked.
    pub layouts: FixedLayouts,
    /// Cached rows covering `tokens[..entry.len()]` (the last generated
    /// token is part of `tokens` but was never consumed by a forward, so
    /// `entry.len()` is typically `tokens.len() - 1`).
    pub entry: Arc<KvEntry>,
}

struct SessionSlot {
    state: Option<Arc<SessionState>>,
    /// Unique id minted at slot creation. Parking requires presenting the
    /// generation observed at admission, so a lane that outlived a
    /// `DELETE /session/:id` (or a delete + re-create) can never resurrect
    /// state into the successor slot — the ABA guard.
    generation: u64,
    last_used: Instant,
}

/// Named parking spots for multi-turn continuation. `begin` at admission
/// returns the parked state (if any) plus the slot's generation; `park` at
/// completion succeeds only if the slot still exists *and* the generation
/// matches. State is handed out as `Arc`, so deletion never frees rows out
/// from under a mid-flight lane — it only prevents them being re-parked.
/// Default for [`SessionRegistry`] capacity and the `[kvstore]
/// max_sessions` knob.
pub const DEFAULT_MAX_SESSIONS: usize = 1024;

pub struct SessionRegistry {
    next_gen: AtomicU64,
    cap: usize,
    slots: Mutex<HashMap<String, SessionSlot>>,
}

impl SessionRegistry {
    pub fn new() -> SessionRegistry {
        SessionRegistry::with_capacity(DEFAULT_MAX_SESSIONS)
    }

    /// Registry bounded to `cap` concurrent sessions. At the cap, a new
    /// session id evicts the least-recently-used *parked* slot (its owner
    /// re-prefills on the next turn) or, when every slot is mid-flight,
    /// is rejected — unparked lanes are never torn out from under their
    /// generation.
    pub fn with_capacity(cap: usize) -> SessionRegistry {
        assert!(cap > 0, "session registry capacity must be > 0");
        SessionRegistry {
            next_gen: AtomicU64::new(1),
            cap,
            slots: Mutex::new(HashMap::new()),
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Open (or create) the session for an admission: returns the parked
    /// state to continue from (None on a fresh or not-yet-parked session)
    /// and the generation the eventual `park` must present. Returns
    /// `None` when the registry is at capacity and no slot is evictable
    /// (every session is mid-flight) — callers surface that as an
    /// at-capacity rejection.
    pub fn begin(&self, id: &str) -> Option<(Option<Arc<SessionState>>, u64)> {
        let mut g = self.slots.lock().unwrap();
        if let Some(slot) = g.get_mut(id) {
            slot.last_used = Instant::now();
            return Some((slot.state.clone(), slot.generation));
        }
        if g.len() >= self.cap {
            let victim = g
                .iter()
                .filter(|(_, s)| s.state.is_some())
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(k) => {
                    g.remove(&k);
                }
                None => return None,
            }
        }
        let slot = g.entry(id.to_string()).or_insert_with(|| SessionSlot {
            state: None,
            generation: self.next_gen.fetch_add(1, Ordering::Relaxed),
            last_used: Instant::now(),
        });
        Some((slot.state.clone(), slot.generation))
    }

    /// Whether `begin(id)` would succeed right now, without creating or
    /// evicting anything. The router checks this before queueing so an
    /// over-capacity session sheds at admission (HTTP 429) instead of
    /// failing deep inside the serve loop.
    pub fn admissible(&self, id: &str) -> bool {
        let g = self.slots.lock().unwrap();
        g.contains_key(id) || g.len() < self.cap || g.values().any(|s| s.state.is_some())
    }

    /// Park a lane's final state under `id`. Fails (returning `false` and
    /// dropping `state`) if the session was deleted or re-created since
    /// the matching `begin` — the generation guard.
    pub fn park(&self, id: &str, generation: u64, state: Arc<SessionState>) -> bool {
        let mut g = self.slots.lock().unwrap();
        match g.get_mut(id) {
            Some(slot) if slot.generation == generation => {
                slot.state = Some(state);
                slot.last_used = Instant::now();
                true
            }
            _ => false,
        }
    }

    /// Drop a session. Mid-flight lanes keep their `Arc`'d state; they
    /// just can't park it back (their generation died with the slot).
    pub fn delete(&self, id: &str) -> bool {
        self.slots.lock().unwrap().remove(id).is_some()
    }

    /// Drop sessions idle longer than `ttl`; returns how many were
    /// removed. Called opportunistically from the serve loop.
    pub fn expire(&self, ttl: Duration) -> usize {
        let mut g = self.slots.lock().unwrap();
        let before = g.len();
        g.retain(|_, slot| slot.last_used.elapsed() <= ttl);
        before - g.len()
    }

    /// Active session count (a `/metrics` gauge).
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for SessionRegistry {
    fn default() -> SessionRegistry {
        SessionRegistry::new()
    }
}

/// Session ids travel in request JSON and URL paths; constrain them to a
/// conservative charset so they round-trip both without escaping.
pub fn valid_session_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: &[i32], d_model: usize, n_layers: usize, fill: f32) -> KvEntry {
        let rows = vec![fill; tokens.len() * d_model];
        KvEntry {
            tokens: tokens.to_vec(),
            k: vec![rows.clone(); n_layers],
            v: vec![rows; n_layers],
            d_model,
        }
    }

    fn state(tokens: &[i32]) -> Arc<SessionState> {
        Arc::new(SessionState {
            tokens: tokens.to_vec(),
            rho: 0.5,
            layouts: FixedLayouts::new(),
            entry: Arc::new(entry(&tokens[..tokens.len() - 1], 2, 1, 0.0)),
        })
    }

    #[test]
    fn prefix_hashes_match_fnv1a64_at_every_length() {
        let toks: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6, -7];
        let hashes = prefix_hashes(&toks);
        assert_eq!(hashes.len(), toks.len() + 1);
        for n in 0..=toks.len() {
            assert_eq!(
                hashes[n],
                fnv1a64(toks[..n].iter().map(|&t| t as u64)),
                "prefix length {n}"
            );
        }
    }

    #[test]
    fn lookup_returns_longest_matching_prefix() {
        let store = KvStore::new(1000);
        store.publish(1, 7, entry(&[10, 11], 2, 1, 0.1));
        store.publish(1, 7, entry(&[10, 11, 12, 13], 2, 1, 0.2));
        let (e, n) = store.lookup(1, 7, &[10, 11, 12, 13, 14, 15]).unwrap();
        assert_eq!(n, 4);
        assert_eq!(e.tokens, vec![10, 11, 12, 13]);
        // identical window: the full-length entry matches at n == T
        let (_, n) = store.lookup(1, 7, &[10, 11, 12, 13]).unwrap();
        assert_eq!(n, 4);
        assert_eq!((store.hits(), store.misses()), (2, 0));
    }

    #[test]
    fn lookup_misses_on_foreign_weights_chain_or_tokens() {
        let store = KvStore::new(1000);
        store.publish(1, 7, entry(&[10, 11, 12], 2, 1, 0.1));
        assert!(store.lookup(2, 7, &[10, 11, 12]).is_none(), "weights id");
        assert!(store.lookup(1, 8, &[10, 11, 12]).is_none(), "layout chain");
        assert!(store.lookup(1, 7, &[20, 21, 22]).is_none(), "tokens");
        assert_eq!((store.hits(), store.misses()), (0, 3));
    }

    #[test]
    fn token_budget_evicts_least_recently_used() {
        let store = KvStore::new(8);
        store.publish(1, 0, entry(&[1, 2, 3], 2, 1, 0.1)); // 3 tokens
        store.publish(1, 0, entry(&[4, 5, 6], 2, 1, 0.2)); // 6 tokens
        // touch the first so the second becomes LRU
        assert!(store.lookup(1, 0, &[1, 2, 3]).is_some());
        store.publish(1, 0, entry(&[7, 8, 9, 10], 2, 1, 0.3)); // would be 10
        assert!(store.resident_tokens() <= 8);
        assert!(store.lookup(1, 0, &[1, 2, 3]).is_some(), "MRU survived");
        assert!(store.lookup(1, 0, &[4, 5, 6]).is_none(), "LRU evicted");
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.len(), 2);
        assert_eq!(store.resident_tokens(), 7);
    }

    #[test]
    fn oversized_entry_is_rejected_not_flushing_the_store() {
        let store = KvStore::new(4);
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1));
        store.publish(1, 0, entry(&[9; 5], 2, 1, 0.2));
        assert_eq!(store.len(), 1);
        assert!(store.lookup(1, 0, &[1, 2]).is_some());
        assert_eq!(store.evictions(), 0);
    }

    #[test]
    fn republish_refreshes_recency_without_duplicating() {
        let store = KvStore::new(6);
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1));
        store.publish(1, 0, entry(&[3, 4], 2, 1, 0.2));
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1)); // refresh, not insert
        assert_eq!((store.len(), store.insertions()), (2, 2));
        store.publish(1, 0, entry(&[5, 6, 7], 2, 1, 0.3)); // evicts [3,4]
        assert!(store.lookup(1, 0, &[1, 2]).is_some());
        assert!(store.lookup(1, 0, &[3, 4]).is_none());
    }

    #[test]
    fn session_begin_park_continue_roundtrip() {
        let reg = SessionRegistry::new();
        let (prior, generation) = reg.begin("chat-1").unwrap();
        assert!(prior.is_none());
        assert!(reg.park("chat-1", generation, state(&[1, 2, 3])));
        let (parked, gen2) = reg.begin("chat-1").unwrap();
        assert_eq!(gen2, generation, "same slot, same generation");
        assert_eq!(parked.unwrap().tokens, vec![1, 2, 3]);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn deleted_session_rejects_stale_park() {
        // regression: evicting a session mid-flight must not let the lane
        // resurrect freed state when it finally completes
        let reg = SessionRegistry::new();
        let (_, generation) = reg.begin("s").unwrap();
        assert!(reg.delete("s"));
        assert!(!reg.park("s", generation, state(&[1, 2])), "slot is gone");
        // delete + re-create: the successor slot has a fresh generation,
        // so the stale lane still cannot park (the ABA case)
        let (prior, gen2) = reg.begin("s").unwrap();
        assert!(prior.is_none());
        assert_ne!(gen2, generation);
        assert!(!reg.park("s", generation, state(&[1, 2])));
        let (prior, _) = reg.begin("s").unwrap();
        assert!(prior.is_none(), "stale state never landed");
        assert!(reg.park("s", gen2, state(&[4, 5])), "live lane parks fine");
    }

    #[test]
    fn cancel_then_continue_shares_one_generation() {
        // two requests on the same live session id (cancelled first turn,
        // then a retry) both hold the same generation: whichever finishes
        // last parks, and neither is rejected
        let reg = SessionRegistry::new();
        let (_, g1) = reg.begin("s").unwrap();
        let (_, g2) = reg.begin("s").unwrap();
        assert_eq!(g1, g2);
        assert!(reg.park("s", g1, state(&[1, 2])), "cancelled turn parks");
        assert!(reg.park("s", g2, state(&[1, 2, 3])), "retry overwrites");
        let (parked, _) = reg.begin("s").unwrap();
        assert_eq!(parked.unwrap().tokens, vec![1, 2, 3]);
    }

    #[test]
    fn expire_drops_idle_sessions() {
        let reg = SessionRegistry::new();
        reg.begin("a").unwrap();
        reg.begin("b").unwrap();
        assert_eq!(reg.expire(Duration::from_secs(3600)), 0);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(reg.expire(Duration::from_millis(1)), 2);
        assert!(reg.is_empty());
    }

    #[test]
    fn session_cap_evicts_idle_lru_or_rejects() {
        // regression: the registry used to grow without bound — every new
        // session id allocated a slot forever
        let reg = SessionRegistry::with_capacity(2);
        let (_, ga) = reg.begin("a").unwrap();
        assert!(reg.park("a", ga, state(&[1, 2])));
        std::thread::sleep(Duration::from_millis(2));
        let (_, gb) = reg.begin("b").unwrap();
        assert!(reg.park("b", gb, state(&[3, 4])));
        // at cap with two parked slots: a third id evicts the LRU ("a")
        assert!(reg.admissible("c"));
        let (prior, _) = reg.begin("c").unwrap();
        assert!(prior.is_none());
        assert_eq!(reg.len(), 2);
        // "a" was evicted: re-beginning it gets a fresh slot (no state,
        // new generation) and in turn evicts the parked "b"
        let (prior, ga2) = reg.begin("a").unwrap();
        assert!(prior.is_none(), "evicted session lost its parked state");
        assert_ne!(ga2, ga);
        assert_eq!(reg.len(), 2);
        // now every slot is mid-flight (none parked): a new id is
        // rejected, while existing ids still begin fine
        assert!(!reg.admissible("d"));
        assert!(reg.begin("d").is_none(), "all slots in flight");
        assert!(reg.begin("c").is_some(), "existing id unaffected by cap");
        assert_eq!(reg.len(), 2, "rejection created nothing");
    }

    #[test]
    fn publish_collision_replaces_foreign_entry() {
        // regression: publish used to treat any key match as "same prefix,
        // refresh recency", so a hash collision would keep serving the
        // foreign prompt's rows forever. Real FNV-1a collisions are
        // impractical to forge from tokens, so drive the keyed core with a
        // hand-built colliding key: same hash/len, different tokens.
        let store = KvStore::new(16);
        let key = PrefixKey {
            weights: 1,
            prefix_hash: 0xDEAD_BEEF,
            prefix_len: 2,
            layout_chain: 0,
        };
        store.publish_keyed(key.clone(), entry(&[1, 2], 2, 1, 0.1));
        store.publish_keyed(key.clone(), entry(&[9, 8], 2, 1, 0.7));
        // the replacement is a real insertion, not a recency refresh, and
        // neither duplicates the slot nor double-counts resident tokens
        assert_eq!((store.len(), store.insertions()), (1, 2));
        assert_eq!(store.resident_tokens(), 2);
        let g = store.inner.lock().unwrap();
        let (resident, _) = g.entries.get(&key).unwrap();
        assert_eq!(resident.tokens, vec![9, 8], "fresh rows won");
        assert_eq!(resident.k[0][0], 0.7);
        drop(g);
        // equal tokens under the same key still only refresh recency
        store.publish_keyed(key.clone(), entry(&[9, 8], 2, 1, 0.7));
        assert_eq!((store.len(), store.insertions()), (1, 2));
    }

    #[test]
    fn lookup_probes_only_published_lengths() {
        // the length index must keep longest-prefix semantics and the
        // one-hit-or-miss counter discipline across publish and eviction
        let store = KvStore::new(16);
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1));
        store.publish(1, 0, entry(&[1, 2, 3, 4], 2, 1, 0.2));
        // a very long window still finds the longest published prefix
        let window: Vec<i32> = (1..=1000).collect();
        let (hit, n) = store.lookup(1, 0, &window).unwrap();
        assert_eq!(n, 4);
        assert_eq!(hit.tokens, vec![1, 2, 3, 4]);
        // shorter window: only length 2 is probeable
        let (_, n) = store.lookup(1, 0, &[1, 2, 3]).unwrap();
        assert_eq!(n, 2);
        // foreign chain has no index entry: pure miss, no probes
        assert!(store.lookup(1, 9, &window).is_none());
        assert_eq!((store.hits(), store.misses()), (2, 1));
        // evicting must unindex: flush both entries with a budget-sized
        // insert, then the old lengths no longer match
        store.publish(1, 0, entry(&[7; 16], 2, 1, 0.3));
        assert!(store.lookup(1, 0, &[1, 2, 3, 4]).is_none());
        let g = store.inner.lock().unwrap();
        let lens = g.lengths.get(&(1, 0)).unwrap();
        assert_eq!(lens.keys().copied().collect::<Vec<_>>(), vec![16]);
    }

    #[test]
    fn session_id_charset() {
        assert!(valid_session_id("chat-1"));
        assert!(valid_session_id("User_42.v2"));
        assert!(!valid_session_id(""));
        assert!(!valid_session_id("a/b"));
        assert!(!valid_session_id("spa ce"));
        assert!(!valid_session_id(&"x".repeat(65)));
    }
}
