//! Cross-request KV reuse: a prefix-keyed KV store plus a session registry.
//!
//! Chat-style traffic resends long shared prefixes (system prompts,
//! few-shot preambles, conversation history), yet a lane's
//! [`crate::nn::kv::KvCache`] dies with its request and every admission
//! pays a full O(T²) prefill. This module is the layer between decode and
//! the coordinator that keeps prefix K/V alive across requests:
//!
//! - [`KvStore`]: a shared, token-budget LRU map from
//!   `(weights-id, token-prefix FNV hash + length, layout chain)` to cloned
//!   per-layer K/V rows for absolute positions `0..n`. Admission consults
//!   it; a hit seeds the lane's cache and only the suffix is prefilled.
//! - [`SessionRegistry`]: named parking spots so a multi-turn client can
//!   continue a finished lane's cache (and its pinned layouts) with zero
//!   prefix prefill, guarded by a generation counter so deleting or
//!   re-creating a session can never let a stale mid-flight lane resurrect
//!   freed state.
//!
//! ## Keying discipline
//!
//! μ-MoE selects micro-experts per prompt, so cached K/V is only reusable
//! when the *layouts that produced it* match — the same
//! calibration-dependence insight behind [`crate::tensor::LayoutCache`]
//! applies to cached activations. A key therefore binds three things:
//!
//! 1. `weights`: [`crate::nn::Model::weights_id`] — two same-architecture
//!    models must never share rows.
//! 2. the token prefix: FNV-1a hash *and* exact length; the entry also
//!    stores the tokens themselves so a lookup verifies them and a hash
//!    collision can never seed a lane with another prompt's cache.
//! 3. [`layout_chain`]: FNV over each prunable linear's
//!    [`RowSparse::fingerprint`] content hash in `linear_names()` order —
//!    content, not `Arc` identity, so independently rebuilt but identical
//!    layouts still hit.
//!
//! ## Exactness
//!
//! Under the model's absolute position embeddings, K/V rows for window
//! positions `0..n` depend only on the tokens at `0..n` and the layouts —
//! so seeding a fresh cache with a matching prefix and stepping the suffix
//! is bit-identical to a full prefill (`forward_step` ≡ full-window
//! forward is proven in `nn`; `proptest.rs::kvstore_props` proves the
//! composition at the decode level). Seeding only applies to windows that
//! start at absolute position 0; slid windows rebuild as before.

use crate::nn::FixedLayouts;
use crate::tensor::fnv1a64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Incremental FNV-1a prefix hashes: `out[n]` is the hash of `tokens[..n]`
/// under the same byte stream [`fnv1a64`] consumes, i.e.
/// `out[n] == fnv1a64(tokens[..n].iter().map(|&t| t as u64))`. One O(T)
/// pass gives a lookup every probe length for free.
pub fn prefix_hashes(tokens: &[i32]) -> Vec<u64> {
    let mut out = Vec::with_capacity(tokens.len() + 1);
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    out.push(h);
    for &t in tokens {
        for b in (t as u64).to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        out.push(h);
    }
    out
}

/// FNV over each linear's [`crate::tensor::RowSparse::fingerprint`] in the
/// caller-supplied (canonical `linear_names()`) order. Content hashes, not
/// `Arc` pointers: two lanes that rebuilt byte-identical layouts chain
/// equal, which is what makes store hits possible across requests. `None`
/// when a linear is missing from the map (never the case for layouts
/// produced by `moe::layouts_for`).
pub fn layout_chain(linear_names: &[String], layouts: &FixedLayouts) -> Option<u64> {
    let mut fps = Vec::with_capacity(linear_names.len());
    for name in linear_names {
        fps.push(layouts.get(name)?.fingerprint());
    }
    Some(fnv1a64(fps))
}

/// Store key: which weights, which exact token prefix (hash + length), and
/// which per-linear layout chain produced the rows.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PrefixKey {
    pub weights: u64,
    pub prefix_hash: u64,
    pub prefix_len: usize,
    pub layout_chain: u64,
}

/// One cached prefix: the exact tokens it covers and cloned per-layer K/V
/// rows for absolute positions `0..len`. Entries are immutable once
/// published and shared out as `Arc`, so a hit costs one refcount bump and
/// the row copy into the lane's private cache.
#[derive(Clone, Debug, PartialEq)]
pub struct KvEntry {
    /// The exact prefix tokens — re-verified on every lookup so an FNV
    /// collision can never seed a lane with another prompt's rows.
    pub tokens: Vec<i32>,
    /// Per-layer K rows, each `len * d_model` long (row `t` at
    /// `t * d_model ..`).
    pub k: Vec<Vec<f32>>,
    /// Per-layer V rows, parallel to `k`.
    pub v: Vec<Vec<f32>>,
    pub d_model: usize,
}

impl KvEntry {
    /// Number of cached positions (tokens).
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    pub fn n_layers(&self) -> usize {
        self.k.len()
    }
}

struct StoreInner {
    entries: HashMap<PrefixKey, (Arc<KvEntry>, u64)>,
    tick: u64,
    resident_tokens: usize,
    hits: u64,
    misses: u64,
    insertions: u64,
    evictions: u64,
}

/// Shared, capacity-bounded prefix-keyed KV store. The budget is in
/// *tokens* (summed entry lengths), not entries — one 4k-token system
/// prompt costs what 64 short prefixes cost. Eviction is
/// least-recently-used by lookup/publish recency. Internally synchronized;
/// share as `Arc<KvStore>`.
pub struct KvStore {
    token_budget: usize,
    inner: Mutex<StoreInner>,
}

impl KvStore {
    pub fn new(token_budget: usize) -> KvStore {
        assert!(token_budget > 0, "kv store token budget must be > 0");
        KvStore {
            token_budget,
            inner: Mutex::new(StoreInner {
                entries: HashMap::new(),
                tick: 0,
                resident_tokens: 0,
                hits: 0,
                misses: 0,
                insertions: 0,
                evictions: 0,
            }),
        }
    }

    pub fn token_budget(&self) -> usize {
        self.token_budget
    }

    /// Longest cached prefix of `window` under (`weights`, `chain`).
    /// Probes every length from `window.len()` down to 1 against the
    /// one-pass [`prefix_hashes`] and verifies the stored tokens on a hash
    /// match. Returns the entry and its matched length `n ≤ window.len()`
    /// — callers seeding a decode cache clamp the seeded rows to
    /// `window.len() - 1` so at least one token remains to step for
    /// logits. Counts exactly one hit or one miss per call.
    pub fn lookup(&self, weights: u64, chain: u64, window: &[i32]) -> Option<(Arc<KvEntry>, usize)> {
        let hashes = prefix_hashes(window);
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        for n in (1..=window.len()).rev() {
            let key = PrefixKey {
                weights,
                prefix_hash: hashes[n],
                prefix_len: n,
                layout_chain: chain,
            };
            if let Some((arc, t)) = g.entries.get_mut(&key) {
                if arc.tokens[..] == window[..n] {
                    *t = tick;
                    let found = arc.clone();
                    g.hits += 1;
                    return Some((found, n));
                }
            }
        }
        g.misses += 1;
        None
    }

    /// Insert a freshly prefilled prefix, evicting least-recently-used
    /// entries until the resident-token total fits the budget. An entry
    /// larger than the whole budget is dropped rather than flushing the
    /// store for a row set nothing else can share space with. Re-publishing
    /// an existing key only refreshes its recency (the keying discipline
    /// makes the rows identical).
    pub fn publish(&self, weights: u64, chain: u64, entry: KvEntry) {
        if entry.is_empty() || entry.len() > self.token_budget {
            return;
        }
        let key = PrefixKey {
            weights,
            prefix_hash: fnv1a64(entry.tokens.iter().map(|&t| t as u64)),
            prefix_len: entry.len(),
            layout_chain: chain,
        };
        let mut g = self.inner.lock().unwrap();
        g.tick += 1;
        let tick = g.tick;
        if let Some(slot) = g.entries.get_mut(&key) {
            slot.1 = tick;
            return;
        }
        g.resident_tokens += entry.len();
        g.insertions += 1;
        g.entries.insert(key, (Arc::new(entry), tick));
        while g.resident_tokens > self.token_budget {
            let victim = g
                .entries
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone());
            let Some(k) = victim else { break };
            if let Some((e, _)) = g.entries.remove(&k) {
                g.resident_tokens -= e.len();
                g.evictions += 1;
            }
        }
    }

    /// Resident entry count (a `/metrics` gauge).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Summed token length of resident entries (the LRU budget's unit).
    pub fn resident_tokens(&self) -> usize {
        self.inner.lock().unwrap().resident_tokens
    }

    pub fn hits(&self) -> u64 {
        self.inner.lock().unwrap().hits
    }

    pub fn misses(&self) -> u64 {
        self.inner.lock().unwrap().misses
    }

    pub fn insertions(&self) -> u64 {
        self.inner.lock().unwrap().insertions
    }

    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }
}

/// A finished (or cancelled-with-partials) lane's continuable state: the
/// final decode window, the snapped ρ it ran at, the layouts active when
/// it parked, and the cached rows. A continuation *pins* `layouts` — it
/// skips every refresh and decodes the concatenated window under exactly
/// these layouts, which is what makes continuation bit-exact against a
/// fixed-layout reference decode (`kvstore_props` seed series 503).
#[derive(Clone, Debug)]
pub struct SessionState {
    /// The full final window (post-slide) — the continuation's prompt is
    /// `tokens ++ new_turn`.
    pub tokens: Vec<i32>,
    /// Snapped active ratio the session decoded at (introspection only;
    /// layouts are pinned regardless).
    pub rho: f64,
    /// Per-linear layouts in force when the lane parked.
    pub layouts: FixedLayouts,
    /// Cached rows covering `tokens[..entry.len()]` (the last generated
    /// token is part of `tokens` but was never consumed by a forward, so
    /// `entry.len()` is typically `tokens.len() - 1`).
    pub entry: Arc<KvEntry>,
}

struct SessionSlot {
    state: Option<Arc<SessionState>>,
    /// Unique id minted at slot creation. Parking requires presenting the
    /// generation observed at admission, so a lane that outlived a
    /// `DELETE /session/:id` (or a delete + re-create) can never resurrect
    /// state into the successor slot — the ABA guard.
    generation: u64,
    last_used: Instant,
}

/// Named parking spots for multi-turn continuation. `begin` at admission
/// returns the parked state (if any) plus the slot's generation; `park` at
/// completion succeeds only if the slot still exists *and* the generation
/// matches. State is handed out as `Arc`, so deletion never frees rows out
/// from under a mid-flight lane — it only prevents them being re-parked.
pub struct SessionRegistry {
    next_gen: AtomicU64,
    slots: Mutex<HashMap<String, SessionSlot>>,
}

impl SessionRegistry {
    pub fn new() -> SessionRegistry {
        SessionRegistry {
            next_gen: AtomicU64::new(1),
            slots: Mutex::new(HashMap::new()),
        }
    }

    /// Open (or create) the session for an admission: returns the parked
    /// state to continue from (None on a fresh or not-yet-parked session)
    /// and the generation the eventual `park` must present.
    pub fn begin(&self, id: &str) -> (Option<Arc<SessionState>>, u64) {
        let mut g = self.slots.lock().unwrap();
        let slot = g.entry(id.to_string()).or_insert_with(|| SessionSlot {
            state: None,
            generation: self.next_gen.fetch_add(1, Ordering::Relaxed),
            last_used: Instant::now(),
        });
        slot.last_used = Instant::now();
        (slot.state.clone(), slot.generation)
    }

    /// Park a lane's final state under `id`. Fails (returning `false` and
    /// dropping `state`) if the session was deleted or re-created since
    /// the matching `begin` — the generation guard.
    pub fn park(&self, id: &str, generation: u64, state: Arc<SessionState>) -> bool {
        let mut g = self.slots.lock().unwrap();
        match g.get_mut(id) {
            Some(slot) if slot.generation == generation => {
                slot.state = Some(state);
                slot.last_used = Instant::now();
                true
            }
            _ => false,
        }
    }

    /// Drop a session. Mid-flight lanes keep their `Arc`'d state; they
    /// just can't park it back (their generation died with the slot).
    pub fn delete(&self, id: &str) -> bool {
        self.slots.lock().unwrap().remove(id).is_some()
    }

    /// Drop sessions idle longer than `ttl`; returns how many were
    /// removed. Called opportunistically from the serve loop.
    pub fn expire(&self, ttl: Duration) -> usize {
        let mut g = self.slots.lock().unwrap();
        let before = g.len();
        g.retain(|_, slot| slot.last_used.elapsed() <= ttl);
        before - g.len()
    }

    /// Active session count (a `/metrics` gauge).
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for SessionRegistry {
    fn default() -> SessionRegistry {
        SessionRegistry::new()
    }
}

/// Session ids travel in request JSON and URL paths; constrain them to a
/// conservative charset so they round-trip both without escaping.
pub fn valid_session_id(id: &str) -> bool {
    !id.is_empty()
        && id.len() <= 64
        && id
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(tokens: &[i32], d_model: usize, n_layers: usize, fill: f32) -> KvEntry {
        let rows = vec![fill; tokens.len() * d_model];
        KvEntry {
            tokens: tokens.to_vec(),
            k: vec![rows.clone(); n_layers],
            v: vec![rows; n_layers],
            d_model,
        }
    }

    fn state(tokens: &[i32]) -> Arc<SessionState> {
        Arc::new(SessionState {
            tokens: tokens.to_vec(),
            rho: 0.5,
            layouts: FixedLayouts::new(),
            entry: Arc::new(entry(&tokens[..tokens.len() - 1], 2, 1, 0.0)),
        })
    }

    #[test]
    fn prefix_hashes_match_fnv1a64_at_every_length() {
        let toks: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6, -7];
        let hashes = prefix_hashes(&toks);
        assert_eq!(hashes.len(), toks.len() + 1);
        for n in 0..=toks.len() {
            assert_eq!(
                hashes[n],
                fnv1a64(toks[..n].iter().map(|&t| t as u64)),
                "prefix length {n}"
            );
        }
    }

    #[test]
    fn lookup_returns_longest_matching_prefix() {
        let store = KvStore::new(1000);
        store.publish(1, 7, entry(&[10, 11], 2, 1, 0.1));
        store.publish(1, 7, entry(&[10, 11, 12, 13], 2, 1, 0.2));
        let (e, n) = store.lookup(1, 7, &[10, 11, 12, 13, 14, 15]).unwrap();
        assert_eq!(n, 4);
        assert_eq!(e.tokens, vec![10, 11, 12, 13]);
        // identical window: the full-length entry matches at n == T
        let (_, n) = store.lookup(1, 7, &[10, 11, 12, 13]).unwrap();
        assert_eq!(n, 4);
        assert_eq!((store.hits(), store.misses()), (2, 0));
    }

    #[test]
    fn lookup_misses_on_foreign_weights_chain_or_tokens() {
        let store = KvStore::new(1000);
        store.publish(1, 7, entry(&[10, 11, 12], 2, 1, 0.1));
        assert!(store.lookup(2, 7, &[10, 11, 12]).is_none(), "weights id");
        assert!(store.lookup(1, 8, &[10, 11, 12]).is_none(), "layout chain");
        assert!(store.lookup(1, 7, &[20, 21, 22]).is_none(), "tokens");
        assert_eq!((store.hits(), store.misses()), (0, 3));
    }

    #[test]
    fn token_budget_evicts_least_recently_used() {
        let store = KvStore::new(8);
        store.publish(1, 0, entry(&[1, 2, 3], 2, 1, 0.1)); // 3 tokens
        store.publish(1, 0, entry(&[4, 5, 6], 2, 1, 0.2)); // 6 tokens
        // touch the first so the second becomes LRU
        assert!(store.lookup(1, 0, &[1, 2, 3]).is_some());
        store.publish(1, 0, entry(&[7, 8, 9, 10], 2, 1, 0.3)); // would be 10
        assert!(store.resident_tokens() <= 8);
        assert!(store.lookup(1, 0, &[1, 2, 3]).is_some(), "MRU survived");
        assert!(store.lookup(1, 0, &[4, 5, 6]).is_none(), "LRU evicted");
        assert_eq!(store.evictions(), 1);
        assert_eq!(store.len(), 2);
        assert_eq!(store.resident_tokens(), 7);
    }

    #[test]
    fn oversized_entry_is_rejected_not_flushing_the_store() {
        let store = KvStore::new(4);
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1));
        store.publish(1, 0, entry(&[9; 5], 2, 1, 0.2));
        assert_eq!(store.len(), 1);
        assert!(store.lookup(1, 0, &[1, 2]).is_some());
        assert_eq!(store.evictions(), 0);
    }

    #[test]
    fn republish_refreshes_recency_without_duplicating() {
        let store = KvStore::new(6);
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1));
        store.publish(1, 0, entry(&[3, 4], 2, 1, 0.2));
        store.publish(1, 0, entry(&[1, 2], 2, 1, 0.1)); // refresh, not insert
        assert_eq!((store.len(), store.insertions()), (2, 2));
        store.publish(1, 0, entry(&[5, 6, 7], 2, 1, 0.3)); // evicts [3,4]
        assert!(store.lookup(1, 0, &[1, 2]).is_some());
        assert!(store.lookup(1, 0, &[3, 4]).is_none());
    }

    #[test]
    fn session_begin_park_continue_roundtrip() {
        let reg = SessionRegistry::new();
        let (prior, generation) = reg.begin("chat-1");
        assert!(prior.is_none());
        assert!(reg.park("chat-1", generation, state(&[1, 2, 3])));
        let (parked, gen2) = reg.begin("chat-1");
        assert_eq!(gen2, generation, "same slot, same generation");
        assert_eq!(parked.unwrap().tokens, vec![1, 2, 3]);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn deleted_session_rejects_stale_park() {
        // regression: evicting a session mid-flight must not let the lane
        // resurrect freed state when it finally completes
        let reg = SessionRegistry::new();
        let (_, generation) = reg.begin("s");
        assert!(reg.delete("s"));
        assert!(!reg.park("s", generation, state(&[1, 2])), "slot is gone");
        // delete + re-create: the successor slot has a fresh generation,
        // so the stale lane still cannot park (the ABA case)
        let (prior, gen2) = reg.begin("s");
        assert!(prior.is_none());
        assert_ne!(gen2, generation);
        assert!(!reg.park("s", generation, state(&[1, 2])));
        assert!(reg.begin("s").0.is_none(), "stale state never landed");
        assert!(reg.park("s", gen2, state(&[4, 5])), "live lane parks fine");
    }

    #[test]
    fn cancel_then_continue_shares_one_generation() {
        // two requests on the same live session id (cancelled first turn,
        // then a retry) both hold the same generation: whichever finishes
        // last parks, and neither is rejected
        let reg = SessionRegistry::new();
        let (_, g1) = reg.begin("s");
        let (_, g2) = reg.begin("s");
        assert_eq!(g1, g2);
        assert!(reg.park("s", g1, state(&[1, 2])), "cancelled turn parks");
        assert!(reg.park("s", g2, state(&[1, 2, 3])), "retry overwrites");
        assert_eq!(reg.begin("s").0.unwrap().tokens, vec![1, 2, 3]);
    }

    #[test]
    fn expire_drops_idle_sessions() {
        let reg = SessionRegistry::new();
        reg.begin("a");
        reg.begin("b");
        assert_eq!(reg.expire(Duration::from_secs(3600)), 0);
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(reg.expire(Duration::from_millis(1)), 2);
        assert!(reg.is_empty());
    }

    #[test]
    fn session_id_charset() {
        assert!(valid_session_id("chat-1"));
        assert!(valid_session_id("User_42.v2"));
        assert!(!valid_session_id(""));
        assert!(!valid_session_id("a/b"));
        assert!(!valid_session_id("spa ce"));
        assert!(!valid_session_id(&"x".repeat(65)));
    }
}
