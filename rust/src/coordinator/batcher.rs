//! Sparsity-aware dynamic batcher.
//!
//! Requests are keyed by snapped sparsity level (a batch shares one ρ —
//! both backends execute one ρ per batch). A batch fires when it reaches
//! the engine's batch capacity, or when its oldest member has waited out
//! the batching window; eligible levels are served round-robin from a
//! rotating cursor so a hot level's backlog cannot starve the others.
//! Pure data structure (no threads, no clocks of its own) so the policy
//! is exhaustively testable; the server loop feeds it time.

use super::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Target (and maximum) batch size — the artifact's static batch dim.
    pub batch_size: usize,
    /// Max time the oldest request may wait for batch-mates.
    pub window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            batch_size: 4,
            window: Duration::from_millis(2),
        }
    }
}

/// A batch ready for execution: requests + the shared sparsity level.
/// This is the unit `coordinator::engine::Engine::execute` consumes.
#[derive(Debug)]
pub struct DecodeBatch {
    pub rho: f64,
    pub requests: Vec<Request>,
}

impl DecodeBatch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// ρ-keyed queues. Keys are level *indices* into the configured rho_levels
/// so float identity never leaks into the map.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    levels: Vec<f64>,
    queues: Vec<VecDeque<Request>>,
    pending: usize,
    /// Rotating scan cursor: the level after the last one that fired.
    /// Scanning from here (not from index 0, and not oldest-head-first)
    /// bounds how long an eligible level can wait: a hot level with a
    /// standing backlog of old requests can win at most one pop before
    /// every other eligible level gets its turn.
    next_level: usize,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig, rho_levels: &[f64]) -> DynamicBatcher {
        assert!(!rho_levels.is_empty());
        assert!(cfg.batch_size > 0);
        DynamicBatcher {
            cfg,
            levels: rho_levels.to_vec(),
            queues: rho_levels.iter().map(|_| VecDeque::new()).collect(),
            pending: 0,
            next_level: 0,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn levels(&self) -> &[f64] {
        &self.levels
    }

    /// Enqueue a request whose ρ has already been snapped to a level.
    pub fn push(&mut self, req: Request) {
        let idx = self
            .levels
            .iter()
            .position(|&l| (l - req.rho).abs() < 1e-9)
            .expect("router must snap rho before push");
        self.queues[idx].push_back(req);
        self.pending += 1;
    }

    /// The policy: scan levels round-robin from the rotating cursor and
    /// fire the first queue that is full or whose head has exceeded the
    /// window. `now` injected for testability.
    ///
    /// The rotation is the fairness guarantee. The previous policy fired
    /// the *oldest* eligible head, which sounds fair but starves: a hot
    /// level with a standing backlog always holds the oldest head, so a
    /// waiting level never won a pop until the backlog fully drained.
    /// Round-robin over eligible levels bounds the wait to one batch per
    /// other level instead.
    pub fn pop_ready(&mut self, now: Instant) -> Option<DecodeBatch> {
        let n_levels = self.queues.len();
        for off in 0..n_levels {
            let i = (self.next_level + off) % n_levels;
            let q = &self.queues[i];
            let Some(head) = q.front() else { continue };
            let full = q.len() >= self.cfg.batch_size;
            let expired = now.duration_since(head.enqueued_at) >= self.cfg.window;
            if full || expired {
                self.next_level = (i + 1) % n_levels;
                return Some(self.take_batch(i));
            }
        }
        None
    }

    /// Admission pop for continuous batching: the oldest queued request
    /// at the given snapped level (`None` if that level's queue is empty
    /// or `rho` is not a configured level). Two deliberate differences
    /// from the batch pop:
    ///
    /// * **no window check** — a freed lane is capacity *right now*, so
    ///   the oldest same-ρ request rides immediately instead of waiting
    ///   for batch-mates;
    /// * **the rotating cursor is untouched** — lane refills are pinned
    ///   to the running pool's ρ, not a scheduling choice among levels.
    ///   If refills spun the cursor, a hot level's admission traffic
    ///   would hand it extra (or cost it owed) `pop_ready` turns and
    ///   break the PR-3 fairness bound; the regression tests pin this.
    pub fn pop_admission(&mut self, rho: f64) -> Option<Request> {
        let idx = self.levels.iter().position(|&l| (l - rho).abs() < 1e-9)?;
        let req = self.queues[idx].pop_front()?;
        self.pending -= 1;
        Some(req)
    }

    /// Pop up to one batch_size worth of requests off level `idx`.
    fn take_batch(&mut self, idx: usize) -> DecodeBatch {
        let q = &mut self.queues[idx];
        let n = q.len().min(self.cfg.batch_size);
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            requests.push(q.pop_front().unwrap());
        }
        self.pending -= n;
        DecodeBatch {
            rho: self.levels[idx],
            requests,
        }
    }

    /// Time until the earliest head expires (server loop sleep hint).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|q| q.front())
            .map(|r| {
                self.cfg
                    .window
                    .saturating_sub(now.duration_since(r.enqueued_at))
            })
            .min()
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<DecodeBatch> {
        let mut out = Vec::new();
        for i in 0..self.queues.len() {
            while !self.queues[i].is_empty() {
                out.push(self.take_batch(i));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, rho: f64) -> Request {
        Request::new(id, vec![1, 2, 3], 3, rho, "synth_wiki", None)
    }

    fn mk() -> DynamicBatcher {
        DynamicBatcher::new(
            BatcherConfig {
                batch_size: 4,
                window: Duration::from_millis(10),
            },
            &[0.4, 1.0],
        )
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = mk();
        for i in 0..4 {
            b.push(req(i, 0.4));
        }
        let batch = b.pop_ready(Instant::now()).expect("should fire");
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.rho, 0.4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_window() {
        let mut b = mk();
        b.push(req(1, 0.4));
        let now = Instant::now();
        assert!(b.pop_ready(now).is_none(), "window not expired");
        let later = now + Duration::from_millis(11);
        let batch = b.pop_ready(later).expect("expired window fires");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batches_never_mix_rho() {
        let mut b = mk();
        for i in 0..3 {
            b.push(req(i, 0.4));
        }
        for i in 3..6 {
            b.push(req(i, 1.0));
        }
        let later = Instant::now() + Duration::from_millis(20);
        while let Some(batch) = b.pop_ready(later) {
            let rhos: Vec<f64> = batch.requests.iter().map(|r| r.rho).collect();
            assert!(rhos.iter().all(|&r| (r - batch.rho).abs() < 1e-9));
        }
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn eligible_levels_fire_in_rotation() {
        let mut b = mk();
        b.push(req(1, 0.4));
        b.push(req(2, 1.0));
        let later = Instant::now() + Duration::from_millis(30);
        let first = b.pop_ready(later).unwrap();
        assert_eq!(first.rho, 0.4, "cursor starts at level 0");
        let second = b.pop_ready(later).unwrap();
        assert_eq!(second.rho, 1.0, "cursor advanced past the fired level");
    }

    #[test]
    fn rotation_prevents_hot_level_starving_others() {
        // A hot level with a standing backlog of *older* requests must not
        // monopolize consecutive pops while another level has an expired
        // head. Under the old oldest-head-first policy the second pop
        // below picked 0.4 again (its backlog head predates the 1.0
        // request), starving 1.0 until the backlog drained.
        let mut b = mk();
        for i in 0..12 {
            b.push(req(i, 0.4)); // three full batches of backlog
        }
        b.push(req(100, 1.0)); // one waiting request at another level
        let later = Instant::now() + Duration::from_millis(30); // all expired
        assert_eq!(b.pop_ready(later).unwrap().rho, 0.4);
        let second = b.pop_ready(later).unwrap();
        assert_eq!(second.rho, 1.0, "waiting level must get the next turn");
        assert_eq!(second.requests[0].id, 100);
        assert_eq!(b.pop_ready(later).unwrap().rho, 0.4, "rotation wraps");
    }

    #[test]
    fn admission_pop_is_fifo_and_window_free() {
        let mut b = mk();
        b.push(req(1, 0.4));
        b.push(req(2, 0.4));
        // no window has expired and the queue is not full, yet admission
        // pops deliver immediately, oldest first
        assert!(b.pop_ready(Instant::now()).is_none());
        assert_eq!(b.pop_admission(0.4).unwrap().id, 1);
        assert_eq!(b.pop_admission(0.4).unwrap().id, 2);
        assert!(b.pop_admission(0.4).is_none(), "level drained");
        assert!(b.pop_admission(0.73).is_none(), "unknown level");
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn admission_pops_preserve_rotating_cursor_fairness() {
        // Regression for the PR-3 starvation fix under continuous
        // batching: a backlogged hot level whose lane refills go through
        // pop_admission must not gain (or lose) batch-pop turns — the
        // waiting level still wins the very next pop_ready after the hot
        // level fires once, no matter how many admission pops interleave.
        let mut b = mk();
        for i in 0..12 {
            b.push(req(i, 0.4)); // hot backlog
        }
        b.push(req(100, 1.0)); // one waiting request at another level
        let later = Instant::now() + Duration::from_millis(30);
        assert_eq!(b.pop_ready(later).unwrap().rho, 0.4, "cursor starts at 0.4");
        // continuous serving refills freed 0.4 lanes straight off the queue
        for _ in 0..3 {
            assert_eq!(b.pop_admission(0.4).unwrap().rho, 0.4);
        }
        // ...but the rotation still owes 1.0 the next batch pop
        let second = b.pop_ready(later).unwrap();
        assert_eq!(second.rho, 1.0, "admission pops must not spin the cursor");
        assert_eq!(second.requests[0].id, 100);
        assert_eq!(b.pop_ready(later).unwrap().rho, 0.4, "rotation wraps back");
    }

    #[test]
    fn oversize_queue_splits_into_full_batches() {
        let mut b = mk();
        for i in 0..9 {
            b.push(req(i, 1.0));
        }
        let later = Instant::now() + Duration::from_millis(30);
        let b1 = b.pop_ready(later).unwrap();
        let b2 = b.pop_ready(later).unwrap();
        let b3 = b.pop_ready(later).unwrap();
        assert_eq!((b1.len(), b2.len(), b3.len()), (4, 4, 1));
        // FIFO within level
        assert_eq!(b1.requests[0].id, 0);
        assert_eq!(b3.requests[0].id, 8);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = mk();
        let now = Instant::now();
        b.push(req(1, 0.4));
        let d = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(7), "{d:?}");
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = mk();
        for i in 0..6 {
            b.push(req(i, if i % 2 == 0 { 0.4 } else { 1.0 }));
        }
        let batches = b.drain();
        let total: usize = batches.iter().map(DecodeBatch::len).sum();
        assert_eq!(total, 6);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    #[should_panic(expected = "snap")]
    fn unsnapped_rho_panics() {
        let mut b = mk();
        b.push(req(1, 0.73));
    }
}
