//! Sparsity-aware dynamic batcher.
//!
//! Requests are keyed by snapped sparsity level (a batch shares one ρ —
//! the μ-MoE artifact takes ρ as a runtime scalar). A batch fires when it
//! reaches the artifact batch size, or when its oldest member has waited
//! out the batching window. Pure data structure (no threads, no clocks of
//! its own) so the policy is exhaustively testable; the server loop feeds
//! it time.

use super::request::Request;
use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Target (and maximum) batch size — the artifact's static batch dim.
    pub batch_size: usize,
    /// Max time the oldest request may wait for batch-mates.
    pub window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            batch_size: 4,
            window: Duration::from_millis(2),
        }
    }
}

/// A batch ready for execution: requests + the shared sparsity level.
#[derive(Debug)]
pub struct Batch {
    pub rho: f64,
    pub requests: Vec<Request>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.requests.len()
    }
    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }
}

/// ρ-keyed queues. Keys are level *indices* into the configured rho_levels
/// so float identity never leaks into the map.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    levels: Vec<f64>,
    queues: Vec<VecDeque<Request>>,
    pending: usize,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig, rho_levels: &[f64]) -> DynamicBatcher {
        assert!(!rho_levels.is_empty());
        assert!(cfg.batch_size > 0);
        DynamicBatcher {
            cfg,
            levels: rho_levels.to_vec(),
            queues: rho_levels.iter().map(|_| VecDeque::new()).collect(),
            pending: 0,
        }
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn levels(&self) -> &[f64] {
        &self.levels
    }

    /// Enqueue a request whose ρ has already been snapped to a level.
    pub fn push(&mut self, req: Request) {
        let idx = self
            .levels
            .iter()
            .position(|&l| (l - req.rho).abs() < 1e-9)
            .expect("router must snap rho before push");
        self.queues[idx].push_back(req);
        self.pending += 1;
    }

    /// The policy: pick the queue whose head has waited longest; fire if
    /// it's full or its head has exceeded the window. `now` injected for
    /// testability.
    pub fn pop_ready(&mut self, now: Instant) -> Option<Batch> {
        let mut best: Option<(usize, Instant)> = None;
        for (i, q) in self.queues.iter().enumerate() {
            if let Some(head) = q.front() {
                let t = head.enqueued_at;
                let full = q.len() >= self.cfg.batch_size;
                let expired = now.duration_since(t) >= self.cfg.window;
                if full || expired {
                    match best {
                        Some((_, bt)) if bt <= t => {}
                        _ => best = Some((i, t)),
                    }
                }
            }
        }
        let (idx, _) = best?;
        let q = &mut self.queues[idx];
        let n = q.len().min(self.cfg.batch_size);
        let mut requests = Vec::with_capacity(n);
        for _ in 0..n {
            requests.push(q.pop_front().unwrap());
        }
        self.pending -= n;
        Some(Batch {
            rho: self.levels[idx],
            requests,
        })
    }

    /// Time until the earliest head expires (server loop sleep hint).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        self.queues
            .iter()
            .filter_map(|q| q.front())
            .map(|r| {
                self.cfg
                    .window
                    .saturating_sub(now.duration_since(r.enqueued_at))
            })
            .min()
    }

    /// Drain everything (shutdown path).
    pub fn drain(&mut self) -> Vec<Batch> {
        let mut out = Vec::new();
        for (i, q) in self.queues.iter_mut().enumerate() {
            while !q.is_empty() {
                let n = q.len().min(self.cfg.batch_size);
                let mut requests = Vec::with_capacity(n);
                for _ in 0..n {
                    requests.push(q.pop_front().unwrap());
                }
                self.pending -= n;
                out.push(Batch {
                    rho: self.levels[i],
                    requests,
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, rho: f64) -> Request {
        Request::new(id, vec![1, 2, 3], 3, rho, "synth_wiki", None)
    }

    fn mk() -> DynamicBatcher {
        DynamicBatcher::new(
            BatcherConfig {
                batch_size: 4,
                window: Duration::from_millis(10),
            },
            &[0.4, 1.0],
        )
    }

    #[test]
    fn full_batch_fires_immediately() {
        let mut b = mk();
        for i in 0..4 {
            b.push(req(i, 0.4));
        }
        let batch = b.pop_ready(Instant::now()).expect("should fire");
        assert_eq!(batch.len(), 4);
        assert_eq!(batch.rho, 0.4);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn partial_batch_waits_for_window() {
        let mut b = mk();
        b.push(req(1, 0.4));
        let now = Instant::now();
        assert!(b.pop_ready(now).is_none(), "window not expired");
        let later = now + Duration::from_millis(11);
        let batch = b.pop_ready(later).expect("expired window fires");
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn batches_never_mix_rho() {
        let mut b = mk();
        for i in 0..3 {
            b.push(req(i, 0.4));
        }
        for i in 3..6 {
            b.push(req(i, 1.0));
        }
        let later = Instant::now() + Duration::from_millis(20);
        while let Some(batch) = b.pop_ready(later) {
            let rhos: Vec<f64> = batch.requests.iter().map(|r| r.rho).collect();
            assert!(rhos.iter().all(|&r| (r - batch.rho).abs() < 1e-9));
        }
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn oldest_queue_first() {
        let mut b = mk();
        b.push(req(1, 0.4));
        std::thread::sleep(Duration::from_millis(2));
        b.push(req(2, 1.0));
        let later = Instant::now() + Duration::from_millis(30);
        let first = b.pop_ready(later).unwrap();
        assert_eq!(first.rho, 0.4, "older head must fire first");
    }

    #[test]
    fn oversize_queue_splits_into_full_batches() {
        let mut b = mk();
        for i in 0..9 {
            b.push(req(i, 1.0));
        }
        let later = Instant::now() + Duration::from_millis(30);
        let b1 = b.pop_ready(later).unwrap();
        let b2 = b.pop_ready(later).unwrap();
        let b3 = b.pop_ready(later).unwrap();
        assert_eq!((b1.len(), b2.len(), b3.len()), (4, 4, 1));
        // FIFO within level
        assert_eq!(b1.requests[0].id, 0);
        assert_eq!(b3.requests[0].id, 8);
    }

    #[test]
    fn next_deadline_counts_down() {
        let mut b = mk();
        let now = Instant::now();
        b.push(req(1, 0.4));
        let d = b.next_deadline(now + Duration::from_millis(4)).unwrap();
        assert!(d <= Duration::from_millis(7), "{d:?}");
    }

    #[test]
    fn drain_flushes_everything() {
        let mut b = mk();
        for i in 0..6 {
            b.push(req(i, if i % 2 == 0 { 0.4 } else { 1.0 }));
        }
        let batches = b.drain();
        let total: usize = batches.iter().map(Batch::len).sum();
        assert_eq!(total, 6);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    #[should_panic(expected = "snap")]
    fn unsnapped_rho_panics() {
        let mut b = mk();
        b.push(req(1, 0.73));
    }
}
