//! Request/response types exchanged between clients and the coordinator.

use crate::pruning::MaskPlan;
use std::sync::mpsc::Sender;
use std::time::Instant;

pub type RequestId = u64;

/// A decode request (the serving unit of the paper's system: prompt in,
/// pruned on the fly, greedy tokens out). `max_new = 1` degenerates to the
/// classic next-token form every backend supports; larger values ask the
/// host engine for a full multi-token generation under `plan`.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// Token window (already padded to the artifact's seq_len).
    pub tokens: Vec<i32>,
    pub valid_len: usize,
    /// Requested active-weight ratio; the router snaps it to a level.
    pub rho: f64,
    /// New tokens to decode (validated against the config cap by
    /// `Router::admit`; the pjrt backend only accepts 1).
    pub max_new: usize,
    /// When micro-expert selection is refreshed during this request's
    /// generation (host engine; ignored by the single-token pjrt path).
    pub plan: MaskPlan,
    /// Originating domain (metrics breakdown only).
    pub domain: String,
    pub enqueued_at: Instant,
    /// Where the response goes; `None` in tests that only exercise policy.
    pub reply: Option<Sender<Response>>,
}

impl Request {
    pub fn new(
        id: RequestId,
        tokens: Vec<i32>,
        valid_len: usize,
        rho: f64,
        domain: impl Into<String>,
        reply: Option<Sender<Response>>,
    ) -> Request {
        Request {
            id,
            tokens,
            valid_len,
            rho,
            max_new: 1,
            plan: MaskPlan::PruneOnce,
            domain: domain.into(),
            enqueued_at: Instant::now(),
            reply,
        }
    }

    /// Attach multi-token decode parameters (builder form so the many
    /// policy-only constructions stay one line).
    pub fn with_decode(mut self, max_new: usize, plan: MaskPlan) -> Request {
        self.max_new = max_new;
        self.plan = plan;
        self
    }
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Logits at the final decode step (vocab-sized), or empty on
    /// rejection. For `max_new = 1` these are exactly the next-token
    /// logits the pre-engine API returned.
    pub logits: Vec<f32>,
    /// First generated token (greedy decode convenience). Equals
    /// `tokens[0]` when `tokens` is non-empty; when the very first step
    /// emits EOS, `tokens` is empty (EOS is never included) while this
    /// still carries the EOS id; `-1` when no step ran at all.
    pub next_token: i32,
    /// Generated tokens, in order (EOS, if hit, is not included).
    pub tokens: Vec<i32>,
    /// Decode steps this request actually ran (≤ `max_new`; may stop
    /// early at EOS).
    pub steps: usize,
    /// End-to-end latency.
    pub latency_us: u64,
    /// Size of the batch this request rode in (occupancy telemetry).
    pub batch_size: usize,
    /// Execution time spent in full-window work for this request:
    /// selection passes + KV prefill/rebuild forwards (host engine;
    /// 0 on the single-token pjrt path).
    pub prefill_us: u64,
    /// Execution time spent in reused decode steps (single-token
    /// `forward_step`s with the KV cache on). The serve loop aggregates
    /// the split per ρ level in `Metrics`.
    pub step_us: u64,
    /// The sparsity level actually used after snapping.
    pub rho_used: f64,
    /// Set if the request was shed by admission control.
    pub rejected: Option<String>,
}

impl Response {
    pub fn rejected(id: RequestId, reason: impl Into<String>) -> Response {
        Response {
            id,
            logits: Vec::new(),
            next_token: -1,
            tokens: Vec::new(),
            steps: 0,
            latency_us: 0,
            batch_size: 0,
            prefill_us: 0,
            step_us: 0,
            rho_used: 0.0,
            rejected: Some(reason.into()),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.rejected.is_none()
    }
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn rejected_response() {
        let r = Response::rejected(7, "queue full");
        assert!(!r.is_ok());
        assert_eq!(r.id, 7);
        assert!(r.tokens.is_empty());
        assert_eq!(r.steps, 0);
    }

    #[test]
    fn request_defaults_to_single_token_and_builder_overrides() {
        let r = Request::new(1, vec![1, 2], 2, 0.5, "d", None);
        assert_eq!(r.max_new, 1);
        assert_eq!(r.plan, MaskPlan::PruneOnce);
        let r = r.with_decode(8, MaskPlan::Refresh(4));
        assert_eq!(r.max_new, 8);
        assert_eq!(r.plan, MaskPlan::Refresh(4));
    }
}
