//! Request/response types exchanged between clients and the coordinator.

use crate::pruning::MaskPlan;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

pub type RequestId = u64;

/// Cooperative cancellation handle for an in-flight request. The client
/// keeps a clone and calls [`CancelToken::cancel`]; the continuous serve
/// loop observes it **between decode sweeps**, frees the request's lane
/// mid-flight and delivers a terminal [`Response::cancelled`]. Queued
/// (not-yet-admitted) requests are shed at admission-pop time in both
/// serve modes; the drain-to-completion path cannot observe a cancel once
/// its batch is executing.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (sticky; observed between decode steps).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

/// One streamed decode step of a request, sent on `Request::stream` as
/// the token is produced. A request's events concatenate — in `index`
/// order, which is also delivery order — to exactly the terminal
/// [`Response::tokens`] (EOS, if hit, ends the stream without an event;
/// a cancelled request's events are the `tokens` of its terminal
/// cancelled response).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StepEvent {
    pub id: RequestId,
    /// 0-based position of this token within the generation.
    pub index: usize,
    pub token: i32,
}

/// A decode request (the serving unit of the paper's system: prompt in,
/// pruned on the fly, greedy tokens out). `max_new = 1` degenerates to the
/// classic next-token form every backend supports; larger values ask the
/// host engine for a full multi-token generation under `plan`.
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// Token window (already padded to the artifact's seq_len).
    pub tokens: Vec<i32>,
    pub valid_len: usize,
    /// Requested active-weight ratio; the router snaps it to a level.
    pub rho: f64,
    /// New tokens to decode (validated against the config cap by
    /// `Router::admit`; the pjrt backend only accepts 1).
    pub max_new: usize,
    /// When micro-expert selection is refreshed during this request's
    /// generation (host engine; ignored by the single-token pjrt path).
    pub plan: MaskPlan,
    /// Originating domain (metrics breakdown only).
    pub domain: String,
    pub enqueued_at: Instant,
    /// Where the response goes; `None` in tests that only exercise policy.
    pub reply: Option<Sender<Response>>,
    /// Optional per-token streaming channel: the serve loop sends one
    /// [`StepEvent`] per generated token (live from the lane in
    /// continuous mode; replayed post-execution on the drain path), then
    /// the terminal [`Response`] on `reply`. Honoured only when
    /// `decode.stream` is on.
    pub stream: Option<Sender<StepEvent>>,
    /// Cancellation token; the client clones it before submitting.
    pub cancel: CancelToken,
    /// Session id for cross-turn KV continuation (`crate::kvstore`): the
    /// serve loop prepends the session's parked window to `tokens`, seeds
    /// its cached rows, and re-parks the finished lane under this id.
    /// Validated by `Router::admit_decode`; `None` is a plain one-shot
    /// request.
    pub session: Option<String>,
}

impl Request {
    pub fn new(
        id: RequestId,
        tokens: Vec<i32>,
        valid_len: usize,
        rho: f64,
        domain: impl Into<String>,
        reply: Option<Sender<Response>>,
    ) -> Request {
        Request {
            id,
            tokens,
            valid_len,
            rho,
            max_new: 1,
            plan: MaskPlan::PruneOnce,
            domain: domain.into(),
            enqueued_at: Instant::now(),
            reply,
            stream: None,
            cancel: CancelToken::new(),
            session: None,
        }
    }

    /// Attach multi-token decode parameters (builder form so the many
    /// policy-only constructions stay one line).
    pub fn with_decode(mut self, max_new: usize, plan: MaskPlan) -> Request {
        self.max_new = max_new;
        self.plan = plan;
        self
    }

    /// Attach a per-token streaming channel.
    pub fn with_stream(mut self, stream: Sender<StepEvent>) -> Request {
        self.stream = Some(stream);
        self
    }

    /// Attach a session id for cross-turn KV continuation.
    pub fn with_session(mut self, session: Option<String>) -> Request {
        self.session = session;
        self
    }
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Logits at the final decode step (vocab-sized), or empty on
    /// rejection. For `max_new = 1` these are exactly the next-token
    /// logits the pre-engine API returned.
    pub logits: Vec<f32>,
    /// First generated token (greedy decode convenience). Equals
    /// `tokens[0]` when `tokens` is non-empty; when the very first step
    /// emits EOS, `tokens` is empty (EOS is never included) while this
    /// still carries the EOS id; `-1` when no step ran at all.
    pub next_token: i32,
    /// Generated tokens, in order (EOS, if hit, is not included).
    pub tokens: Vec<i32>,
    /// Decode steps this request actually ran (≤ `max_new`; may stop
    /// early at EOS).
    pub steps: usize,
    /// End-to-end latency.
    pub latency_us: u64,
    /// Occupancy telemetry: the executed batch's size on the drain path,
    /// or the lane-pool capacity under continuous batching.
    pub batch_size: usize,
    /// Execution time spent in full-window work for this request:
    /// selection passes + KV prefill/rebuild forwards (host engine;
    /// 0 on the single-token pjrt path).
    pub prefill_us: u64,
    /// Execution time spent in reused decode steps (single-token
    /// `forward_step`s with the KV cache on). The serve loop aggregates
    /// the split per ρ level in `Metrics`.
    pub step_us: u64,
    /// The sparsity level actually used after snapping.
    pub rho_used: f64,
    /// Prompt/window tokens prefilled by full forward work for this
    /// request (suffix-only on a prefix-store hit; see `crate::kvstore`).
    pub prefilled_tokens: usize,
    /// Window tokens whose K/V rows were seeded from the prefix store or
    /// a parked session instead of being recomputed.
    pub seeded_tokens: usize,
    /// Time spent queued before a lane/batch picked the request up,
    /// stamped by the serve loop (0 for rejected requests).
    pub queue_wait_us: u64,
    /// Time to first token: enqueue → first generated token. On the
    /// drain path (whole batch executes, then replies) this equals
    /// `latency_us`; the continuous loop stamps the first live
    /// [`StepEvent`]'s wall-clock instead.
    pub ttft_us: u64,
    /// Set if the request was shed by admission control.
    pub rejected: Option<String>,
}

/// Terminal-state marker of a cancelled request (the `rejected` reason
/// the serve loop uses, so clients can tell shed load from their own
/// cancellations).
pub const CANCELLED: &str = "cancelled";

impl Response {
    pub fn rejected(id: RequestId, reason: impl Into<String>) -> Response {
        Response {
            id,
            logits: Vec::new(),
            next_token: -1,
            tokens: Vec::new(),
            steps: 0,
            latency_us: 0,
            batch_size: 0,
            prefill_us: 0,
            step_us: 0,
            rho_used: 0.0,
            prefilled_tokens: 0,
            seeded_tokens: 0,
            queue_wait_us: 0,
            ttft_us: 0,
            rejected: Some(reason.into()),
        }
    }

    /// The terminal response of a cancelled request: carries whatever was
    /// decoded before the cancel was observed (matching any `StepEvent`s
    /// already streamed), marked `rejected = "cancelled"`.
    pub fn cancelled(id: RequestId, rho: f64, partial: &crate::decode::DecodeOutput) -> Response {
        Response::from_decode(id, rho, partial, Some(CANCELLED.into()))
    }

    /// Terminal response for a request cancelled while still queued (no
    /// lane ever ran, so there is no partial output).
    pub fn cancelled_before_start(id: RequestId, rho: f64) -> Response {
        Response {
            rho_used: rho,
            ..Response::rejected(id, CANCELLED)
        }
    }

    /// Map one lane's [`crate::decode::DecodeOutput`] to the wire form —
    /// shared by `HostEngine::execute` (drain) and the continuous serve
    /// loop so the two paths cannot diverge in how a generation is
    /// reported. `latency_us`/`batch_size` are stamped by the serve loop.
    pub fn from_decode(
        id: RequestId,
        rho: f64,
        out: &crate::decode::DecodeOutput,
        rejected: Option<String>,
    ) -> Response {
        Response {
            id,
            logits: out.steps.last().map(|s| s.logits.clone()).unwrap_or_default(),
            next_token: out.steps.first().map_or(-1, |s| s.token),
            tokens: out.new_tokens().to_vec(),
            steps: out.steps.len(),
            latency_us: 0,
            batch_size: 0,
            prefill_us: out.prefill_us,
            step_us: out.step_us,
            rho_used: rho,
            prefilled_tokens: out.prefilled_tokens,
            seeded_tokens: out.seeded_tokens,
            queue_wait_us: 0,
            ttft_us: 0,
            rejected,
        }
    }

    pub fn is_ok(&self) -> bool {
        self.rejected.is_none()
    }

    pub fn is_cancelled(&self) -> bool {
        self.rejected.as_deref() == Some(CANCELLED)
    }
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn rejected_response() {
        let r = Response::rejected(7, "queue full");
        assert!(!r.is_ok());
        assert_eq!(r.id, 7);
        assert!(r.tokens.is_empty());
        assert_eq!(r.steps, 0);
    }

    #[test]
    fn request_defaults_to_single_token_and_builder_overrides() {
        let r = Request::new(1, vec![1, 2], 2, 0.5, "d", None);
        assert_eq!(r.max_new, 1);
        assert_eq!(r.plan, MaskPlan::PruneOnce);
        assert!(r.stream.is_none());
        assert!(!r.cancel.is_cancelled());
        let r = r.with_decode(8, MaskPlan::Refresh(4));
        assert_eq!(r.max_new, 8);
        assert_eq!(r.plan, MaskPlan::Refresh(4));
        let (tx, _rx) = std::sync::mpsc::channel();
        let r = r.with_stream(tx);
        assert!(r.stream.is_some());
        assert!(r.session.is_none());
        let r = r.with_session(Some("chat-1".into()));
        assert_eq!(r.session.as_deref(), Some("chat-1"));
    }

    #[test]
    fn cancel_token_is_sticky_and_shared_across_clones() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!clone.is_cancelled());
        t.cancel();
        assert!(clone.is_cancelled(), "clones observe the cancel");
        assert!(t.is_cancelled(), "cancellation is sticky");
    }

    #[test]
    fn cancelled_responses_are_terminal_and_carry_partials() {
        let partial = crate::decode::DecodeOutput {
            tokens: vec![1, 2, 3, 40, 41],
            prompt_len: 3,
            steps: Vec::new(),
            refresh_count: 1,
            prefill_us: 10,
            step_us: 5,
            cache_hits: 0,
            cache_misses: 0,
            prefilled_tokens: 3,
            seeded_tokens: 0,
            parked: None,
        };
        let r = Response::cancelled(9, 0.6, &partial);
        assert_eq!((r.prefilled_tokens, r.seeded_tokens), (3, 0));
        assert!(!r.is_ok());
        assert!(r.is_cancelled());
        assert_eq!(r.tokens, vec![40, 41], "partial tokens survive");
        assert_eq!(r.rho_used, 0.6);
        let q = Response::cancelled_before_start(3, 0.4);
        assert!(q.is_cancelled());
        assert!(q.tokens.is_empty());
        assert_eq!(q.rho_used, 0.4);
        // a plain shed is not a cancellation
        assert!(!Response::rejected(1, "queue full").is_cancelled());
    }
}
