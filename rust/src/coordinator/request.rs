//! Request/response types exchanged between clients and the coordinator.

use std::sync::mpsc::Sender;
use std::time::Instant;

pub type RequestId = u64;

/// A next-token inference request (the serving unit of the paper's
/// system: prompt in, last-position logits out, pruned on the fly).
#[derive(Debug)]
pub struct Request {
    pub id: RequestId,
    /// Token window (already padded to the artifact's seq_len).
    pub tokens: Vec<i32>,
    pub valid_len: usize,
    /// Requested active-weight ratio; the router snaps it to a level.
    pub rho: f64,
    /// Originating domain (metrics breakdown only).
    pub domain: String,
    pub enqueued_at: Instant,
    /// Where the response goes; `None` in tests that only exercise policy.
    pub reply: Option<Sender<Response>>,
}

impl Request {
    pub fn new(
        id: RequestId,
        tokens: Vec<i32>,
        valid_len: usize,
        rho: f64,
        domain: impl Into<String>,
        reply: Option<Sender<Response>>,
    ) -> Request {
        Request {
            id,
            tokens,
            valid_len,
            rho,
            domain: domain.into(),
            enqueued_at: Instant::now(),
            reply,
        }
    }
}

/// Outcome of one request.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: RequestId,
    /// Next-token logits at the last valid position (vocab-sized), or
    /// empty on rejection.
    pub logits: Vec<f32>,
    /// Argmax token (greedy decode convenience).
    pub next_token: i32,
    /// End-to-end latency.
    pub latency_us: u64,
    /// Size of the batch this request rode in (occupancy telemetry).
    pub batch_size: usize,
    /// The sparsity level actually used after snapping.
    pub rho_used: f64,
    /// Set if the request was shed by admission control.
    pub rejected: Option<String>,
}

impl Response {
    pub fn rejected(id: RequestId, reason: impl Into<String>) -> Response {
        Response {
            id,
            logits: Vec::new(),
            next_token: -1,
            latency_us: 0,
            batch_size: 0,
            rho_used: 0.0,
            rejected: Some(reason.into()),
        }
    }

    pub fn is_ok(&self) -> bool {
        self.rejected.is_none()
    }
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in logits.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[-5.0]), 0);
    }

    #[test]
    fn rejected_response() {
        let r = Response::rejected(7, "queue full");
        assert!(!r.is_ok());
        assert_eq!(r.id, 7);
    }
}
