//! Serving metrics: lock-free counters + a log₂-bucketed latency histogram
//! good enough for p50/p95/p99 without allocation on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

const BUCKETS: usize = 40; // 2^0 .. 2^39 us (~9 minutes)

/// Shared metrics sink (all methods take &self; safe across threads).
#[derive(Debug)]
pub struct Metrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    pub batches: AtomicU64,
    pub batch_slots: AtomicU64,
    pub batch_occupied: AtomicU64,
    pub queue_peak: AtomicU64,
    latency_us: [AtomicU64; BUCKETS],
    latency_sum_us: AtomicU64,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_slots: AtomicU64::new(0),
            batch_occupied: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            latency_us: std::array::from_fn(|_| AtomicU64::new(0)),
            latency_sum_us: AtomicU64::new(0),
        }
    }

    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, occupied: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_occupied
            .fetch_add(occupied as u64, Ordering::Relaxed);
        self.batch_slots.fetch_add(capacity as u64, Ordering::Relaxed);
    }

    pub fn record_completion(&self, latency_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency_sum_us.fetch_add(latency_us, Ordering::Relaxed);
        let b = (64 - latency_us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.latency_us[b].fetch_add(1, Ordering::Relaxed);
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the containing bucket).
    pub fn latency_percentile_us(&self, pct: f64) -> u64 {
        let total: u64 = self
            .latency_us
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * pct / 100.0).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.latency_us.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    pub fn mean_latency_us(&self) -> f64 {
        let n = self.completed.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.latency_sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Mean fraction of batch slots actually occupied.
    pub fn batch_occupancy(&self) -> f64 {
        let slots = self.batch_slots.load(Ordering::Relaxed);
        if slots == 0 {
            return 0.0;
        }
        self.batch_occupied.load(Ordering::Relaxed) as f64 / slots as f64
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "accepted={} rejected={} completed={} batches={} occupancy={:.2} \
             mean_lat={:.0}us p50={}us p95={}us p99={}us",
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.batch_occupancy(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
        )
    }

    /// JSON dump for machine consumers.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::HashMap::new();
        let g = |k: &AtomicU64| Json::Num(k.load(Ordering::Relaxed) as f64);
        m.insert("accepted".into(), g(&self.accepted));
        m.insert("rejected".into(), g(&self.rejected));
        m.insert("completed".into(), g(&self.completed));
        m.insert("batches".into(), g(&self.batches));
        m.insert("occupancy".into(), Json::Num(self.batch_occupancy()));
        m.insert("mean_latency_us".into(), Json::Num(self.mean_latency_us()));
        m.insert(
            "p50_us".into(),
            Json::Num(self.latency_percentile_us(50.0) as f64),
        );
        m.insert(
            "p99_us".into(),
            Json::Num(self.latency_percentile_us(99.0) as f64),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_accept();
        m.record_accept();
        m.record_reject();
        assert_eq!(m.accepted.load(Ordering::Relaxed), 2);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn occupancy() {
        let m = Metrics::new();
        m.record_batch(4, 4);
        m.record_batch(2, 4);
        assert!((m.batch_occupancy() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_monotone() {
        let m = Metrics::new();
        for us in [10, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            m.record_completion(us);
        }
        let p50 = m.latency_percentile_us(50.0);
        let p95 = m.latency_percentile_us(95.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 >= 100_000);
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_percentile_zero() {
        assert_eq!(Metrics::new().latency_percentile_us(99.0), 0);
    }

    #[test]
    fn summary_and_json() {
        let m = Metrics::new();
        m.record_accept();
        m.record_completion(500);
        m.record_batch(1, 4);
        let s = m.summary();
        assert!(s.contains("accepted=1"));
        let j = m.to_json();
        assert_eq!(j.req("completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn queue_peak_tracks_max() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(5);
        assert_eq!(m.queue_peak.load(Ordering::Relaxed), 9);
    }
}
