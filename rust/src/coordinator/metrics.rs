//! Serving metrics: lock-free counters + log₂-bucketed µs histograms
//! (end-to-end latency, queue wait, server-side TTFT, inter-token gap)
//! good enough for p50/p95/p99 without allocation on the hot path, plus a
//! per-ρ-level decode breakdown (batches / requests / tokens per snapped
//! level, and aggregate decode tokens/sec) so host serving is observable
//! per level. Decode execution time is split into **prefill** (selection
//! passes + full-window KV prefill/rebuild forwards) vs **per-step**
//! (reused incremental steps) — the attribution that tells you whether
//! serve throughput is bound by selection/prefill cost or by steady-state
//! token stepping. The per-level map is the one mutex-guarded piece — it
//! is touched once per *batch*, not per request, and only by the serve
//! loop.

use crate::tensor::rho_milli;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

const BUCKETS: usize = 40; // 2^0 .. 2^39 us (~9 minutes)

/// Per-ρ-level decode counters (keyed by snapped level).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LevelStats {
    pub batches: u64,
    pub requests: u64,
    pub tokens: u64,
    /// Execution time in full-window work (selection + prefill/rebuild).
    pub prefill_us: u64,
    /// Execution time in reused decode steps.
    pub step_us: u64,
    /// Requests admitted into a *running* lane pool (continuous batching:
    /// a freed lane was refilled mid-run instead of waiting for the pool
    /// to drain). Seed admissions — lanes filled when the pool starts —
    /// are not counted.
    pub admitted_running: u64,
    /// Lane-occupancy numerator: active lanes summed over sweeps.
    pub lane_steps: u64,
    /// Lane-occupancy denominator: pool capacity summed over sweeps.
    pub lane_slots: u64,
    /// Matrix-major execution groups observed (each fused batch and each
    /// singleton fallback counts once).
    pub fused_groups: u64,
    /// Lane-rows carried by those groups; `fused_rows / fused_groups` is
    /// the mean fused width — 1.0 means fusion never engaged.
    pub fused_rows: u64,
    /// Histogram of group widths: buckets 1..=7 plus an 8+ overflow
    /// bucket, indexed by `width - 1`.
    pub fused_width_hist: [u64; 8],
    /// Window tokens prefilled by full forward work (selection +
    /// prefill/rebuild). With the prefix KV store on, a seeded admission
    /// prefills only its suffix, so this is the *residual* full-window
    /// work — the split prefix reuse exists to shrink.
    pub prefilled_tokens: u64,
    /// Window tokens whose K/V rows were seeded from the cross-request
    /// prefix store or a parked session instead of being recomputed.
    pub seeded_tokens: u64,
}

impl LevelStats {
    /// Mean fraction of lane-pool slots occupied per sweep (continuous
    /// serving's occupancy measure; 0 before any sweep was recorded).
    pub fn lane_occupancy(&self) -> f64 {
        if self.lane_slots == 0 {
            return 0.0;
        }
        self.lane_steps as f64 / self.lane_slots as f64
    }

    /// Mean lanes per execution group (1.0 = lane-major behaviour, > 1
    /// means matrix-major fusion engaged; 0 before any sweep).
    pub fn mean_fused_width(&self) -> f64 {
        if self.fused_groups == 0 {
            return 0.0;
        }
        self.fused_rows as f64 / self.fused_groups as f64
    }
}

/// Lock-free log₂-bucketed µs histogram (2^0 .. 2^39, ~9 minutes): one
/// relaxed atomic add per observation, cumulative `le`-bucket rendering
/// for Prometheus. Shared by the latency / queue-wait / TTFT /
/// inter-token-gap families so their shapes cannot drift.
#[derive(Debug)]
struct Histo {
    buckets: [AtomicU64; BUCKETS],
    sum_us: AtomicU64,
}

impl Histo {
    fn new() -> Histo {
        Histo {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
        }
    }

    fn record(&self, us: u64) {
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
    }

    fn total(&self) -> u64 {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).sum()
    }

    fn sum(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// Approximate percentile: the upper bound of the containing bucket
    /// (0 when empty).
    fn percentile(&self, pct: f64) -> u64 {
        let total = self.total();
        if total == 0 {
            return 0;
        }
        let target = ((total as f64) * pct / 100.0).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << BUCKETS
    }

    fn mean(&self) -> f64 {
        let n = self.total();
        if n == 0 {
            return 0.0;
        }
        self.sum() as f64 / n as f64
    }

    /// Append the family in Prometheus text format: cumulative `le`
    /// buckets (empties elided), `+Inf`, `_sum`, `_count`.
    fn render_prometheus(&self, s: &mut String, name: &str, help: &str) {
        use std::fmt::Write as _;
        let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let count = b.load(Ordering::Relaxed);
            cum += count;
            if count > 0 {
                let _ = writeln!(s, "{name}_bucket{{le=\"{}\"}} {cum}", 1u64 << (i + 1));
            }
        }
        let _ = writeln!(s, "{name}_bucket{{le=\"+Inf\"}} {cum}");
        let _ = writeln!(s, "{name}_sum {}", self.sum());
        let _ = writeln!(s, "{name}_count {cum}");
    }
}

/// Escape a string for use as a Prometheus label value (text format
/// 0.0.4: backslash, double quote and newline must be escaped).
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Shared metrics sink (all methods take &self; safe across threads).
#[derive(Debug)]
pub struct Metrics {
    pub accepted: AtomicU64,
    pub rejected: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that ended in a client cancellation (lane freed mid-flight
    /// or shed from the queue at admission-pop time). Disjoint from
    /// `completed` and `rejected`.
    pub cancelled: AtomicU64,
    /// Session requests shed because the registry was at capacity with
    /// every slot mid-flight (a subset of `rejected`; HTTP returns 429).
    pub sessions_rejected: AtomicU64,
    pub batches: AtomicU64,
    pub batch_slots: AtomicU64,
    pub batch_occupied: AtomicU64,
    pub queue_peak: AtomicU64,
    latency: Histo,
    /// Enqueue → execution pickup (lane admission / batch pop).
    queue_wait: Histo,
    /// Enqueue → first generated token, measured server-side.
    ttft: Histo,
    /// Wall-clock gap between consecutive tokens of one request
    /// (continuous serving only; the drain path has no live tokens).
    token_gap: Histo,
    decode_tokens: AtomicU64,
    decode_time_us: AtomicU64,
    decode_prefill_us: AtomicU64,
    decode_step_us: AtomicU64,
    // live occupancy gauges, overwritten by the serve loop after each
    // executed batch / sweep (last-write-wins snapshots, not counters)
    layout_cache_entries: AtomicU64,
    layout_cache_evictions: AtomicU64,
    kv_store_entries: AtomicU64,
    kv_store_tokens: AtomicU64,
    kv_store_evictions: AtomicU64,
    sessions_active: AtomicU64,
    levels: Mutex<HashMap<u32, LevelStats>>,
}

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            accepted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            cancelled: AtomicU64::new(0),
            sessions_rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_slots: AtomicU64::new(0),
            batch_occupied: AtomicU64::new(0),
            queue_peak: AtomicU64::new(0),
            latency: Histo::new(),
            queue_wait: Histo::new(),
            ttft: Histo::new(),
            token_gap: Histo::new(),
            decode_tokens: AtomicU64::new(0),
            decode_time_us: AtomicU64::new(0),
            decode_prefill_us: AtomicU64::new(0),
            decode_step_us: AtomicU64::new(0),
            layout_cache_entries: AtomicU64::new(0),
            layout_cache_evictions: AtomicU64::new(0),
            kv_store_entries: AtomicU64::new(0),
            kv_store_tokens: AtomicU64::new(0),
            kv_store_evictions: AtomicU64::new(0),
            sessions_active: AtomicU64::new(0),
            levels: Mutex::new(HashMap::new()),
        }
    }

    /// Snapshot the serve loop's shared layout-cache occupancy (resident
    /// entries; lifetime LRU evictions) for `/metrics`.
    pub fn set_layout_cache_gauges(&self, entries: usize, evictions: u64) {
        self.layout_cache_entries
            .store(entries as u64, Ordering::Relaxed);
        self.layout_cache_evictions.store(evictions, Ordering::Relaxed);
    }

    /// Snapshot the prefix KV store and session registry occupancy
    /// (`crate::kvstore`) for `/metrics`.
    pub fn set_kvstore_gauges(
        &self,
        entries: usize,
        resident_tokens: usize,
        evictions: u64,
        sessions: usize,
    ) {
        self.kv_store_entries.store(entries as u64, Ordering::Relaxed);
        self.kv_store_tokens
            .store(resident_tokens as u64, Ordering::Relaxed);
        self.kv_store_evictions.store(evictions, Ordering::Relaxed);
        self.sessions_active
            .store(sessions as u64, Ordering::Relaxed);
    }

    pub fn record_accept(&self) {
        self.accepted.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_reject(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_cancel(&self) {
        self.cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// A session request shed because the registry was at capacity (the
    /// caller also records the generic reject).
    pub fn record_session_rejected(&self) {
        self.sessions_rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// One request admitted into a *running* lane pool at a snapped level
    /// (continuous batching's refill path).
    pub fn record_admitted_running(&self, rho: f64) {
        let mut levels = self.levels.lock().expect("metrics level map poisoned");
        levels.entry(rho_milli(rho)).or_default().admitted_running += 1;
    }

    /// One lane-pool sweep at a snapped level: `active` lanes stepped out
    /// of `capacity` slots. The per-level ratio of the two sums is the
    /// mean lane occupancy continuous batching exists to lift.
    pub fn record_lane_sweep(&self, rho: f64, active: usize, capacity: usize) {
        let mut levels = self.levels.lock().expect("metrics level map poisoned");
        let e = levels.entry(rho_milli(rho)).or_default();
        e.lane_steps += active as u64;
        e.lane_slots += capacity as u64;
    }

    /// One lane-pool sweep's execution-group widths at a snapped level
    /// (matrix-major fusion: each fused batch or singleton fallback is
    /// one group carrying `width` lane-rows). No-op on an empty sweep.
    pub fn record_fused_sweep(&self, rho: f64, group_sizes: &[usize]) {
        if group_sizes.is_empty() {
            return;
        }
        let mut levels = self.levels.lock().expect("metrics level map poisoned");
        let e = levels.entry(rho_milli(rho)).or_default();
        for &w in group_sizes {
            e.fused_groups += 1;
            e.fused_rows += w as u64;
            e.fused_width_hist[w.clamp(1, 8) - 1] += 1;
        }
    }

    /// Aggregate mean fused width across levels (0 before any sweep).
    pub fn mean_fused_width(&self) -> f64 {
        let levels = self.levels.lock().expect("metrics level map poisoned");
        let (rows, groups) = levels
            .values()
            .fold((0u64, 0u64), |(a, b), s| (a + s.fused_rows, b + s.fused_groups));
        if groups == 0 {
            return 0.0;
        }
        rows as f64 / groups as f64
    }

    /// Aggregate mean lane occupancy across levels (0 before any sweep).
    pub fn lane_occupancy(&self) -> f64 {
        let levels = self.levels.lock().expect("metrics level map poisoned");
        let (steps, slots) = levels
            .values()
            .fold((0u64, 0u64), |(a, b), s| (a + s.lane_steps, b + s.lane_slots));
        if slots == 0 {
            return 0.0;
        }
        steps as f64 / slots as f64
    }

    pub fn record_queue_depth(&self, depth: usize) {
        self.queue_peak.fetch_max(depth as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, occupied: usize, capacity: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.batch_occupied
            .fetch_add(occupied as u64, Ordering::Relaxed);
        self.batch_slots.fetch_add(capacity as u64, Ordering::Relaxed);
    }

    /// One executed decode batch at a snapped level (drain path): how
    /// many requests it carried, how many tokens it generated, how long
    /// execution took and how that time splits into prefill-class
    /// (selection + full-window prefill/rebuild) vs per-step (reused
    /// incremental) work — plus the prefilled/seeded window-token split
    /// (seeded = K/V rows reused from the prefix store or a session).
    #[allow(clippy::too_many_arguments)] // mirrors record_decode_parts
    pub fn record_decode(
        &self,
        rho: f64,
        requests: usize,
        tokens: u64,
        elapsed_us: u64,
        prefill_us: u64,
        step_us: u64,
        prefilled_tokens: u64,
        seeded_tokens: u64,
    ) {
        self.record_decode_parts(
            rho,
            1,
            requests as u64,
            tokens,
            elapsed_us,
            prefill_us,
            step_us,
            prefilled_tokens,
            seeded_tokens,
        );
    }

    /// One lane-pool run starting at a snapped level (continuous path):
    /// counts one batch globally and per level, with the *seed* occupancy
    /// (how full the pool started; the per-sweep refill behaviour is what
    /// [`Metrics::record_lane_sweep`] measures).
    pub fn record_pool_run(&self, rho: f64, seeded: usize, capacity: usize) {
        self.record_batch(seeded, capacity);
        let mut levels = self.levels.lock().expect("metrics level map poisoned");
        levels.entry(rho_milli(rho)).or_default().batches += 1;
    }

    /// One finished — or cancelled-mid-flight — lane of a continuous
    /// pool: request/token/time accounting without a batch increment —
    /// its pool run was already counted once by
    /// [`Metrics::record_pool_run`], so `batches` keeps meaning
    /// "scheduling units" in both serve modes. Cancelled lanes report the
    /// steps they actually ran (that compute happened; capacity numbers
    /// must see it).
    #[allow(clippy::too_many_arguments)] // mirrors record_decode_parts
    pub fn record_lane_decode(
        &self,
        rho: f64,
        tokens: u64,
        elapsed_us: u64,
        prefill_us: u64,
        step_us: u64,
        prefilled_tokens: u64,
        seeded_tokens: u64,
    ) {
        self.record_decode_parts(
            rho,
            0,
            1,
            tokens,
            elapsed_us,
            prefill_us,
            step_us,
            prefilled_tokens,
            seeded_tokens,
        );
    }

    #[allow(clippy::too_many_arguments)] // private accumulator behind the two public forms
    fn record_decode_parts(
        &self,
        rho: f64,
        batches: u64,
        requests: u64,
        tokens: u64,
        elapsed_us: u64,
        prefill_us: u64,
        step_us: u64,
        prefilled_tokens: u64,
        seeded_tokens: u64,
    ) {
        self.decode_tokens.fetch_add(tokens, Ordering::Relaxed);
        self.decode_time_us.fetch_add(elapsed_us, Ordering::Relaxed);
        self.decode_prefill_us.fetch_add(prefill_us, Ordering::Relaxed);
        self.decode_step_us.fetch_add(step_us, Ordering::Relaxed);
        let mut levels = self.levels.lock().expect("metrics level map poisoned");
        let e = levels.entry(rho_milli(rho)).or_default();
        e.batches += batches;
        e.requests += requests;
        e.tokens += tokens;
        e.prefill_us += prefill_us;
        e.step_us += step_us;
        e.prefilled_tokens += prefilled_tokens;
        e.seeded_tokens += seeded_tokens;
    }

    /// Aggregate decode throughput over execution time (not wall time —
    /// idle batching windows don't dilute it).
    pub fn decode_tokens_per_sec(&self) -> f64 {
        let us = self.decode_time_us.load(Ordering::Relaxed);
        if us == 0 {
            return 0.0;
        }
        self.decode_tokens.load(Ordering::Relaxed) as f64 * 1e6 / us as f64
    }

    /// Aggregate (prefill_us, step_us) decode-time split.
    pub fn decode_time_split_us(&self) -> (u64, u64) {
        (
            self.decode_prefill_us.load(Ordering::Relaxed),
            self.decode_step_us.load(Ordering::Relaxed),
        )
    }

    /// Per-level decode counters, ascending by level.
    pub fn level_stats(&self) -> Vec<(f64, LevelStats)> {
        let levels = self.levels.lock().expect("metrics level map poisoned");
        let mut out: Vec<(f64, LevelStats)> = levels
            .iter()
            .map(|(&milli, &stats)| (milli as f64 / 1000.0, stats))
            .collect();
        out.sort_by(|a, b| a.0.total_cmp(&b.0));
        out
    }

    pub fn record_completion(&self, latency_us: u64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.latency.record(latency_us);
    }

    /// Queued time of one request (enqueue → execution pickup), stamped
    /// by the serve loop when a batch pops it or a lane admits it.
    pub fn record_queue_wait(&self, us: u64) {
        self.queue_wait.record(us);
    }

    /// Server-side time-to-first-token of one request (enqueue → first
    /// generated token; equals delivery latency on the drain path, which
    /// only replies once the whole batch has executed).
    pub fn record_ttft(&self, us: u64) {
        self.ttft.record(us);
    }

    /// Gap between two consecutive live tokens of one continuously
    /// decoded request.
    pub fn record_token_gap(&self, us: u64) {
        self.token_gap.record(us);
    }

    /// Approximate latency percentile from the histogram (upper bound of
    /// the containing bucket).
    pub fn latency_percentile_us(&self, pct: f64) -> u64 {
        self.latency.percentile(pct)
    }

    pub fn ttft_percentile_us(&self, pct: f64) -> u64 {
        self.ttft.percentile(pct)
    }

    pub fn queue_wait_percentile_us(&self, pct: f64) -> u64 {
        self.queue_wait.percentile(pct)
    }

    /// `(count, sum_us)` of the server-side TTFT histogram — lets tests
    /// bracket client-observed TTFT without parsing `/metrics` text.
    pub fn ttft_stats(&self) -> (u64, u64) {
        (self.ttft.total(), self.ttft.sum())
    }

    pub fn mean_latency_us(&self) -> f64 {
        self.latency.mean()
    }

    /// Mean fraction of batch slots actually occupied.
    pub fn batch_occupancy(&self) -> f64 {
        let slots = self.batch_slots.load(Ordering::Relaxed);
        if slots == 0 {
            return 0.0;
        }
        self.batch_occupied.load(Ordering::Relaxed) as f64 / slots as f64
    }

    /// One-line human summary (plus one line per active ρ level).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "accepted={} rejected={} completed={} cancelled={} batches={} \
             occupancy={:.2} lane_occ={:.2} fused_width={:.2} \
             mean_lat={:.0}us p50={}us \
             p95={}us p99={}us decode_tok_s={:.1}",
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.completed.load(Ordering::Relaxed),
            self.cancelled.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.batch_occupancy(),
            self.lane_occupancy(),
            self.mean_fused_width(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.decode_tokens_per_sec(),
        );
        let (prefill, step) = self.decode_time_split_us();
        s.push_str(&format!(" prefill_us={prefill} step_us={step}"));
        for (rho, st) in self.level_stats() {
            s.push_str(&format!(
                "\n  level rho={rho:.2}: batches={} requests={} tokens={} \
                 prefill_us={} step_us={} prefilled={} seeded={} \
                 admitted_running={} lane_occ={:.2} fused_width={:.2}",
                st.batches,
                st.requests,
                st.tokens,
                st.prefill_us,
                st.step_us,
                st.prefilled_tokens,
                st.seeded_tokens,
                st.admitted_running,
                st.lane_occupancy(),
                st.mean_fused_width(),
            ));
        }
        s
    }

    /// Prometheus text-format (version 0.0.4) rendering for the HTTP
    /// `/metrics` endpoint: global counters/gauges, the request-latency
    /// histogram (log₂ buckets mapped to cumulative `le` buckets), and the
    /// per-ρ-level decode counters — including per-level token counters
    /// and the fused-width histogram — as `rho`-labelled families.
    pub fn to_prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::with_capacity(4096);
        let counter = |s: &mut String, name: &str, help: &str, v: u64| {
            let _ = writeln!(
                s,
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}"
            );
        };
        let gauge = |s: &mut String, name: &str, help: &str, v: f64| {
            let _ = writeln!(
                s,
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}"
            );
        };
        let g = |k: &AtomicU64| k.load(Ordering::Relaxed);
        counter(
            &mut s,
            "mumoe_requests_accepted_total",
            "Requests admitted by the router",
            g(&self.accepted),
        );
        counter(
            &mut s,
            "mumoe_requests_rejected_total",
            "Requests shed by admission control or failed execution",
            g(&self.rejected),
        );
        counter(
            &mut s,
            "mumoe_requests_completed_total",
            "Requests that delivered a successful terminal response",
            g(&self.completed),
        );
        counter(
            &mut s,
            "mumoe_requests_cancelled_total",
            "Requests that ended in a client cancellation",
            g(&self.cancelled),
        );
        counter(
            &mut s,
            "mumoe_sessions_rejected_total",
            "Session requests shed at the registry capacity bound",
            g(&self.sessions_rejected),
        );
        counter(
            &mut s,
            "mumoe_batches_total",
            "Scheduling units executed (drained batches + lane-pool runs)",
            g(&self.batches),
        );
        counter(
            &mut s,
            "mumoe_decode_tokens_total",
            "Tokens generated by decode execution",
            g(&self.decode_tokens),
        );
        counter(
            &mut s,
            "mumoe_decode_prefill_us_total",
            "Decode execution time in selection + full-window prefill/rebuild work (us)",
            g(&self.decode_prefill_us),
        );
        counter(
            &mut s,
            "mumoe_decode_step_us_total",
            "Decode execution time in reused incremental steps (us)",
            g(&self.decode_step_us),
        );
        gauge(
            &mut s,
            "mumoe_queue_peak",
            "Highest queue depth observed at admission",
            g(&self.queue_peak) as f64,
        );
        gauge(
            &mut s,
            "mumoe_batch_occupancy",
            "Mean fraction of batch slots occupied",
            self.batch_occupancy(),
        );
        gauge(
            &mut s,
            "mumoe_lane_occupancy",
            "Mean fraction of lane-pool slots active per sweep",
            self.lane_occupancy(),
        );
        gauge(
            &mut s,
            "mumoe_mean_fused_width",
            "Mean lanes per matrix-major execution group",
            self.mean_fused_width(),
        );
        gauge(
            &mut s,
            "mumoe_decode_tokens_per_sec",
            "Aggregate decode throughput over execution time",
            self.decode_tokens_per_sec(),
        );
        gauge(
            &mut s,
            "mumoe_layout_cache_entries",
            "Resident entries in the serve loop's shared layout cache",
            g(&self.layout_cache_entries) as f64,
        );
        counter(
            &mut s,
            "mumoe_layout_cache_evictions_total",
            "Layout-cache entries evicted by the LRU capacity bound",
            g(&self.layout_cache_evictions),
        );
        gauge(
            &mut s,
            "mumoe_kvstore_entries",
            "Resident prefix entries in the cross-request KV store",
            g(&self.kv_store_entries) as f64,
        );
        gauge(
            &mut s,
            "mumoe_kvstore_resident_tokens",
            "Cached K/V tokens resident in the cross-request KV store",
            g(&self.kv_store_tokens) as f64,
        );
        counter(
            &mut s,
            "mumoe_kvstore_evictions_total",
            "Prefix entries evicted from the KV store under its token budget",
            g(&self.kv_store_evictions),
        );
        gauge(
            &mut s,
            "mumoe_sessions_active",
            "Parked sessions resident in the session registry",
            g(&self.sessions_active) as f64,
        );

        // µs histograms: log2 buckets render as cumulative `le` bounds
        self.latency.render_prometheus(
            &mut s,
            "mumoe_request_latency_us",
            "End-to-end request latency (us)",
        );
        self.queue_wait.render_prometheus(
            &mut s,
            "mumoe_queue_wait_us",
            "Time requests spent queued before execution pickup (us)",
        );
        self.ttft.render_prometheus(
            &mut s,
            "mumoe_ttft_us",
            "Server-side time to first generated token (us)",
        );
        self.token_gap.render_prometheus(
            &mut s,
            "mumoe_inter_token_gap_us",
            "Gap between consecutive tokens of a continuously decoded request (us)",
        );

        // per-ρ-level decode families, `rho`-labelled
        let levels = self.level_stats();
        let level_counter =
            |s: &mut String, name: &str, help: &str, get: &dyn Fn(&LevelStats) -> u64| {
                let _ = writeln!(s, "# HELP {name} {help}\n# TYPE {name} counter");
                for (rho, st) in &levels {
                    let _ = writeln!(s, "{name}{{rho=\"{rho:.2}\"}} {}", get(st));
                }
            };
        level_counter(
            &mut s,
            "mumoe_level_tokens_total",
            "Tokens generated per snapped rho level",
            &|st| st.tokens,
        );
        level_counter(
            &mut s,
            "mumoe_level_requests_total",
            "Requests decoded per snapped rho level",
            &|st| st.requests,
        );
        level_counter(
            &mut s,
            "mumoe_level_batches_total",
            "Scheduling units per snapped rho level",
            &|st| st.batches,
        );
        level_counter(
            &mut s,
            "mumoe_level_prefill_us_total",
            "Prefill-class execution time per snapped rho level (us)",
            &|st| st.prefill_us,
        );
        level_counter(
            &mut s,
            "mumoe_level_step_us_total",
            "Per-step execution time per snapped rho level (us)",
            &|st| st.step_us,
        );
        level_counter(
            &mut s,
            "mumoe_level_prefilled_tokens_total",
            "Window tokens prefilled by full forward work per snapped rho level",
            &|st| st.prefilled_tokens,
        );
        level_counter(
            &mut s,
            "mumoe_level_seeded_tokens_total",
            "Window tokens seeded from the prefix KV store or a session per snapped rho level",
            &|st| st.seeded_tokens,
        );
        level_counter(
            &mut s,
            "mumoe_level_admitted_running_total",
            "Requests admitted into a running lane pool per snapped rho level",
            &|st| st.admitted_running,
        );
        let _ = writeln!(
            s,
            "# HELP mumoe_level_lane_occupancy Mean lane occupancy per snapped rho level\n\
             # TYPE mumoe_level_lane_occupancy gauge"
        );
        for (rho, st) in &levels {
            let _ = writeln!(
                s,
                "mumoe_level_lane_occupancy{{rho=\"{rho:.2}\"}} {}",
                st.lane_occupancy()
            );
        }
        // fused-width histogram: widths 1..7 plus the 8+ overflow bucket
        let _ = writeln!(
            s,
            "# HELP mumoe_fused_width_groups Matrix-major execution groups by fused width \
             per snapped rho level\n# TYPE mumoe_fused_width_groups counter"
        );
        for (rho, st) in &levels {
            for (i, &count) in st.fused_width_hist.iter().enumerate() {
                if count > 0 {
                    let width = if i == 7 {
                        "8+".to_string()
                    } else {
                        (i + 1).to_string()
                    };
                    let _ = writeln!(
                        s,
                        "mumoe_fused_width_groups{{rho=\"{rho:.2}\",width=\"{width}\"}} {count}"
                    );
                }
            }
        }
        s
    }

    /// JSON dump for machine consumers.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::HashMap::new();
        let g = |k: &AtomicU64| Json::Num(k.load(Ordering::Relaxed) as f64);
        m.insert("accepted".into(), g(&self.accepted));
        m.insert("rejected".into(), g(&self.rejected));
        m.insert("completed".into(), g(&self.completed));
        m.insert("cancelled".into(), g(&self.cancelled));
        m.insert("sessions_rejected".into(), g(&self.sessions_rejected));
        m.insert("batches".into(), g(&self.batches));
        m.insert("occupancy".into(), Json::Num(self.batch_occupancy()));
        m.insert("lane_occupancy".into(), Json::Num(self.lane_occupancy()));
        m.insert("mean_fused_width".into(), Json::Num(self.mean_fused_width()));
        m.insert("mean_latency_us".into(), Json::Num(self.mean_latency_us()));
        m.insert(
            "p50_us".into(),
            Json::Num(self.latency_percentile_us(50.0) as f64),
        );
        m.insert(
            "p99_us".into(),
            Json::Num(self.latency_percentile_us(99.0) as f64),
        );
        m.insert("decode_tokens".into(), g(&self.decode_tokens));
        m.insert(
            "decode_tokens_per_sec".into(),
            Json::Num(self.decode_tokens_per_sec()),
        );
        m.insert("decode_prefill_us".into(), g(&self.decode_prefill_us));
        m.insert("decode_step_us".into(), g(&self.decode_step_us));
        m.insert(
            "queue_wait_mean_us".into(),
            Json::Num(self.queue_wait.mean()),
        );
        m.insert("ttft_mean_us".into(), Json::Num(self.ttft.mean()));
        m.insert(
            "ttft_p50_us".into(),
            Json::Num(self.ttft.percentile(50.0) as f64),
        );
        m.insert(
            "inter_token_gap_mean_us".into(),
            Json::Num(self.token_gap.mean()),
        );
        let mut levels = std::collections::HashMap::new();
        for (rho, st) in self.level_stats() {
            levels.insert(
                format!("{rho:.2}"),
                Json::Obj(std::collections::HashMap::from([
                    ("batches".into(), Json::Num(st.batches as f64)),
                    ("requests".into(), Json::Num(st.requests as f64)),
                    ("tokens".into(), Json::Num(st.tokens as f64)),
                    ("prefill_us".into(), Json::Num(st.prefill_us as f64)),
                    ("step_us".into(), Json::Num(st.step_us as f64)),
                    (
                        "prefilled_tokens".into(),
                        Json::Num(st.prefilled_tokens as f64),
                    ),
                    ("seeded_tokens".into(), Json::Num(st.seeded_tokens as f64)),
                    (
                        "admitted_running".into(),
                        Json::Num(st.admitted_running as f64),
                    ),
                    ("lane_occupancy".into(), Json::Num(st.lane_occupancy())),
                    ("fused_groups".into(), Json::Num(st.fused_groups as f64)),
                    ("fused_rows".into(), Json::Num(st.fused_rows as f64)),
                    (
                        "mean_fused_width".into(),
                        Json::Num(st.mean_fused_width()),
                    ),
                    (
                        "fused_width_hist".into(),
                        Json::Arr(
                            st.fused_width_hist
                                .iter()
                                .map(|&c| Json::Num(c as f64))
                                .collect(),
                        ),
                    ),
                ])),
            );
        }
        m.insert("levels".into(), Json::Obj(levels));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_accept();
        m.record_accept();
        m.record_reject();
        assert_eq!(m.accepted.load(Ordering::Relaxed), 2);
        assert_eq!(m.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn occupancy() {
        let m = Metrics::new();
        m.record_batch(4, 4);
        m.record_batch(2, 4);
        assert!((m.batch_occupancy() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn latency_percentiles_monotone() {
        let m = Metrics::new();
        for us in [10, 20, 40, 80, 160, 320, 640, 1280, 2560, 100_000] {
            m.record_completion(us);
        }
        let p50 = m.latency_percentile_us(50.0);
        let p95 = m.latency_percentile_us(95.0);
        let p99 = m.latency_percentile_us(99.0);
        assert!(p50 <= p95 && p95 <= p99);
        assert!(p99 >= 100_000);
        assert!(m.mean_latency_us() > 0.0);
    }

    #[test]
    fn empty_percentile_zero() {
        assert_eq!(Metrics::new().latency_percentile_us(99.0), 0);
    }

    #[test]
    fn summary_and_json() {
        let m = Metrics::new();
        m.record_accept();
        m.record_completion(500);
        m.record_batch(1, 4);
        let s = m.summary();
        assert!(s.contains("accepted=1"));
        let j = m.to_json();
        assert_eq!(j.req("completed").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn per_level_decode_counters_accumulate() {
        let m = Metrics::new();
        m.record_decode(0.4, 3, 12, 1_000, 700, 300, 20, 0);
        m.record_decode(0.4, 1, 4, 500, 400, 100, 1, 7);
        m.record_decode(1.0, 2, 2, 250, 250, 0, 6, 0);
        let levels = m.level_stats();
        assert_eq!(levels.len(), 2);
        assert_eq!(levels[0].0, 0.4);
        assert_eq!(
            levels[0].1,
            LevelStats {
                batches: 2,
                requests: 4,
                tokens: 16,
                prefill_us: 1_100,
                step_us: 400,
                prefilled_tokens: 21,
                seeded_tokens: 7,
                ..Default::default()
            }
        );
        assert_eq!(levels[1].0, 1.0);
        assert_eq!(levels[1].1.tokens, 2);
        assert_eq!(levels[1].1.step_us, 0);
        // 18 tokens over 1750us
        let tps = m.decode_tokens_per_sec();
        assert!((tps - 18.0 * 1e6 / 1750.0).abs() < 1e-6, "{tps}");
        assert_eq!(m.decode_time_split_us(), (1_350, 400));
    }

    #[test]
    fn decode_rate_zero_before_any_batch() {
        assert_eq!(Metrics::new().decode_tokens_per_sec(), 0.0);
        assert!(Metrics::new().level_stats().is_empty());
    }

    #[test]
    fn summary_and_json_carry_levels() {
        let m = Metrics::new();
        m.record_decode(0.6, 2, 8, 1_000, 900, 100, 9, 5);
        let s = m.summary();
        assert!(s.contains("decode_tok_s="), "{s}");
        assert!(s.contains("level rho=0.60"), "{s}");
        assert!(s.contains("prefill_us=900"), "{s}");
        assert!(s.contains("step_us=100"), "{s}");
        assert!(s.contains("prefilled=9"), "{s}");
        assert!(s.contains("seeded=5"), "{s}");
        let j = m.to_json();
        assert_eq!(j.req("decode_tokens").unwrap().as_f64(), Some(8.0));
        assert_eq!(j.req("decode_prefill_us").unwrap().as_f64(), Some(900.0));
        assert_eq!(j.req("decode_step_us").unwrap().as_f64(), Some(100.0));
        let levels = j.req("levels").unwrap();
        let l = levels.req("0.60").unwrap();
        assert_eq!(l.req("requests").unwrap().as_f64(), Some(2.0));
        assert_eq!(l.req("prefill_us").unwrap().as_f64(), Some(900.0));
        assert_eq!(l.req("step_us").unwrap().as_f64(), Some(100.0));
        assert_eq!(l.req("prefilled_tokens").unwrap().as_f64(), Some(9.0));
        assert_eq!(l.req("seeded_tokens").unwrap().as_f64(), Some(5.0));
    }

    #[test]
    fn lane_occupancy_and_continuous_counters() {
        let m = Metrics::new();
        assert_eq!(m.lane_occupancy(), 0.0, "no sweeps yet");
        // 4-slot pool: three sweeps at 4/4, 2/4, 2/4 active
        m.record_lane_sweep(0.4, 4, 4);
        m.record_lane_sweep(0.4, 2, 4);
        m.record_lane_sweep(0.6, 2, 4);
        m.record_admitted_running(0.4);
        m.record_admitted_running(0.4);
        m.record_cancel();
        // one pool run seeded 3/4 full, finishing four lanes: batches
        // counts scheduling units (1), not completed lanes (4)
        m.record_pool_run(0.4, 3, 4);
        for _ in 0..4 {
            m.record_lane_decode(0.4, 2, 100, 80, 20, 3, 2);
        }
        assert!((m.lane_occupancy() - 8.0 / 12.0).abs() < 1e-9);
        assert_eq!(m.cancelled.load(Ordering::Relaxed), 1);
        assert_eq!(m.batches.load(Ordering::Relaxed), 1);
        assert!((m.batch_occupancy() - 0.75).abs() < 1e-9, "seed occupancy");
        let levels = m.level_stats();
        assert_eq!(levels[0].0, 0.4);
        assert_eq!(levels[0].1.admitted_running, 2);
        assert_eq!(levels[0].1.batches, 1, "one pool run, not four lanes");
        assert_eq!(levels[0].1.requests, 4);
        assert_eq!(levels[0].1.tokens, 8);
        assert_eq!(levels[0].1.prefill_us, 320);
        assert_eq!(levels[0].1.step_us, 80);
        assert_eq!(levels[0].1.prefilled_tokens, 12);
        assert_eq!(levels[0].1.seeded_tokens, 8);
        assert!((levels[0].1.lane_occupancy() - 6.0 / 8.0).abs() < 1e-9);
        assert_eq!(levels[1].1.admitted_running, 0);
        let s = m.summary();
        assert!(s.contains("cancelled=1"), "{s}");
        assert!(s.contains("lane_occ="), "{s}");
        assert!(s.contains("admitted_running=2"), "{s}");
        let j = m.to_json();
        assert_eq!(j.req("cancelled").unwrap().as_f64(), Some(1.0));
        assert!((j.req("lane_occupancy").unwrap().as_f64().unwrap() - 8.0 / 12.0).abs() < 1e-9);
        let l = j.req("levels").unwrap().req("0.40").unwrap();
        assert_eq!(l.req("admitted_running").unwrap().as_f64(), Some(2.0));
        assert!((l.req("lane_occupancy").unwrap().as_f64().unwrap() - 0.75).abs() < 1e-9);
    }

    #[test]
    fn fused_width_histogram_accumulates() {
        let m = Metrics::new();
        assert_eq!(m.mean_fused_width(), 0.0, "no sweeps yet");
        m.record_fused_sweep(0.4, &[]); // empty sweep must not create a level
        assert!(m.level_stats().is_empty());
        // Two sweeps at rho=0.4: [3, 1] then [4]; one sweep at 0.6: [1, 1].
        m.record_fused_sweep(0.4, &[3, 1]);
        m.record_fused_sweep(0.4, &[4]);
        m.record_fused_sweep(0.6, &[1, 1]);
        // A width-12 group lands in the 8+ overflow bucket.
        m.record_fused_sweep(0.6, &[12]);
        let levels = m.level_stats();
        assert_eq!(levels[0].0, 0.4);
        let st = levels[0].1;
        assert_eq!(st.fused_groups, 3);
        assert_eq!(st.fused_rows, 8);
        assert_eq!(st.fused_width_hist[0], 1); // width 1
        assert_eq!(st.fused_width_hist[2], 1); // width 3
        assert_eq!(st.fused_width_hist[3], 1); // width 4
        assert!((st.mean_fused_width() - 8.0 / 3.0).abs() < 1e-9);
        assert_eq!(levels[1].1.fused_width_hist[7], 1, "12 overflows to 8+");
        // Aggregate: (8 + 2 + 12) rows over (3 + 2 + 1) groups.
        assert!((m.mean_fused_width() - 22.0 / 6.0).abs() < 1e-9);
        let s = m.summary();
        assert!(s.contains("fused_width="), "{s}");
        let j = m.to_json();
        assert!(j.req("mean_fused_width").unwrap().as_f64().unwrap() > 1.0);
        let l = j.req("levels").unwrap().req("0.40").unwrap();
        assert_eq!(l.req("fused_groups").unwrap().as_f64(), Some(3.0));
        assert_eq!(l.req("fused_rows").unwrap().as_f64(), Some(8.0));
        assert!(
            (l.req("mean_fused_width").unwrap().as_f64().unwrap() - 8.0 / 3.0).abs() < 1e-9
        );
    }

    #[test]
    fn prometheus_text_carries_counters_levels_and_histograms() {
        let m = Metrics::new();
        m.record_accept();
        m.record_completion(500);
        m.record_decode(0.6, 2, 8, 1_000, 900, 100, 9, 5);
        m.record_fused_sweep(0.6, &[3, 1, 12]);
        let text = m.to_prometheus();
        assert!(text.contains("# TYPE mumoe_requests_accepted_total counter"), "{text}");
        assert!(text.contains("mumoe_requests_accepted_total 1"), "{text}");
        assert!(text.contains("mumoe_requests_completed_total 1"), "{text}");
        // 500us lands in the 2^8..2^9 bucket => cumulative at le="512"
        assert!(text.contains("mumoe_request_latency_us_bucket{le=\"512\"} 1"), "{text}");
        assert!(text.contains("mumoe_request_latency_us_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("mumoe_request_latency_us_sum 500"), "{text}");
        assert!(text.contains("mumoe_level_tokens_total{rho=\"0.60\"} 8"), "{text}");
        assert!(text.contains("mumoe_level_requests_total{rho=\"0.60\"} 2"), "{text}");
        assert!(
            text.contains("mumoe_level_prefilled_tokens_total{rho=\"0.60\"} 9"),
            "{text}"
        );
        assert!(
            text.contains("mumoe_level_seeded_tokens_total{rho=\"0.60\"} 5"),
            "{text}"
        );
        assert!(text.contains("mumoe_fused_width_groups{rho=\"0.60\",width=\"3\"} 1"), "{text}");
        assert!(text.contains("mumoe_fused_width_groups{rho=\"0.60\",width=\"8+\"} 1"), "{text}");
        // empty buckets are elided; the zero-width family never renders a
        // width it did not observe
        assert!(!text.contains("width=\"5\""), "{text}");
    }

    #[test]
    fn occupancy_gauges_snapshot_latest_values() {
        let m = Metrics::new();
        let text = m.to_prometheus();
        assert!(text.contains("mumoe_layout_cache_entries 0"), "{text}");
        assert!(text.contains("mumoe_kvstore_entries 0"), "{text}");
        m.set_layout_cache_gauges(3, 7);
        m.set_kvstore_gauges(2, 48, 5, 1);
        // last write wins: these are snapshots, not accumulators
        m.set_layout_cache_gauges(4, 9);
        let text = m.to_prometheus();
        assert!(text.contains("mumoe_layout_cache_entries 4"), "{text}");
        assert!(text.contains("mumoe_layout_cache_evictions_total 9"), "{text}");
        assert!(text.contains("mumoe_kvstore_entries 2"), "{text}");
        assert!(text.contains("mumoe_kvstore_resident_tokens 48"), "{text}");
        assert!(text.contains("mumoe_kvstore_evictions_total 5"), "{text}");
        assert!(text.contains("mumoe_sessions_active 1"), "{text}");
    }

    #[test]
    fn session_rejections_render_in_prometheus_and_json() {
        let m = Metrics::new();
        m.record_reject();
        m.record_session_rejected();
        let text = m.to_prometheus();
        assert!(text.contains("mumoe_sessions_rejected_total 1"), "{text}");
        let j = m.to_json();
        assert_eq!(j.req("sessions_rejected").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn queue_peak_tracks_max() {
        let m = Metrics::new();
        m.record_queue_depth(3);
        m.record_queue_depth(9);
        m.record_queue_depth(5);
        assert_eq!(m.queue_peak.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn prometheus_families_have_exactly_one_type_line() {
        let m = Metrics::new();
        m.record_accept();
        m.record_completion(500);
        m.record_queue_wait(100);
        m.record_ttft(300);
        m.record_token_gap(50);
        m.record_decode(0.6, 2, 8, 1_000, 900, 100, 9, 5);
        m.record_fused_sweep(0.6, &[3, 1]);
        let text = m.to_prometheus();
        let mut seen = std::collections::HashSet::new();
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let fam = rest.split_whitespace().next().unwrap().to_string();
                assert!(seen.insert(fam.clone()), "duplicate # TYPE for {fam}\n{text}");
            }
        }
        for fam in [
            "mumoe_request_latency_us",
            "mumoe_queue_wait_us",
            "mumoe_ttft_us",
            "mumoe_inter_token_gap_us",
        ] {
            assert!(seen.contains(fam), "missing # TYPE for {fam}\n{text}");
        }
    }

    /// Conformance: the `+Inf` bucket, `_count` and `_sum` of a rendered
    /// histogram family agree, and cumulative buckets never decrease.
    fn assert_histo_conformant(text: &str, name: &str, want_count: u64, want_sum: u64) {
        let inf = format!("{name}_bucket{{le=\"+Inf\"}} {want_count}");
        assert!(text.contains(&inf), "{name}: missing `{inf}`\n{text}");
        assert!(
            text.contains(&format!("{name}_count {want_count}")),
            "{name}: _count != +Inf bucket\n{text}"
        );
        assert!(
            text.contains(&format!("{name}_sum {want_sum}")),
            "{name}: bad _sum\n{text}"
        );
        let prefix = format!("{name}_bucket{{le=\"");
        let mut prev = 0u64;
        let mut bucket_lines = 0usize;
        for line in text.lines().filter(|l| l.starts_with(&prefix)) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= prev, "buckets not cumulative: {line}\n{text}");
            prev = v;
            bucket_lines += 1;
        }
        assert!(bucket_lines >= 1, "{name}: no bucket lines\n{text}");
        assert_eq!(prev, want_count, "{name}: last bucket is not the total");
    }

    #[test]
    fn histogram_inf_count_and_sum_are_consistent() {
        let m = Metrics::new();
        m.record_completion(500);
        m.record_completion(4_000);
        m.record_queue_wait(120);
        m.record_ttft(10);
        m.record_ttft(90_000);
        m.record_token_gap(7);
        let text = m.to_prometheus();
        assert_histo_conformant(&text, "mumoe_request_latency_us", 2, 4_500);
        assert_histo_conformant(&text, "mumoe_queue_wait_us", 1, 120);
        assert_histo_conformant(&text, "mumoe_ttft_us", 2, 90_010);
        assert_histo_conformant(&text, "mumoe_inter_token_gap_us", 1, 7);
        assert_eq!(m.ttft_stats(), (2, 90_010));
        assert!(m.ttft_percentile_us(50.0) >= 10);
        assert!(m.queue_wait_percentile_us(99.0) >= 120);
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn json_carries_ttft_and_queue_wait() {
        let m = Metrics::new();
        m.record_queue_wait(200);
        m.record_ttft(1_000);
        m.record_token_gap(40);
        let j = m.to_json();
        assert_eq!(j.req("queue_wait_mean_us").unwrap().as_f64(), Some(200.0));
        assert_eq!(j.req("ttft_mean_us").unwrap().as_f64(), Some(1_000.0));
        assert!(j.req("ttft_p50_us").unwrap().as_f64().unwrap() >= 1_000.0);
        assert_eq!(
            j.req("inter_token_gap_mean_us").unwrap().as_f64(),
            Some(40.0)
        );
    }
}
