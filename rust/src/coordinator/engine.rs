//! The execution boundary of the serving stack: one [`Engine`] trait the
//! serve loop is generic over, with two backends behind it.
//!
//! * [`HostEngine`] — batched greedy decode on the host model
//!   ([`crate::decode::decode_batch`]) through the **router's shared
//!   [`LayoutCache`]**: batch-mates at one snapped ρ whose refresh steps
//!   select the same micro-experts share one set of compressed
//!   [`crate::tensor::RowSparse`] layouts. Works in the default
//!   (no-`pjrt`) build and honours multi-token requests.
//! * [`PjrtEngine`] (`--features pjrt`) — the PJRT artifact-session path:
//!   single-token batches against the AOT-compiled μ-MoE/dense graphs,
//!   exactly the loop body `coordinator::server` used to hard-code.
//!
//! The contract: [`Engine::prepare`] runs **on the serve thread** (PJRT
//! objects hold raw pointers and never cross threads; the host model just
//! doesn't need to) and returns a [`Prepared`] carrying the engine plus
//! the startup facts the loop needs (seq_len for the ready signal, batch
//! capacity for the batcher); capability introspection lives on
//! [`EngineKind`], where the router's admission check reads it.
//! [`Engine::execute`] consumes one ρ-keyed [`DecodeBatch`] and returns
//! exactly one [`Response`] per request, in request order; the loop owns
//! reply delivery, latency stamping and metrics, so engines stay pure
//! compute.

use super::batcher::DecodeBatch;
use super::metrics::Metrics;
use super::request::Response;
use crate::config::{EngineKind, ServeConfig};
use crate::decode::{decode_batch_observed, BatchRequest};
use crate::model::checkpoint::Checkpoint;
use crate::model::config_by_name;
use crate::nn::{random_model, Model};
use crate::tensor::LayoutCache;
use crate::util::error::Error;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Seed of the deterministic fallback model used when no checkpoint
/// exists under the artifacts dir — shared by `serve`, `generate`, the
/// host-serve e2e test and the serve-throughput bench so they all decode
/// the same weights.
pub const HOST_FALLBACK_SEED: u64 = 7;

/// Load the host model a [`ServeConfig`] names: the checkpoint if one
/// exists, else the deterministic random fallback (a *present but
/// corrupt* checkpoint is an error, never a silent fallback). A
/// `coordinator.eos_id` config override replaces the family default so
/// checkpoints whose vocabulary ends sequences differently stop at
/// *their* EOS (validated against the model's vocab here, where the
/// vocab size is known).
pub fn host_model(cfg: &ServeConfig) -> Result<Model, Error> {
    let mut mcfg = config_by_name(&cfg.model)
        .ok_or_else(|| Error::config(format!("unknown model '{}'", cfg.model)))?;
    if let Some(eos) = cfg.eos_id {
        if eos < 0 || eos as usize >= mcfg.vocab_size {
            return Err(Error::config(format!(
                "eos_id {eos} outside vocab (0..{})",
                mcfg.vocab_size
            )));
        }
        mcfg.eos_id = eos;
    }
    let ckpt_path = Path::new(&cfg.artifacts_dir)
        .join("ckpt")
        .join(format!("{}.ckpt", cfg.model));
    if ckpt_path.exists() {
        let ckpt = Checkpoint::load(&ckpt_path)?;
        Model::from_checkpoint(&mcfg, &ckpt)
    } else {
        crate::warn_!(
            "no checkpoint at {}; serving a deterministic random model",
            ckpt_path.display()
        );
        Ok(random_model(&mcfg, HOST_FALLBACK_SEED))
    }
}

/// A ready engine plus the startup facts the serve loop needs before the
/// first batch. Capability introspection (multi-token support) lives on
/// [`EngineKind`] instead — one source of truth, and it's the form the
/// router's admission check consumes.
pub struct Prepared<E> {
    pub engine: E,
    /// Token window requests are padded to (the ready-signal payload).
    pub seq_len: usize,
    /// Max requests per executed batch (sizes the batcher).
    pub batch_capacity: usize,
}

/// A serving backend. See the module docs for the contract.
pub trait Engine: Sized {
    /// Which config selector picks this engine.
    fn kind() -> EngineKind;

    /// Build the engine on the calling (serve) thread. `cache` is the
    /// router's shared layout cache; backends that don't compress
    /// layouts ignore it. `metrics` is the serve loop's shared sink for
    /// execution-internal observations (fused sweep widths); backends
    /// without per-sweep structure ignore it.
    fn prepare(
        cfg: &ServeConfig,
        cache: Arc<Mutex<LayoutCache>>,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<Prepared<Self>, Error>;

    /// Execute one ρ-keyed batch: exactly one [`Response`] per request,
    /// in request order. `latency_us`/`batch_size` are stamped by the
    /// serve loop afterwards.
    fn execute(&mut self, batch: DecodeBatch) -> Result<Vec<Response>, Error>;
}

// ---------------------------------------------------------------------------
// HostEngine
// ---------------------------------------------------------------------------

/// Batched host decode through the shared layout cache.
pub struct HostEngine {
    model: Model,
    cache: Arc<Mutex<LayoutCache>>,
    stop_at_eos: bool,
    /// Per-lane KV caches inside `decode_batch` (`[decode] kv_cache`,
    /// default on; outputs are bit-identical either way).
    kv_cache: bool,
    /// Compress layouts with int8 sidecars and run the quantized kernels
    /// (`[kernel] quant`, default off — approximate, gate with the
    /// decode-drift eval).
    quant: bool,
    /// Optional sink for fused-sweep width observations (the drain
    /// path's counterpart of `run_pool`'s per-sweep recording).
    metrics: Option<Arc<Metrics>>,
}

impl HostEngine {
    /// Build directly from parts (tests and `generate` use this to supply
    /// their own model/cache; the serve loop goes through `prepare`).
    pub fn with_model(
        model: Model,
        cache: Arc<Mutex<LayoutCache>>,
        stop_at_eos: bool,
        kv_cache: bool,
    ) -> Self {
        HostEngine::with_model_quant(model, cache, stop_at_eos, kv_cache, false)
    }

    /// [`with_model`](HostEngine::with_model) plus the int8-quantized
    /// kernel toggle.
    pub fn with_model_quant(
        model: Model,
        cache: Arc<Mutex<LayoutCache>>,
        stop_at_eos: bool,
        kv_cache: bool,
        quant: bool,
    ) -> Self {
        HostEngine {
            model,
            cache,
            stop_at_eos,
            kv_cache,
            quant,
            metrics: None,
        }
    }

    /// Attach a metrics sink; executed batches then report per-sweep
    /// fused group widths via [`Metrics::record_fused_sweep`].
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Self {
        self.metrics = Some(metrics);
        self
    }

    pub fn model(&self) -> &Model {
        &self.model
    }
}

impl Engine for HostEngine {
    fn kind() -> EngineKind {
        EngineKind::Host
    }

    fn prepare(
        cfg: &ServeConfig,
        cache: Arc<Mutex<LayoutCache>>,
        metrics: Option<Arc<Metrics>>,
    ) -> Result<Prepared<Self>, Error> {
        let model = host_model(cfg)?;
        let seq_len = model.cfg.max_seq_len;
        // resolve the process-wide SIMD mode once, on the serve thread:
        // config request, clamped to host capability, MUMOE_SIMD override
        crate::tensor::simd::set_mode(cfg.kernel.simd);
        let mut engine = HostEngine::with_model_quant(
            model,
            cache,
            cfg.decode.stop_at_eos,
            cfg.decode.kv_cache,
            cfg.kernel.quant,
        );
        if let Some(m) = metrics {
            engine = engine.with_metrics(m);
        }
        Ok(Prepared {
            engine,
            seq_len,
            batch_capacity: cfg.decode.batch_size,
        })
    }

    fn execute(&mut self, batch: DecodeBatch) -> Result<Vec<Response>, Error> {
        let rho = batch.rho;
        let items: Vec<BatchRequest> = batch
            .requests
            .iter()
            .map(|r| BatchRequest {
                // the router pads to seq_len; decode wants the real prompt
                prompt: &r.tokens[..r.valid_len],
                max_new: r.max_new,
                plan: r.plan,
            })
            .collect();
        // one lock per batch: the whole point is that batch-mates share
        // compressed layouts, and the serve loop is the only writer
        let mut cache = self
            .cache
            .lock()
            .map_err(|_| Error::coordinator("layout cache poisoned"))?;
        let metrics = self.metrics.clone();
        let outs = decode_batch_observed(
            &self.model,
            &items,
            rho,
            self.stop_at_eos,
            self.kv_cache,
            self.quant,
            Some(&mut cache),
            |groups| {
                if let Some(m) = &metrics {
                    m.record_fused_sweep(rho, groups);
                }
            },
        );
        drop(cache);

        Ok(batch
            .requests
            .iter()
            .zip(outs)
            // latency/batch_size are stamped by the serve loop; the
            // mapping itself is shared with the continuous path
            .map(|(req, out)| Response::from_decode(req.id, rho, &out, None))
            .collect())
    }
}

// ---------------------------------------------------------------------------
// PjrtEngine
// ---------------------------------------------------------------------------

/// The PJRT artifact-session backend: one `execute` runs a padded
/// single-token batch through the μ-MoE session (or the dense session at
/// ρ = 1). Multi-token requests never reach it — `Router::admit` rejects
/// `max_new > 1` when the configured engine lacks the capability.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    mumoe: crate::runtime::session::Session,
    dense: crate::runtime::session::Session,
}

#[cfg(feature = "pjrt")]
impl Engine for PjrtEngine {
    fn kind() -> EngineKind {
        EngineKind::Pjrt
    }

    fn prepare(
        cfg: &ServeConfig,
        _cache: Arc<Mutex<LayoutCache>>,
        _metrics: Option<Arc<Metrics>>,
    ) -> Result<Prepared<Self>, Error> {
        use crate::runtime::registry::Registry;
        use crate::runtime::session::Session;
        use crate::runtime::weights::DeviceWeights;
        use crate::runtime::Client;
        use crate::util::error::ResultExt;

        let client = Client::cpu()?;
        let registry = Registry::open(Path::new(&cfg.artifacts_dir), client.clone())?;
        let ckpt = Checkpoint::load(&registry.ckpt_path(&cfg.model))
            .with_context(|| format!("loading checkpoint for {}", cfg.model))?;
        let mumoe_meta = registry.meta_for("mumoe_logits", &cfg.model)?.name.clone();
        let dense_meta = registry.meta_for("dense_logits", &cfg.model)?.name.clone();
        let order = registry.meta(&mumoe_meta)?.params.clone();
        let weights = Arc::new(DeviceWeights::upload(&client, &ckpt, &order)?);
        let mumoe = Session::bind(&registry, &mumoe_meta, weights.clone())?;
        let dense = Session::bind(&registry, &dense_meta, weights)?;
        let (seq_len, batch_capacity) = (mumoe.meta.seq_len, mumoe.meta.batch);
        Ok(Prepared {
            engine: PjrtEngine { mumoe, dense },
            seq_len,
            batch_capacity,
        })
    }

    fn execute(&mut self, batch: DecodeBatch) -> Result<Vec<Response>, Error> {
        use crate::runtime::session::{literal_f32, Input};
        use super::request::argmax;

        let n = batch.len();
        let use_dense = batch.rho >= 0.999;
        let session = if use_dense { &self.dense } else { &self.mumoe };
        let cap = session.meta.batch;
        let seq = session.meta.seq_len;

        let mut tokens = Vec::with_capacity(cap * seq);
        let mut lengths = Vec::with_capacity(cap);
        for r in &batch.requests {
            tokens.extend_from_slice(&r.tokens);
            lengths.push(r.valid_len as i32);
        }
        // pad unused slots by replicating the first request (outputs ignored)
        for _ in n..cap {
            tokens.extend_from_slice(&batch.requests[0].tokens);
            lengths.push(batch.requests[0].valid_len as i32);
        }

        let mut inputs = vec![
            Input::I32(tokens, vec![cap, seq]),
            Input::I32(lengths, vec![cap]),
        ];
        if !use_dense {
            inputs.push(Input::ScalarF32(batch.rho as f32));
        }

        let flat = session.run(&inputs).and_then(|outs| literal_f32(&outs[0]))?;
        let vocab = flat.len() / cap;
        Ok(batch
            .requests
            .iter()
            .enumerate()
            .map(|(i, req)| {
                let row = flat[i * vocab..(i + 1) * vocab].to_vec();
                let next = argmax(&row);
                Response {
                    id: req.id,
                    next_token: next,
                    tokens: vec![next],
                    steps: 1,
                    logits: row,
                    latency_us: 0,
                    batch_size: 0,
                    // single-token graph execution: no prefill/step split
                    // and no KV reuse to attribute
                    prefill_us: 0,
                    step_us: 0,
                    rho_used: batch.rho,
                    prefilled_tokens: 0,
                    seeded_tokens: 0,
                    queue_wait_us: 0,
                    ttft_us: 0,
                    rejected: None,
                }
            })
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use crate::decode::{decode_greedy, DecodeConfig};
    use crate::model::ModelConfig;
    use crate::pruning::MaskPlan;

    fn tiny_model() -> Model {
        random_model(&ModelConfig::new("eng-tiny", 2, 2, 16), 41)
    }

    fn engine_with(cache_cap: usize) -> (HostEngine, Arc<Mutex<LayoutCache>>) {
        let cache = Arc::new(Mutex::new(LayoutCache::new(cache_cap)));
        (
            HostEngine::with_model(tiny_model(), cache.clone(), false, true),
            cache,
        )
    }

    fn req(id: u64, prompt: &[i32], rho: f64, max_new: usize) -> Request {
        Request::new(id, prompt.to_vec(), prompt.len(), rho, "d", None)
            .with_decode(max_new, MaskPlan::PruneOnce)
    }

    #[test]
    fn host_engine_matches_direct_decode_greedy() {
        let (mut eng, _cache) = engine_with(64);
        let batch = DecodeBatch {
            rho: 0.5,
            requests: vec![req(1, &[1, 2, 3], 0.5, 4), req(2, &[9, 8], 0.5, 2)],
        };
        let responses = eng.execute(batch).expect("execute");
        assert_eq!(responses.len(), 2);
        let reference = tiny_model();
        for (resp, (prompt, max_new)) in responses
            .iter()
            .zip([(vec![1, 2, 3], 4usize), (vec![9, 8], 2)])
        {
            // reference decodes without kv: the engine's KV path must
            // reproduce the plain full-window semantics exactly
            let out = decode_greedy(
                &reference,
                &prompt,
                &DecodeConfig {
                    rho: 0.5,
                    plan: MaskPlan::PruneOnce,
                    max_new,
                    stop_at_eos: false,
                    kv_cache: false,
                },
                None,
            );
            assert_eq!(resp.tokens, out.new_tokens());
            assert_eq!(resp.steps, max_new);
            assert_eq!(resp.next_token, out.new_tokens()[0]);
            assert_eq!(resp.logits, out.steps.last().unwrap().logits);
            assert_eq!(resp.rho_used, 0.5);
            assert!(resp.is_ok());
        }
    }

    #[test]
    fn kv_toggle_does_not_change_responses() {
        // --kv / --no-kv is a performance knob, never a semantics knob
        let run = |kv: bool| {
            let cache = Arc::new(Mutex::new(LayoutCache::new(64)));
            let mut eng = HostEngine::with_model(tiny_model(), cache, false, kv);
            eng.execute(DecodeBatch {
                rho: 0.5,
                requests: vec![req(1, &[1, 2, 3], 0.5, 4), req(2, &[9, 8], 0.5, 2)],
            })
            .expect("execute")
        };
        let on = run(true);
        let off = run(false);
        assert_eq!(on.len(), off.len());
        for (a, b) in on.iter().zip(&off) {
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.logits, b.logits);
            assert_eq!(a.steps, b.steps);
        }
    }

    #[test]
    fn quant_engine_is_deterministic() {
        // int8 kernels are approximate vs f32 but must stay a pure
        // function of the batch — two runs agree bit-exactly
        let run = || {
            let cache = Arc::new(Mutex::new(LayoutCache::new(64)));
            let mut eng = HostEngine::with_model_quant(tiny_model(), cache, false, true, true);
            eng.execute(DecodeBatch {
                rho: 0.5,
                requests: vec![req(1, &[1, 2, 3], 0.5, 4), req(2, &[9, 8], 0.5, 2)],
            })
            .expect("execute")
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.tokens, y.tokens);
            assert_eq!(x.logits, y.logits);
        }
    }

    #[test]
    fn host_engine_batch_mates_share_cache() {
        let (mut eng, cache) = engine_with(64);
        let n_linears = eng.model().cfg.linear_names().len() as u64;
        let batch = DecodeBatch {
            rho: 0.5,
            requests: vec![req(1, &[4, 2, 9], 0.5, 3), req(2, &[4, 2, 9], 0.5, 3)],
        };
        let responses = eng.execute(batch).expect("execute");
        assert_eq!(responses[0].tokens, responses[1].tokens);
        let c = cache.lock().unwrap();
        assert_eq!(c.misses(), n_linears, "one compression for the pair");
        assert_eq!(c.hits(), n_linears, "second lane must hit, not rebuild");
    }

    #[test]
    fn host_engine_respects_valid_len_padding() {
        // a request padded to seq_len must decode exactly like its
        // unpadded prompt
        let (mut eng, _cache) = engine_with(64);
        let mut padded = vec![5, 6, 7];
        padded.resize(16, crate::model::PAD_ID);
        let mut r = Request::new(1, padded, 3, 0.6, "d", None);
        r = r.with_decode(3, MaskPlan::PruneOnce);
        let responses = eng
            .execute(DecodeBatch {
                rho: 0.6,
                requests: vec![r],
            })
            .expect("execute");
        let out = decode_greedy(
            &tiny_model(),
            &[5, 6, 7],
            &DecodeConfig {
                rho: 0.6,
                plan: MaskPlan::PruneOnce,
                max_new: 3,
                stop_at_eos: false,
                kv_cache: false,
            },
            None,
        );
        assert_eq!(responses[0].tokens, out.new_tokens());
    }

    #[test]
    fn execute_reports_fused_widths_to_metrics() {
        // Two identical requests share every layout via the cache, so
        // after their prefill sweep the pool fuses them: the metrics
        // sink must see width-2 groups, and attaching it must not
        // change the decoded tokens.
        let metrics = Arc::new(Metrics::new());
        let (eng, _cache) = engine_with(64);
        let mut eng = eng.with_metrics(metrics.clone());
        let batch = DecodeBatch {
            rho: 0.5,
            requests: vec![req(1, &[4, 2, 9], 0.5, 4), req(2, &[4, 2, 9], 0.5, 4)],
        };
        let responses = eng.execute(batch).expect("execute");
        assert_eq!(responses[0].tokens, responses[1].tokens);
        let (mut plain_eng, _c) = engine_with(64);
        let plain = plain_eng
            .execute(DecodeBatch {
                rho: 0.5,
                requests: vec![req(1, &[4, 2, 9], 0.5, 4), req(2, &[4, 2, 9], 0.5, 4)],
            })
            .expect("execute");
        assert_eq!(responses[0].tokens, plain[0].tokens);
        let levels = metrics.level_stats();
        assert_eq!(levels.len(), 1);
        let st = levels[0].1;
        assert!(st.fused_groups > 0);
        assert!(
            st.fused_width_hist[1] > 0,
            "same-layout pair must fuse at width 2: {:?}",
            st.fused_width_hist
        );
        assert!(st.mean_fused_width() > 1.0);
    }

    #[test]
    fn prepare_falls_back_to_deterministic_model() {
        let cfg = ServeConfig {
            artifacts_dir: "definitely-absent-artifacts-dir".into(),
            model: "mu-opt-micro".into(),
            ..Default::default()
        };
        let cache = Arc::new(Mutex::new(LayoutCache::new(cfg.layout_cache_cap)));
        let prepared = HostEngine::prepare(&cfg, cache, None).expect("prepare");
        assert_eq!(prepared.seq_len, crate::model::MAX_SEQ_LEN);
        assert_eq!(prepared.batch_capacity, cfg.decode.batch_size);
        assert_eq!(HostEngine::kind(), EngineKind::Host);
        assert!(HostEngine::kind().supports_multi_token());
        // the fallback is deterministic: same weights every prepare
        let m = host_model(&cfg).unwrap();
        let reference = random_model(
            &config_by_name("mu-opt-micro").unwrap(),
            HOST_FALLBACK_SEED,
        );
        assert_eq!(m.mat("tok_emb").data, reference.mat("tok_emb").data);
    }

    #[test]
    fn prepare_rejects_unknown_model() {
        let cfg = ServeConfig {
            model: "mu-opt-nonexistent".into(),
            ..Default::default()
        };
        let cache = Arc::new(Mutex::new(LayoutCache::new(8)));
        assert!(HostEngine::prepare(&cfg, cache, None).is_err());
    }

    #[test]
    fn eos_override_reaches_the_served_model() {
        // the production path of the configurable-EOS fix: a
        // coordinator.eos_id override must land on the model the engine
        // decodes with, and out-of-vocab ids must fail at load
        let base = ServeConfig {
            artifacts_dir: "definitely-absent-artifacts-dir".into(),
            ..Default::default()
        };
        assert_eq!(
            host_model(&base).unwrap().cfg.eos_id,
            crate::model::EOS_ID,
            "no override keeps the family default"
        );
        let overridden = ServeConfig {
            eos_id: Some(42),
            ..base.clone()
        };
        assert_eq!(host_model(&overridden).unwrap().cfg.eos_id, 42);
        let out_of_vocab = ServeConfig {
            eos_id: Some(crate::model::VOCAB_SIZE as i32),
            ..base
        };
        assert!(host_model(&out_of_vocab).is_err());
    }
}
