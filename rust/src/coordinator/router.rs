//! Router: the coordinator's front door. Tokenizes/pads prompts, snaps the
//! requested sparsity to a configured level, applies admission control and
//! hands requests to the batcher queue.

use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use crate::config::ServeConfig;
use crate::model::tokenizer::ByteTokenizer;
use crate::moe::snap_rho;
use crate::tensor::LayoutCache;
use crate::util::error::Error;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

/// Stateless-ish router; shared across client threads.
pub struct Router {
    cfg: ServeConfig,
    seq_len: usize,
    tokenizer: ByteTokenizer,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    /// Live queue depth (approximate; maintained by the server loop).
    depth: Arc<AtomicU64>,
    /// Shared compressed-layout cache keyed by
    /// `(model weights, linear, snapped-ρ level, mask fingerprint)`.
    /// Because `admit` snaps every request's ρ to a configured level,
    /// batch-mates and repeated prefixes at the same level share cache
    /// keys. This handle is the integration point for host-side batch
    /// execution (`decode::decode_greedy` takes `&mut LayoutCache`); the
    /// host server loop that drains the batcher through it is a ROADMAP
    /// open item — today only per-request host decode (`generate`) and
    /// tests consume layout caches.
    layout_cache: Arc<Mutex<LayoutCache>>,
}

impl Router {
    /// Build a router, rejecting invalid configs (empty/unsorted
    /// `rho_levels`, zero caps) with a typed error instead of panicking
    /// later inside `snap_rho` or the batcher.
    pub fn new(cfg: ServeConfig, seq_len: usize, metrics: Arc<Metrics>) -> Result<Router, Error> {
        cfg.validate()?;
        let layout_cache = Arc::new(Mutex::new(LayoutCache::new(cfg.layout_cache_cap)));
        Ok(Router {
            cfg,
            seq_len,
            tokenizer: ByteTokenizer,
            next_id: AtomicU64::new(1),
            metrics,
            depth: Arc::new(AtomicU64::new(0)),
            layout_cache,
        })
    }

    pub fn depth_handle(&self) -> Arc<AtomicU64> {
        self.depth.clone()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Handle to the shared level-keyed layout cache.
    pub fn layout_cache(&self) -> Arc<Mutex<LayoutCache>> {
        self.layout_cache.clone()
    }

    /// Admission decision + request construction. Returns `Err(Response)`
    /// with a rejection when load must be shed (queue full, bad input).
    pub fn admit(
        &self,
        prompt: &str,
        rho: f64,
        domain: &str,
        reply: Option<Sender<Response>>,
    ) -> Result<Request, Box<Response>> {
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);

        if prompt.is_empty() {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "empty prompt")));
        }
        let depth = self.depth.load(Ordering::Relaxed) as usize;
        self.metrics.record_queue_depth(depth);
        if depth >= self.cfg.queue_cap {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "queue full")));
        }

        let rho = if rho <= 0.0 { self.cfg.default_rho } else { rho };
        let snapped = snap_rho(rho.clamp(0.0, 1.0), &self.cfg.rho_levels);

        let ids = self.tokenizer.encode(prompt, true);
        let (tokens, valid_len) = self.tokenizer.pad_to(ids, self.seq_len);

        self.metrics.record_accept();
        self.depth.fetch_add(1, Ordering::Relaxed);
        Ok(Request::new(id, tokens, valid_len, snapped, domain, reply))
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(queue_cap: usize) -> Router {
        let cfg = ServeConfig {
            queue_cap,
            rho_levels: vec![0.4, 0.6, 1.0],
            default_rho: 0.6,
            ..Default::default()
        };
        Router::new(cfg, 128, Arc::new(Metrics::new())).expect("valid config")
    }

    #[test]
    fn new_rejects_invalid_rho_levels() {
        // regression: these used to be accepted here and only explode
        // later inside snap_rho / DynamicBatcher::new
        for levels in [vec![], vec![0.6, 0.4], vec![0.5, 0.5]] {
            let cfg = ServeConfig {
                rho_levels: levels.clone(),
                ..Default::default()
            };
            let err = Router::new(cfg, 128, Arc::new(Metrics::new()));
            assert!(err.is_err(), "levels {levels:?} must be rejected");
        }
    }

    #[test]
    fn layout_cache_shared_and_sized_from_config() {
        let cfg = ServeConfig {
            layout_cache_cap: 32,
            ..Default::default()
        };
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        let a = r.layout_cache();
        let b = r.layout_cache();
        assert!(Arc::ptr_eq(&a, &b), "handles must share one cache");
        assert_eq!(a.lock().unwrap().capacity(), 32);
    }

    #[test]
    fn admits_and_snaps() {
        let r = router(10);
        let req = r.admit("hello world", 0.55, "synth_wiki", None).unwrap();
        assert_eq!(req.rho, 0.6);
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 12); // BOS + 11 bytes
    }

    #[test]
    fn default_rho_when_unspecified() {
        let r = router(10);
        let req = r.admit("x", 0.0, "d", None).unwrap();
        assert_eq!(req.rho, 0.6);
    }

    #[test]
    fn rejects_empty_prompt() {
        let r = router(10);
        let rej = r.admit("", 0.5, "d", None).unwrap_err();
        assert!(!rej.is_ok());
    }

    #[test]
    fn sheds_load_at_cap() {
        let r = router(2);
        r.depth_handle().store(2, Ordering::Relaxed);
        let rej = r.admit("hi", 0.5, "d", None).unwrap_err();
        assert_eq!(rej.rejected.as_deref(), Some("queue full"));
        assert_eq!(r.metrics().rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ids_unique_and_increasing() {
        let r = router(10);
        let a = r.admit("a", 0.5, "d", None).unwrap();
        let b = r.admit("b", 0.5, "d", None).unwrap();
        assert!(b.id > a.id);
    }

    #[test]
    fn long_prompt_truncated_to_window() {
        let r = router(10);
        let long = "x".repeat(500);
        let req = r.admit(&long, 1.0, "d", None).unwrap();
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 128);
    }
}
