//! Router: the coordinator's front door. Tokenizes/pads prompts, snaps the
//! requested sparsity to a configured level, applies admission control and
//! hands requests to the batcher queue.

use super::metrics::Metrics;
use super::request::{Request, RequestId, Response, StepEvent};
use crate::config::ServeConfig;
use crate::kvstore::{valid_session_id, KvStore, SessionRegistry};
use crate::model::tokenizer::ByteTokenizer;
use crate::moe::snap_rho;
use crate::tensor::{rho_milli, LayoutCache};
use crate::trace::{AttrValue, FlightRecorder};
use crate::util::error::Error;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

/// Stateless-ish router; shared across client threads.
pub struct Router {
    cfg: ServeConfig,
    seq_len: usize,
    tokenizer: ByteTokenizer,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    /// Live queue depth: incremented here on admission, decremented by the
    /// server loop when a request leaves the queue for a batch or lane.
    ///
    /// **Consistency contract.** Writers publish with `Release`
    /// (`admit_decode` increments, the serve loop decrements) and readers
    /// load with `Acquire` (`admit_decode`'s cap check, `/metrics` from
    /// HTTP worker threads), so a reader that observes a count also
    /// observes the request-state writes that preceded it. The gauge is
    /// still *approximate*: the cap check's load and increment are two
    /// operations, not one RMW, so concurrent admitters can overshoot
    /// `queue_cap` by at most the number of racing threads — it is a
    /// load-shedding heuristic, not a capacity invariant.
    depth: Arc<AtomicU64>,
    /// Shared compressed-layout cache keyed by
    /// `(model weights, linear, snapped-ρ level, mask fingerprint)`.
    /// Because `admit` snaps every request's ρ to a configured level,
    /// batch-mates and repeated prefixes at the same level share cache
    /// keys. `Server::start` hands this to `Engine::prepare`, so the
    /// host serve loop drains the batcher through it: every
    /// `HostEngine::execute` compresses through (and reuses from) this
    /// one cache.
    layout_cache: Arc<Mutex<LayoutCache>>,
    /// Shared cross-request prefix KV store (`crate::kvstore`), sized by
    /// `kvstore.token_budget`. The continuous host serve loop consults it
    /// at every lane prefill and publishes fresh prefixes back; `None`
    /// when `kvstore.enabled` is off.
    kv_store: Option<Arc<KvStore>>,
    /// Parked multi-turn sessions keyed by client-chosen id; admissions
    /// carrying `session` continue from (and re-park into) it.
    sessions: Arc<SessionRegistry>,
    /// Per-request span recorder (`crate::trace`), sized by `[trace]`
    /// config. The router opens each admitted request's timeline; the
    /// serve loop spans its lifecycle phases and closes it. A disabled
    /// recorder no-ops behind one relaxed atomic load.
    recorder: Arc<FlightRecorder>,
}

impl Router {
    /// Build a router, rejecting invalid configs (empty/unsorted
    /// `rho_levels`, zero caps) with a typed error instead of panicking
    /// later inside `snap_rho` or the batcher.
    pub fn new(cfg: ServeConfig, seq_len: usize, metrics: Arc<Metrics>) -> Result<Router, Error> {
        cfg.validate()?;
        let layout_cache = Arc::new(Mutex::new(LayoutCache::new(cfg.layout_cache_cap)));
        let kv_store = cfg
            .kvstore
            .enabled
            .then(|| Arc::new(KvStore::new(cfg.kvstore.token_budget)));
        let recorder = Arc::new(FlightRecorder::new(
            cfg.trace.enabled,
            cfg.trace.capacity,
            cfg.trace.kernel_sample_every,
        ));
        // the registry asserts cap > 0; a disabled kvstore never begins
        // sessions, so its (unvalidated) max_sessions must not trip that
        let max_sessions = cfg.kvstore.max_sessions.max(1);
        Ok(Router {
            cfg,
            seq_len,
            tokenizer: ByteTokenizer,
            next_id: AtomicU64::new(1),
            metrics,
            depth: Arc::new(AtomicU64::new(0)),
            layout_cache,
            kv_store,
            sessions: Arc::new(SessionRegistry::with_capacity(max_sessions)),
            recorder,
        })
    }

    pub fn depth_handle(&self) -> Arc<AtomicU64> {
        self.depth.clone()
    }

    /// Current approximate queue depth (see the `depth` field's
    /// consistency contract). Safe to call from any thread; `/metrics`
    /// renders it as a gauge.
    pub fn queue_depth(&self) -> u64 {
        self.depth.load(Ordering::Acquire)
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Handle to the shared level-keyed layout cache.
    pub fn layout_cache(&self) -> Arc<Mutex<LayoutCache>> {
        self.layout_cache.clone()
    }

    /// Handle to the shared prefix KV store (`None` when disabled).
    pub fn kv_store(&self) -> Option<Arc<KvStore>> {
        self.kv_store.clone()
    }

    /// Handle to the session registry.
    pub fn sessions(&self) -> Arc<SessionRegistry> {
        self.sessions.clone()
    }

    /// Handle to the per-request flight recorder.
    pub fn recorder(&self) -> Arc<FlightRecorder> {
        self.recorder.clone()
    }

    /// Admission with the config's decode defaults (`max_new` from
    /// `decode.default_max_new`, plan from `decode.plan`). Returns
    /// `Err(Response)` with a rejection when load must be shed (queue
    /// full, bad input).
    pub fn admit(
        &self,
        prompt: &str,
        rho: f64,
        domain: &str,
        reply: Option<Sender<Response>>,
    ) -> Result<Request, Box<Response>> {
        self.admit_decode(prompt, rho, domain, 0, None, None, None, reply)
    }

    /// Admission decision + request construction with explicit decode
    /// parameters. `max_new = 0` means "use the config default"; an
    /// explicit value is validated against `decode.max_new_cap` and the
    /// configured engine's capability (the pjrt backend is single-token),
    /// so invalid decode work is shed here instead of failing a whole
    /// batch at execution. `stream` receives one `StepEvent` per
    /// generated token (dropped here when `decode.stream` is off, so a
    /// disabled knob is enforced at the front door); the returned
    /// request's `cancel` token is the client's mid-flight cancellation
    /// handle — clone it before submitting. `session` asks for cross-turn
    /// KV continuation under a client-chosen id (`crate::kvstore`) —
    /// validated here, and shed with a named reason when the serving mode
    /// cannot honour it (store disabled, drain path, KV decode off)
    /// rather than silently decoding without continuity.
    #[allow(clippy::too_many_arguments)] // the request's full client surface
    pub fn admit_decode(
        &self,
        prompt: &str,
        rho: f64,
        domain: &str,
        max_new: usize,
        plan: Option<crate::pruning::MaskPlan>,
        session: Option<String>,
        stream: Option<Sender<StepEvent>>,
        reply: Option<Sender<Response>>,
    ) -> Result<Request, Box<Response>> {
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);
        let t_admit = if self.recorder.enabled() {
            self.recorder.now_us()
        } else {
            0
        };

        if prompt.is_empty() {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "empty prompt")));
        }
        if let Some(s) = &session {
            if !valid_session_id(s) {
                self.metrics.record_reject();
                return Err(Box::new(Response::rejected(
                    id,
                    "invalid session id (1..=64 chars of [A-Za-z0-9._-])",
                )));
            }
            if self.kv_store.is_none()
                || !self.cfg.decode.continuous
                || !self.cfg.decode.kv_cache
            {
                self.metrics.record_reject();
                return Err(Box::new(Response::rejected(
                    id,
                    "sessions need kvstore.enabled, decode.continuous and decode.kv_cache",
                )));
            }
            // registry at capacity with every slot mid-flight: shed here
            // (HTTP maps this to 429) instead of failing in the serve
            // loop. Checking without creating keeps admission slot-free.
            if !self.sessions.admissible(s) {
                self.metrics.record_reject();
                self.metrics.record_session_rejected();
                return Err(Box::new(Response::rejected(
                    id,
                    "session registry at capacity",
                )));
            }
        }
        let max_new = if max_new == 0 {
            self.cfg.decode.default_max_new
        } else {
            max_new
        };
        if max_new > self.cfg.decode.max_new_cap {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(
                id,
                format!(
                    "max_new {max_new} exceeds cap {}",
                    self.cfg.decode.max_new_cap
                ),
            )));
        }
        if max_new > 1 && !self.cfg.engine.supports_multi_token() {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(
                id,
                format!(
                    "engine '{}' is single-token (max_new {max_new} > 1)",
                    self.cfg.engine.label()
                ),
            )));
        }
        let depth = self.depth.load(Ordering::Acquire) as usize;
        self.metrics.record_queue_depth(depth);
        if depth >= self.cfg.queue_cap {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "queue full")));
        }

        let rho = if rho <= 0.0 { self.cfg.default_rho } else { rho };
        let snapped = snap_rho(rho.clamp(0.0, 1.0), &self.cfg.rho_levels);

        let ids = self.tokenizer.encode(prompt, true);
        let (tokens, valid_len) = self.tokenizer.pad_to(ids, self.seq_len);

        self.metrics.record_accept();
        self.depth.fetch_add(1, Ordering::Release);
        if self.recorder.enabled() {
            // backdate the root to the start of admission so the admit
            // span (and everything after) nests within it
            self.recorder.begin_at(id, t_admit);
            self.recorder.span(
                id,
                "admit",
                None,
                t_admit,
                self.recorder.now_us(),
                &[
                    ("rho_milli", AttrValue::Num(rho_milli(snapped) as u64)),
                    ("max_new", AttrValue::Num(max_new as u64)),
                ],
            );
        }
        let mut req = Request::new(id, tokens, valid_len, snapped, domain, reply)
            .with_decode(max_new, plan.unwrap_or(self.cfg.decode.plan))
            .with_session(session);
        if self.cfg.decode.stream {
            if let Some(stream) = stream {
                req = req.with_stream(stream);
            }
        }
        Ok(req)
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(queue_cap: usize) -> Router {
        let cfg = ServeConfig {
            queue_cap,
            rho_levels: vec![0.4, 0.6, 1.0],
            default_rho: 0.6,
            ..Default::default()
        };
        Router::new(cfg, 128, Arc::new(Metrics::new())).expect("valid config")
    }

    #[test]
    fn new_rejects_invalid_rho_levels() {
        // regression: these used to be accepted here and only explode
        // later inside snap_rho / DynamicBatcher::new
        for levels in [vec![], vec![0.6, 0.4], vec![0.5, 0.5]] {
            let cfg = ServeConfig {
                rho_levels: levels.clone(),
                ..Default::default()
            };
            let err = Router::new(cfg, 128, Arc::new(Metrics::new()));
            assert!(err.is_err(), "levels {levels:?} must be rejected");
        }
    }

    #[test]
    fn layout_cache_shared_and_sized_from_config() {
        let cfg = ServeConfig {
            layout_cache_cap: 32,
            ..Default::default()
        };
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        let a = r.layout_cache();
        let b = r.layout_cache();
        assert!(Arc::ptr_eq(&a, &b), "handles must share one cache");
        assert_eq!(a.lock().unwrap().capacity(), 32);
    }

    #[test]
    fn admits_and_snaps() {
        let r = router(10);
        let req = r.admit("hello world", 0.55, "synth_wiki", None).unwrap();
        assert_eq!(req.rho, 0.6);
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 12); // BOS + 11 bytes
    }

    #[test]
    fn default_rho_when_unspecified() {
        let r = router(10);
        let req = r.admit("x", 0.0, "d", None).unwrap();
        assert_eq!(req.rho, 0.6);
    }

    #[test]
    fn rejects_empty_prompt() {
        let r = router(10);
        let rej = r.admit("", 0.5, "d", None).unwrap_err();
        assert!(!rej.is_ok());
    }

    #[test]
    fn sheds_load_at_cap() {
        let r = router(2);
        r.depth_handle().store(2, Ordering::Relaxed);
        let rej = r.admit("hi", 0.5, "d", None).unwrap_err();
        assert_eq!(rej.rejected.as_deref(), Some("queue full"));
        assert_eq!(r.metrics().rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn admit_applies_decode_defaults() {
        let r = router(10);
        let req = r.admit("hello", 0.5, "d", None).unwrap();
        assert_eq!(req.max_new, 1, "config default");
        assert_eq!(req.plan, crate::pruning::MaskPlan::PruneOnce);
    }

    #[test]
    fn admit_decode_validates_max_new_and_plan() {
        let mut cfg = ServeConfig {
            queue_cap: 10,
            rho_levels: vec![0.4, 0.6, 1.0],
            default_rho: 0.6,
            ..Default::default()
        };
        cfg.decode.max_new_cap = 8;
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        let req = r
            .admit_decode(
                "hi",
                0.5,
                "d",
                4,
                Some(crate::pruning::MaskPlan::Refresh(2)),
                None,
                None,
                None,
            )
            .unwrap();
        assert_eq!(req.max_new, 4);
        assert_eq!(req.plan, crate::pruning::MaskPlan::Refresh(2));
        // above the cap: shed with a named reason
        let rej = r
            .admit_decode("hi", 0.5, "d", 9, None, None, None, None)
            .unwrap_err();
        assert!(rej.rejected.as_deref().unwrap().contains("exceeds cap"));
        assert_eq!(r.metrics().rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stream_knob_gates_stream_attachment_at_admission() {
        // stream on (the default): the sender rides the request
        let r = router(10);
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = r
            .admit_decode("hi", 0.5, "d", 1, None, None, Some(tx), None)
            .unwrap();
        assert!(req.stream.is_some());
        assert!(!req.cancel.is_cancelled(), "fresh token");
        // stream off: the sender is dropped at the front door
        let mut cfg = ServeConfig {
            queue_cap: 10,
            rho_levels: vec![0.4, 0.6, 1.0],
            default_rho: 0.6,
            ..Default::default()
        };
        cfg.decode.stream = false;
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        let (tx, _rx) = std::sync::mpsc::channel();
        let req = r
            .admit_decode("hi", 0.5, "d", 1, None, None, Some(tx), None)
            .unwrap();
        assert!(req.stream.is_none(), "disabled knob must drop the sender");
    }

    #[test]
    fn single_token_engine_rejects_multi_token_requests() {
        let cfg = ServeConfig {
            engine: crate::config::EngineKind::Pjrt,
            queue_cap: 10,
            rho_levels: vec![0.4, 1.0],
            ..Default::default()
        };
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        // max_new = 1 is always fine
        assert!(r
            .admit_decode("hi", 0.4, "d", 1, None, None, None, None)
            .is_ok());
        let rej = r
            .admit_decode("hi", 0.4, "d", 2, None, None, None, None)
            .unwrap_err();
        assert!(rej.rejected.as_deref().unwrap().contains("single-token"));
    }

    #[test]
    fn session_admission_validates_id_and_serving_mode() {
        let r = router(10);
        // well-formed id on the default (continuous + kv + store) config
        let req = r
            .admit_decode("hi", 0.5, "d", 1, None, Some("chat-1".into()), None, None)
            .unwrap();
        assert_eq!(req.session.as_deref(), Some("chat-1"));
        assert!(r.kv_store().is_some(), "enabled by default");
        assert_eq!(r.sessions().len(), 0, "admission does not create slots");
        // malformed ids are shed with a named reason
        for bad in ["", "has space", "x".repeat(65).as_str()] {
            let rej = r
                .admit_decode("hi", 0.5, "d", 1, None, Some(bad.into()), None, None)
                .unwrap_err();
            assert!(
                rej.rejected.as_deref().unwrap().contains("session id"),
                "{bad:?} must be rejected by name"
            );
        }
        // a serving mode that cannot honour continuity rejects instead of
        // silently decoding without it
        let mut cfg = ServeConfig {
            queue_cap: 10,
            rho_levels: vec![0.4, 0.6, 1.0],
            ..Default::default()
        };
        cfg.kvstore.enabled = false;
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        assert!(r.kv_store().is_none());
        let rej = r
            .admit_decode("hi", 0.5, "d", 1, None, Some("chat-1".into()), None, None)
            .unwrap_err();
        assert!(rej.rejected.as_deref().unwrap().contains("kvstore.enabled"));
        // sessionless requests still admit fine with the store off
        assert!(r.admit("hi", 0.5, "d", None).is_ok());
    }

    #[test]
    fn session_admission_sheds_at_registry_capacity() {
        // regression for the unbounded registry: a full registry whose
        // slots are all mid-flight must 429 new session ids at the front
        // door, not fail inside the serve loop
        let mut cfg = ServeConfig {
            queue_cap: 10,
            rho_levels: vec![0.4, 0.6, 1.0],
            ..Default::default()
        };
        cfg.kvstore.max_sessions = 1;
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        assert_eq!(r.sessions().capacity(), 1);
        // occupy the single slot with an in-flight session (begun, never
        // parked — not evictable)
        r.sessions().begin("busy").unwrap();
        let rej = r
            .admit_decode("hi", 0.5, "d", 1, None, Some("other".into()), None, None)
            .unwrap_err();
        assert!(rej.rejected.as_deref().unwrap().contains("at capacity"));
        assert_eq!(r.metrics().rejected.load(Ordering::Relaxed), 1);
        assert_eq!(r.metrics().sessions_rejected.load(Ordering::Relaxed), 1);
        // the existing session id still admits, as do sessionless requests
        assert!(r
            .admit_decode("hi", 0.5, "d", 1, None, Some("busy".into()), None, None)
            .is_ok());
        assert!(r.admit("hi", 0.5, "d", None).is_ok());
    }

    #[test]
    fn queue_depth_tracks_admissions() {
        let r = router(10);
        assert_eq!(r.queue_depth(), 0);
        r.admit("a", 0.5, "d", None).unwrap();
        r.admit("b", 0.5, "d", None).unwrap();
        assert_eq!(r.queue_depth(), 2, "admissions increment the gauge");
        // the serve loop's decrement side (Release) is exercised e2e in
        // tests/host_serve_e2e.rs; here only the reader contract matters
        r.depth_handle().fetch_sub(1, Ordering::Release);
        assert_eq!(r.queue_depth(), 1);
    }

    #[test]
    fn ids_unique_and_increasing() {
        let r = router(10);
        let a = r.admit("a", 0.5, "d", None).unwrap();
        let b = r.admit("b", 0.5, "d", None).unwrap();
        assert!(b.id > a.id);
    }

    #[test]
    fn admission_opens_a_trace_timeline() {
        let r = router(10);
        let req = r.admit("hello", 0.5, "d", None).unwrap();
        let rec = r.recorder();
        assert!(rec.enabled(), "tracing on by default");
        let t = rec.timeline(req.id).expect("active timeline after admit");
        assert_eq!(t.spans.len(), 1);
        assert_eq!(t.spans[0].phase, "admit");
        assert!(t.spans[0].start_us >= t.begin_us, "admit nests in root");
        // rejections never open a timeline
        let rej = r.admit("", 0.5, "d", None).unwrap_err();
        assert!(rec.timeline(rej.id).is_none());
        // disabled tracing records nothing at admission
        let mut cfg = ServeConfig {
            queue_cap: 10,
            rho_levels: vec![0.4, 0.6, 1.0],
            ..Default::default()
        };
        cfg.trace.enabled = false;
        let r = Router::new(cfg, 128, Arc::new(Metrics::new())).unwrap();
        let req = r.admit("hello", 0.5, "d", None).unwrap();
        assert!(r.recorder().is_empty());
        assert!(r.recorder().timeline(req.id).is_none());
    }

    #[test]
    fn long_prompt_truncated_to_window() {
        let r = router(10);
        let long = "x".repeat(500);
        let req = r.admit(&long, 1.0, "d", None).unwrap();
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 128);
    }
}
