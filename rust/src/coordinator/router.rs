//! Router: the coordinator's front door. Tokenizes/pads prompts, snaps the
//! requested sparsity to a configured level, applies admission control and
//! hands requests to the batcher queue.

use super::metrics::Metrics;
use super::request::{Request, RequestId, Response};
use crate::config::ServeConfig;
use crate::model::tokenizer::ByteTokenizer;
use crate::moe::snap_rho;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

/// Stateless-ish router; shared across client threads.
pub struct Router {
    cfg: ServeConfig,
    seq_len: usize,
    tokenizer: ByteTokenizer,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    /// Live queue depth (approximate; maintained by the server loop).
    depth: Arc<AtomicU64>,
}

impl Router {
    pub fn new(cfg: ServeConfig, seq_len: usize, metrics: Arc<Metrics>) -> Router {
        Router {
            cfg,
            seq_len,
            tokenizer: ByteTokenizer,
            next_id: AtomicU64::new(1),
            metrics,
            depth: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn depth_handle(&self) -> Arc<AtomicU64> {
        self.depth.clone()
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Admission decision + request construction. Returns `Err(Response)`
    /// with a rejection when load must be shed (queue full, bad input).
    pub fn admit(
        &self,
        prompt: &str,
        rho: f64,
        domain: &str,
        reply: Option<Sender<Response>>,
    ) -> Result<Request, Box<Response>> {
        let id: RequestId = self.next_id.fetch_add(1, Ordering::Relaxed);

        if prompt.is_empty() {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "empty prompt")));
        }
        let depth = self.depth.load(Ordering::Relaxed) as usize;
        self.metrics.record_queue_depth(depth);
        if depth >= self.cfg.queue_cap {
            self.metrics.record_reject();
            return Err(Box::new(Response::rejected(id, "queue full")));
        }

        let rho = if rho <= 0.0 { self.cfg.default_rho } else { rho };
        let snapped = snap_rho(rho.clamp(0.0, 1.0), &self.cfg.rho_levels);

        let ids = self.tokenizer.encode(prompt, true);
        let (tokens, valid_len) = self.tokenizer.pad_to(ids, self.seq_len);

        self.metrics.record_accept();
        self.depth.fetch_add(1, Ordering::Relaxed);
        Ok(Request::new(id, tokens, valid_len, snapped, domain, reply))
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(queue_cap: usize) -> Router {
        let cfg = ServeConfig {
            queue_cap,
            rho_levels: vec![0.4, 0.6, 1.0],
            default_rho: 0.6,
            ..Default::default()
        };
        Router::new(cfg, 128, Arc::new(Metrics::new()))
    }

    #[test]
    fn admits_and_snaps() {
        let r = router(10);
        let req = r.admit("hello world", 0.55, "synth_wiki", None).unwrap();
        assert_eq!(req.rho, 0.6);
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 12); // BOS + 11 bytes
    }

    #[test]
    fn default_rho_when_unspecified() {
        let r = router(10);
        let req = r.admit("x", 0.0, "d", None).unwrap();
        assert_eq!(req.rho, 0.6);
    }

    #[test]
    fn rejects_empty_prompt() {
        let r = router(10);
        let rej = r.admit("", 0.5, "d", None).unwrap_err();
        assert!(!rej.is_ok());
    }

    #[test]
    fn sheds_load_at_cap() {
        let r = router(2);
        r.depth_handle().store(2, Ordering::Relaxed);
        let rej = r.admit("hi", 0.5, "d", None).unwrap_err();
        assert_eq!(rej.rejected.as_deref(), Some("queue full"));
        assert_eq!(r.metrics().rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ids_unique_and_increasing() {
        let r = router(10);
        let a = r.admit("a", 0.5, "d", None).unwrap();
        let b = r.admit("b", 0.5, "d", None).unwrap();
        assert!(b.id > a.id);
    }

    #[test]
    fn long_prompt_truncated_to_window() {
        let r = router(10);
        let long = "x".repeat(500);
        let req = r.admit(&long, 1.0, "d", None).unwrap();
        assert_eq!(req.tokens.len(), 128);
        assert_eq!(req.valid_len, 128);
    }
}
