//! HTTP/1.1 + SSE serving front-end: the first real transport in front of
//! the coordinator. Dependency-free by design — a std `TcpListener`, a
//! small hand-rolled request parser and chunked-transfer writer, matching
//! the repo's pure-std policy (no axum/hyper in the hermetic build).
//!
//! Endpoints:
//!
//! * `POST /generate` — JSON body `{"prompt": "...", "rho": 0.6,
//!   "max_new": 8, "plan": "prune-once", "domain": "chat",
//!   "stream": true, "session": "chat-1"}` → [`Router::admit_decode`].
//!   Field errors and router rejections are 4xx **before anything touches
//!   the engine thread**; `"stream": true` answers with
//!   `text/event-stream` over chunked transfer, one `data:` event per
//!   generated token (driven by the existing [`StepEvent`] channel) and a
//!   terminal `event: done` carrying the full response. Without `stream`
//!   the response is one JSON object. `"session"` opts into cross-turn KV
//!   continuation (`crate::kvstore`): the id is echoed in the terminal
//!   response so clients know which id to continue or delete.
//! * `DELETE /session/:id` — drop a parked session (idempotent;
//!   `{"session": id, "deleted": bool}` says whether it existed).
//! * `GET /health` — `{"status": "ready" | "draining", ...}`; flips to
//!   `draining` when shutdown begins.
//! * `GET /metrics` — Prometheus text ([`Metrics::to_prometheus`],
//!   including the layout-cache and prefix-KV-store occupancy gauges)
//!   plus the router's live `mumoe_queue_depth` gauge.
//! * `GET /trace?last=N` — Chrome trace-event JSON (loadable in
//!   Perfetto / `chrome://tracing`) for the last N completed requests
//!   in the flight recorder, plus sampled kernel-attribution slices;
//!   404 when tracing is disabled.
//! * `GET /requests/:id` — one request's span timeline as plain JSON
//!   (phases with start/end/duration in µs); 404 for unknown ids.
//!
//! A client disconnect mid-stream cancels its request: the connection
//! worker fires the request's [`CancelToken`] on the first failed write,
//! and — belt and braces — the continuous serve loop treats the dropped
//! `StepEvent` receiver as an implicit cancel, so the lane frees within
//! one sweep either way.
//!
//! Lifecycle: `bind` (ready) → [`HttpHandle::begin_drain`] (health says
//! draining, new generations get 503, in-flight streams keep running) →
//! [`HttpHandle::shutdown`] (stop accepting, join workers so in-flight
//! requests deliver, then shut the engine loop down).

use super::metrics::Metrics;
use super::request::{CancelToken, RequestId, Response, StepEvent};
use super::router::Router;
use super::server::{Server, ServerHandle};
use crate::config::ServeConfig;
use crate::model::tokenizer::ByteTokenizer;
use crate::pruning::MaskPlan;
use crate::trace::{chrome_trace, FlightRecorder};
use crate::util::error::Error;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Largest accepted request head (request line + headers).
const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Largest accepted request body.
const MAX_BODY_BYTES: usize = 1024 * 1024;
/// How long a connection may dribble its request in.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// How long a worker waits for the engine to deliver (covers a full
/// `max_new_cap` generation queued behind a busy pool).
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);

/// Everything a connection worker needs, shared across all of them.
struct Shared {
    router: Arc<Router>,
    engine: ServerHandle,
    draining: AtomicBool,
    recorder: Arc<FlightRecorder>,
    started: Instant,
}

/// The HTTP front-end launcher.
pub struct HttpServer;

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:8080"`; port 0 picks an ephemeral
    /// one), start the engine serve loop for the router's configured
    /// backend and spawn the accept loop. Fails fast on a bad address or
    /// a bad model — nothing listens unless the engine came up.
    pub fn start(router: Arc<Router>, addr: &str) -> Result<HttpHandle, Error> {
        let engine = Server::start(&router)?;
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::coordinator(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::coordinator(format!("local_addr: {e}")))?;
        let recorder = router.recorder();
        let shared = Arc::new(Shared {
            router,
            engine,
            draining: AtomicBool::new(false),
            recorder,
            started: Instant::now(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let workers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));

        let accept = {
            let shared = shared.clone();
            let stop = stop.clone();
            let workers = workers.clone();
            std::thread::Builder::new()
                .name("mumoe-http".into())
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break; // the shutdown wake-up connection
                        }
                        let Ok(stream) = incoming else { continue };
                        let shared = shared.clone();
                        let worker = std::thread::Builder::new()
                            .name("mumoe-http-conn".into())
                            .spawn(move || handle_connection(&shared, stream))
                            .expect("spawn connection worker");
                        let mut guard = workers.lock().expect("worker list poisoned");
                        guard.retain(|w| !w.is_finished());
                        guard.push(worker);
                    }
                })
                .expect("spawn http accept thread")
        };
        crate::info!("http server listening on {local}");
        Ok(HttpHandle {
            addr: local,
            shared,
            stop,
            accept: Some(accept),
            workers,
        })
    }
}

/// Control-plane handle for a running HTTP front-end.
pub struct HttpHandle {
    addr: SocketAddr,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl HttpHandle {
    /// The bound address (resolves port 0 to the ephemeral port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.shared.engine.metrics
    }

    /// Flip `/health` to `draining` and refuse new generations with 503.
    /// In-flight requests (and their streams) keep running; `/health` and
    /// `/metrics` keep answering. [`HttpHandle::shutdown`] calls this
    /// first, so the flip is observable before the listener closes.
    pub fn begin_drain(&self) {
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Graceful shutdown: drain (new generations 503), stop accepting,
    /// join every connection worker so in-flight requests deliver, then
    /// shut the engine loop down.
    pub fn shutdown(mut self) -> Result<(), Error> {
        self.begin_drain();
        self.stop.store(true, Ordering::SeqCst);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        let workers = std::mem::take(&mut *self.workers.lock().expect("worker list poisoned"));
        for w in workers {
            let _ = w.join();
        }
        self.shared.engine.shutdown()
    }

    /// Block on the accept loop (the `mumoe serve --http` foreground
    /// mode: runs until the process is killed).
    pub fn join(mut self) -> Result<(), Error> {
        if let Some(accept) = self.accept.take() {
            accept
                .join()
                .map_err(|_| Error::coordinator("http accept thread panicked"))?;
        }
        self.shared.engine.shutdown()
    }
}

/// `mumoe serve --http <addr>`: start the coordinator behind the HTTP
/// front-end and serve until killed.
pub fn serve_http(cfg: ServeConfig, addr: &str) -> Result<(), Error> {
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new(cfg, crate::model::MAX_SEQ_LEN, metrics)?);
    let handle = HttpServer::start(router, addr)?;
    println!("serving on http://{}", handle.addr());
    println!("  POST /generate   DELETE /session/:id   GET /health   GET /metrics");
    println!("  GET /trace?last=N   GET /requests/:id");
    handle.join()
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

/// One parsed request. Bodies are raw bytes; `/generate` re-parses them
/// as JSON with its own error mapping.
struct HttpRequest {
    method: String,
    path: String,
    body: Vec<u8>,
}

/// (status, message) — rendered as `{"error": message}` with the code's
/// reason phrase.
type HttpError = (u16, String);

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Error",
    }
}

/// Serve exactly one request on the connection, then close (every
/// response carries `Connection: close`; workers are cheap threads and
/// the load generator measures per-request latency anyway).
fn handle_connection(shared: &Shared, mut stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let req = match read_request(&mut stream) {
        Ok(req) => req,
        Err((status, msg)) => {
            write_json(&mut stream, status, &json_error(&msg, None));
            return;
        }
    };
    // route on the path alone; `?last=N`-style query strings ride along
    let (path, query) = req.path.split_once('?').unwrap_or((req.path.as_str(), ""));
    match (req.method.as_str(), path) {
        ("GET", "/health") => {
            let draining = shared.draining.load(Ordering::SeqCst);
            let cfg = shared.router.config();
            let body = Json::Obj(HashMap::from([
                (
                    "status".into(),
                    Json::Str(if draining { "draining" } else { "ready" }.into()),
                ),
                ("model".into(), Json::Str(cfg.model.clone())),
                ("engine".into(), Json::Str(cfg.engine.label().into())),
                ("version".into(), Json::Str(env!("CARGO_PKG_VERSION").into())),
                (
                    "uptime_seconds".into(),
                    Json::Num(shared.started.elapsed().as_secs_f64()),
                ),
                (
                    "queue_depth".into(),
                    Json::Num(shared.router.queue_depth() as f64),
                ),
                (
                    "lane_occupancy".into(),
                    Json::Num(shared.engine.metrics.lane_occupancy()),
                ),
            ]));
            write_json(&mut stream, 200, &body);
        }
        ("GET", "/metrics") => {
            let mut text = shared.engine.metrics.to_prometheus();
            text.push_str(&format!(
                "# HELP mumoe_queue_depth Requests queued between admission and execution\n\
                 # TYPE mumoe_queue_depth gauge\n\
                 mumoe_queue_depth {}\n",
                shared.router.queue_depth()
            ));
            write_response(
                &mut stream,
                200,
                "text/plain; version=0.0.4",
                text.as_bytes(),
            );
        }
        ("GET", "/trace") => handle_trace(shared, &mut stream, query),
        ("GET", p) if p.starts_with("/requests/") => {
            let timeline = p["/requests/".len()..]
                .parse::<RequestId>()
                .ok()
                .and_then(|id| shared.recorder.timeline(id));
            match timeline {
                Some(t) => write_json(&mut stream, 200, &t.to_json()),
                None => write_json(
                    &mut stream,
                    404,
                    &json_error(&format!("no trace for {p}"), None),
                ),
            }
        }
        ("POST", "/generate") => handle_generate(shared, &mut stream, &req.body),
        ("DELETE", path) => match path.strip_prefix("/session/") {
            Some(id) if !id.is_empty() => {
                // idempotent: deleting an unknown (or already-expired)
                // session reports deleted=false rather than 404, so
                // clients can fire-and-forget cleanup
                let deleted = shared.router.sessions().delete(id);
                let body = Json::Obj(HashMap::from([
                    ("session".into(), Json::Str(id.into())),
                    ("deleted".into(), Json::Bool(deleted)),
                ]));
                write_json(&mut stream, 200, &body);
            }
            _ => {
                write_json(
                    &mut stream,
                    404,
                    &json_error(&format!("no route for {path}"), None),
                );
            }
        },
        ("GET", "/generate") | ("POST", "/health") | ("POST", "/metrics") => {
            write_json(
                &mut stream,
                405,
                &json_error(&format!("{} does not allow {}", req.path, req.method), None),
            );
        }
        (_, path) => {
            write_json(
                &mut stream,
                404,
                &json_error(&format!("no route for {path}"), None),
            );
        }
    }
}

/// `GET /trace?last=N`: Chrome trace-event JSON for the last N completed
/// requests (default: the recorder's full ring) plus the sampled
/// kernel-attribution slices. 404 while tracing is disabled so scrapers
/// can distinguish "off" from "empty".
fn handle_trace(shared: &Shared, stream: &mut TcpStream, query: &str) {
    let rec = &shared.recorder;
    if !rec.enabled() {
        write_json(stream, 404, &json_error("tracing disabled", None));
        return;
    }
    let last = match query_param(query, "last") {
        Some(v) => match v.parse::<usize>() {
            Ok(n) => n,
            Err(_) => {
                let msg = "query parameter 'last' must be an integer";
                write_json(stream, 400, &json_error(msg, None));
                return;
            }
        },
        None => rec.capacity(),
    };
    let body = chrome_trace(&rec.last(last), &rec.kernel_samples());
    write_json(stream, 200, &body);
}

/// Value of `name` in a `k=v&k2=v2` query string.
fn query_param<'a>(query: &'a str, name: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        pair.split_once('=')
            .filter(|(k, _)| *k == name)
            .map(|(_, v)| v)
    })
}

/// The decode request a `/generate` body parses into.
struct GenerateBody {
    prompt: String,
    rho: f64,
    max_new: usize,
    plan: Option<MaskPlan>,
    domain: String,
    stream: bool,
    /// Session id for cross-turn KV continuation; content rules
    /// (`crate::kvstore::valid_session_id`) are the router's to enforce.
    session: Option<String>,
}

/// Parse and validate the JSON body; every failure names the offending
/// field so clients can fix requests without reading server logs.
fn parse_generate(body: &[u8]) -> Result<GenerateBody, HttpError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, "body is not valid UTF-8".to_string()))?;
    let json =
        Json::parse(text).map_err(|e| (400, format!("body is not valid JSON: {e}")))?;
    if json.as_obj().is_none() {
        return Err((400, "body must be a JSON object".to_string()));
    }
    let field = |name: &str, want: &str| (400, format!("field '{name}' must be {want}"));

    let prompt = match json.get("prompt") {
        Some(v) => v
            .as_str()
            .ok_or_else(|| field("prompt", "a string"))?
            .to_string(),
        None => return Err((400, "field 'prompt' is required".to_string())),
    };
    let rho = match json.get("rho") {
        Some(v) => v.as_f64().ok_or_else(|| field("rho", "a number"))?,
        None => 0.0, // router substitutes the configured default
    };
    let max_new = match json.get("max_new") {
        Some(v) => {
            let x = v
                .as_f64()
                .filter(|x| x.fract() == 0.0 && *x >= 0.0)
                .ok_or_else(|| field("max_new", "a non-negative integer"))?;
            x as usize
        }
        None => 0, // router substitutes the configured default
    };
    let plan = match json.get("plan") {
        Some(v) => {
            let s = v.as_str().ok_or_else(|| field("plan", "a string"))?;
            Some(
                MaskPlan::parse(s)
                    .map_err(|e| (400, format!("field 'plan' is invalid: {e}")))?,
            )
        }
        None => None,
    };
    let domain = match json.get("domain") {
        Some(v) => v
            .as_str()
            .ok_or_else(|| field("domain", "a string"))?
            .to_string(),
        None => "http".to_string(),
    };
    let stream = match json.get("stream") {
        Some(v) => match v {
            Json::Bool(b) => *b,
            _ => return Err(field("stream", "a boolean")),
        },
        None => false,
    };
    let session = match json.get("session") {
        Some(v) => Some(
            v.as_str()
                .ok_or_else(|| field("session", "a string"))?
                .to_string(),
        ),
        None => None,
    };
    Ok(GenerateBody {
        prompt,
        rho,
        max_new,
        plan,
        domain,
        stream,
        session,
    })
}

fn handle_generate(shared: &Shared, stream: &mut TcpStream, body: &[u8]) {
    let greq = match parse_generate(body) {
        Ok(greq) => greq,
        Err((status, msg)) => {
            // malformed bodies never reach the router, let alone the
            // engine thread
            write_json(stream, status, &json_error(&msg, None));
            return;
        }
    };
    if shared.draining.load(Ordering::SeqCst) {
        write_json(stream, 503, &json_error("server is draining", None));
        return;
    }

    let (reply_tx, reply_rx) = channel::<Response>();
    let (step_tx, step_rx) = channel::<StepEvent>();
    let step_tx = greq.stream.then_some(step_tx);
    // admission runs on this worker thread: rejections (empty prompt,
    // over-cap max_new, queue full) are shed here as 4xx without ever
    // touching the engine thread
    let req = match shared.router.admit_decode(
        &greq.prompt,
        greq.rho,
        &greq.domain,
        greq.max_new,
        greq.plan,
        greq.session.clone(),
        step_tx,
        Some(reply_tx),
    ) {
        Ok(req) => req,
        Err(rej) => {
            // load shedding (full queue / full session registry) is 429;
            // everything else is a malformed request
            let status = match rej.rejected.as_deref() {
                Some("queue full") | Some("session registry at capacity") => 429,
                _ => 400,
            };
            let id = rej.id;
            let msg = rej.rejected.unwrap_or_else(|| "rejected".into());
            crate::debug!("generate rejected: {msg}"; id = id, status = status);
            write_json(stream, status, &json_error(&msg, Some(id)));
            return;
        }
    };
    let id = req.id;
    let cancel = req.cancel.clone();
    if shared.engine.submit(req).is_err() {
        write_json(stream, 503, &json_error("server is shutting down", Some(id)));
        return;
    }
    crate::debug!("generate admitted"; id = id, stream = greq.stream);

    if greq.stream {
        stream_response(stream, id, greq.session.as_deref(), &cancel, step_rx, reply_rx);
    } else {
        drop(step_rx);
        match reply_rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(resp) => {
                if resp.is_ok() || resp.is_cancelled() {
                    write_json(stream, 200, &response_json(&resp, greq.session.as_deref()));
                } else {
                    let msg = resp.rejected.clone().unwrap_or_else(|| "failed".into());
                    write_json(stream, 500, &json_error(&msg, Some(id)));
                }
            }
            Err(_) => {
                // give the lane back before walking away
                cancel.cancel();
                write_json(stream, 504, &json_error("timed out waiting for decode", Some(id)));
            }
        }
    }
}

/// SSE over chunked transfer: one `data:` event per [`StepEvent`], then a
/// terminal `event: done` with the full response. The first failed write
/// means the client hung up — fire the request's [`CancelToken`] so its
/// lane frees within a sweep (the serve loop's dropped-receiver detection
/// backstops this when the worker dies outright).
fn stream_response(
    stream: &mut TcpStream,
    id: RequestId,
    session: Option<&str>,
    cancel: &CancelToken,
    step_rx: std::sync::mpsc::Receiver<StepEvent>,
    reply_rx: std::sync::mpsc::Receiver<Response>,
) {
    let head = "HTTP/1.1 200 OK\r\n\
                Content-Type: text/event-stream\r\n\
                Cache-Control: no-cache\r\n\
                Transfer-Encoding: chunked\r\n\
                Connection: close\r\n\r\n";
    if stream.write_all(head.as_bytes()).is_err() {
        cancel.cancel();
        return;
    }
    loop {
        match step_rx.recv_timeout(REPLY_TIMEOUT) {
            Ok(ev) => {
                let payload = Json::Obj(HashMap::from([
                    ("id".into(), Json::Num(ev.id as f64)),
                    ("index".into(), Json::Num(ev.index as f64)),
                    ("token".into(), Json::Num(ev.token as f64)),
                ]));
                let event = format!("data: {}\n\n", payload.dump());
                if write_chunk(stream, event.as_bytes()).is_err() {
                    cancel.cancel();
                    return;
                }
            }
            // the serve loop dropped its sender: the terminal response is
            // delivered (or imminently will be) on the reply channel
            Err(RecvTimeoutError::Disconnected) => break,
            Err(RecvTimeoutError::Timeout) => {
                cancel.cancel();
                let event = format!(
                    "event: error\ndata: {}\n\n",
                    json_error("timed out waiting for decode", Some(id)).dump()
                );
                let _ = write_chunk(stream, event.as_bytes());
                let _ = write_chunk(stream, b"");
                return;
            }
        }
    }
    match reply_rx.recv_timeout(REPLY_TIMEOUT) {
        Ok(resp) => {
            let event = format!(
                "event: done\ndata: {}\n\n",
                response_json(&resp, session).dump()
            );
            if write_chunk(stream, event.as_bytes()).is_err() {
                cancel.cancel();
                return;
            }
        }
        Err(_) => {
            cancel.cancel();
            let event = format!(
                "event: error\ndata: {}\n\n",
                json_error("decode ended without a terminal response", Some(id)).dump()
            );
            let _ = write_chunk(stream, event.as_bytes());
        }
    }
    let _ = write_chunk(stream, b""); // terminating zero-length chunk
}

// ---------------------------------------------------------------------------
// Wire helpers
// ---------------------------------------------------------------------------

/// Read one request: head until `\r\n\r\n` (bounded), then exactly
/// `Content-Length` body bytes (bounded).
fn read_request(stream: &mut TcpStream) -> Result<HttpRequest, HttpError> {
    let mut buf = Vec::with_capacity(1024);
    let mut chunk = [0u8; 1024];
    let head_end = loop {
        if let Some(pos) = find_head_end(&buf) {
            break pos;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err((431, "request head too large".into()));
        }
        let n = stream
            .read(&mut chunk)
            .map_err(|e| (400, format!("read: {e}")))?;
        if n == 0 {
            return Err((400, "truncated request".into()));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| (400, "request head is not valid UTF-8".to_string()))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = (
        parts.next().unwrap_or("").to_string(),
        parts.next().unwrap_or("").to_string(),
        parts.next().unwrap_or(""),
    );
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err((400, format!("malformed request line '{request_line}'")));
    }
    let mut content_length = 0usize;
    for line in lines {
        let Some((key, value)) = line.split_once(':') else {
            continue;
        };
        if key.trim().eq_ignore_ascii_case("content-length") {
            content_length = value
                .trim()
                .parse()
                .map_err(|_| (400, "bad Content-Length".to_string()))?;
        }
    }
    if content_length > MAX_BODY_BYTES {
        return Err((413, "request body too large".into()));
    }
    // whatever followed the head in the last read is the body's prefix
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let n = stream
            .read(&mut chunk)
            .map_err(|e| (400, format!("read body: {e}")))?;
        if n == 0 {
            return Err((400, "truncated request body".into()));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok(HttpRequest { method, path, body })
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// One fixed-length response; every connection serves a single exchange.
fn write_response(stream: &mut TcpStream, status: u16, content_type: &str, body: &[u8]) {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    let _ = stream
        .write_all(head.as_bytes())
        .and_then(|()| stream.write_all(body))
        .and_then(|()| stream.flush());
}

fn write_json(stream: &mut TcpStream, status: u16, body: &Json) {
    write_response(stream, status, "application/json", body.dump().as_bytes());
}

/// One chunk of a chunked-transfer body; empty payload terminates.
fn write_chunk(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n", payload.len())?;
    stream.write_all(payload)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

fn json_error(msg: &str, id: Option<RequestId>) -> Json {
    let mut m = HashMap::from([("error".into(), Json::Str(msg.into()))]);
    if let Some(id) = id {
        m.insert("id".into(), Json::Num(id as f64));
    }
    Json::Obj(m)
}

/// The wire form of a terminal [`Response`] (shared by the plain-JSON and
/// the SSE `done` paths so the two framings cannot diverge). `session`
/// echoes the request's id back so a client knows which id continues the
/// turn (the serve loop parks the lane under it).
fn response_json(resp: &Response, session: Option<&str>) -> Json {
    let mut m = HashMap::from([
        ("id".into(), Json::Num(resp.id as f64)),
        (
            "tokens".into(),
            Json::Arr(resp.tokens.iter().map(|&t| Json::Num(t as f64)).collect()),
        ),
        ("text".into(), Json::Str(ByteTokenizer.decode(&resp.tokens))),
        ("steps".into(), Json::Num(resp.steps as f64)),
        ("latency_us".into(), Json::Num(resp.latency_us as f64)),
        ("prefill_us".into(), Json::Num(resp.prefill_us as f64)),
        ("step_us".into(), Json::Num(resp.step_us as f64)),
        ("batch_size".into(), Json::Num(resp.batch_size as f64)),
        ("rho_used".into(), Json::Num(resp.rho_used)),
        ("prefilled".into(), Json::Num(resp.prefilled_tokens as f64)),
        ("seeded".into(), Json::Num(resp.seeded_tokens as f64)),
        ("cancelled".into(), Json::Bool(resp.is_cancelled())),
        // server-side latency breakdown: where this request's wall time
        // went, from admission to terminal delivery
        (
            "timing".into(),
            Json::Obj(HashMap::from([
                ("queue_wait_us".into(), Json::Num(resp.queue_wait_us as f64)),
                ("ttft_us".into(), Json::Num(resp.ttft_us as f64)),
                ("prefill_us".into(), Json::Num(resp.prefill_us as f64)),
                ("step_us".into(), Json::Num(resp.step_us as f64)),
                ("total_us".into(), Json::Num(resp.latency_us as f64)),
            ])),
        ),
    ]);
    if let Some(session) = session {
        m.insert("session".into(), Json::Str(session.into()));
    }
    Json::Obj(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generate_body_defaults_and_field_errors() {
        let ok = parse_generate(br#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(ok.prompt, "hi");
        assert_eq!(ok.rho, 0.0, "router substitutes the default");
        assert_eq!(ok.max_new, 0, "router substitutes the default");
        assert!(ok.plan.is_none());
        assert_eq!(ok.domain, "http");
        assert!(!ok.stream);

        let full = parse_generate(
            br#"{"prompt": "p", "rho": 0.6, "max_new": 4, "plan": "refresh:2",
                 "domain": "chat", "stream": true, "session": "chat-1"}"#,
        )
        .unwrap();
        assert_eq!(full.rho, 0.6);
        assert_eq!(full.max_new, 4);
        assert_eq!(full.plan, Some(MaskPlan::Refresh(2)));
        assert_eq!(full.domain, "chat");
        assert!(full.stream);
        assert_eq!(full.session.as_deref(), Some("chat-1"));

        // every bad field is a 400 naming the field
        for (body, field) in [
            (&br#"{"rho": 0.5}"#[..], "prompt"),
            (br#"{"prompt": 3}"#, "prompt"),
            (br#"{"prompt": "p", "rho": "x"}"#, "rho"),
            (br#"{"prompt": "p", "max_new": 1.5}"#, "max_new"),
            (br#"{"prompt": "p", "max_new": -1}"#, "max_new"),
            (br#"{"prompt": "p", "plan": "sometimes"}"#, "plan"),
            (br#"{"prompt": "p", "stream": "yes"}"#, "stream"),
            (br#"{"prompt": "p", "domain": 9}"#, "domain"),
            (br#"{"prompt": "p", "session": 5}"#, "session"),
        ] {
            let (status, msg) = parse_generate(body).unwrap_err();
            assert_eq!(status, 400, "{msg}");
            assert!(msg.contains(field), "'{msg}' should name '{field}'");
        }
        // non-JSON and non-object bodies
        assert_eq!(parse_generate(b"not json").unwrap_err().0, 400);
        assert_eq!(parse_generate(b"[1,2]").unwrap_err().0, 400);
        assert_eq!(parse_generate(&[0xff, 0xfe]).unwrap_err().0, 400);
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(16));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
    }

    #[test]
    fn response_json_carries_tokens_and_text() {
        let out = crate::decode::DecodeOutput {
            tokens: vec![1, 104, 105],
            prompt_len: 1,
            steps: Vec::new(),
            refresh_count: 0,
            prefill_us: 10,
            step_us: 5,
            cache_hits: 0,
            cache_misses: 0,
            prefilled_tokens: 1,
            seeded_tokens: 3,
            parked: None,
        };
        let mut resp = Response::from_decode(7, 0.6, &out, None);
        resp.steps = 2;
        let j = response_json(&resp, None);
        assert_eq!(j.req("id").unwrap().as_f64(), Some(7.0));
        assert_eq!(j.req("tokens").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(j.req("text").unwrap().as_str(), Some("hi"));
        assert_eq!(j.req("prefilled").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.req("seeded").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.req("cancelled").unwrap(), &Json::Bool(false));
        assert!(j.get("session").is_none(), "one-shot requests carry no session");
        let timing = j.req("timing").unwrap();
        assert_eq!(timing.req("prefill_us").unwrap().as_f64(), Some(10.0));
        assert_eq!(timing.req("step_us").unwrap().as_f64(), Some(5.0));
        assert_eq!(
            timing.req("total_us").unwrap().as_f64(),
            Some(resp.latency_us as f64)
        );
        assert_eq!(timing.req("queue_wait_us").unwrap().as_f64(), Some(0.0));
        assert_eq!(timing.req("ttft_us").unwrap().as_f64(), Some(0.0));
        let j = response_json(&resp, Some("chat-1"));
        assert_eq!(j.req("session").unwrap().as_str(), Some("chat-1"));
    }

    #[test]
    fn query_param_picks_named_pair() {
        assert_eq!(query_param("last=5", "last"), Some("5"));
        assert_eq!(query_param("a=1&last=9&b=2", "last"), Some("9"));
        assert_eq!(query_param("", "last"), None);
        assert_eq!(query_param("lastx=5", "last"), None);
        assert_eq!(query_param("last", "last"), None, "valueless key");
    }
}
