//! The serve loop: a dedicated runtime thread that owns every PJRT object
//! (client, registry, sessions — they hold raw pointers and never cross
//! threads), fed by an mpsc channel of admitted requests.
//!
//! Loop body: drain arrivals → batcher → fire ready batches → execute on
//! the μ-MoE session (or the dense session when ρ = 1) → reply + metrics.

use super::batcher::{Batch, BatcherConfig, DynamicBatcher};
use super::metrics::Metrics;
use super::request::{argmax, Request, Response};
use crate::config::ServeConfig;
use crate::model::checkpoint::Checkpoint;
use crate::runtime::registry::Registry;
use crate::runtime::session::{literal_f32, Input, Session};
use crate::runtime::weights::DeviceWeights;
use crate::runtime::Client;
use crate::util::error::{Error, ResultExt};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Control-plane handle returned by [`Server::start`].
pub struct ServerHandle {
    tx: Option<Sender<Request>>,
    join: Option<std::thread::JoinHandle<Result<(), Error>>>,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Submit an admitted request (router output).
    pub fn submit(&self, req: Request) -> Result<(), Error> {
        self.tx
            .as_ref()
            .expect("server already shut down")
            .send(req)
            .map_err(|_| Error::coordinator("server loop exited"))
    }

    /// Graceful shutdown: flush queues, join the loop.
    pub fn shutdown(mut self) -> Result<(), Error> {
        self.stop.store(true, Ordering::SeqCst);
        drop(self.tx.take());
        match self.join.take() {
            Some(j) => j
                .join()
                .map_err(|_| Error::coordinator("server thread panicked"))?,
            None => Ok(()),
        }
    }
}

/// Server configuration beyond ServeConfig: which artifact kinds to bind.
pub struct Server;

impl Server {
    /// Spawn the runtime thread. Blocks until the model is loaded and the
    /// sessions are compiled (so callers can fail fast), then returns the
    /// handle plus the queue-depth cell the router decrements are tied to.
    pub fn start(
        cfg: ServeConfig,
        depth: Arc<AtomicU64>,
        metrics: Arc<Metrics>,
    ) -> Result<ServerHandle, Error> {
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<usize, Error>>();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let metrics2 = metrics.clone();

        let join = std::thread::Builder::new()
            .name("mumoe-serve".into())
            .spawn(move || serve_thread(cfg, rx, ready_tx, depth, metrics2, stop2))
            .expect("spawn serve thread");

        match ready_rx.recv() {
            Ok(Ok(seq_len)) => {
                crate::info!("server ready (seq_len={seq_len})");
                Ok(ServerHandle {
                    tx: Some(tx),
                    join: Some(join),
                    metrics,
                    stop,
                })
            }
            Ok(Err(e)) => {
                let _ = join.join();
                Err(e)
            }
            Err(_) => Err(Error::coordinator("server thread died during startup")),
        }
    }
}

fn serve_thread(
    cfg: ServeConfig,
    rx: Receiver<Request>,
    ready_tx: Sender<Result<usize, Error>>,
    depth: Arc<AtomicU64>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) -> Result<(), Error> {
    // --- startup: all PJRT state lives and dies on this thread ---------
    let setup = (|| -> Result<(Session, Session), Error> {
        let client = Client::cpu()?;
        let registry = Registry::open(Path::new(&cfg.artifacts_dir), client.clone())?;
        let ckpt = Checkpoint::load(&registry.ckpt_path(&cfg.model))
            .with_context(|| format!("loading checkpoint for {}", cfg.model))?;
        let mumoe_meta = registry.meta_for("mumoe_logits", &cfg.model)?.name.clone();
        let dense_meta = registry.meta_for("dense_logits", &cfg.model)?.name.clone();
        let order = registry.meta(&mumoe_meta)?.params.clone();
        let weights = Arc::new(DeviceWeights::upload(&client, &ckpt, &order)?);
        let mumoe = Session::bind(&registry, &mumoe_meta, weights.clone())?;
        let dense = Session::bind(&registry, &dense_meta, weights)?;
        Ok((mumoe, dense))
    })();

    let (mumoe, dense) = match setup {
        Ok(s) => {
            let _ = ready_tx.send(Ok(s.0.meta.seq_len));
            s
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return Err(Error::coordinator("startup failed"));
        }
    };

    let batch_size = mumoe.meta.batch;
    let mut batcher = DynamicBatcher::new(
        BatcherConfig {
            batch_size,
            window: Duration::from_micros(cfg.batch_window_us),
        },
        &cfg.rho_levels,
    );

    // --- event loop -----------------------------------------------------
    loop {
        let now = Instant::now();
        let timeout = batcher
            .next_deadline(now)
            .unwrap_or(Duration::from_millis(5));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                batcher.push(req);
                // opportunistically drain whatever else arrived
                while let Ok(more) = rx.try_recv() {
                    batcher.push(more);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => break,
        }
        let now = Instant::now();
        while let Some(batch) = batcher.pop_ready(now) {
            execute_batch(&mumoe, &dense, batch, &depth, &metrics);
        }
        if stop.load(Ordering::SeqCst) && batcher.pending() == 0 {
            break;
        }
    }
    // flush remaining work on shutdown
    for batch in batcher.drain() {
        execute_batch(&mumoe, &dense, batch, &depth, &metrics);
    }
    Ok(())
}

/// End-to-end driver: generate a synthetic trace from the three test
/// corpora, start the server, replay arrivals in (compressed) real time
/// and report throughput / latency / occupancy / per-domain stats.
/// Shared by `mumoe serve` and `examples/serve_trace.rs`.
pub fn replay_trace(
    cfg: ServeConfig,
    n_requests: usize,
    rate: f64,
) -> Result<String, Error> {
    use crate::data::corpus::Corpus;
    use crate::data::trace::{generate, TraceConfig};

    let data_dir = Path::new(&cfg.artifacts_dir).join("data");
    let corpora: Vec<Corpus> = crate::data::DOMAINS
        .iter()
        .map(|d| Corpus::load(&data_dir, d, "test"))
        .collect::<Result<_, _>>()?;
    let trace = generate(
        &TraceConfig {
            rate,
            n_requests,
            rho_choices: cfg.rho_levels.clone(),
            ..Default::default()
        },
        &corpora,
    );

    let metrics = Arc::new(Metrics::new());
    let router =
        super::router::Router::new(cfg.clone(), crate::model::MAX_SEQ_LEN, metrics.clone())?;
    let depth = router.depth_handle();
    let handle = Server::start(cfg, depth, metrics.clone())?;

    let (rtx, rrx) = channel::<Response>();
    let t0 = Instant::now();
    let mut submitted = 0usize;
    for entry in &trace {
        // replay arrivals on the trace clock
        let target = Duration::from_micros(entry.arrival_us);
        let elapsed = t0.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        match router.admit(&entry.prompt, entry.rho, &entry.domain, Some(rtx.clone())) {
            Ok(req) => {
                handle.submit(req)?;
                submitted += 1;
            }
            Err(_rej) => {} // metrics already counted the shed
        }
    }
    drop(rtx);
    let mut ok = 0usize;
    let mut by_rho: std::collections::HashMap<u64, (usize, u64)> = Default::default();
    for _ in 0..submitted {
        let resp = rrx
            .recv_timeout(Duration::from_secs(60))
            .map_err(|_| Error::coordinator("timed out waiting for responses"))?;
        if resp.is_ok() {
            ok += 1;
            let key = (resp.rho_used * 100.0) as u64;
            let e = by_rho.entry(key).or_default();
            e.0 += 1;
            e.1 += resp.latency_us;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    handle.shutdown()?;

    let mut report = format!(
        "replayed {} requests in {:.2}s -> {:.1} req/s completed ({} ok)\n{}\n",
        trace.len(),
        wall,
        ok as f64 / wall,
        ok,
        metrics.summary()
    );
    let mut keys: Vec<_> = by_rho.keys().copied().collect();
    keys.sort();
    for k in keys {
        let (n, lat) = by_rho[&k];
        report.push_str(&format!(
            "  rho={:.2}: {} reqs, mean latency {:.0}us\n",
            k as f64 / 100.0,
            n,
            lat as f64 / n.max(1) as f64
        ));
    }
    Ok(report)
}

/// Run one batch and deliver responses. Failures reject the whole batch.
fn execute_batch(
    mumoe: &Session,
    dense: &Session,
    batch: Batch,
    depth: &AtomicU64,
    metrics: &Metrics,
) {
    let n = batch.len();
    let use_dense = batch.rho >= 0.999;
    let session = if use_dense { dense } else { mumoe };
    let cap = session.meta.batch;
    metrics.record_batch(n, cap);
    depth.fetch_sub(n as u64, Ordering::Relaxed);

    let seq = session.meta.seq_len;
    let mut tokens = Vec::with_capacity(cap * seq);
    let mut lengths = Vec::with_capacity(cap);
    for r in &batch.requests {
        tokens.extend_from_slice(&r.tokens);
        lengths.push(r.valid_len as i32);
    }
    // pad unused slots by replicating the first request (outputs ignored)
    for _ in n..cap {
        tokens.extend_from_slice(&batch.requests[0].tokens);
        lengths.push(batch.requests[0].valid_len as i32);
    }

    let mut inputs = vec![
        Input::I32(tokens, vec![cap, seq]),
        Input::I32(lengths, vec![cap]),
    ];
    if !use_dense {
        inputs.push(Input::ScalarF32(batch.rho as f32));
    }

    let result = session
        .run(&inputs)
        .and_then(|outs| literal_f32(&outs[0]));

    match result {
        Ok(flat) => {
            let vocab = flat.len() / cap;
            for (i, req) in batch.requests.into_iter().enumerate() {
                let row = flat[i * vocab..(i + 1) * vocab].to_vec();
                let latency = req.enqueued_at.elapsed().as_micros() as u64;
                metrics.record_completion(latency);
                let resp = Response {
                    id: req.id,
                    next_token: argmax(&row),
                    logits: row,
                    latency_us: latency,
                    batch_size: n,
                    rho_used: batch.rho,
                    rejected: None,
                };
                if let Some(reply) = req.reply {
                    let _ = reply.send(resp);
                }
            }
        }
        Err(e) => {
            crate::error!("batch execution failed: {e}");
            for req in batch.requests {
                metrics.record_reject();
                if let Some(reply) = req.reply {
                    let _ = reply.send(Response::rejected(req.id, format!("exec: {e}")));
                }
            }
        }
    }
}
